"""Hash-randomization regression gate (qdlint QD002's dynamic twin).

The spawn-worker fleet gives every process its own ``PYTHONHASHSEED``;
any merge or signature path that iterates a str-keyed set/dict in hash
order would produce different bytes per worker and break the
bit-identical fold contract.  This runs tests/_hash_seed_probe.py —
k-way ShardState and TrackerState merges, replica ``signature_features``,
``trace_delta``, and coordinator-cadence folds (k ∈ {1, 2, 4, 8} worker
partials in uneven arrival orders through a FleetCoordinator) — in
subprocesses under different seeds and asserts the digests match
exactly.
"""

import os
import pathlib
import subprocess
import sys

PROBE = pathlib.Path(__file__).resolve().parent / "_hash_seed_probe.py"
REPO = pathlib.Path(__file__).resolve().parent.parent


def _probe_digest(seed: int) -> str:
    env = dict(os.environ)
    src = str(REPO / "src")
    existing = env.get("PYTHONPATH")
    env["PYTHONPATH"] = (
        src + os.pathsep + existing if existing else src
    )
    env["PYTHONHASHSEED"] = str(seed)
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, str(PROBE)],
        capture_output=True,
        text=True,
        env=env,
        timeout=300,
    )
    assert proc.returncode == 0, (
        f"probe failed under PYTHONHASHSEED={seed}:\n{proc.stderr}"
    )
    digest = proc.stdout.strip().splitlines()[-1]
    assert len(digest) == 64, digest
    return digest


def test_merges_are_hash_seed_independent():
    digests = {seed: _probe_digest(seed) for seed in (0, 1, 2)}
    assert len(set(digests.values())) == 1, (
        f"merge/signature outputs vary with PYTHONHASHSEED: {digests}"
    )
