"""Fleet coordinator tests: cadence folds publish bit-identically to
single-stream ingest, the fold commutes over arrival order and cadence
partition, tracker deltas merge fleet-wide, stale-generation partials are
dropped (never published), worker join/leave mid-stream, the
apply_partial CAS against racing swaps, and the protocol's rejection of
row-carrying states."""

import dataclasses
import hashlib

import numpy as np
import pytest

from repro.core import query as qry
from repro.coordinator import FleetCoordinator, FoldReport, WorkerHandle
from repro.engine import LayoutEngine, replicate_tree
from repro.engine.sharded import ShardIngestor, micro_batches
from repro.service import IngestOptions, LayoutService, build_layout
from tests.test_qdtree import small_setup
from tests.test_query import random_query


def _setup(seed=0, n_queries=8):
    schema, records, cuts = small_setup(seed)
    rng = np.random.default_rng(seed)
    work = qry.Workload(
        schema, tuple(random_query(schema, rng) for _ in range(n_queries))
    )
    return schema, records, cuts, work


def _prefix_service(seed=0, backend="numpy", min_block=30):
    """A service whose tree was built from a PREFIX of the records, so
    ingesting the full stream genuinely tightens descriptions (a tree
    built from the full records is already a tightening fixed point —
    bit-identity assertions against it would be vacuous)."""
    schema, records, cuts, work = _setup(seed)
    build = build_layout(
        records[: len(records) // 2], work, strategy="greedy", cuts=cuts,
        min_block=min_block, seed=seed,
    )
    return schema, records, cuts, work, LayoutService(build)


def _digest(tree):
    h = hashlib.sha256()
    for arr in (tree.leaf_lo, tree.leaf_hi, tree.leaf_cat, tree.leaf_adv):
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def _worker_state(tree, rows, batch=64, observe=None):
    """What a fleet worker ships: route ``rows`` on a private replica,
    return the aggregates-only ShardState."""
    eng = LayoutEngine(replicate_tree(tree), backend="numpy")
    probe = eng.observation_probe(observe) if observe is not None else None
    return ShardIngestor(eng, shard_id=0, probe=probe).run(
        micro_batches(rows, batch)
    )


def _single_stream_digest(tree, records, batch=64):
    replica = replicate_tree(tree)
    LayoutEngine(replica, backend="numpy").ingest(
        micro_batches(records, batch)
    )
    return _digest(replica)


# ---------------------------------------------------------------------------
# The cadence fold: publish parity with single-stream ingest
# ---------------------------------------------------------------------------
def test_fold_publishes_bit_identical_to_single_stream():
    _, records, _, _, svc = _prefix_service(3)
    ref = _single_stream_digest(svc.tree, records)
    before = _digest(svc.tree)
    assert before != ref  # prefix-built: the stream has something to teach

    coord = FleetCoordinator(svc, cadence=2)
    a, b = coord.register("ingest-a"), coord.register("ingest-b")
    halves = np.array_split(records, 2)
    assert coord.submit(a, state=_worker_state(svc.tree, halves[0])) is None
    rep = coord.submit(b, state=_worker_state(svc.tree, halves[1]))
    assert isinstance(rep, FoldReport)
    assert rep.published and rep.n_partials == 2 and rep.fold == 1
    assert rep.n_records == len(records)
    assert _digest(svc.tree) == ref
    assert coord.stats()["folds"] == 1 and coord.stats()["pending"] == 0


@pytest.mark.parametrize("k", [1, 2, 4, 8])
@pytest.mark.parametrize("cadence", [1, 3])
def test_fold_commutes_over_arrival_order_and_cadence(k, cadence):
    """Any worker arrival order and any cadence partition of the same k
    partials publishes bit-identical descriptions."""
    _, records, _, _, svc = _prefix_service(5)
    ref = _single_stream_digest(svc.tree, records)
    parts = np.array_split(records, k)
    states = [_worker_state(svc.tree, p) for p in parts]

    order = np.random.default_rng(k * 31 + cadence).permutation(k)
    coord = FleetCoordinator(svc, cadence=cadence)
    w = coord.register()
    for i in order:
        coord.submit(w, state=states[int(i)])
    if coord.stats()["pending"]:
        coord.fold()  # flush the sub-cadence tail
    assert _digest(svc.tree) == ref


def test_coordinator_routed_service_ingest():
    """ingest(records, IngestOptions(coordinator=)) routes and
    aggregates locally but publishes only through the coordinator fold."""
    import warnings

    _, records, _, _, svc = _prefix_service(7)
    ref = _single_stream_digest(svc.tree, records, batch=64)
    before = _digest(svc.tree)
    coord = FleetCoordinator(svc, cadence=1)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # thread-executor footgun
        rep = svc.ingest(
            records,
            IngestOptions(shards=2, batch=64, executor="thread",
                          coordinator=coord),
        )
    assert not rep.published  # the local publish was suppressed…
    assert coord.stats()["folds"] == 1  # …the fold owned it
    assert _digest(svc.tree) == ref != before


# ---------------------------------------------------------------------------
# Tracker deltas and the fleet rebuilder
# ---------------------------------------------------------------------------
def test_tracker_deltas_fold_fleet_wide():
    schema, _, _, work, svc = _prefix_service(9)
    coord = FleetCoordinator(svc, cadence=2)
    a, b = coord.register(), coord.register()
    t1, t2 = svc.workload_tracker(), svc.workload_tracker()
    t1.record(qry.Workload(schema, work.queries[:4]))
    t2.record(qry.Workload(schema, work.queries[4:]))
    coord.submit(a, tracker_state=t1.drain_state())
    rep = coord.submit(b, tracker_state=t2.drain_state())
    assert rep is not None and rep.tracker_merges == 2
    assert rep.n_partials == 0 and not rep.published
    # drain is destructive worker-side; the fleet tracker has everything
    assert not t1.snapshot().top_signatures(8)
    fleet = coord.tracker.snapshot()
    assert fleet.queries_seen == len(work.queries)


def test_fold_feeds_fleet_rebuilder_the_merged_window():
    class RecordingRebuilder:
        def __init__(self):
            self.observations = []

        def observe(self, obs):
            self.observations.append(obs)
            return "decision"

    _, records, _, work, svc = _prefix_service(11)
    rb = RecordingRebuilder()
    coord = FleetCoordinator(svc, cadence=2, rebuilder=rb)
    w = coord.register()
    halves = np.array_split(records, 2)
    coord.submit(w, state=_worker_state(svc.tree, halves[0], observe=work))
    rep = coord.submit(
        w, state=_worker_state(svc.tree, halves[1], observe=work)
    )
    assert rep.drift == "decision"
    (merged_obs,) = rb.observations
    assert merged_obs.capacity > 0
    assert merged_obs.n_records == len(records)


# ---------------------------------------------------------------------------
# Staleness, racing swaps, membership, protocol validation
# ---------------------------------------------------------------------------
def test_stale_generation_partials_are_dropped():
    _, records, cuts, work, svc = _prefix_service(13)
    coord = FleetCoordinator(svc, cadence=8)
    w = coord.register()
    old_gen = svc.generation
    stale = _worker_state(svc.tree, records[:200])
    svc.swap(build_layout(
        records, work, strategy="greedy", cuts=cuts, min_block=30, seed=99,
    ))
    coord.submit(w, state=stale, generation=old_gen)
    rep = coord.fold()
    assert rep.stale_partials == 1 and not rep.published
    assert rep.n_records == 0
    assert coord.stats()["stale_dropped"] == 1


def test_apply_partial_cas_rejects_superseded_live_version():
    """The publish CAS: a swap that lands between routing and fold makes
    apply_partial refuse the merged partial (no silent mutation of either
    the outgoing or the new live tree)."""
    from repro.engine import plan as planlib

    _, records, cuts, work, svc = _prefix_service(15)
    live = svc.live_version()
    state = _worker_state(svc.tree, records)
    old_tree = svc.tree
    v0 = planlib.desc_version(old_tree)
    svc.swap(build_layout(
        records, work, strategy="greedy", cuts=cuts, min_block=30, seed=42,
    ))
    assert svc.apply_partial(state, expected=live) is False
    assert planlib.desc_version(old_tree) == v0  # untouched
    # without an expectation the partial must still match the live shape
    if svc.tree.n_leaves != old_tree.n_leaves:
        with pytest.raises(ValueError):
            svc.apply_partial(state)


def test_worker_join_and_leave_mid_stream():
    _, records, _, _, svc = _prefix_service(17)
    ref = _single_stream_digest(svc.tree, records)
    coord = FleetCoordinator(svc, cadence=8)
    a = coord.register("early")
    thirds = np.array_split(records, 3)
    coord.submit(a, state=_worker_state(svc.tree, thirds[0]))
    b = coord.register("late-joiner")  # joins mid-stream
    assert {w.name for w in coord.workers()} == {"early", "late-joiner"}
    coord.submit(b, state=_worker_state(svc.tree, thirds[1]))
    coord.submit(a, state=_worker_state(svc.tree, thirds[2]))
    coord.leave(a)  # leaves with partials still pending
    assert [w.name for w in coord.workers()] == ["late-joiner"]
    with pytest.raises(ValueError, match="unregistered"):
        coord.submit(a, state=_worker_state(svc.tree, thirds[0]))
    # the departed worker's pending partials are still valid aggregates
    rep = coord.fold()
    assert rep.published and rep.n_partials == 3
    assert _digest(svc.tree) == ref


def test_protocol_validation():
    _, records, _, _, svc = _prefix_service(19)
    with pytest.raises(ValueError, match="cadence"):
        FleetCoordinator(svc, cadence=0)
    coord = FleetCoordinator(svc, cadence=4)
    w = coord.register()
    with pytest.raises(ValueError, match="ShardState"):
        coord.submit(w)  # neither state nor tracker delta
    rows = records[:100]
    chunky = dataclasses.replace(
        _worker_state(svc.tree, rows), chunks={0: [(0, rows[:2])]}
    )
    with pytest.raises(ValueError, match="aggregates, not rows"):
        coord.submit(w, state=chunky)
    assert isinstance(w, WorkerHandle) and w.worker_id == 1
