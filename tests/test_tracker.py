"""Workload auto-detection tests: canonical signature extraction (atoms vs
tensors parity, bucketing), TrackerState algebra (associative+commutative
merge bit-identical across serving shard counts, tick/merge homomorphism,
order-independent recording within a generation), deterministic inference,
serialization round-trips, the route_queries/route_query/serve observation
hooks, and the workload="auto" drift loop end to end."""

import pickle

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 containers without hypothesis
    from tests._hypothesis_shim import given, settings, st

from repro.core import query as qry
from repro.core.predicates import (
    OP_EQ,
    OP_GE,
    OP_GT,
    OP_LE,
    OP_LT,
    Column,
    Schema,
)
from repro.core.query import AdvAtom, InAtom, Query, RangeAtom
from repro.engine import LayoutEngine
from repro.service import (
    DriftConfig,
    IngestOptions,
    LayoutService,
    RebuildPolicy,
    TrackerConfig,
    TrackerState,
    WorkloadTracker,
    build_layout,
    merge_states,
)
from repro.service.tracker import (
    bucket_hi,
    bucket_lo,
    query_from_signature,
    query_signatures,
    query_signatures_from_tensors,
)

SCHEMA = Schema((
    Column("a", "numeric", 1000),
    Column("b", "numeric", 1000),
    Column("c", "categorical", 6),
))


def _range_query(dim, lo, width):
    return Query.conjunction(
        [RangeAtom(dim, OP_GE, lo), RangeAtom(dim, OP_LT, lo + width)]
    )


def _random_query(rng) -> Query:
    atoms = []
    dim = int(rng.integers(0, 2))
    op = int(rng.choice([OP_LT, OP_LE, OP_GT, OP_GE, OP_EQ]))
    atoms.append(RangeAtom(dim, op, int(rng.integers(1, 999))))
    if rng.random() < 0.5:
        atoms.append(RangeAtom(1 - dim, OP_GE, int(rng.integers(0, 500))))
    if rng.random() < 0.4:
        vals = rng.choice(6, size=int(rng.integers(1, 4)), replace=False)
        atoms.append(InAtom(2, tuple(int(v) for v in vals)))
    if rng.random() < 0.3:
        atoms.append(AdvAtom(0, OP_LT, 1, polarity=bool(rng.random() < 0.5)))
    return Query.conjunction(atoms)


def _random_workload(seed, n=6) -> qry.Workload:
    rng = np.random.default_rng(seed)
    return qry.Workload(SCHEMA, tuple(_random_query(rng) for _ in range(n)))


def _cfg(**kw) -> TrackerConfig:
    base = dict(n_buckets=64, n_gens=8, decay=0.5)
    base.update(kw)
    return TrackerConfig(**base)


# ---------------------------------------------------------------------------
# Canonical signatures
# ---------------------------------------------------------------------------
def test_bucket_edges_bracket_the_bound():
    for dom in (7, 100, 2526, 10000):
        for b in (4, 64, 256):
            for v in (1, dom // 3, dom // 2, dom - 1):
                lo, hi = bucket_lo(v, dom, b), bucket_hi(v, dom, b)
                assert 0 <= lo <= v, (dom, b, v, lo)
                assert v <= hi <= dom, (dom, b, v, hi)
    # enough buckets ⇒ exact bounds
    assert bucket_lo(123, 100, 256) == 123
    assert bucket_hi(123, 100, 256) == 123


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_signatures_atoms_match_tensors(seed):
    """The serving hot path records from WorkloadTensors; direct API users
    record from Workload atoms — both must canonicalize identically (the
    workload's own candidate cuts carry every advanced atom)."""
    wl = _random_workload(seed)
    cuts = wl.candidate_cuts()
    from_atoms = query_signatures(wl, 64)
    from_tensors = query_signatures_from_tensors(
        wl.tensorize(cuts), SCHEMA, adv=cuts.adv, n_buckets=64
    )
    assert from_atoms == from_tensors


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_signature_roundtrips_to_equivalent_query(seed):
    """query_from_signature(sig) must re-canonicalize to the same sig (the
    signature space is a fixed point), and with enough buckets the
    reconstructed query matches the original records exactly."""
    wl = _random_workload(seed)
    sigs = query_signatures(wl, 64)
    rebuilt = qry.Workload(
        SCHEMA, tuple(query_from_signature(s, SCHEMA) for s in sigs)
    )
    assert query_signatures(rebuilt, 64) == sigs
    # exact-bucket round trip preserves semantics on data
    exact = query_signatures(wl, 1 << 20)
    rng = np.random.default_rng(seed + 1)
    records = np.stack([
        rng.integers(0, 1000, 500),
        rng.integers(0, 1000, 500),
        rng.integers(0, 6, 500),
    ], axis=1).astype(np.int32)
    for q, sig in zip(wl.queries, exact):
        q2 = query_from_signature(sig, SCHEMA)
        np.testing.assert_array_equal(
            q.evaluate(records, SCHEMA), q2.evaluate(records, SCHEMA)
        )


def test_record_parity_when_adv_atom_missing_from_cuts():
    """A query whose advanced atom is NOT in the cut table must map to the
    same sketch key whether it is served as a Workload or pre-tensorized
    (tensorize drops non-cut adv atoms; record() filters to match)."""
    q = Query.conjunction(
        [RangeAtom(0, OP_GE, 100), AdvAtom(0, OP_LT, 1)]
    )
    wl = qry.Workload(SCHEMA, (q,))
    cuts = qry.Workload(
        SCHEMA, (_range_query(0, 100, 60),)
    ).candidate_cuts()  # no adv predicates
    assert cuts.n_adv == 0
    t_atoms = WorkloadTracker(SCHEMA, _cfg())
    t_atoms.record(wl, cuts=cuts)
    t_tensors = WorkloadTracker(SCHEMA, _cfg())
    t_tensors.record(wl.tensorize(cuts), cuts=cuts)
    assert t_atoms.snapshot().equals(t_tensors.snapshot())
    # without a cut table, direct recording keeps the adv atom (richer
    # signal for candidate-cut discovery)
    t_free = WorkloadTracker(SCHEMA, _cfg())
    t_free.record(wl)
    (free_sig,) = (s for s, _ in t_free.top_signatures(1))
    assert any(atom[0] == 2 for atom in free_sig[0])  # SIG_ADV kept


def test_signatures_dedupe_near_identical_queries():
    # same bucket ⇒ same key; different bucket ⇒ different key
    a = query_signatures(
        qry.Workload(SCHEMA, (_range_query(0, 100, 60),)), 10
    )
    b = query_signatures(
        qry.Workload(SCHEMA, (_range_query(0, 103, 57),)), 10
    )
    c = query_signatures(
        qry.Workload(SCHEMA, (_range_query(0, 400, 60),)), 10
    )
    assert a == b != c


# ---------------------------------------------------------------------------
# TrackerState algebra
# ---------------------------------------------------------------------------
def _replay(streams, cfg, k):
    """Round-robin the per-round query lists over k trackers (each round is
    one generation everywhere), then fold the shard states."""
    trackers = [WorkloadTracker(SCHEMA, cfg) for _ in range(k)]
    for rnd in streams:
        for j, q in enumerate(rnd):
            trackers[j % k].record(qry.Workload(SCHEMA, (q,)))
        for t in trackers:
            t.tick()
    return merge_states([t.snapshot() for t in trackers])


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_kway_merge_bit_identical_to_single_stream(seed):
    rng = np.random.default_rng(seed)
    streams = [
        [_random_query(rng) for _ in range(int(rng.integers(1, 9)))]
        for _ in range(5)
    ]
    cfg = _cfg()
    single = _replay(streams, cfg, 1)
    for k in (2, 4, 8):
        merged = _replay(streams, cfg, k)
        assert merged.equals(single), f"k={k} diverged"
        # the inferred mix is a pure function of the state
        assert (
            merged.infer_workload(SCHEMA, top_k=8, budget=16).queries
            == single.infer_workload(SCHEMA, top_k=8, budget=16).queries
        )


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_recording_order_independent_within_generation(seed):
    rng = np.random.default_rng(seed)
    queries = [_random_query(rng) for _ in range(12)]
    cfg = _cfg()
    t1, t2 = WorkloadTracker(SCHEMA, cfg), WorkloadTracker(SCHEMA, cfg)
    t1.record(qry.Workload(SCHEMA, tuple(queries)))
    perm = rng.permutation(len(queries))
    for i in perm:
        t2.record(qry.Workload(SCHEMA, (queries[int(i)],)))
    assert t1.snapshot().equals(t2.snapshot())
    t1.tick(), t2.tick()
    assert t1.snapshot().equals(t2.snapshot())


def test_merge_associative_and_tick_homomorphism():
    rng = np.random.default_rng(3)
    cfg = _cfg()
    states = []
    for _ in range(3):
        t = WorkloadTracker(SCHEMA, cfg)
        for _ in range(int(rng.integers(1, 4))):
            t.record(_random_workload(int(rng.integers(0, 100))))
            t.tick()
        t.record(_random_workload(int(rng.integers(0, 100))))
        states.append(t.snapshot())
    a, b, c = states
    assert a.merge(b).merge(c).equals(a.merge(b.merge(c)))
    assert a.merge(b).equals(b.merge(a))
    # tick distributes over merge
    ab = a.merge(b)
    ab.tick()
    a2, b2 = a.copy(), b.copy()
    a2.tick(), b2.tick()
    assert ab.equals(a2.merge(b2))
    # configs must match
    with pytest.raises(ValueError):
        a.merge(TrackerState.fresh(_cfg(decay=0.25)))


def test_decay_forgets_and_generations_age_out():
    cfg = _cfg(n_gens=3, decay=0.5)
    t = WorkloadTracker(SCHEMA, cfg)
    old, new = _range_query(0, 100, 50), _range_query(1, 200, 50)
    t.record(qry.Workload(SCHEMA, (old,)))
    t.tick()
    t.record(qry.Workload(SCHEMA, (new,)))
    (sig_old,) = query_signatures(qry.Workload(SCHEMA, (old,)), 64)
    (sig_new,) = query_signatures(qry.Workload(SCHEMA, (new,)), 64)
    w = t.snapshot().weights()
    assert w[sig_new] == 1.0 and w[sig_old] == 0.5  # decayed once
    t.tick(3)  # beyond n_gens: exact zero, key forgotten
    assert sig_old not in t.snapshot().counts
    assert t.snapshot().n_keys == 0


def test_prune_keeps_heaviest_keys():
    t = WorkloadTracker(SCHEMA, _cfg(max_keys=2))
    heavy = _range_query(0, 100, 50)
    t.record(qry.Workload(SCHEMA, (heavy,) * 5))
    t.record(qry.Workload(SCHEMA, (_range_query(0, 300, 50),) * 3))
    t.record(qry.Workload(SCHEMA, (_range_query(0, 600, 50),)))
    t.tick()  # prunes past max_keys
    state = t.snapshot()
    assert state.n_keys == 2
    (sig_heavy,) = query_signatures(qry.Workload(SCHEMA, (heavy,)), 64)
    assert sig_heavy in state.counts


# ---------------------------------------------------------------------------
# Inference
# ---------------------------------------------------------------------------
def test_infer_workload_deterministic_and_weighted():
    cfg = _cfg(infer_top_k=4, infer_budget=16)
    runs = []
    for _ in range(2):
        t = WorkloadTracker(SCHEMA, cfg)
        for rnd in range(3):
            t.record(qry.Workload(SCHEMA, (_range_query(0, 100, 50),) * 6))
            t.record(qry.Workload(SCHEMA, (_range_query(1, 500, 50),) * 2))
            t.tick()
        runs.append(t.infer_workload())
    assert runs[0].queries == runs[1].queries  # deterministic
    wl = runs[0]
    assert len(wl) == 16  # fixed budget, weights as multiplicity
    (hot,) = query_signatures(
        qry.Workload(SCHEMA, (_range_query(0, 100, 50),)), 64
    )
    hot_q = query_from_signature(hot, SCHEMA)
    assert sum(1 for q in wl.queries if q == hot_q) > 8  # 3x the traffic
    # a plain Workload: candidate cuts + Eq. 1 + build_layout all work
    rng = np.random.default_rng(0)
    records = np.stack([
        rng.integers(0, 1000, 2000),
        rng.integers(0, 1000, 2000),
        rng.integers(0, 6, 2000),
    ], axis=1).astype(np.int32)
    build = build_layout(records, wl, min_block=100)
    assert build.tree.n_leaves > 1
    assert 0.0 < build.scanned_fraction < 1.0


def test_infer_recency_beats_stale_frequency():
    """A heavy-but-stale signature must decay below the live one."""
    t = WorkloadTracker(SCHEMA, _cfg(n_gens=8, decay=0.5))
    stale, live = _range_query(0, 100, 50), _range_query(1, 700, 50)
    t.record(qry.Workload(SCHEMA, (stale,) * 4))
    for _ in range(4):
        t.tick()
        t.record(qry.Workload(SCHEMA, (live,)))
    top = t.top_signatures(2)
    (sig_live,) = query_signatures(qry.Workload(SCHEMA, (live,)), 64)
    assert top[0][0] == sig_live  # 4*0.5^4 = 0.25 < ~1.9
    empty = WorkloadTracker(SCHEMA, _cfg()).infer_workload()
    assert len(empty) == 0  # nothing served yet -> empty mix


def test_tracker_state_serialization_roundtrips(tmp_path):
    t = WorkloadTracker(SCHEMA, _cfg())
    for seed in range(3):
        t.record(_random_workload(seed))
        t.tick()
    t.record(_random_workload(99))
    state = t.snapshot()
    # npz (cross-host shipping)
    p = str(tmp_path / "tracker_state.npz")
    state.save(p)
    assert TrackerState.load(p).equals(state)
    # pickle (process pools)
    assert pickle.loads(pickle.dumps(state)).equals(state)


# ---------------------------------------------------------------------------
# Serving-path hooks
# ---------------------------------------------------------------------------
def _service(records, workload, **kw):
    kw.setdefault("min_block", 100)
    return LayoutService.build(
        records, workload, strategy="greedy", backend="numpy", **kw
    )


def _setup(seed=0, rows=4000):
    rng = np.random.default_rng(seed)
    records = np.stack([
        rng.integers(0, 1000, rows),
        rng.integers(0, 1000, rows),
        rng.integers(0, 6, rows),
    ], axis=1).astype(np.int32)

    def workload(dim, wseed, n=8, width=60):
        wrng = np.random.default_rng(wseed)
        return qry.Workload(SCHEMA, tuple(
            _range_query(dim, int(wrng.integers(0, 1000 - width)), width)
            for _ in range(n)
        ))

    return records, workload(0, seed + 1), workload(1, seed + 2)


def test_route_queries_and_route_query_feed_the_tracker():
    records, work_a, _ = _setup()
    build = build_layout(records, work_a, min_block=100)
    eng = LayoutEngine(build.tree, backend="numpy")
    tracker = WorkloadTracker(SCHEMA, _cfg())
    # batched hook: results identical with and without tracking
    tracked = eng.route_queries(work_a, track=tracker)
    plain = eng.route_queries(work_a)
    for x, y in zip(tracked, plain):
        np.testing.assert_array_equal(x, y)
    assert tracker.queries_seen == len(work_a)
    # the recorded mix is exactly the served workload's signature set
    assert set(s for s, _ in tracker.top_signatures(100)) == set(
        query_signatures(work_a, tracker.config.n_buckets)
    )
    # 1-query path records too
    before = tracker.queries_seen
    bids = eng.route_query(work_a.queries[0], track=tracker)
    np.testing.assert_array_equal(bids, plain[0])
    assert tracker.queries_seen == before + 1


def test_service_serve_records_and_ticks():
    records, work_a, work_b = _setup(1)
    svc = _service(records, work_a)
    tracker = svc.workload_tracker(_cfg())
    gen_before = tracker.snapshot().generation
    lists = svc.serve(work_a, tracker=tracker)
    assert len(lists) == len(work_a)
    assert tracker.snapshot().generation == gen_before + 1  # round closed
    svc.serve(work_b, tracker=tracker, tick=False)
    assert tracker.snapshot().generation == gen_before + 1
    # untracked serving still works
    assert len(svc.serve(work_b)) == len(work_b)
    # inference reflects both workloads, latest dominating after ticks
    for _ in range(3):
        svc.serve(work_b, tracker=tracker)
    top = tracker.top_signatures(1)[0][0]
    assert top in set(query_signatures(work_b, tracker.config.n_buckets))


def test_auto_rebuilder_infers_the_shifted_mix_and_recovers():
    """The full loop with NO declared workload anywhere: a stale tree, live
    queries shift, the tracker infers the mix, drift fires, the rebuild
    optimizes for the inferred (true) mix."""
    records, work_a, work_b = _setup(7)
    svc = _service(records[:2000], work_a)
    gen0 = svc.generation
    tracker = svc.workload_tracker(_cfg(n_buckets=256, n_gens=16))
    with svc.auto_rebuilder(RebuildPolicy(
        workload="auto",
        tracker=tracker,
        drift=DriftConfig(window=4, min_fill=2, abs_threshold=0.5,
                          rel_degradation=None, hysteresis=2, cooldown=4),
        reservoir_capacity=4000,
        executor="sync",
        rebuild_kw=dict(min_block=100),
    )) as rebuilder:
        assert rebuilder.tracker is tracker
        # nothing served yet: ingest runs unobserved (no drift signal)
        rep = svc.ingest([records[:500]], IngestOptions(monitor=rebuilder))
        assert rep.observation is None and not rebuilder.events

        # phase A: the live mix matches the tree — healthy window
        for s in range(500, 2000, 500):
            svc.serve(work_a, tracker=tracker)
            rep = svc.ingest(
                [records[s:s + 500]], IngestOptions(monitor=rebuilder)
            )
        assert rep.observation.scanned_fraction < 0.5
        assert svc.generation == gen0 and not rebuilder.events

        # phase B: users start asking orthogonal queries — nobody tells
        # the monitor; it must notice from the serving path alone
        for s in range(2000, 4000, 500):
            svc.serve(work_b, tracker=tracker)
            svc.ingest(
                [records[s:s + 500]], IngestOptions(monitor=rebuilder)
            )
        assert rebuilder.rebuilds_deployed == 1
        assert svc.generation > gen0
        (event,) = [e for e in rebuilder.events if e.deployed]
        # the rebuild was scored and built against the inferred mix
        assert event.report.build.provenance["n_queries"] == (
            tracker.config.infer_budget
        )
        recovered = svc.skip_stats(
            records, work_b, tighten=False
        ).scanned_fraction
        oracle = build_layout(records, work_b, min_block=100)
        assert recovered <= max(
            1.2 * oracle.scanned_fraction, oracle.scanned_fraction + 0.04
        )


def test_auto_rebuilder_validation_and_empty_workload_skip():
    records, work_a, _ = _setup(2)
    svc = _service(records[:1000], work_a)
    with pytest.raises(ValueError):
        svc.auto_rebuilder(RebuildPolicy(workload="magic"))
    # the loose pre-policy kwargs are gone, not silently accepted
    with pytest.raises(TypeError):
        svc.auto_rebuilder("auto")
    # auto without an explicit tracker creates one from the service
    reb = svc.auto_rebuilder(RebuildPolicy(
        workload="auto",
        drift=DriftConfig(window=1, min_fill=1, abs_threshold=0.1,
                          rel_degradation=None, hysteresis=1, cooldown=0),
        executor="sync",
    ))
    assert reb.tracker is not None
    assert len(reb.current_workload()) == 0
    # a trigger with an empty inferred mix is skipped, not crashed
    from repro.engine import WindowStat

    reb.add_records(records[:100])
    reb.observe(WindowStat(scanned_tuples=99, capacity=100, n_records=100))
    assert reb.events[-1].skipped == "empty_workload"
    reb.close()
