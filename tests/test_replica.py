"""Replica-set tests: k=1 bit-identity with the single-tree path
(routing, serving, cache hits), cheapest-replica choice invariance under
replica order permutation, per-replica cache invalidation and
release/rollback semantics, the Epoch value type, and the unified
IngestOptions surface (loose kwargs retired; ingest_sharded shim)."""


import warnings

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 containers without hypothesis
    from tests._hypothesis_shim import given, settings, st

from repro.core import query as qry
from repro.serve import QueryServer, ResultCache, ServeConfig
from repro.service import (
    DriftConfig,
    Epoch,
    IngestOptions,
    LayoutService,
    RebuildPolicy,
    ReplicaSet,
    build_layout,
    cluster_signatures,
    cluster_workloads,
    workload_signature_weights,
)
from repro.service.replica import blended_mix, materialize_mix
from tests.test_qdtree import small_setup
from tests.test_query import random_query


def _setup(seed=0, n_queries=8):
    schema, records, cuts = small_setup(seed)
    rng = np.random.default_rng(seed)
    work = qry.Workload(
        schema, tuple(random_query(schema, rng) for _ in range(n_queries))
    )
    return schema, records, cuts, work


def _service(seed=0, n_queries=8, backend="numpy", min_block=30):
    schema, records, cuts, work = _setup(seed, n_queries)
    svc = LayoutService.build(
        records, work, strategy="greedy", cuts=cuts, backend=backend,
        min_block=min_block,
    )
    return schema, records, cuts, work, svc


def _split_workload(work, parts=2):
    """Deterministic partition of a workload's queries into sub-mixes."""
    subs = []
    for p in range(parts):
        qs = tuple(
            q for i, q in enumerate(work.queries) if i % parts == p
        )
        subs.append(qry.Workload(work.schema, qs))
    return subs


# ---------------------------------------------------------------------------
# Epoch: the shared serving identity
# ---------------------------------------------------------------------------
def test_epoch_value_type():
    e = Epoch(3, 7)
    assert e.replica_id == 0
    assert list(e) == [3, 7, 0]  # iterable, all three fields
    assert e == Epoch(3, 7, 0)
    assert hash(e) == hash(Epoch(3, 7, 0))
    assert Epoch(2, 9, 0) < Epoch(3, 0, 0) < Epoch(3, 0, 1)
    # the legacy-tuple coercion had its release and is gone: every call
    # site now passes real Epoch instances
    assert not hasattr(Epoch, "of")


def test_service_epochs_are_epoch_instances():
    _, _, _, _, svc = _service(11)
    e = svc.live_epoch()
    assert isinstance(e, Epoch)
    assert e.replica_id == 0
    assert svc.live_epochs() == (e,)
    assert svc.replica_generations() == (svc.generation,)
    assert svc.stats()["replicas"] == 1


# ---------------------------------------------------------------------------
# k=1 bit-identity: the replica path degrades to today's single-tree path
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_k1_routing_bit_identical_to_engine(seed):
    schema, records, cuts, work, svc = _service(5)
    rng = np.random.default_rng(seed)
    probe = qry.Workload(
        schema, tuple(random_query(schema, rng) for _ in range(6))
    )
    direct = svc.engine.route_queries(probe.tensorize(svc.tree.cuts))
    routes = svc.route_queries_cheapest(probe)
    assert len(routes) == len(probe)
    for d, r in zip(direct, routes):
        assert r.replica_id == 0
        np.testing.assert_array_equal(r.bids, d)


def test_k1_replica_set_is_single_live_version():
    _, _, _, _, svc = _service(6)
    rset = svc.live_replica_set()
    assert rset.k == 1
    assert rset.primary is svc.live_version()
    assert rset.epochs() == (svc.live_epoch(),)
    # the k=1 cache-key filter is exactly the live tree's own filter
    from repro.service.tracker import adv_filter_for

    assert rset.adv_filter() == adv_filter_for(svc.tree.cuts)


def test_k1_serving_counters_and_hits_match_single_tree_path():
    """Serving the same mix twice on a k=1 service: second pass fully
    cached, every answer bit-identical to direct engine routing, every
    provenance epoch the primary's."""
    schema, records, cuts, work, svc = _service(7, n_queries=6)
    server = QueryServer(svc, ServeConfig(max_batch=8))
    mix = [work.queries[i % len(work)] for i in range(12)]
    r1 = server.serve_batch(mix)
    r2 = server.serve_batch(mix)
    assert all(not r.cached for r in r1[: len(work)])
    assert all(r.cached for r in r2)
    assert all(r.replica_id == 0 for r in r1 + r2)
    assert all(r.epoch == svc.live_epoch() for r in r1 + r2)
    direct = svc.engine.route_queries(
        qry.Workload(schema, tuple(mix)).tensorize(svc.tree.cuts)
    )
    for res, d in zip(r2, direct):
        np.testing.assert_array_equal(res.bids, d)
    assert server.counters.stale_responses == 0
    server.stop()


# ---------------------------------------------------------------------------
# Cheapest-replica routing: permutation invariance + cost model
# ---------------------------------------------------------------------------
def _deploy_two(svc, records, cuts, work, order=(0, 1), min_block=30):
    subs = _split_workload(work, 2)
    builds = [
        build_layout(records, s, strategy="greedy", cuts=cuts,
                     min_block=min_block)
        for s in subs
    ]
    return svc.deploy_replicas([builds[i] for i in order])


@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_cheapest_choice_invariant_under_replica_permutation(seed):
    """The chosen block IDs and Eq. 1 cost per query do not depend on
    the order replicas were deployed in — the content tiebreak
    ``(cost, n_blocks, bids bytes)`` is intrinsic to the answer."""
    schema, records, cuts, work = _setup(9, n_queries=10)
    rng = np.random.default_rng(seed)
    probe = qry.Workload(
        schema, tuple(random_query(schema, rng) for _ in range(8))
    )
    routes = {}
    for order in ((0, 1), (1, 0)):
        svc = LayoutService.build(
            records, work, strategy="greedy", cuts=cuts, backend="numpy",
            min_block=30,
        )
        _deploy_two(svc, records, cuts, work, order)
        routes[order] = svc.route_queries_cheapest(probe)
    for a, b in zip(routes[(0, 1)], routes[(1, 0)]):
        assert a.cost == b.cost
        np.testing.assert_array_equal(a.bids, b.bids)


def test_cheapest_route_is_argmin_over_replicas():
    schema, records, cuts, work = _setup(4, n_queries=10)
    svc = LayoutService.build(
        records, work, strategy="greedy", cuts=cuts, backend="numpy",
        min_block=30,
    )
    rset = _deploy_two(svc, records, cuts, work)
    assert rset.k == 2
    probe = work
    per_replica = [
        v.engine.route_queries(probe.tensorize(v.tree.cuts))
        for v in rset.versions
    ]
    routes = rset.route_queries(probe)
    for qi, r in enumerate(routes):
        costs = [
            int(rset.block_sizes[i][per_replica[i][qi]].sum())
            for i in range(rset.k)
        ]
        assert r.cost == min(costs)
    # Eq. 1 under cheapest-replica routing can only improve on any
    # single replica's scanned fraction (argmin per query)
    frac = rset.scanned_fraction(probe, n_records=records.shape[0])
    for i, v in enumerate(rset.versions):
        single = sum(
            int(rset.block_sizes[i][bids].sum())
            for bids in per_replica[i]
        ) / float(records.shape[0] * len(probe))
        assert frac <= single + 1e-12


def test_replica_set_validates_positions_and_replace():
    _, _, _, _, svc = _service(2)
    live = svc.live_version()
    with pytest.raises(ValueError, match="ids must match positions"):
        ReplicaSet((live, live))  # second slot carries replica_id 0
    rset = svc.live_replica_set()
    with pytest.raises(ValueError, match="not in live set"):
        rset.replace(3, live)


# ---------------------------------------------------------------------------
# Serving a k-replica set: cache soundness, per-replica invalidation
# ---------------------------------------------------------------------------
def test_serving_replica_set_cached_and_bit_identical():
    schema, records, cuts, work = _setup(8, n_queries=8)
    svc = LayoutService.build(
        records, work, strategy="greedy", cuts=cuts, backend="numpy",
        min_block=30,
    )
    rset = _deploy_two(svc, records, cuts, work)
    server = QueryServer(svc, ServeConfig(max_batch=8))
    mix = list(work.queries) * 2
    r1 = server.serve_batch(mix)
    r2 = server.serve_batch(mix)
    assert all(r.cached for r in r2)
    assert server.counters.stale_responses == 0
    expected = rset.route_queries(qry.Workload(schema, tuple(mix)))
    for res, exp in zip(r2, expected):
        assert res.replica_id == exp.replica_id
        np.testing.assert_array_equal(res.bids, exp.bids)
    # provenance epochs carry the serving replica's id
    assert {r.replica_id for r in r2} <= {0, 1}
    server.stop()


def test_result_cache_per_replica_invalidation():
    cache = ResultCache(capacity=16)
    e0, e1 = Epoch(1, 0, 0), Epoch(1, 0, 1)
    cache.activate((e0, e1))
    bids = np.arange(3, dtype=np.int32)
    assert cache.put(e0, ("a",), bids)
    assert cache.put(e1, ("b",), bids)
    # swapping replica 1 retires ONLY replica 1's entries
    cache.activate(Epoch(2, 0, 1))
    assert cache.get(e0, ("a",)) is not None
    assert cache.get(e1, ("b",)) is None
    assert cache.stats.invalidated == 1
    # lookup walks the live epochs in order, one count per signature
    hits_before = cache.stats.hits
    found = cache.lookup((e0, Epoch(2, 0, 1)), [("a",), ("b",)])
    assert found[0] is not None and found[0][0] == e0
    assert found[1] is None
    assert cache.stats.hits == hits_before + 1


def test_swap_primary_keeps_secondary_cache_entries():
    schema, records, cuts, work = _setup(10, n_queries=8)
    svc = LayoutService.build(
        records, work, strategy="greedy", cuts=cuts, backend="numpy",
        min_block=30,
    )
    _deploy_two(svc, records, cuts, work)
    server = QueryServer(svc, ServeConfig(max_batch=8))
    mix = list(work.queries)
    server.serve_batch(mix)
    by_replica = {}
    for res in server.serve_batch(mix):
        by_replica.setdefault(res.replica_id, 0)
        by_replica[res.replica_id] += 1
    assert by_replica.get(1)  # the probe mix exercises both replicas
    entries_before = len(server.cache)
    invalidated_before = server.cache.stats.invalidated
    # hot-swap the primary only: the swap listener's activation purges
    # replica 0's entries and ONLY those — replica 1's survive in place
    build = build_layout(
        records, work, strategy="greedy", cuts=cuts, min_block=40
    )
    svc.swap(build)
    purged = server.cache.stats.invalidated - invalidated_before
    assert purged == entries_before - len(server.cache)
    assert len(server.cache) > 0  # replica 1's entries were NOT purged
    r3 = server.serve_batch(mix)
    assert all(r.replica_id in (0, 1) for r in r3)
    assert server.counters.stale_responses == 0
    server.stop()


# ---------------------------------------------------------------------------
# Lifecycle: per-replica release / rollback errors
# ---------------------------------------------------------------------------
def test_release_names_replica_holding_generation():
    schema, records, cuts, work = _setup(12)
    svc = LayoutService.build(
        records, work, strategy="greedy", cuts=cuts, backend="numpy",
        min_block=30,
    )
    rset = _deploy_two(svc, records, cuts, work)
    g0, g1 = rset.generations()
    with pytest.raises(ValueError, match="cannot release the live"):
        svc.release(g0)
    with pytest.raises(
        ValueError, match=r"serving as replica 1"
    ):
        svc.release(g1)
    with pytest.raises(ValueError, match=r"held by replica r0.*r1"):
        svc.release(999)


def test_rollback_is_per_replica():
    schema, records, cuts, work = _setup(13)
    svc = LayoutService.build(
        records, work, strategy="greedy", cuts=cuts, backend="numpy",
        min_block=30,
    )
    first = _deploy_two(svc, records, cuts, work)
    g0_old, g1_old = first.generations()
    second = _deploy_two(svc, records, cuts, work, min_block=40)
    assert svc.live_replica_set() is second
    # roll back only the secondary replica: the primary stays current
    got = svc.rollback(g1_old)
    assert got == g1_old
    rset = svc.live_replica_set()
    assert rset.generations() == (second.generations()[0], g1_old)
    assert svc.generation == second.generations()[0]
    # default rollback targets the primary's previous generation
    got = svc.rollback()
    assert svc.generation == got
    assert svc.live_replica_set().generations()[0] == got


# ---------------------------------------------------------------------------
# Clustering: determinism, k=1 degradation, the lam blend
# ---------------------------------------------------------------------------
def test_cluster_signatures_k1_and_determinism():
    schema, _, _, work = _setup(14, n_queries=12)
    items = workload_signature_weights(work)
    assert cluster_signatures(items, schema, 1) == [
        list(range(len(items)))
    ]
    a = cluster_signatures(items, schema, 3)
    b = cluster_signatures(items, schema, 3)
    assert a == b  # deterministic for a fixed input order
    assert sorted(i for c in a for i in c) == list(range(len(items)))


def test_blended_mix_lambda_endpoints():
    schema, _, _, work = _setup(15, n_queries=10)
    items = workload_signature_weights(work)
    cluster = list(range(len(items) // 2))
    # lam=0: pure cluster share — out-of-cluster signatures vanish
    pure = blended_mix(items, cluster, 0.0)
    assert {s for s, _ in pure} == {items[i][0] for i in cluster}
    # lam=1: pure uniform prior — every signature, equal weight
    uniform = blended_mix(items, cluster, 1.0)
    assert len(uniform) == len(items)
    ws = {w for _, w in uniform}
    assert len(ws) == 1
    with pytest.raises(ValueError):
        blended_mix(items, cluster, 1.5)
    wls, sigs = cluster_workloads(items, schema, 2, lam=0.25, budget=32)
    assert len(wls) == len(sigs) <= 2
    assert all(len(w) > 0 for w in wls)
    assert len(materialize_mix(items, schema, budget=16)) > 0


def test_rebuild_replicas_from_declared_workload():
    schema, records, cuts, work = _setup(16, n_queries=12)
    svc = LayoutService.build(
        records, work, strategy="greedy", cuts=cuts, backend="numpy",
        min_block=30,
    )
    single = svc.engine.skip_stats(
        records, work, tighten=False
    ).scanned_fraction
    rep = svc.rebuild_replicas(
        records, workload=work, k=2, lam=0.25, swap="always",
        cuts=cuts, min_block=30,
    )
    assert rep.swapped
    assert svc.live_replica_set().k == len(rep.builds)
    assert rep.candidate_scanned <= single + 1e-9
    # the deployed set serves the single-tree APIs through its primary
    assert svc.live_version() is svc.live_replica_set().primary
    with pytest.raises(ValueError, match="invalid swap policy"):
        svc.rebuild_replicas(records, workload=work, swap="sometimes")
    with pytest.raises(ValueError, match="needs a tracker"):
        svc.rebuild_replicas(records, workload=None)


# ---------------------------------------------------------------------------
# The option-surface lifecycle: loose kwargs retired, ingest_sharded shims
# ---------------------------------------------------------------------------
def _batches(records, n=4):
    step = max(len(records) // n, 1)
    for s in range(0, len(records), step):
        yield records[s : s + step]


def _tree_bits(tree):
    return tuple(
        np.ascontiguousarray(a).tobytes()
        for a in (tree.leaf_lo, tree.leaf_hi, tree.leaf_cat, tree.leaf_adv)
    )


def test_ingest_loose_kwargs_are_rejected():
    """The PR 8 one-release warning shim is retired: loose option kwargs
    raise TypeError naming the typed spelling, with or without options."""
    _, records, _, _, svc = _service(17)
    for kw in (
        dict(fused=False),
        dict(observe=None, monitor=None),
        dict(executor="thread"),
        dict(shards=2),
    ):
        with pytest.raises(TypeError, match="IngestOptions"):
            svc.ingest(_batches(records), **kw)
    with pytest.raises(TypeError, match="IngestOptions"):
        svc.ingest(
            _batches(records), options=IngestOptions(fused=False),
            fused=True,
        )


def test_ingest_sharded_shim_warns_and_forwards():
    _, records, _, _, svc = _service(19)
    with pytest.warns(DeprecationWarning, match="ingest_sharded.*deprecated"):
        rep = svc.ingest_sharded(
            records, 2, options=IngestOptions(executor="thread")
        )
    assert rep.n_records == len(records) and rep.n_shards == 2


@settings(max_examples=8, deadline=None)
@given(k=st.integers(2, 3), batch=st.integers(16, 96))
def test_ingest_sharded_shim_matches_unified_ingest(k, batch):
    """Property: the deprecated ingest_sharded spelling and the unified
    ingest(records, IngestOptions(shards=, batch=)) produce bit-identical
    trees and matching reports over the same inputs."""
    schema, records, cuts, work = _setup(21)
    opts = IngestOptions(shards=k, batch=batch, executor="thread")

    def run(method):
        svc = LayoutService.build(
            records[: len(records) // 2], work, strategy="greedy",
            cuts=cuts, backend="numpy", min_block=30,
        )
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")  # shim + thread footgun
            if method == "old":
                rep = svc.ingest_sharded(records, k, batch=batch,
                                         options=IngestOptions(
                                             executor="thread"))
            else:
                rep = svc.ingest(records, opts)
        return rep, _tree_bits(svc.tree)

    rep_old, bits_old = run("old")
    rep_new, bits_new = run("new")
    assert bits_old == bits_new
    assert rep_old.n_records == rep_new.n_records == len(records)
    assert rep_old.n_batches == rep_new.n_batches
    assert rep_old.n_shards == rep_new.n_shards == k
    np.testing.assert_array_equal(rep_old.block_sizes, rep_new.block_sizes)


def test_auto_rebuilder_requires_policy():
    _, _, _, work, svc = _service(20)
    cfg = DriftConfig(window=4, min_fill=2, abs_threshold=0.9)
    with pytest.raises(TypeError, match="RebuildPolicy"):
        svc.auto_rebuilder(work, config=cfg)
    rb_new = svc.auto_rebuilder(
        RebuildPolicy(workload=work, drift=cfg, replicas=2, lam=0.5)
    )
    assert rb_new.monitor.config is cfg
    assert rb_new.policy.replicas == 2
    assert rb_new.policy.lam == 0.5


def test_rebuild_policy_validation():
    with pytest.raises(ValueError):
        RebuildPolicy(replicas=0)
    with pytest.raises(ValueError):
        RebuildPolicy(lam=1.5)
    p = RebuildPolicy(replicas=3, lam=0.0)
    assert p.replicas == 3 and p.lam == 0.0
