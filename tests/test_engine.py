"""LayoutEngine tests: backend registry + cross-backend bit-identity,
compiled-plan cache behavior (same bucket ⇒ zero retraces), incremental
vs one-shot tighten equivalence, and streaming ingestion into block buffers.
"""

import numpy as np
import pytest

from repro.core import predicates as preds
from repro.core import query as qry
from repro.core import rewards
from repro.core.qdtree import IncrementalTightener
from repro.data.blocks import BlockBuffers, BlockStore
from repro.engine import (
    LayoutEngine,
    PlanCache,
    available_backends,
    engine_for,
    get_backend,
    pad_bucket,
)
from repro.engine import plan as planlib
from tests.test_qdtree import random_tree, small_setup
from tests.test_query import random_query

ALL_BACKENDS = ("numpy", "jax", "pallas")


def _frozen(seed=0):
    schema, records, cuts = small_setup(seed)
    rng = np.random.default_rng(seed)
    tree = random_tree(schema, cuts, records, rng)
    return schema, records, cuts, tree.freeze()


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
def test_registry_lists_all_backends():
    assert set(ALL_BACKENDS) <= set(available_backends())
    for name in ALL_BACKENDS:
        assert get_backend(name).name == name
    with pytest.raises(ValueError, match="unknown backend"):
        get_backend("cuda")
    _, _, _, frozen = _frozen()
    with pytest.raises(ValueError, match="unknown backend"):
        LayoutEngine(frozen, backend="cuda")


def test_pad_bucket():
    assert pad_bucket(1) == 1
    assert pad_bucket(3) == 4
    assert pad_bucket(256) == 256
    assert pad_bucket(257) == 512
    assert pad_bucket(5, minimum=64) == 64


# ---------------------------------------------------------------------------
# Cross-backend bit-identity on randomized trees/workloads
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 7, 123])
def test_backends_bit_identical_routing(seed):
    schema, records, cuts, frozen = _frozen(seed)
    eng = LayoutEngine(frozen)
    want = frozen.route(records)
    for backend in ALL_BACKENDS:
        got = eng.route(records, backend=backend)
        assert got.dtype == np.int32
        np.testing.assert_array_equal(got, want, err_msg=backend)


@pytest.mark.parametrize("seed", [1, 42])
def test_backends_bit_identical_query_hits(seed):
    schema, records, cuts, frozen = _frozen(seed)
    rng = np.random.default_rng(seed)
    bids = frozen.route(records)
    frozen.tighten(records, bids)
    work = qry.Workload(
        schema, tuple(random_query(schema, rng) for _ in range(9))
    )
    wt = work.tensorize(cuts)
    eng = LayoutEngine(frozen)
    want = rewards.block_query_hits(frozen, wt)
    for backend in ALL_BACKENDS:
        got = eng.query_hits(wt, backend=backend)
        np.testing.assert_array_equal(got, want, err_msg=backend)


def test_skip_stats_matches_evaluate_layout():
    schema, records, cuts, frozen = _frozen(5)
    rng = np.random.default_rng(5)
    work = qry.Workload(
        schema, tuple(random_query(schema, rng) for _ in range(5))
    )
    stats = engine_for(frozen).skip_stats(records, work)
    assert stats.n_records == records.shape[0]
    assert stats.scanned_tuples + stats.skipped_tuples == (
        records.shape[0] * len(work)
    )
    # engine skip_stats on a fresh identical tree ≡ rewards.evaluate_layout
    _, _, _, frozen2 = _frozen(5)
    stats2 = rewards.evaluate_layout(frozen2, records, work)
    assert stats.scanned_tuples == stats2.scanned_tuples
    np.testing.assert_array_equal(stats.query_hits, stats2.query_hits)
    np.testing.assert_array_equal(stats.block_sizes, stats2.block_sizes)


# ---------------------------------------------------------------------------
# Plan cache: same bucket ⇒ cache hit and zero retraces
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_plan_cache_same_bucket_no_retrace(backend):
    schema, records, cuts, frozen = _frozen(11)
    eng = LayoutEngine(frozen)
    want = frozen.route(records)
    # cold call compiles the plan for batch bucket pad_bucket(300) == 512
    np.testing.assert_array_equal(
        eng.route(records[:300], backend=backend), want[:300]
    )
    misses0 = eng.plans.stats()["misses"]
    hits0 = eng.plans.stats()["hits"]
    traces0 = sum(planlib.trace_counts().values())
    # different batch sizes, same power-of-two bucket ⇒ plan-cache hits,
    # zero retraces
    for m in (290, 400, 511, 300):
        np.testing.assert_array_equal(
            eng.route(records[:m], backend=backend), want[:m]
        )
    assert eng.plans.stats()["misses"] == misses0
    assert eng.plans.stats()["hits"] == hits0 + 4
    assert sum(planlib.trace_counts().values()) == traces0
    # a bucket-crossing batch reuses the packed operands (no plan miss) and
    # compiles at most one new executable for the new batch bucket
    big = np.concatenate([records, records])
    np.testing.assert_array_equal(
        eng.route(big, backend=backend), np.concatenate([want, want])
    )
    assert eng.plans.stats()["misses"] == misses0
    assert sum(planlib.trace_counts().values()) <= traces0 + 1


def test_plan_cache_shared_across_legacy_callsites():
    from repro.core import routing
    from repro.kernels import ops

    schema, records, cuts, frozen = _frozen(13)
    want = frozen.route(records[:256])
    np.testing.assert_array_equal(
        routing.route(frozen, records[:256], backend="pallas"), want
    )
    hits0 = engine_for(frozen).plans.stats()["hits"]
    # ops.route_records dispatches through the same attached engine
    np.testing.assert_array_equal(
        ops.route_records(frozen, records[:256]), want
    )
    assert engine_for(frozen).plans.stats()["hits"] > hits0


def test_query_plans_evicted_after_tighten_cycles():
    """Ingest/score loops must not accumulate stale leaf-description plans."""
    schema, records, cuts, frozen = _frozen(43)
    rng = np.random.default_rng(43)
    work = qry.Workload(
        schema, tuple(random_query(schema, rng) for _ in range(3))
    )
    wt = work.tensorize(cuts)
    eng = LayoutEngine(frozen)
    eng.query_hits(wt, backend="jax")
    size0 = eng.plans.stats()["size"]
    bids = frozen.route(records)
    for _ in range(5):  # repeated tighten bumps the description version
        frozen.tighten(records, bids)
        got = eng.query_hits(wt, backend="jax")
        np.testing.assert_array_equal(
            got, rewards.block_query_hits(frozen, wt)
        )
    assert eng.plans.stats()["size"] == size0  # stale versions evicted


def test_workload_tensor_cache_handles_object_churn():
    """id()-keyed caching must never serve tensors of a dead workload."""
    schema, records, cuts, frozen = _frozen(47)
    rng = np.random.default_rng(47)
    eng = LayoutEngine(frozen)
    bids = frozen.route(records)
    frozen.tighten(records, bids)
    for _ in range(30):  # churn temporaries so CPython reuses addresses
        work = qry.Workload(
            schema, tuple(random_query(schema, rng) for _ in range(2))
        )
        want = rewards.block_query_hits(frozen, work.tensorize(cuts))
        np.testing.assert_array_equal(eng.query_hits(work), want)


def test_plan_cache_stats_accounting():
    cache = PlanCache()
    built = []
    for _ in range(3):
        cache.get("k", lambda: built.append(1) or "plan")
    assert cache.stats() == {"hits": 2, "misses": 1, "size": 1}
    assert len(built) == 1


# ---------------------------------------------------------------------------
# Incremental vs one-shot tighten
# ---------------------------------------------------------------------------
def _tighten_reference(tree, records, bids):
    """The original per-leaf Python loop, kept as the test oracle."""
    adv_truth = preds.eval_adv(records, tree.cuts.adv)
    off = tree.schema.cat_offsets
    is_cat = tree.schema.is_categorical
    lo = np.zeros_like(tree.leaf_lo)
    hi = np.zeros_like(tree.leaf_hi)
    cat = np.zeros_like(tree.leaf_cat)
    adv = np.zeros_like(tree.leaf_adv)
    for b in range(tree.n_leaves):
        sel = bids == b
        if not sel.any():
            continue
        rows = records[sel]
        lo[b] = rows.min(axis=0)
        hi[b] = rows.max(axis=0) + 1
        for d in np.nonzero(is_cat)[0]:
            cat[b, off[d] + np.unique(rows[:, d]).astype(np.int64)] = True
        if tree.cuts.n_adv:
            t = adv_truth[sel]
            adv[b, :, 0] = t.any(axis=0)
            adv[b, :, 1] = (~t).any(axis=0)
    return lo, hi, cat, adv


@pytest.mark.parametrize("seed", [0, 3, 17])
def test_vectorized_tighten_matches_reference(seed):
    schema, records, cuts, frozen = _frozen(seed)
    bids = frozen.route(records)
    want = _tighten_reference(frozen, records, bids)
    frozen.tighten(records, bids)
    got = (frozen.leaf_lo, frozen.leaf_hi, frozen.leaf_cat, frozen.leaf_adv)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(g, w)


@pytest.mark.parametrize("chunk", [1, 7, 64, 10_000])
def test_incremental_tighten_matches_batch(chunk):
    schema, records, cuts, frozen = _frozen(23)
    bids = frozen.route(records)
    frozen.tighten(records, bids)
    want = (
        frozen.leaf_lo.copy(), frozen.leaf_hi.copy(),
        frozen.leaf_cat.copy(), frozen.leaf_adv.copy(),
    )
    t = IncrementalTightener(frozen)
    for s in range(0, records.shape[0], chunk):
        t.update(records[s : s + chunk], bids[s : s + chunk])
    t.apply()
    got = (frozen.leaf_lo, frozen.leaf_hi, frozen.leaf_cat, frozen.leaf_adv)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(g, w)


def test_tighten_bumps_desc_version_and_query_plans_refresh():
    schema, records, cuts, frozen = _frozen(29)
    rng = np.random.default_rng(29)
    work = qry.Workload(
        schema, tuple(random_query(schema, rng) for _ in range(4))
    )
    wt = work.tensorize(cuts)
    eng = LayoutEngine(frozen)
    before = eng.query_hits(wt, backend="jax")
    v0 = planlib.desc_version(frozen)
    bids = frozen.route(records)
    frozen.tighten(records, bids)
    assert planlib.desc_version(frozen) == v0 + 1
    after = eng.query_hits(wt, backend="jax")
    want = rewards.block_query_hits(frozen, wt)
    np.testing.assert_array_equal(after, want)
    # tightening can only prune (hits never grow)
    assert (after <= before).all()


# ---------------------------------------------------------------------------
# Streaming ingestion
# ---------------------------------------------------------------------------
def test_ingest_streams_into_buffers_and_store(tmp_path):
    schema, records, cuts, frozen = _frozen(31)
    eng = LayoutEngine(frozen, backend="numpy")
    buffers = BlockBuffers.for_tree(frozen)
    report = eng.ingest(
        (records[s : s + 57] for s in range(0, records.shape[0], 57)),
        buffers=buffers,
    )
    bids = frozen.route(records)
    sizes = np.bincount(bids, minlength=frozen.n_leaves)
    assert report.n_records == records.shape[0]
    np.testing.assert_array_equal(report.block_sizes, sizes)
    np.testing.assert_array_equal(buffers.sizes, sizes)
    # buffered rows per block == one-shot grouping (order-preserving)
    for b in range(frozen.n_leaves):
        np.testing.assert_array_equal(buffers.block(b), records[bids == b])
    # incremental tighten during ingest == one-shot tighten
    _, _, _, fresh = _frozen(31)
    fresh.tighten(records, bids)
    np.testing.assert_array_equal(frozen.leaf_lo, fresh.leaf_lo)
    np.testing.assert_array_equal(frozen.leaf_hi, fresh.leaf_hi)
    # persisted store round-trips
    buffers.write_store(tmp_path / "store", frozen)
    reopened = BlockStore.open(tmp_path / "store")
    np.testing.assert_array_equal(reopened.sizes, sizes)
    np.testing.assert_array_equal(
        reopened.read_block(0), records[bids == 0]
    )


def test_create_streaming_equals_create(tmp_path):
    schema, records, cuts, frozen = _frozen(37)
    _, _, _, frozen2 = _frozen(37)
    s1 = BlockStore.create(tmp_path / "oneshot", frozen, records)
    s2 = BlockStore.create_streaming(
        tmp_path / "streamed",
        frozen2,
        (records[s : s + 101] for s in range(0, records.shape[0], 101)),
    )
    np.testing.assert_array_equal(s1.sizes, s2.sizes)
    for b in range(frozen.n_leaves):
        np.testing.assert_array_equal(s1.read_block(b), s2.read_block(b))
    np.testing.assert_array_equal(frozen.leaf_lo, frozen2.leaf_lo)


def test_ingest_empty_and_varying_batches():
    schema, records, cuts, frozen = _frozen(41)
    eng = LayoutEngine(frozen, backend="jax")
    batches = [records[:0], records[:33], records[:0], records[33:190]]
    report = eng.ingest(iter(batches))
    assert report.n_batches == 2  # empty batches are skipped
    assert report.n_records == 190
    np.testing.assert_array_equal(
        report.block_sizes,
        np.bincount(frozen.route(records[:190]), minlength=frozen.n_leaves),
    )
