"""Serving-tier tests: exact-signature cache soundness (equal signatures
route identically, re-canonicalization is a fixed point), epoch-keyed
invalidation (hot swap and in-place tighten each retire cached results),
a stale-read hammer under concurrent swaps, admission/coalescing
semantics, and the cached-traffic → WorkloadTracker observation contract
(drift scoring itself stays ingest-side; serving influences it only
through the tracker-inferred workload)."""

import threading
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 containers without hypothesis
    from tests._hypothesis_shim import given, settings, st

from repro.core import query as qry
from repro.core.predicates import OP_GE, OP_LT
from repro.core.query import InAtom, Query, RangeAtom
from repro.engine import plan as planlib
from repro.serve import (
    EXACT_RESOLUTION,
    AdmissionError,
    QueryServer,
    RequestQueue,
    ResultCache,
    ServeConfig,
    exact_signatures,
)
from repro.service import Epoch, LayoutService, build_layout
from repro.service.tracker import query_from_signature
from tests.test_qdtree import small_setup
from tests.test_query import random_query


def _setup(seed=0, n_queries=8):
    schema, records, cuts = small_setup(seed)
    rng = np.random.default_rng(seed)
    work = qry.Workload(
        schema, tuple(random_query(schema, rng) for _ in range(n_queries))
    )
    return schema, records, cuts, work


def _service(seed=0, n_queries=8, backend="numpy", min_block=30):
    schema, records, cuts, work = _setup(seed, n_queries)
    svc = LayoutService.build(
        records, work, strategy="greedy", cuts=cuts, backend=backend,
        min_block=min_block,
    )
    return schema, records, cuts, work, svc


def _sig1(schema, q, cuts=None):
    return exact_signatures(qry.Workload(schema, (q,)), cuts)[0]


# ---------------------------------------------------------------------------
# Exact signatures: the cache-key soundness properties
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_equal_signatures_route_identically(seed):
    """Two textually different queries whose atoms fold to the same
    canonical form share an exact signature AND route to bit-identical
    block IDs — the property that makes signature-keyed result reuse
    sound."""
    schema, records, cuts, work = _setup(3)
    build = build_layout(
        records, work, strategy="greedy", cuts=cuts, min_block=30
    )
    rng = np.random.default_rng(seed)
    lo = int(rng.integers(0, 32))
    hi = lo + int(rng.integers(1, 32))
    a1 = RangeAtom(0, OP_GE, lo)
    a2 = RangeAtom(0, OP_LT, hi)
    a3 = InAtom(2, (0, 2, 4))
    q1 = Query.conjunction([a1, a2, a3])
    # reordered and with a redundant duplicate atom: min/max folding and
    # value-set intersection canonicalize both to one form
    q2 = Query.conjunction([a3, a2, a1, RangeAtom(0, OP_GE, lo)])
    s1 = _sig1(schema, q1, build.tree.cuts)
    s2 = _sig1(schema, q2, build.tree.cuts)
    assert s1 == s2
    eng = build.tree
    np.testing.assert_array_equal(
        qry.route_query(eng, q1), qry.route_query(eng, q2)
    )


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_exact_signature_recanonicalization_fixed_point(seed):
    """Materializing a query back from its exact signature and re-signing
    it reproduces the signature exactly — at EXACT_RESOLUTION the
    bucketing maps are the identity, so canonicalization is lossless and
    idempotent."""
    schema, _, _, _ = _setup(3)
    rng = np.random.default_rng(seed)
    q = random_query(schema, rng)
    sig = _sig1(schema, q)  # no cut filter: keep every advanced atom
    rebuilt = query_from_signature(sig, schema)
    assert _sig1(schema, rebuilt) == sig
    assert EXACT_RESOLUTION > max(c.dom for c in schema.columns)


# ---------------------------------------------------------------------------
# ResultCache: epoch keying, LRU, stale-put rejection
# ---------------------------------------------------------------------------
def test_result_cache_epoch_lifecycle():
    cache = ResultCache(capacity=8)
    e1, e2 = Epoch(1, 0), Epoch(2, 0)
    bids = np.arange(3, dtype=np.int32)

    # puts before any activation are stale (no live epoch yet)
    assert not cache.put(e1, ("sig",), bids)
    assert cache.stats.stale_puts == 1

    cache.activate(e1)
    assert cache.put(e1, ("sig",), bids)
    got = cache.get(e1, ("sig",))
    np.testing.assert_array_equal(got, bids)
    assert not got.flags.writeable  # shared by reference, read-only
    assert cache.stats.hits == 1

    # a swap retires every e1 entry; e1 results computed in-flight are
    # rejected rather than poisoning the new generation
    cache.activate(e2)
    assert len(cache) == 0
    assert cache.stats.invalidated == 1
    assert cache.get(e1, ("sig",)) is None
    assert not cache.put(e1, ("sig",), bids)
    assert cache.stats.stale_puts == 2
    assert cache.stats.epoch_changes == 2


def test_result_cache_lru_eviction_and_get_many_parity():
    cache = ResultCache(capacity=2)
    e = Epoch(1, 0)
    cache.activate(e)
    for i in range(3):
        cache.put(e, (i,), np.array([i], np.int32))
    assert len(cache) == 2
    assert cache.stats.evictions == 1
    assert cache.get(e, (0,)) is None  # oldest evicted

    many = cache.get_many(e, [(1,), (2,), (0,)])
    np.testing.assert_array_equal(many[0], [1])
    np.testing.assert_array_equal(many[1], [2])
    assert many[2] is None
    assert cache.stats.hits == cache.stats.hits  # counters consistent
    single = [cache.get(e, s) for s in [(1,), (2,), (0,)]]
    for a, b in zip(many, single):
        if a is None:
            assert b is None
        else:
            np.testing.assert_array_equal(a, b)


def test_result_cache_rejects_bad_capacity():
    with pytest.raises(ValueError):
        ResultCache(capacity=0)


# ---------------------------------------------------------------------------
# Admission + coalescing
# ---------------------------------------------------------------------------
def test_admission_queue_and_tenant_bounds():
    cfg = ServeConfig(max_batch=4, max_queue=4, max_per_tenant=2)
    queue = RequestQueue(cfg)
    schema, _, _, work = _setup(5)
    q = work.queries[0]

    queue.submit(q, tenant="a")
    queue.submit(q, tenant="a")
    with pytest.raises(AdmissionError) as exc:
        queue.submit(q, tenant="a")
    assert exc.value.reason == "tenant"
    queue.submit(q, tenant="b")  # fairness: other tenants still admitted
    queue.submit(q, tenant="c")
    with pytest.raises(AdmissionError) as exc:
        queue.submit(q, tenant="d")
    assert exc.value.reason == "queue"
    assert queue.stats.rejected_tenant == 1
    assert queue.stats.rejected_queue == 1
    assert queue.stats.accepted == 4


def test_submit_many_keeps_admitted_prefix_on_rejection():
    cfg = ServeConfig(max_batch=4, max_queue=3)
    queue = RequestQueue(cfg)
    _, _, _, work = _setup(5)
    with pytest.raises(AdmissionError):
        queue.submit_many([work.queries[0]] * 5)
    assert queue.stats.accepted == 3  # prefix admitted, identical to a
    assert len(queue) == 3            # submit() loop's behavior
    batch = queue.next_batch(timeout=0)
    assert len(batch) == 3
    queue.release_many(batch)
    assert queue.inflight("default") == 0


def test_sync_serve_batch_chunks_at_max_batch():
    _, _, _, work, svc = _service(7, n_queries=6)
    server = QueryServer(svc, ServeConfig(max_batch=8))
    qs = [work.queries[i % len(work)] for i in range(8 * 2 + 3)]
    results = server.serve_batch(qs)
    assert len(results) == 19
    assert server.counters.dispatches == 3  # 8 + 8 + 3
    assert server.counters.queries_served == 19
    server.stop()


def test_async_deadline_coalesces_a_trickle():
    _, _, _, work, svc = _service(7, n_queries=6)
    server = QueryServer(
        svc, ServeConfig(max_batch=32, max_delay_s=0.1)
    ).start()
    tickets = [server.submit(work.queries[i % 3]) for i in range(3)]
    for t in tickets:
        t.result(timeout=10.0)
    # all three arrived well inside the oldest waiter's deadline, so the
    # dispatcher served them as ONE coalesced engine visit
    assert server.counters.dispatches == 1
    server.stop()
    with pytest.raises(RuntimeError):
        server.start()  # stopped servers don't resurrect


# ---------------------------------------------------------------------------
# Epoch invalidation: hot swap and in-place tighten
# ---------------------------------------------------------------------------
def test_hot_swap_retires_prior_generation_entries():
    _, records, cuts, work, svc = _service(11)
    server = QueryServer(svc, ServeConfig(max_batch=8))
    qs = list(work.queries[:4])
    server.serve_batch(qs)
    r2 = server.serve_batch(qs)
    assert all(r.cached for r in r2)
    old_epoch = svc.live_epoch()

    other = build_layout(
        records, work, strategy="greedy", cuts=cuts, min_block=60
    )
    gen = svc.swap(other)
    # the swap listener purged eagerly; prior-generation keys are
    # unreachable regardless, because lookups carry the live epoch
    assert server.cache.epoch == svc.live_epoch()
    assert server.cache.stats.invalidated > 0
    assert server.cache.get(old_epoch, ("anything",)) is None

    r3 = server.serve_batch(qs)
    assert not any(r.cached for r in r3)  # cold at the new generation
    assert all(r.generation == gen for r in r3)
    for q, r in zip(qs, r3):
        np.testing.assert_array_equal(
            r.bids, svc.version(gen).engine.route_query(q)
        )
    server.stop()


def test_tighten_bumps_epoch_and_refreshes_results():
    _, records, _, work, svc = _service(13)
    server = QueryServer(svc, ServeConfig(max_batch=8))
    qs = list(work.queries[:4])
    server.serve_batch(qs)
    assert all(r.cached for r in server.serve_batch(qs))

    live = svc.live_version()
    v0 = planlib.desc_version(live.tree)
    live.tree.tighten(records, live.engine.route(records))
    assert planlib.desc_version(live.tree) == v0 + 1

    # same generation, new desc_version: the next dispatch activates the
    # new epoch, so every entry from (gen, v0) is unreachable and the
    # batch re-routes against the tightened descriptions
    r = server.serve_batch(qs)
    assert not any(x.cached for x in r)
    assert all(x.desc_version == v0 + 1 for x in r)
    for q, x in zip(qs, r):
        np.testing.assert_array_equal(x.bids, live.engine.route_query(q))
    assert all(x.cached for x in server.serve_batch(qs))  # re-cached
    server.stop()


# ---------------------------------------------------------------------------
# Stale-read hammer: swaps under live concurrent traffic
# ---------------------------------------------------------------------------
def test_stale_read_hammer_under_concurrent_swaps():
    _, records, cuts, work, svc = _service(17, n_queries=10)
    builds = [
        build_layout(records, work, strategy="greedy", cuts=cuts,
                     min_block=mb)
        for mb in (40, 70)
    ]
    server = QueryServer(
        svc, ServeConfig(max_batch=8, max_delay_s=0.002)
    ).start()
    pairs = []
    lock = threading.Lock()
    errors = []

    def client(tid):
        rng = np.random.default_rng(100 + tid)
        mine = []
        try:
            for _ in range(40):
                q = work.queries[int(rng.integers(0, len(work)))]
                mine.append((q, server.serve(q, tenant=f"t{tid}",
                                             timeout=30.0)))
        except BaseException as e:
            errors.append(e)
        with lock:
            pairs.extend(mine)

    threads = [threading.Thread(target=client, args=(i,)) for i in range(3)]
    for t in threads:
        t.start()
    for i in range(4):  # hot swaps under live traffic
        time.sleep(0.01)
        svc.swap(builds[i % 2])
    for t in threads:
        t.join()
    server.stop()
    assert not errors, errors[0]
    assert len(pairs) == 120
    # the serving contract: zero stale responses, and every response is
    # bit-identical to routing that query on its provenance generation
    assert server.counters.stale_responses == 0
    for q, res in pairs:
        np.testing.assert_array_equal(
            res.bids, svc.version(res.generation).engine.route_query(q)
        )


# ---------------------------------------------------------------------------
# Cached traffic still feeds workload observation (drift stays ingest-side)
# ---------------------------------------------------------------------------
def test_cache_hits_record_into_tracker():
    """Serving records EVERY query — hit or miss — into the tracker, so
    workload inference never goes blind behind a hot cache.  Drift
    *scoring* (skip-rate monitoring) remains ingest-side by design: the
    serving tier influences rebuilds only through the tracker-inferred
    workload, exactly like ``launch.serve --workload auto`` drives
    ``service.rebuild(records, tracker.infer_workload())``."""
    _, records, _, work, svc = _service(19, n_queries=6)
    tracker = svc.workload_tracker()
    server = QueryServer(svc, ServeConfig(max_batch=8), tracker=tracker)
    qs = list(work.queries[:4])
    server.serve_batch(qs)
    seen1 = tracker.snapshot().queries_seen
    assert seen1 == 4
    r = server.serve_batch(qs)  # pure cache hits
    assert all(x.cached for x in r)
    assert tracker.snapshot().queries_seen == 8  # hits recorded too
    inferred = tracker.infer_workload()
    assert len(inferred) > 0
    # and the inferred mix is actually buildable — the auto-rebuild loop
    rep = svc.rebuild(records, inferred, min_block=30)
    assert rep.old_generation == 1
    server.stop()


def test_serve_stats_surface():
    _, _, _, work, svc = _service(23)
    tracker = svc.workload_tracker()
    server = QueryServer(svc, ServeConfig(max_batch=8), tracker=tracker)
    server.warm(work)
    server.serve_batch(list(work.queries[:3]))
    stats = server.stats()
    assert stats["queue_depth"] == 0
    assert stats["epoch"] == list(svc.live_epoch())
    assert stats["cache"]["lookups"] == 3
    assert stats["latency"]["count"] == 3
    assert stats["counters"]["queries_served"] == 3
    assert stats["admission"]["accepted"] == 3
    res = server.serve(work.queries[0])
    assert res.epoch == svc.live_epoch()
    server.stop()
    # post-stop: admission is closed
    with pytest.raises(RuntimeError):
        server.submit(work.queries[0])
