"""Unit + property tests for cuts and predicate evaluation."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 containers without hypothesis
    from tests._hypothesis_shim import given, settings, st

from repro.core import predicates as preds
from repro.core.predicates import Column, CutTableBuilder, Schema
from repro.core import routing


def tiny_schema():
    return Schema((
        Column("a", "numeric", 100),
        Column("b", "numeric", 50),
        Column("c", "categorical", 8),
        Column("d", "categorical", 5),
    ))


def test_schema_validation():
    s = tiny_schema()
    assert s.ndims == 4
    assert s.total_cat_bits == 13
    assert s.cat_offsets.tolist() == [-1, -1, 0, 8]
    with pytest.raises(ValueError):
        Schema((Column("x", "weird", 3),))
    with pytest.raises(ValueError):
        s.validate_records(np.array([[100, 0, 0, 0]], np.int32))


def test_cut_canonicalization_and_dedup():
    s = tiny_schema()
    b = CutTableBuilder(s)
    b.add_range(0, preds.OP_LT, 10)
    b.add_range(0, preds.OP_GE, 10)  # same cutpoint → dedup
    b.add_range(0, preds.OP_LE, 9)  # v <= 9 ⇒ v < 10 → dedup
    b.add_range(0, preds.OP_GT, 9)  # → v < 10 → dedup
    cuts = b.build()
    assert cuts.n_cuts == 1
    assert cuts.describe(0) == "a < 10"


def test_trivial_cuts_dropped():
    s = tiny_schema()
    b = CutTableBuilder(s)
    b.add_range(0, preds.OP_GE, 0)  # cutpoint 0: splits nothing
    b.add_range(1, preds.OP_LT, 50)  # cutpoint == dom: splits nothing
    b.add_in(2, [0, 1, 2, 3, 4, 5, 6, 7])  # full domain
    b.add_in(3, [])
    assert b.build().n_cuts == 0


def test_eq_makes_two_cuts():
    s = tiny_schema()
    b = CutTableBuilder(s)
    b.add_range(0, preds.OP_EQ, 7)
    cuts = b.build()
    assert cuts.n_cuts == 2  # v<7 and v<8 isolate [7,8)


def test_in_cut_eval():
    s = tiny_schema()
    b = CutTableBuilder(s)
    b.add_in(2, [1, 3])
    b.add_in(3, [0])
    cuts = b.build()
    recs = np.array(
        [[0, 0, 1, 0], [0, 0, 3, 1], [0, 0, 2, 0]], np.int32
    )
    m = preds.eval_cuts(recs, cuts)
    np.testing.assert_array_equal(
        m, [[True, True], [True, False], [False, True]]
    )


def test_adv_cut_eval():
    s = tiny_schema()
    b = CutTableBuilder(s)
    b.add_adv(0, preds.OP_LT, 1)
    cuts = b.build()
    recs = np.array([[5, 9, 0, 0], [9, 5, 0, 0], [5, 5, 0, 0]], np.int32)
    m = preds.eval_cuts(recs, cuts)
    np.testing.assert_array_equal(m[:, 0], [True, False, False])


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_eval_cuts_jax_matches_numpy(data):
    """Property: the jnp predicate matrix is bit-identical to numpy."""
    s = tiny_schema()
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
    b = CutTableBuilder(s)
    for _ in range(data.draw(st.integers(1, 6))):
        kind = data.draw(st.sampled_from(["range", "in", "adv"]))
        if kind == "range":
            dim = data.draw(st.sampled_from([0, 1]))
            op = data.draw(st.sampled_from(
                [preds.OP_LT, preds.OP_LE, preds.OP_GT, preds.OP_GE]
            ))
            b.add_range(dim, op, int(rng.integers(1, s.columns[dim].dom)))
        elif kind == "in":
            dim = data.draw(st.sampled_from([2, 3]))
            dom = s.columns[dim].dom
            k = data.draw(st.integers(1, dom - 1))
            b.add_in(dim, rng.choice(dom, k, replace=False).tolist())
        else:
            b.add_adv(0, preds.OP_LT, 1)
    cuts = b.build()
    if cuts.n_cuts == 0:
        return
    m = data.draw(st.integers(1, 64))
    recs = np.stack(
        [rng.integers(0, c.dom, m) for c in s.columns], axis=1
    ).astype(np.int32)
    ref = preds.eval_cuts(recs, cuts)
    import jax.numpy as jnp

    got = np.asarray(
        routing.eval_cuts_jax(jnp.asarray(recs), routing.cut_arrays(cuts))
    )
    np.testing.assert_array_equal(ref, got)
