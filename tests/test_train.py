"""Training substrate: optimizer (fp32 + int8), schedules, microbatching,
checkpoint/restart, failure injection, straggler watchdog."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_lib
from repro.train import steps
from repro.train.loop import (
    FailureInjector,
    LoopConfig,
    StragglerWatch,
    maybe_restore,
    train_loop,
)
from repro.train.optimizer import AdamWConfig
from repro.train.schedule import ScheduleConfig, warmup_cosine

CFG = get_config("qwen1.5-32b").reduced(n_layers=2)
OCFG = AdamWConfig()
SCFG = ScheduleConfig(peak_lr=1e-3, warmup_steps=5, total_steps=100)


def batch_stream(seed=0, B=8, S=32):
    rng = np.random.default_rng(seed)
    while True:
        t = rng.integers(0, CFG.vocab, (B, S + 1)).astype(np.int32)
        yield {
            "tokens": jnp.asarray(t[:, :-1]),
            "labels": jnp.asarray(t[:, 1:]),
        }


@pytest.fixture(scope="module")
def jitted_step():
    return jax.jit(lambda s, b: steps.train_step(s, b, CFG, OCFG, SCFG))


def test_loss_decreases(jitted_step):
    state = steps.init_train_state(jax.random.PRNGKey(0), CFG, OCFG)
    it = batch_stream()
    losses = []
    for _ in range(15):
        state, m = jitted_step(state, next(it))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert all(np.isfinite(losses))


def test_int8_optimizer_tracks_fp32():
    o8 = AdamWConfig(eight_bit=True)
    s32 = steps.init_train_state(jax.random.PRNGKey(0), CFG, OCFG)
    s8 = steps.init_train_state(jax.random.PRNGKey(0), CFG, o8)
    f32 = jax.jit(lambda s, b: steps.train_step(s, b, CFG, OCFG, SCFG))
    f8 = jax.jit(lambda s, b: steps.train_step(s, b, CFG, o8, SCFG))
    a, b = [], []
    it1, it2 = batch_stream(1), batch_stream(1)
    for _ in range(15):
        s32, m32 = f32(s32, next(it1))
        s8, m8 = f8(s8, next(it2))
        a.append(float(m32["loss"]))
        b.append(float(m8["loss"]))
    assert b[-1] < b[0]
    assert abs(a[-1] - b[-1]) < 0.4  # int8 moments track fp32 closely
    # int8 state really is int8
    q_leaves = [
        x for x in jax.tree.leaves(s8["opt"]["m"]) if x.dtype == jnp.int8
    ]
    assert q_leaves, "no quantized moment tensors found"


def test_quantize_roundtrip_property():
    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:  # tier-1 containers without hypothesis
        from tests._hypothesis_shim import given, settings, st

    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def run(seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(
            rng.standard_normal((8, 64)) * 10 ** rng.uniform(-6, 2),
            jnp.float32,
        )
        qs = opt_lib.quantize(x)
        err = np.abs(np.asarray(opt_lib.dequantize(qs)) - np.asarray(x))
        bound = np.abs(np.asarray(x)).max(axis=1, keepdims=True) / 127 + 1e-12
        assert (err <= bound + 1e-9).all()

    run()


def test_microbatch_equivalence():
    cfg_mb = dataclasses.replace(CFG, microbatches=4)
    s_a = steps.init_train_state(jax.random.PRNGKey(0), CFG, OCFG)
    s_b = steps.init_train_state(jax.random.PRNGKey(0), cfg_mb, OCFG)
    batch = next(batch_stream(2))
    s_a, _ = jax.jit(
        lambda s, b: steps.train_step(s, b, CFG, OCFG, SCFG)
    )(s_a, batch)
    s_b, _ = jax.jit(
        lambda s, b: steps.train_step(s, b, cfg_mb, OCFG, SCFG)
    )(s_b, batch)
    for x, y in zip(
        jax.tree.leaves(s_a["params"]), jax.tree.leaves(s_b["params"])
    ):
        np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(y, np.float32), atol=2e-5
        )


def test_schedule_shape():
    s = jnp.arange(0, 100)
    lr = warmup_cosine(s, SCFG)
    assert float(lr[0]) == 0.0
    assert abs(float(lr[5]) - SCFG.peak_lr) < 1e-9
    assert float(lr[99]) < SCFG.peak_lr
    assert float(lr[99]) >= SCFG.final_frac * SCFG.peak_lr * 0.99


def test_checkpoint_roundtrip(tmp_path, jitted_step):
    state = steps.init_train_state(jax.random.PRNGKey(0), CFG, OCFG)
    state, _ = jitted_step(state, next(batch_stream()))
    ckpt.save_checkpoint(tmp_path, 3, state)
    shapes, _ = steps.abstract_state(CFG, OCFG)
    restored = ckpt.restore_checkpoint(tmp_path, 3, shapes)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_latest(tmp_path):
    state = steps.init_train_state(jax.random.PRNGKey(0), CFG, OCFG)
    for s in (1, 2, 3, 4):
        ckpt.save_checkpoint(tmp_path, s, state, keep=2)
    assert ckpt.all_steps(tmp_path) == [3, 4]
    assert ckpt.latest_step(tmp_path) == 4


def test_failure_injection_and_resume(tmp_path, jitted_step):
    lcfg = LoopConfig(
        total_steps=12, ckpt_dir=str(tmp_path), ckpt_every=4, log_every=0
    )
    s0 = steps.init_train_state(jax.random.PRNGKey(0), CFG, OCFG)
    it = batch_stream(3)
    with pytest.raises(RuntimeError, match="injected failure"):
        train_loop(
            jitted_step, s0, it, lcfg,
            failure=FailureInjector(fail_at_step=9),
        )
    shapes, _ = steps.abstract_state(CFG, OCFG)
    st, step = maybe_restore(str(tmp_path), shapes)
    assert step == 8
    st2, hist = train_loop(jitted_step, st, it, lcfg)
    assert int(np.asarray(st2["step"])) == 12
    assert [h["step"] for h in hist] == [8, 9, 10, 11]


def test_straggler_watch_flags_outlier():
    fired = []
    w = StragglerWatch(
        z=3.0, warmup=5, on_straggle=lambda s, dt, mu: fired.append(s)
    )
    for i in range(20):
        w.observe(i, 0.1 + 0.001 * (i % 3))
    w.observe(20, 5.0)
    assert fired == [20]


def test_grad_clip_applied():
    state = steps.init_train_state(jax.random.PRNGKey(0), CFG, OCFG)
    batch = next(batch_stream())
    # huge lr would diverge instantly without clipping; assert the reported
    # grad norm > clip means the applied step was rescaled (params finite)
    hot = ScheduleConfig(peak_lr=1.0, warmup_steps=0, total_steps=10)
    s1, m = jax.jit(
        lambda s, b: steps.train_step(s, b, CFG, OCFG, hot)
    )(state, batch)
    assert np.isfinite(
        sum(float(jnp.sum(x.astype(jnp.float32)))
            for x in jax.tree.leaves(s1["params"]))
    )
