"""Greedy construction (paper Alg. 1) + the Fig. 3 disjunction scenario."""

import numpy as np

from repro.core import greedy, predicates as preds, query as qry, rewards
from repro.core.predicates import Column, CutTableBuilder, Schema


def test_block_size_constraint(tpch_small):
    schema, records, work, cuts = tpch_small
    b = 250
    tree = greedy.build_greedy(
        records, work, cuts, greedy.GreedyConfig(min_block=b)
    )
    frozen = tree.freeze()
    bids = frozen.route(records)
    sizes = np.bincount(bids, minlength=frozen.n_leaves)
    assert (sizes >= b).all(), sizes.min()


def test_greedy_beats_random(tpch_small):
    from repro.baselines import partitioners

    schema, records, work, cuts = tpch_small
    tree = greedy.build_greedy(
        records, work, cuts, greedy.GreedyConfig(min_block=250)
    )
    frozen = tree.freeze()
    g = rewards.evaluate_layout(frozen, records, work)
    rtree, rbids = partitioners.random_layout(records, schema, cuts, 250)
    sizes = np.bincount(rbids, minlength=rtree.n_leaves).astype(np.int64)
    hits = rewards.block_query_hits(rtree, work.tensorize(cuts))
    r_frac = (hits * sizes[:, None]).sum() / (records.shape[0] * len(work))
    assert g.scanned_fraction < 0.6 * r_frac


def fig3_setup(n=20_000, seed=0):
    """Paper Fig. 3: disjunctive query defeats the greedy criterion."""
    schema = Schema((
        Column("cpu", "numeric", 100),
        Column("disk", "numeric", 1000),
    ))
    rng = np.random.default_rng(seed)
    records = np.stack([
        rng.integers(0, 100, n), rng.integers(0, 1000, n)
    ], axis=1).astype(np.int32)
    q1 = qry.Query.disjunction([
        [qry.RangeAtom(0, preds.OP_LT, 10)],
        [qry.RangeAtom(0, preds.OP_GT, 90)],
    ])
    q2 = qry.Query.conjunction([qry.RangeAtom(1, preds.OP_LT, 10)])
    work = qry.Workload(schema, (q1, q2))
    b = CutTableBuilder(schema)
    b.add_range(0, preds.OP_LT, 10)
    b.add_range(0, preds.OP_GT, 90)
    b.add_range(1, preds.OP_LT, 10)
    return schema, records, work, b.build()


def test_fig3_greedy_limited():
    """Greedy only cuts on disk (the cpu cuts have zero marginal skip);
    the 4-block layout (cpu cuts after disk) is ~4× better — this is the
    paper's motivation for WOODBLOCK."""
    schema, records, work, cuts = fig3_setup()
    tree = greedy.build_greedy(
        records, work, cuts, greedy.GreedyConfig(min_block=150)
    )
    frozen = tree.freeze()
    stats = rewards.evaluate_layout(frozen, records, work)
    # greedy's layout scans roughly half the data (Q1 hits both disk blocks)
    assert stats.scanned_fraction > 0.40

    # manually build the 4-block layout WOODBLOCK finds (Fig. 3 right)
    from repro.core.qdtree import singleton_tree

    M = preds.eval_cuts(records, cuts)
    t2 = singleton_tree(schema, cuts, np.arange(records.shape[0]))
    n_disk = t2.root
    l, r = t2.split(n_disk, 2, cut_matrix=M)  # disk < 10
    l2, r2 = t2.split(r, 1, cut_matrix=M)  # left: cpu < 91
    t2.split(l2, 0, cut_matrix=M)  # cpu < 10
    f2 = t2.freeze()
    s2 = rewards.evaluate_layout(f2, records, work)
    assert s2.scanned_fraction < 0.5 * stats.scanned_fraction


def test_overlap_extension_allows_small_child():
    """Sec 6.2: relaxed cutting lets one child fall below b."""
    schema, records, work, cuts = fig3_setup(n=2_000)
    cfg = greedy.GreedyConfig(min_block=900, allow_small_child=True)
    tree = greedy.build_greedy(records, work, cuts, cfg)
    frozen = tree.freeze()
    bids = frozen.route(records)
    sizes = np.bincount(bids, minlength=frozen.n_leaves)
    assert frozen.n_leaves >= 2
    assert sizes.min() < 900  # a small (replicable) leaf exists
