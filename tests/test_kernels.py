"""Pallas kernel validation: shape/dtype sweeps + allclose vs ref oracles.

Kernels run in interpret mode on CPU (the container has no TPU); the same
pl.pallas_call/BlockSpec code path compiles for TPU.
"""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 containers without hypothesis
    from tests._hypothesis_shim import given, settings, st

from repro.core import predicates as preds
from repro.core import query as qry
from repro.core import rewards
from repro.kernels import ops
from tests.test_qdtree import random_tree, small_setup
from tests.test_query import random_query


@pytest.mark.parametrize("tile_m", [128, 256])
@pytest.mark.parametrize("m", [64, 300, 1024])
def test_route_records_shapes(tile_m, m):
    schema, records, cuts = small_setup(seed=m + tile_m, m=max(m, 600))
    rng = np.random.default_rng(0)
    tree = random_tree(schema, cuts, records, rng)
    frozen = tree.freeze()
    recs = records[:m]
    want = frozen.route(recs)
    got = ops.route_records(frozen, recs, tile_m=tile_m, interpret=True)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("dtype", [np.int32, np.int16, np.int64])
def test_route_records_dtypes(dtype):
    schema, records, cuts = small_setup(seed=5)
    rng = np.random.default_rng(5)
    tree = random_tree(schema, cuts, records, rng)
    frozen = tree.freeze()
    recs = records[:256].astype(dtype)
    want = frozen.route(records[:256])
    got = ops.route_records(frozen, recs.astype(np.int32), interpret=True)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_route_records_property(seed):
    """Property: Pallas routing ≡ numpy oracle for random trees/records."""
    schema, records, cuts = small_setup(seed)
    rng = np.random.default_rng(seed)
    tree = random_tree(schema, cuts, records, rng)
    frozen = tree.freeze()
    recs = records[: int(rng.integers(1, 400))]
    np.testing.assert_array_equal(
        ops.route_records(frozen, recs, interpret=True), frozen.route(recs)
    )


@pytest.mark.parametrize("tile_l,tile_c", [(128, 128), (256, 128)])
def test_query_intersect_tiles(tile_l, tile_c, tpch_tree, tpch_small):
    schema, records, work, cuts = tpch_small
    frozen, bids = tpch_tree
    wt = work.tensorize(cuts)
    want = rewards.block_query_hits(frozen, wt)
    sizes = np.bincount(bids, minlength=frozen.n_leaves)
    got, scanned = ops.query_intersect(
        frozen, wt, block_sizes=sizes, tile_l=tile_l, tile_c=tile_c,
        interpret=True,
    )
    np.testing.assert_array_equal(got, want)
    # fused scan count matches the oracle's per-conjunct reduction
    conj = qry.conjuncts_intersect(
        frozen.leaf_lo, frozen.leaf_hi, frozen.leaf_cat, frozen.leaf_adv,
        wt, schema,
    )
    want_scan = (conj * sizes[:, None]).sum(axis=0)
    np.testing.assert_allclose(scanned, want_scan, rtol=1e-6)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_query_intersect_property(seed):
    schema, records, cuts = small_setup(seed)
    rng = np.random.default_rng(seed)
    tree = random_tree(schema, cuts, records, rng)
    frozen = tree.freeze()
    bids = frozen.route(records)
    frozen.tighten(records, bids)
    work = qry.Workload(
        schema, tuple(random_query(schema, rng) for _ in range(7))
    )
    wt = work.tensorize(cuts)
    want = rewards.block_query_hits(frozen, wt)
    got, _ = ops.query_intersect(frozen, wt, interpret=True)
    np.testing.assert_array_equal(got, want)


def test_eval_cuts_kernel_wide_cats():
    """IN cuts over a wide categorical bit space exercise the one-hot
    matmul path with multiple 128-lane tiles."""
    schema = preds.Schema((
        preds.Column("n", "numeric", 1000),
        preds.Column("big", "categorical", 300),
    ))
    b = preds.CutTableBuilder(schema)
    rng = np.random.default_rng(0)
    for _ in range(5):
        b.add_in(1, rng.choice(300, 40, replace=False).tolist())
    b.add_range(0, preds.OP_LT, 500)
    cuts = b.build()
    records = np.stack(
        [rng.integers(0, 1000, 512), rng.integers(0, 300, 512)], axis=1
    ).astype(np.int32)
    from repro.core.qdtree import singleton_tree

    tree = singleton_tree(schema, cuts, np.arange(512))
    M = preds.eval_cuts(records, cuts)
    tree.split(tree.root, 0, cut_matrix=M)
    frozen = tree.freeze()
    np.testing.assert_array_equal(
        ops.route_records(frozen, records, interpret=True),
        frozen.route(records),
    )
