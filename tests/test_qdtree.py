"""Qd-tree structure tests + the paper's two core properties:

* semantic description — every routed record satisfies its leaf's
  description (range ∩ categorical mask ∩ advanced bits),
* completeness — every record satisfying a leaf's description is routed
  to that leaf (binary cuts ⇒ leaves partition the space).
"""

import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 containers without hypothesis
    from tests._hypothesis_shim import given, settings, st

from repro.core import predicates as preds
from repro.core.predicates import Column, CutTableBuilder, Schema
from repro.core.qdtree import FrozenQdTree, child_descs, root_desc, singleton_tree


def small_setup(seed=0, m=500):
    schema = Schema((
        Column("x", "numeric", 64),
        Column("y", "numeric", 32),
        Column("c", "categorical", 6),
    ))
    rng = np.random.default_rng(seed)
    records = np.stack([
        rng.integers(0, 64, m),
        rng.integers(0, 32, m),
        rng.integers(0, 6, m),
    ], axis=1).astype(np.int32)
    b = CutTableBuilder(schema)
    for c in (8, 16, 24, 32, 48):
        b.add_range(0, preds.OP_LT, c)
    for c in (8, 16, 24):
        b.add_range(1, preds.OP_LT, c)
    b.add_in(2, [0, 1])
    b.add_in(2, [2])
    b.add_adv(0, preds.OP_LT, 1)
    return schema, records, b.build()


def random_tree(schema, cuts, records, rng, max_splits=10):
    tree = singleton_tree(schema, cuts, np.arange(records.shape[0]))
    M = preds.eval_cuts(records, cuts)
    leaves = {id(tree.root): tree.root}
    for _ in range(max_splits):
        splittable = [n for n in leaves.values() if n.size >= 2]
        if not splittable:
            break
        node = splittable[rng.integers(0, len(splittable))]
        legal = []
        for c in range(cuts.n_cuts):
            col = M[node.rows, c]
            if 0 < col.sum() < node.size:
                legal.append(c)
        if not legal:
            del leaves[id(node)]
            continue
        cut = legal[rng.integers(0, len(legal))]
        l, r = tree.split(node, cut, cut_matrix=M)
        del leaves[id(node)]
        leaves[id(l)] = l
        leaves[id(r)] = r
    return tree


def desc_satisfied(rec, lo, hi, cat, adv, schema, cuts):
    ok = True
    for dim in range(schema.ndims):
        if schema.is_categorical[dim]:
            off = schema.cat_offsets[dim]
            ok &= bool(cat[off + rec[dim]])
        else:
            ok &= bool(lo[dim] <= rec[dim] < hi[dim])
    truth = preds.eval_adv(rec[None], cuts.adv)[0]
    for a in range(cuts.n_adv):
        ok &= bool(adv[a, 0]) if truth[a] else bool(adv[a, 1])
    return ok


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_routing_semantic_description_and_completeness(seed):
    schema, records, cuts = small_setup(seed)
    rng = np.random.default_rng(seed)
    tree = random_tree(schema, cuts, records, rng)
    frozen = tree.freeze()
    bids = frozen.route(records)
    assert (bids >= 0).all() and (bids < frozen.n_leaves).all()
    # descriptions BEFORE tightening partition the space: each record
    # satisfies exactly one leaf description (= completeness + uniqueness)
    sample = records[rng.choice(records.shape[0], 64, replace=False)]
    sbids = frozen.route(sample)
    for rec, bid in zip(sample, sbids):
        hits = [
            b
            for b in range(frozen.n_leaves)
            if desc_satisfied(
                rec, frozen.leaf_lo[b], frozen.leaf_hi[b],
                frozen.leaf_cat[b], frozen.leaf_adv[b], schema, cuts,
            )
        ]
        assert hits == [int(bid)]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_tighten_preserves_membership(seed):
    """Tightened (min-max) descriptions still cover every routed record."""
    schema, records, cuts = small_setup(seed)
    rng = np.random.default_rng(seed)
    tree = random_tree(schema, cuts, records, rng)
    frozen = tree.freeze()
    bids = frozen.route(records)
    frozen.tighten(records, bids)
    for i in rng.choice(records.shape[0], 64, replace=False):
        bid = bids[i]
        assert desc_satisfied(
            records[i], frozen.leaf_lo[bid], frozen.leaf_hi[bid],
            frozen.leaf_cat[bid], frozen.leaf_adv[bid], schema, cuts,
        )


def test_child_descs_restrict():
    schema, records, cuts = small_setup()
    root = root_desc(schema, cuts.n_adv)
    # range cut
    rng_cut = int(np.nonzero(cuts.kind == preds.KIND_RANGE)[0][0])
    l, r = child_descs(root, cuts, rng_cut)
    d, c = int(cuts.dim[rng_cut]), int(cuts.cutpoint[rng_cut])
    assert l.hi[d] == c and r.lo[d] == c
    # in cut
    in_cut = int(np.nonzero(cuts.kind == preds.KIND_IN)[0][0])
    l, r = child_descs(root, cuts, in_cut)
    seg = schema.cat_segment(int(cuts.dim[in_cut]))
    assert not (l.cat[seg] & r.cat[seg]).any()
    assert (l.cat[seg] | r.cat[seg]).all()
    # adv cut
    adv_cut = int(np.nonzero(cuts.kind == preds.KIND_ADV)[0][0])
    l, r = child_descs(root, cuts, adv_cut)
    assert l.adv[0].tolist() == [True, False]
    assert r.adv[0].tolist() == [False, True]


def test_freeze_roundtrip(tmp_path):
    schema, records, cuts = small_setup()
    rng = np.random.default_rng(3)
    tree = random_tree(schema, cuts, records, rng)
    frozen = tree.freeze()
    bids = frozen.route(records)
    frozen.tighten(records, bids)
    path = str(tmp_path / "tree.npz")
    frozen.save(path)
    loaded = FrozenQdTree.load(path)
    np.testing.assert_array_equal(loaded.route(records), bids)
    np.testing.assert_array_equal(loaded.leaf_lo, frozen.leaf_lo)
    np.testing.assert_array_equal(loaded.leaf_cat, frozen.leaf_cat)


def test_route_backends_agree(tpch_tree, tpch_small):
    from repro.core import routing

    schema, records, work, cuts = tpch_small
    frozen, bids = tpch_tree
    np.testing.assert_array_equal(
        routing.route(frozen, records[:2048], backend="jax"), bids[:2048]
    )
    np.testing.assert_array_equal(
        routing.route(frozen, records[:2048], backend="pallas"),
        bids[:2048],
    )
