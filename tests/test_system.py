"""End-to-end system test: layout → block store → pipeline → training.

The full loop the framework exists for: a workload-learned qd-tree lays
out the corpus, a curation query prunes blocks, the pipeline feeds a
sharded train step, a checkpoint survives a restart.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import greedy, query as qry
from repro.data import datagen, workload as wl
from repro.data.blocks import BlockStore
from repro.data.pipeline import PipelineConfig, QdTreePipeline
from repro.train import steps
from repro.train.optimizer import AdamWConfig
from repro.train.schedule import ScheduleConfig


def test_end_to_end_layout_to_training(tmp_path):
    # 1. learn a layout
    schema, records = datagen.make_errorlog_int(8_000, seed=0)
    work, _ = wl.make_errorlog_int_workload(schema, n_queries=40, seed=0)
    cuts = work.candidate_cuts()
    tree = greedy.build_greedy(
        records, work, cuts, greedy.GreedyConfig(min_block=400)
    )
    store = BlockStore.create(tmp_path / "blocks", tree.freeze(), records)

    # 2. curated pipeline skips blocks
    curation = qry.Query.conjunction(
        [qry.InAtom(schema.dim("event_type"), (0, 1))]
    )
    cfg = get_config("qwen1.5-32b").reduced(n_layers=2)
    pcfg = PipelineConfig(
        batch_size=4, seq_len=32, vocab=cfg.vocab,
        curation_query=curation, epochs=1_000,
    )
    pipe = QdTreePipeline(store, pcfg)
    assert pipe.blocks_skipped > 0

    # 3. train a few steps on the pipeline
    ocfg = AdamWConfig()
    scfg = ScheduleConfig(peak_lr=1e-3, warmup_steps=2, total_steps=50)
    state = steps.init_train_state(jax.random.PRNGKey(0), cfg, ocfg)
    step = jax.jit(lambda s, b: steps.train_step(s, b, cfg, ocfg, scfg))
    it = iter(pipe)
    losses = []
    for _ in range(8):
        toks, labels = next(it)
        state, m = step(
            state,
            {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)},
        )
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]

    # 4. checkpoint + restore continues bit-exactly
    from repro.train import checkpoint as ckpt

    ckpt.save_checkpoint(tmp_path / "ckpt", 8, state)
    shapes, _ = steps.abstract_state(cfg, ocfg)
    restored = ckpt.restore_checkpoint(tmp_path / "ckpt", 8, shapes)
    toks, labels = next(it)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
    s1, m1 = step(state, batch)
    s2, m2 = step(restored, batch)
    assert float(m1["loss"]) == float(m2["loss"])
