"""LayoutService tests: builder-registry parity across strategies and
backends, batched vs per-query routing equivalence, and versioned
rebuild-in-place (hot swap keeps pre-swap plans usable and bit-identical,
rollback/release semantics)."""

import numpy as np
import pytest

from repro.core import query as qry
from repro.engine import LayoutEngine
from repro.engine import plan as planlib
from repro.engine.plan import PlanKey
from repro.service import (
    LayoutBuild,
    LayoutService,
    available_strategies,
    build_layout,
    get_builder,
)
from tests.test_qdtree import small_setup
from tests.test_query import random_query

STRATEGY_CFG = {
    "greedy": {},
    "woodblock": dict(n_iters=2, episodes_per_iter=2),
    "bottom_up": {},
    "random": {},
    "range": dict(column=0),
}


def _setup(seed=0, n_queries=8):
    schema, records, cuts = small_setup(seed)
    rng = np.random.default_rng(seed)
    work = qry.Workload(
        schema, tuple(random_query(schema, rng) for _ in range(n_queries))
    )
    return schema, records, cuts, work


# ---------------------------------------------------------------------------
# Builder registry
# ---------------------------------------------------------------------------
def test_registry_covers_all_strategies():
    assert {"greedy", "woodblock", "random", "range", "bottom_up"} <= set(
        available_strategies()
    )
    for name in available_strategies():
        assert get_builder(name).name == name
    with pytest.raises(ValueError, match="unknown strategy"):
        get_builder("kd_tree")


def test_unknown_config_keys_rejected():
    _, records, cuts, work = _setup()
    with pytest.raises(TypeError, match="unknown config keys"):
        build_layout(
            records, work, strategy="greedy", cuts=cuts, min_block=30,
            episodes_per_iter=4,  # woodblock-only key
        )


@pytest.mark.parametrize("strategy", sorted(STRATEGY_CFG))
def test_every_strategy_returns_parity_checked_layout_build(strategy):
    """Each strategy → LayoutBuild whose tree round-trips through the
    engine with identical SkipStats on the numpy and jax backends."""
    _, records, cuts, work = _setup(3)
    build = build_layout(
        records, work, strategy=strategy, cuts=cuts, min_block=30,
        **STRATEGY_CFG[strategy],
    )
    assert isinstance(build, LayoutBuild)
    assert build.strategy == strategy
    assert build.bids.shape == (records.shape[0],)
    assert build.n_leaves >= 1
    assert 0.0 <= build.scanned_fraction <= 1.0
    assert build.provenance["n_records"] == records.shape[0]
    assert build.provenance["min_block"] == 30

    eng = LayoutEngine(build.tree)
    stats = {
        b: eng.skip_stats(records, work, tighten=False, backend=b)
        for b in ("numpy", "jax")
    }
    np.testing.assert_array_equal(
        eng.route(records, backend="numpy"),
        eng.route(records, backend="jax"),
    )
    assert stats["numpy"].scanned_tuples == stats["jax"].scanned_tuples
    np.testing.assert_array_equal(
        stats["numpy"].query_hits, stats["jax"].query_hits
    )
    np.testing.assert_array_equal(
        stats["numpy"].block_sizes, stats["jax"].block_sizes
    )


# ---------------------------------------------------------------------------
# Batched query routing
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["numpy", "jax", "pallas"])
def test_route_queries_matches_per_query_loop(backend):
    _, records, cuts, work = _setup(7, n_queries=12)
    svc = LayoutService.build(
        records, work, strategy="greedy", cuts=cuts, min_block=30
    )
    batched = svc.route_queries(work, backend=backend)
    assert len(batched) == len(work)
    per_query = [svc.route_query(q) for q in work.queries]
    for got, want in zip(batched, per_query):
        assert got.dtype == np.int32
        np.testing.assert_array_equal(got, want, err_msg=backend)


def test_core_route_query_delegates_to_engine():
    """Single source of truth: qry.route_query ≡ LayoutEngine.route_query."""
    from repro.engine import engine_for

    _, records, cuts, work = _setup(9)
    build = build_layout(
        records, work, strategy="greedy", cuts=cuts, min_block=30
    )
    for q in work.queries:
        np.testing.assert_array_equal(
            qry.route_query(build.tree, q),
            engine_for(build.tree).route_query(q),
        )


def test_workload_tensor_cache_is_lru():
    _, records, cuts, work = _setup(11)
    build = build_layout(
        records, work, strategy="greedy", cuts=cuts, min_block=30
    )
    eng = LayoutEngine(build.tree)
    rng = np.random.default_rng(11)
    keep = qry.Workload(
        build.tree.schema, tuple(random_query(build.tree.schema, rng)
                                 for _ in range(2))
    )
    eng.query_hits(keep, backend="numpy")
    churn = [
        qry.Workload(
            build.tree.schema,
            tuple(random_query(build.tree.schema, rng) for _ in range(2)),
        )
        for _ in range(eng.WT_CACHE_CAP + 5)
    ]
    for i, w in enumerate(churn):
        eng.query_hits(keep, backend="numpy")  # touch: keep stays hot
        eng.query_hits(w, backend="numpy")
    assert len(eng._wt_cache) == eng.WT_CACHE_CAP  # bounded, not cleared
    assert any(entry[0] is keep for entry in eng._wt_cache.values())
    # aliasing-impossible invariant: every key carries the id of the
    # workload the entry strongly references (so that id cannot be reused
    # while cached), plus the cut-table content signature
    assert all(
        k == (planlib.cuts_signature(build.tree.cuts), id(entry[0]))
        for k, entry in eng._wt_cache.items()
    )


def test_workload_tensor_cache_safe_under_concurrent_queries():
    """The shared LRU interleaves get/move_to_end/popitem across query
    threads; the cache lock must keep every sequence atomic (no KeyError,
    bounded size)."""
    from concurrent.futures import ThreadPoolExecutor

    _, records, cuts, work = _setup(41)
    svc = LayoutService.build(
        records, work, strategy="greedy", cuts=cuts, min_block=30
    )
    schema = svc.tree.schema
    want = svc.query_hits(work, backend="numpy")

    def hammer(i):
        local_rng = np.random.default_rng(1000 + i)
        for _ in range(30):  # churn well past WT_CACHE_CAP
            w = qry.Workload(
                schema,
                tuple(random_query(schema, local_rng) for _ in range(2)),
            )
            svc.query_hits(w, backend="numpy")
            np.testing.assert_array_equal(
                svc.query_hits(work, backend="numpy"), want
            )

    with ThreadPoolExecutor(max_workers=8) as pool:
        for f in [pool.submit(hammer, i) for i in range(8)]:
            f.result()  # surfaces KeyError/corruption from any thread
    assert len(svc.engine._wt_cache) <= svc.engine.WT_CACHE_CAP


def test_workload_tensors_survive_hot_swap():
    """ROADMAP: a swap to a tree built from an equal cut table must not
    re-tensorize standing workloads (shared cache keyed by cut-table
    content signature)."""
    _, records, cuts, work = _setup(37)
    svc = LayoutService.build(
        records, work, strategy="greedy", cuts=cuts, min_block=30
    )
    wt_before = svc.engine._tensorize(work)
    old_engine = svc.engine
    report = svc.rebuild(
        records, work, cuts=cuts, min_block=20, swap="always"
    )
    assert report.swapped and svc.engine is not old_engine
    # the new generation's engine serves the SAME tensorization object
    assert svc.engine._tensorize(work) is wt_before
    # and batched routing through it matches a from-scratch tensorize
    want = work.tensorize(svc.tree.cuts)
    got = svc.query_hits(work, backend="numpy")
    np.testing.assert_array_equal(
        got, svc.engine.query_hits(want, backend="numpy")
    )
    # a *different* cut table gets its own entry (no false sharing): an
    # engine over a tree built from other cuts, sharing the same cache,
    # must tensorize the same workload afresh
    other_cuts = work.candidate_cuts(max_adv=0)
    assert planlib.cuts_signature(other_cuts) != planlib.cuts_signature(
        svc.tree.cuts
    )
    other_build = build_layout(
        records, work, strategy="greedy", cuts=other_cuts, min_block=30
    )
    other_eng = LayoutEngine(
        other_build.tree, wt_cache=svc.engine._wt_cache
    )
    assert other_eng._tensorize(work) is not wt_before
    assert svc.engine._tensorize(work) is wt_before  # original entry kept


# ---------------------------------------------------------------------------
# Versioned rebuild-in-place
# ---------------------------------------------------------------------------
def test_rebuild_hot_swap_keeps_preswap_plans_usable():
    _, records, cuts, work = _setup(13)
    svc = LayoutService.build(
        records, work, strategy="greedy", cuts=cuts, min_block=60
    )
    gen0 = svc.generation
    old_engine = svc.engine
    old_sig = planlib.tree_signature(svc.tree)
    want_bids = svc.route(records, backend="jax")
    want_lists = svc.route_queries(work, backend="jax")

    # routing stays consistent mid-rebuild: the hook runs after the
    # candidate is built/scored but before the swap
    seen = {}

    def mid_rebuild(candidate):
        seen["generation"] = svc.generation
        np.testing.assert_array_equal(
            svc.route(records, backend="jax"), want_bids
        )

    report = svc.rebuild(
        records, work, cuts=cuts, min_block=30, swap="always",
        on_candidate=mid_rebuild,
    )
    assert seen["generation"] == gen0
    assert report.swapped and report.new_generation > gen0
    assert svc.generation == report.new_generation
    assert svc.versions() == (gen0, report.new_generation)
    # the live tree changed shape — rebuild really produced a new layout
    assert planlib.tree_signature(svc.tree) != old_sig

    # pre-swap plan-cache entries stay usable: the old generation routes
    # bit-identically, entirely from cache (no new misses, no retraces)
    misses0 = svc.plans.stats()["misses"]
    traces0 = sum(planlib.trace_counts().values())
    np.testing.assert_array_equal(
        old_engine.route(records, backend="jax"), want_bids
    )
    for got, want in zip(
        old_engine.route_queries(work, backend="jax"), want_lists
    ):
        np.testing.assert_array_equal(got, want)
    assert svc.plans.stats()["misses"] == misses0
    assert sum(planlib.trace_counts().values()) == traces0

    # rollback restores the old generation as live
    assert svc.rollback() == gen0
    np.testing.assert_array_equal(svc.route(records, backend="jax"),
                                  want_bids)
    svc.rollback(report.new_generation)

    # release drops the old generation and evicts exactly its plans
    assert svc.plans.evict(lambda k: False) == 0  # sanity: evict is selective
    n_old = sum(
        1 for k in svc.plans._plans
        if isinstance(k, PlanKey) and k.sig == old_sig
    )
    assert n_old > 0
    assert svc.release(gen0) == n_old
    assert svc.versions() == (report.new_generation,)
    with pytest.raises(KeyError):
        svc.version(gen0)
    # live serving unaffected by the release
    svc.route(records, backend="jax")


def test_rebuild_if_better_policy():
    _, records, cuts, work = _setup(17)
    svc = LayoutService.build(
        records, work, strategy="greedy", cuts=cuts, min_block=30
    )
    gen0 = svc.generation
    # a random layout over the same data cannot beat greedy here
    report = svc.rebuild(
        records, work, strategy="random", cuts=cuts, min_block=30
    )
    assert report.candidate_scanned >= report.live_scanned
    assert not report.swapped
    assert svc.generation == gen0 == report.new_generation
    # but the candidate artifact is returned, so callers may force-deploy
    gen1 = svc.swap(report.build)
    assert svc.generation == gen1 > gen0


def test_rebuild_never_policy_and_validation():
    _, records, cuts, work = _setup(19)
    svc = LayoutService.build(
        records, work, strategy="greedy", cuts=cuts, min_block=30
    )
    report = svc.rebuild(
        records, work, cuts=cuts, min_block=20, swap="never"
    )
    assert not report.swapped and svc.generation == report.old_generation
    with pytest.raises(ValueError, match="invalid swap policy"):
        svc.rebuild(records, work, cuts=cuts, swap="maybe")
    with pytest.raises(ValueError, match="cannot release the live"):
        svc.release(svc.generation)
    with pytest.raises(ValueError, match="no older generation"):
        svc.rollback()


def test_rollback_and_release_name_retained_generations():
    """Unknown / already-released generations must raise ValueError naming
    what IS retained — not leak a bare KeyError from the version dict."""
    _, records, cuts, work = _setup(43)
    svc = LayoutService.build(
        records, work, strategy="greedy", cuts=cuts, min_block=30
    )
    svc.rebuild(records, work, cuts=cuts, min_block=20, swap="always")
    with pytest.raises(ValueError, match=r"generation 99.*retained: \(1, 2\)"):
        svc.rollback(99)
    with pytest.raises(ValueError, match=r"generation 99.*retained: \(1, 2\)"):
        svc.release(99)
    svc.release(1)
    with pytest.raises(ValueError, match="unknown or released generation 1"):
        svc.rollback(1)  # released: no longer a rollback target


def test_release_refcounts_shared_tree_across_generations():
    """Regression: releasing one of two generations that deploy the SAME
    tree object (same plan signature) must not evict the other's warm
    plans — eviction only fires when the last holder is released."""
    _, records, cuts, work = _setup(47)
    svc = LayoutService.build(
        records, work, strategy="greedy", cuts=cuts, min_block=60
    )
    shared = build_layout(
        records, work, strategy="greedy", cuts=cuts, min_block=30
    )
    gen_a = svc.swap(shared)
    gen_b = svc.swap(shared)  # re-deploy: two generations, one tree
    sig = planlib.tree_signature(shared.tree)
    svc.route(records, backend="jax")
    svc.route_queries(work, backend="jax")
    n_shared = sum(
        1 for k in svc.plans._plans
        if isinstance(k, PlanKey) and k.sig == sig
    )
    assert n_shared > 0

    # releasing the first holder must evict nothing…
    assert svc.release(gen_a) == 0
    assert sum(
        1 for k in svc.plans._plans
        if isinstance(k, PlanKey) and k.sig == sig
    ) == n_shared
    # …and the surviving generation still serves fully warm
    misses0 = svc.plans.stats()["misses"]
    svc.route(records, backend="jax")
    assert svc.plans.stats()["misses"] == misses0

    # once the LAST holder goes, the plans go with it
    final = build_layout(
        records, work, strategy="greedy", cuts=cuts, min_block=25
    )
    svc.swap(final)
    assert svc.release(gen_b) == n_shared


def test_swap_if_live_is_exactly_one_winner_per_baseline():
    """CAS hammer: concurrent deploys against one observed baseline must
    admit exactly one winner per round — the foundation the drift
    auto-rebuilder's no-double-swap guarantee rests on."""
    from concurrent.futures import ThreadPoolExecutor

    _, records, cuts, work = _setup(53)
    svc = LayoutService.build(
        records, work, strategy="greedy", cuts=cuts, min_block=30
    )
    candidates = [
        build_layout(records, work, strategy="random", cuts=cuts,
                     min_block=30, seed=s)
        for s in range(8)
    ]
    with ThreadPoolExecutor(max_workers=8) as pool:
        for _ in range(5):  # rounds, each with a fresh observed baseline
            baseline = svc._live
            got = list(pool.map(
                lambda b: svc._swap_if_live_is(baseline, b), candidates
            ))
            wins = [g for g in got if g is not None]
            assert len(wins) == 1  # exactly one deploy per baseline
            assert svc.generation == wins[0]


def test_rebuild_if_better_is_stale_safe():
    """A concurrent swap mid-rebuild invalidates the scored baseline — the
    rebuild must not deploy its candidate on top of the newer tree."""
    _, records, cuts, work = _setup(29)
    svc = LayoutService.build(
        records, work, strategy="random", cuts=cuts, min_block=30
    )
    racing_build = build_layout(
        records, work, strategy="greedy", cuts=cuts, min_block=30
    )

    def concurrent_swap(candidate):
        svc.swap(racing_build)  # another rebuild wins the race

    report = svc.rebuild(
        records, work, strategy="greedy", cuts=cuts, min_block=40,
        on_candidate=concurrent_swap,
    )
    # the candidate beat the (stale) random baseline it was scored against…
    assert report.candidate_scanned < report.live_scanned
    # …but must not be deployed over the concurrently-swapped tree
    assert not report.swapped
    assert svc.tree is racing_build.tree


def test_rebuild_defaults_to_greedy_for_adopted_tree():
    _, records, cuts, work = _setup(31)
    build = build_layout(
        records, work, strategy="random", cuts=cuts, min_block=30
    )
    svc = LayoutService(build.tree)  # adopted: strategy not in registry
    report = svc.rebuild(records, work, cuts=cuts, min_block=30)
    assert report.strategy == "greedy"
    assert report.swapped  # greedy beats the random layout it adopted


def test_service_adopts_bare_frozen_tree():
    _, records, cuts, work = _setup(23)
    build = build_layout(
        records, work, strategy="greedy", cuts=cuts, min_block=30
    )
    svc = LayoutService(build.tree, backend="numpy")
    np.testing.assert_array_equal(
        svc.route(records), build.tree.route(records)
    )
    assert svc.version(svc.generation).build.strategy == "adopted"
