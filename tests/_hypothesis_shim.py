"""Minimal deterministic stand-in for ``hypothesis`` (dev-requirements).

The tier-1 suite must collect and run on containers without ``hypothesis``
installed.  This shim implements the tiny slice of the API the tests use —
``@settings``/``@given`` plus ``st.integers``, ``st.sampled_from`` and
``st.data()`` — by replaying each property ``max_examples`` times with a
deterministic per-example RNG.  No shrinking, no database, no coverage
heuristics: it is a fallback so property tests still execute (rather than
skip) everywhere; install the real package for serious fuzzing.
"""

from __future__ import annotations

import functools
import random


class _Strategy:
    def draw(self, rng: random.Random):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = lo, hi

    def draw(self, rng):
        return rng.randint(self.lo, self.hi)


class _SampledFrom(_Strategy):
    def __init__(self, options):
        self.options = list(options)

    def draw(self, rng):
        return self.options[rng.randrange(len(self.options))]


class _DataObject:
    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: _Strategy, label=None):
        return strategy.draw(self._rng)


class _Data(_Strategy):
    def draw(self, rng):
        return _DataObject(rng)


class _StrategiesNamespace:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Integers(min_value, max_value)

    @staticmethod
    def sampled_from(options) -> _Strategy:
        return _SampledFrom(options)

    @staticmethod
    def data() -> _Strategy:
        return _Data()


strategies = st = _StrategiesNamespace()

_DEFAULT_MAX_EXAMPLES = 10


class settings:
    def __init__(self, max_examples: int = _DEFAULT_MAX_EXAMPLES, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._shim_max_examples = self.max_examples
        return fn


def given(*arg_strategies, **kw_strategies):
    """Replay the property with deterministic draws (no shrinking)."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # @settings is usually applied OUTSIDE @given, so the example
            # budget lands on the wrapper — check it first.
            n = getattr(
                wrapper,
                "_shim_max_examples",
                getattr(fn, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES),
            )
            for example in range(n):
                rng = random.Random(example * 7919 + 0x5EED)
                drawn = [s.draw(rng) for s in arg_strategies]
                drawn_kw = {k: s.draw(rng) for k, s in kw_strategies.items()}
                fn(*args, *drawn, **kwargs, **drawn_kw)

        # hide the inner signature: pytest must not mistake the strategy
        # parameters (filled in by the replay loop above) for fixtures
        del wrapper.__wrapped__
        return wrapper

    return deco
