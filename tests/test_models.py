"""Per-architecture smoke tests (reduced same-family configs, deliverable f)
+ prefill↔decode logits consistency per model family."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config, runnable_cells
from repro.configs.base import SUBQUADRATIC_FAMILIES
from repro.models import model, transformer


def make_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S)), jnp.int32
        ),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S)), jnp.int32
        ),
    }
    if cfg.n_image_patches:
        batch["patches"] = jnp.asarray(
            0.01 * rng.standard_normal((B, cfg.n_image_patches, cfg.d_model)),
            jnp.float32,
        )
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            0.01 * rng.standard_normal((B, 16, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke(arch):
    """Reduced config: one forward/train step on CPU; shapes + no NaNs."""
    cfg = get_config(arch).reduced()
    B, S = 2, 32
    batch = make_batch(cfg, B, S)
    params, specs = model.init_model(jax.random.PRNGKey(0), cfg)
    # every param leaf has a matching logical-axes tuple of equal rank
    pl = jax.tree.leaves(params)
    sl = jax.tree.leaves(
        specs,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )
    assert len(pl) == len(sl)
    for p, s in zip(pl, sl):
        assert len(s) == p.ndim
    loss, metrics = jax.jit(
        lambda p, b: model.train_loss(p, b, cfg)
    )(params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: model.train_loss(p, batch, cfg)[0])(params)
    gsq = sum(
        float(jnp.sum(g.astype(jnp.float32) ** 2))
        for g in jax.tree.leaves(grads)
    )
    assert np.isfinite(gsq) and gsq > 0
    logits_last, caches = jax.jit(
        lambda p, b: model.prefill(p, b, cfg)
    )(params, batch)
    assert logits_last.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits_last, np.float32)).all()


@pytest.mark.parametrize(
    "arch",
    ["qwen1.5-32b", "mamba2-780m", "jamba-1.5-large-398b",
     "whisper-small", "qwen3-moe-235b-a22b"],
)
def test_prefill_decode_consistency(arch):
    """Teacher-forced logits at position t == decode-step logits at t.

    This validates the cache plumbing for every mixer type: GQA KV caches,
    SSD state recurrence (chunked scan ≡ stepwise recurrence), hybrid
    interleave, and enc-dec cross caches.
    """
    cfg = get_config(arch).reduced()
    # generous capacity so MoE dropping can't perturb the comparison
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    B, S = 2, 16
    batch = make_batch(cfg, B, S, seed=3)
    params, _ = model.init_model(jax.random.PRNGKey(1), cfg)
    full_logits, _, _ = (
        _encdec_logits(params, batch, cfg)
        if cfg.is_encdec
        else transformer.decoder_forward(
            params, batch["tokens"], cfg, patches=batch.get("patches")
        )
    )
    full_logits = np.asarray(full_logits, np.float32)

    plen = S // 2
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :plen]
    _, pre_caches = model.prefill(params, pre_batch, cfg)
    caches, _ = model.init_caches(cfg, B, S)
    caches = _splice(cfg, caches, pre_caches, plen)
    step = jax.jit(
        lambda p, c, t, pos: model.decode_step(p, c, t, pos, cfg)
    )
    for t in range(plen, S):
        logits, caches = step(
            params, caches, batch["tokens"][:, t : t + 1], jnp.int32(t)
        )
        np.testing.assert_allclose(
            np.asarray(logits, np.float32), full_logits[:, t],
            rtol=2e-2, atol=2e-2,
        )


def _encdec_logits(params, batch, cfg):
    from repro.models import encdec

    enc = encdec.encode(params, batch["frames"], cfg)
    logits, _ = encdec.decode_train(params, enc, batch["tokens"], cfg)
    return logits, None, None


def _splice(cfg, caches, prefill_caches, plen):
    from repro.launch.serve_lm import _splice as splice

    return splice(cfg, caches, prefill_caches, plen)


def test_layer_program_jamba():
    cfg = get_config("jamba-1.5-large-398b")
    prog = transformer.layer_program(cfg)
    assert len(prog) == 8
    assert [s.mixer for s in prog].count("attn") == 1
    assert prog[4].mixer == "attn"  # attn_offset=4
    assert [s.mlp for s in prog] == [
        "dense", "moe", "dense", "moe", "dense", "moe", "dense", "moe"
    ]
    assert transformer.n_groups(cfg) == 9


def test_runnable_cells_matrix():
    cells = runnable_cells()
    # 10 archs × 4 shapes − 8 long_500k skips (full-attention archs)
    assert len(cells) == 32
    longs = {a for a, s in cells if s == "long_500k"}
    assert longs == {"mamba2-780m", "jamba-1.5-large-398b"}
    for a, s in cells:
        assert a in ARCHS and s in SHAPES
        if s == "long_500k":
            assert ARCHS[a].family in SUBQUADRATIC_FAMILIES


def test_model_flops_positive():
    for arch, cfg in ARCHS.items():
        f = model.model_flops_per_token(cfg)
        assert f > 0, arch
        # MoE active params ≪ total: grok 314B total but ~86B active
        if arch == "grok-1-314b":
            assert f < 6 * 200e9
