"""Query processing: DNF intersection is conservative (no false negatives),
BID routing returns exactly the intersecting blocks."""

import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 containers without hypothesis
    from tests._hypothesis_shim import given, settings, st

from repro.core import predicates as preds
from repro.core import query as qry
from repro.core import rewards
from tests.test_qdtree import random_tree, small_setup


def random_query(schema, rng) -> qry.Query:
    def atom():
        kind = rng.integers(0, 3)
        if kind == 0:
            dim = int(rng.integers(0, 2))
            op = int(rng.choice(
                [preds.OP_LT, preds.OP_LE, preds.OP_GT, preds.OP_GE]
            ))
            return qry.RangeAtom(dim, op, int(rng.integers(0, 64)))
        if kind == 1:
            k = int(rng.integers(1, 4))
            vals = tuple(int(v) for v in rng.choice(6, k, replace=False))
            return qry.InAtom(2, vals)
        return qry.AdvAtom(0, preds.OP_LT, 1, polarity=bool(rng.integers(2)))

    n_conj = int(rng.integers(1, 3))
    return qry.Query.disjunction([
        [atom() for _ in range(int(rng.integers(1, 4)))]
        for _ in range(n_conj)
    ])


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_intersection_no_false_negatives(seed):
    """If any record in block b matches query q, q must intersect b."""
    schema, records, cuts = small_setup(seed)
    rng = np.random.default_rng(seed)
    tree = random_tree(schema, cuts, records, rng)
    frozen = tree.freeze()
    bids = frozen.route(records)
    frozen.tighten(records, bids)
    queries = tuple(random_query(schema, rng) for _ in range(10))
    work = qry.Workload(schema, queries)
    wt = work.tensorize(cuts)
    hits = rewards.block_query_hits(frozen, wt)  # (L, Q)
    for qi, q in enumerate(queries):
        truth = q.evaluate(records, schema)
        blocks_with_matches = set(np.unique(bids[truth]).tolist())
        claimed = set(np.nonzero(hits[:, qi])[0].tolist())
        assert blocks_with_matches <= claimed, (
            f"query {qi}: blocks {blocks_with_matches - claimed} "
            "have matches but were pruned"
        )


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_route_query_matches_hits(seed):
    schema, records, cuts = small_setup(seed)
    rng = np.random.default_rng(seed)
    tree = random_tree(schema, cuts, records, rng)
    frozen = tree.freeze()
    bids = frozen.route(records)
    frozen.tighten(records, bids)
    q = random_query(schema, rng)
    got = set(qry.route_query(frozen, q).tolist())
    wt = qry.Workload(schema, (q,)).tensorize(cuts)
    want = set(np.nonzero(rewards.block_query_hits(frozen, wt)[:, 0])[0].tolist())
    assert got == want


def test_scan_fraction_sanity(tpch_tree, tpch_small):
    schema, records, work, cuts = tpch_small
    frozen, bids = tpch_tree
    stats = rewards.evaluate_layout(frozen, records, work, tighten=False)
    lb = rewards.selectivity_lower_bound(records, work)
    assert lb <= stats.scanned_fraction <= 1.0
    # greedy must beat a full scan substantially on TPC-H-like data
    assert stats.scanned_fraction < 0.7


def test_adv_polarity_pruning():
    """A block of all commit<receipt rows must be pruned for NOT(q)."""
    schema, records, cuts = small_setup(7)
    rng = np.random.default_rng(7)
    tree = random_tree(schema, cuts, records, rng)
    frozen = tree.freeze()
    bids = frozen.route(records)
    frozen.tighten(records, bids)
    truth = records[:, 0] < records[:, 1]
    pos = qry.Query.conjunction([qry.AdvAtom(0, preds.OP_LT, 1, True)])
    neg = qry.Query.conjunction([qry.AdvAtom(0, preds.OP_LT, 1, False)])
    pos_blocks = set(qry.route_query(frozen, pos).tolist())
    neg_blocks = set(qry.route_query(frozen, neg).tolist())
    for b in range(frozen.n_leaves):
        rows = truth[bids == b]
        if rows.size == 0:
            continue
        if rows.all():
            assert b not in neg_blocks
        if (~rows).all():
            assert b not in pos_blocks
