"""Sharded-ingestion tests: ShardState merge is associative and
commutative, k-shard ingest is bit-identical to single-stream
``LayoutEngine.ingest`` (tightened leaf descriptions, per-block counts,
and buffered block contents), ShardState ships across processes/hosts
(pickle + npz), and the LayoutService facade publishes atomically."""

import pickle

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 containers without hypothesis
    from tests._hypothesis_shim import given, settings, st

from repro.core import query as qry
from repro.data.blocks import BlockBuffers
from repro.engine import LayoutEngine, replicate_tree, sharded_ingest
from repro.engine.sharded import (
    MergeCoordinator,
    PerformanceWarning,
    ShardIngestor,
    ShardState,
    micro_batches,
    shard_slices,
    states_bit_identical,
)
from repro.service import IngestOptions, LayoutService
from tests.test_qdtree import random_tree, small_setup
from tests.test_query import random_query


def _frozen(seed=0):
    schema, records, cuts = small_setup(seed)
    rng = np.random.default_rng(seed)
    tree = random_tree(schema, cuts, records, rng)
    return schema, records, cuts, tree.freeze()


def _shard_states(base, records, bounds, batch=41, collect_blocks=False,
                  probe=None):
    """One ShardState per contiguous [bounds[i], bounds[i+1]) slice."""
    states = []
    for i in range(len(bounds) - 1):
        part = records[bounds[i] : bounds[i + 1]]
        ing = ShardIngestor(
            LayoutEngine(replicate_tree(base), backend="numpy"),
            shard_id=i,
            collect_blocks=collect_blocks,
            probe=probe,
        )
        states.append(ing.run(micro_batches(part, batch)))
    return states


# ---------------------------------------------------------------------------
# Merge algebra
# ---------------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(st.data())
def test_merge_associative_and_commutative(data):
    _, records, _, base = _frozen(0)
    n = records.shape[0]
    # random 3-way contiguous partition (empty shards allowed)
    c1 = data.draw(st.integers(min_value=0, max_value=n), label="cut1")
    c2 = data.draw(st.integers(min_value=0, max_value=n), label="cut2")
    lo_cut, hi_cut = sorted((c1, c2))
    a, b, c = _shard_states(base, records, [0, lo_cut, hi_cut, n])
    left = a.merge(b).merge(c)
    right = a.merge(b.merge(c))
    assert states_bit_identical(left, right)
    assert left.shard_ids == right.shard_ids == (0, 1, 2)
    assert states_bit_identical(a.merge(b), b.merge(a))
    assert left.n_records == n


@settings(max_examples=8, deadline=None)
@given(st.data())
def test_kshard_ingest_bit_identical_to_single_stream(data):
    seed = data.draw(st.integers(min_value=0, max_value=5), label="seed")
    k = data.draw(st.sampled_from([1, 2, 3, 4, 8]), label="k")
    batch = data.draw(st.sampled_from([17, 64, 500]), label="batch")
    _, records, _, base = _frozen(seed)

    oracle = replicate_tree(base)
    rep1 = LayoutEngine(oracle, backend="numpy").ingest(
        micro_batches(records, batch)
    )
    replica = replicate_tree(base)
    repk = sharded_ingest(
        LayoutEngine(replica, backend="numpy"), records, k, batch=batch
    )
    np.testing.assert_array_equal(repk.block_sizes, rep1.block_sizes)
    np.testing.assert_array_equal(replica.leaf_lo, oracle.leaf_lo)
    np.testing.assert_array_equal(replica.leaf_hi, oracle.leaf_hi)
    np.testing.assert_array_equal(replica.leaf_cat, oracle.leaf_cat)
    np.testing.assert_array_equal(replica.leaf_adv, oracle.leaf_adv)
    assert repk.n_shards == k and len(repk.shard_wall_s) == k


# ---------------------------------------------------------------------------
# Deterministic end-to-end paths
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_sharded_buffers_match_single_stream(k):
    """Contiguous split + shard-id-ordered merge reproduces the exact
    buffered block contents of single-stream ingestion, row for row."""
    _, records, _, base = _frozen(7)
    oracle = replicate_tree(base)
    buf1 = BlockBuffers.for_tree(oracle)
    LayoutEngine(oracle, backend="numpy").ingest(
        micro_batches(records, 53), buffers=buf1
    )
    replica = replicate_tree(base)
    bufk = BlockBuffers.for_tree(replica)
    sharded_ingest(
        LayoutEngine(replica, backend="numpy"), records, k, batch=53,
        buffers=bufk,
    )
    np.testing.assert_array_equal(bufk.sizes, buf1.sizes)
    for b in range(base.n_leaves):
        np.testing.assert_array_equal(bufk.block(b), buf1.block(b))


def test_shard_slices_cover_stream_contiguously():
    _, records, _, _ = _frozen(1)
    for k in (1, 3, 7):
        parts = shard_slices(records, k)
        assert len(parts) == k
        np.testing.assert_array_equal(np.concatenate(parts), records)
    with pytest.raises(ValueError, match="n_shards"):
        shard_slices(records, 0)


def test_shard_state_pickles_and_roundtrips_npz(tmp_path):
    """Process-pool and cross-host shipping: pure-numpy state survives
    pickle and npz round trips bit-identically, chunks and window-stat
    partials included."""
    schema, records, _, base = _frozen(3)
    rng = np.random.default_rng(3)
    work = qry.Workload(
        schema, tuple(random_query(schema, rng) for _ in range(3))
    )
    probe = LayoutEngine(base, backend="numpy").observation_probe(work)
    (state,) = _shard_states(
        base, records, [0, records.shape[0]], collect_blocks=True,
        probe=probe,
    )
    assert state.obs.capacity == records.shape[0] * len(work)
    clone = pickle.loads(pickle.dumps(state))
    assert states_bit_identical(clone, state)
    assert clone.obs == state.obs

    path = str(tmp_path / "shard.npz")
    state.save(path)
    loaded = ShardState.load(path)
    assert states_bit_identical(loaded, state)
    assert loaded.shard_ids == state.shard_ids
    assert loaded.n_records == state.n_records
    assert loaded.obs == state.obs
    assert sorted(loaded.chunks) == sorted(state.chunks)
    for b in state.chunks:
        for (sid_a, rows_a), (sid_b, rows_b) in zip(
            state.chunks[b], loaded.chunks[b]
        ):
            assert sid_a == sid_b
            np.testing.assert_array_equal(rows_a, rows_b)


def test_merge_rejects_duplicates_and_mismatched_trees():
    _, records, _, base = _frozen(5)
    n = records.shape[0]
    a, b = _shard_states(base, records, [0, n // 2, n])
    with pytest.raises(ValueError, match="merged twice"):
        a.merge(a)
    _, records9, _, other = _frozen(9)
    (c,) = _shard_states(other, records9, [0, records9.shape[0]])
    if c.n_leaves != a.n_leaves or c.lo.shape != a.lo.shape:
        with pytest.raises(ValueError, match="different trees"):
            a.merge(c)
    coord = MergeCoordinator(base)
    with pytest.raises(ValueError, match="no shard states"):
        _ = coord.merged


def test_coordinator_publish_matches_engine_tighten():
    """publish() goes through IncrementalTightener.apply: descriptions and
    the desc-version bump are exactly the single-stream ones."""
    from repro.engine import plan as planlib

    _, records, _, base = _frozen(11)
    oracle = replicate_tree(base)
    bids = oracle.route(records)
    oracle.tighten(records, bids)

    replica = replicate_tree(base)
    v0 = planlib.desc_version(replica)
    coord = MergeCoordinator(replica)
    for s in _shard_states(base, records, [0, 140, 300, records.shape[0]]):
        coord.add(s)
    sizes = coord.publish()
    assert planlib.desc_version(replica) == v0 + 1
    np.testing.assert_array_equal(
        sizes, np.bincount(bids, minlength=base.n_leaves)
    )
    np.testing.assert_array_equal(replica.leaf_lo, oracle.leaf_lo)
    np.testing.assert_array_equal(replica.leaf_hi, oracle.leaf_hi)
    np.testing.assert_array_equal(replica.leaf_cat, oracle.leaf_cat)
    np.testing.assert_array_equal(replica.leaf_adv, oracle.leaf_adv)


def test_service_ingest_sharded_hot_publishes():
    schema, records, cuts, _ = _frozen(13)
    rng = np.random.default_rng(13)
    work = qry.Workload(
        schema, tuple(random_query(schema, rng) for _ in range(4))
    )
    svc = LayoutService.build(
        records, work, strategy="greedy", backend="numpy", cuts=cuts,
        min_block=30,
    )
    svc2 = LayoutService.build(
        records, work, strategy="greedy", backend="numpy", cuts=cuts,
        min_block=30,
    )
    hits_before = svc.query_hits(work, backend="numpy")
    with pytest.warns(PerformanceWarning):  # thread executor, GIL-bound
        rep = svc.ingest(
            records,
            IngestOptions(shards=4, batch=97, executor="thread"),
        )
    rep2 = svc2.ingest(micro_batches(records, 97))
    assert rep.n_records == rep2.n_records == records.shape[0]
    np.testing.assert_array_equal(rep.block_sizes, rep2.block_sizes)
    np.testing.assert_array_equal(svc.tree.leaf_lo, svc2.tree.leaf_lo)
    np.testing.assert_array_equal(svc.tree.leaf_hi, svc2.tree.leaf_hi)
    # the tightening was published: query plans refreshed, hits only prune
    hits_after = svc.query_hits(work, backend="numpy")
    assert (hits_after <= hits_before).all()
    np.testing.assert_array_equal(
        hits_after, svc2.query_hits(work, backend="numpy")
    )
    assert svc.generation == 1  # tighten publishes in place, no new gen


def test_sharded_ingest_tighten_false_leaves_tree_untouched():
    """Same contract as engine.ingest(tighten=False): buffers fill and
    counts report, but descriptions and desc version don't move."""
    from repro.engine import plan as planlib

    _, records, _, base = _frozen(19)
    replica = replicate_tree(base)
    lo0, hi0 = replica.leaf_lo.copy(), replica.leaf_hi.copy()
    v0 = planlib.desc_version(replica)
    buf = BlockBuffers.for_tree(replica)
    rep = sharded_ingest(
        LayoutEngine(replica, backend="numpy"), records, 3, batch=71,
        buffers=buf, tighten=False,
    )
    bids = base.route(records)
    np.testing.assert_array_equal(
        rep.block_sizes, np.bincount(bids, minlength=base.n_leaves)
    )
    np.testing.assert_array_equal(buf.sizes, rep.block_sizes)
    np.testing.assert_array_equal(replica.leaf_lo, lo0)
    np.testing.assert_array_equal(replica.leaf_hi, hi0)
    assert planlib.desc_version(replica) == v0


@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_sharded_window_stats_bit_identical_to_single_stream(k):
    """Drift accounting under sharding: the merged Eq. 1 WindowStat
    partials equal the single-stream per-batch totals bit for bit (exact
    int sums against one replicated ObservationProbe)."""
    schema, records, _, base = _frozen(21)
    rng = np.random.default_rng(21)
    work = qry.Workload(
        schema, tuple(random_query(schema, rng) for _ in range(6))
    )
    rep1 = LayoutEngine(replicate_tree(base), backend="numpy").ingest(
        micro_batches(records, 67), observe=work
    )
    assert rep1.observation is not None and rep1.observation.capacity > 0
    repk = sharded_ingest(
        LayoutEngine(replicate_tree(base), backend="numpy"), records, k,
        batch=67, observe=work,
    )
    assert repk.observation == rep1.observation
    # the probe itself is exact: totals match a from-scratch Eq. 1 count
    eng = LayoutEngine(replicate_tree(base), backend="numpy")
    per_leaf = eng.query_hits(work).sum(axis=1).astype(np.int64)
    want = int(per_leaf[eng.route(records)].sum())
    assert rep1.observation.scanned_tuples == want


def test_service_ingest_sharded_detects_stale_generation():
    """A hot swap while shards are routing must not let the merged
    tightening silently mutate the outgoing tree: the publish is skipped
    and the report says so."""
    from repro.engine import plan as planlib
    from repro.service import build_layout

    schema, records, cuts, _ = _frozen(23)
    rng = np.random.default_rng(23)
    work = qry.Workload(
        schema, tuple(random_query(schema, rng) for _ in range(4))
    )
    svc = LayoutService.build(
        records, work, strategy="greedy", backend="numpy", cuts=cuts,
        min_block=30,
    )
    racing = build_layout(
        records, work, strategy="greedy", cuts=cuts, min_block=20
    )

    class SwapBetweenRouteAndPublish:
        """Executor whose map() completes the shards, then swaps."""

        def map(self, fn, *its):
            out = list(map(fn, *its))
            svc.swap(racing)
            return out

    old_tree = svc.tree
    lo0, hi0 = old_tree.leaf_lo.copy(), old_tree.leaf_hi.copy()
    v0 = planlib.desc_version(old_tree)
    rep = svc.ingest(
        records,
        IngestOptions(shards=3, batch=64,
                      executor=SwapBetweenRouteAndPublish()),
    )
    assert rep.stale_generation and not rep.published
    # neither the outgoing nor the new live tree was mutated…
    np.testing.assert_array_equal(old_tree.leaf_lo, lo0)
    np.testing.assert_array_equal(old_tree.leaf_hi, hi0)
    assert planlib.desc_version(old_tree) == v0
    assert svc.tree is racing.tree
    # …but the run's aggregates are still reported
    bids = old_tree.route(records)
    np.testing.assert_array_equal(
        rep.block_sizes, np.bincount(bids, minlength=old_tree.n_leaves)
    )
    # a run with no interference still publishes
    with pytest.warns(PerformanceWarning):
        rep2 = svc.ingest(
            records,
            IngestOptions(shards=3, batch=64, executor="thread"),
        )
    assert rep2.published and not rep2.stale_generation


def test_sharded_ingest_zero_retraces_when_warm():
    """Every shard reuses the same compiled plans: with the fused-ingest
    padding buckets pre-warmed, a k-shard run performs zero retraces."""
    from repro.engine import plan as planlib
    from repro.engine.sharded import warm_sizes

    _, records, _, base = _frozen(17)
    replica = replicate_tree(base)
    eng = LayoutEngine(replica, backend="jax")
    n, k, batch = records.shape[0], 4, 64
    eng.warm_ingest(warm_sizes(n, k, batch))
    traces0 = sum(planlib.trace_counts().values())
    rep = sharded_ingest(eng, records, k, batch=batch)
    assert sum(planlib.trace_counts().values()) == traces0
    assert rep.traces == {}


# ---------------------------------------------------------------------------
# launch/ingest CLI helpers
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mean_batch", [1, 2, 7, 2048])
def test_batch_sizes_covers_stream_for_any_mean(mean_batch):
    """mean_batch=1 used to raise (rng.integers(1, 1)); every mean must
    produce positive sizes that sum to the stream length."""
    from repro.launch.ingest import batch_sizes

    sizes = batch_sizes(1000, mean_batch, seed=0)
    assert sum(sizes) == 1000
    assert all(s >= 1 for s in sizes)
    if mean_batch == 1:
        assert sizes == [1] * 1000
