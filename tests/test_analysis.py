"""qdlint tests: per-checker fixture corpus (one true-positive and one
must-not-flag case per rule), suppression semantics, baseline round-trip,
fingerprint stability, the CLI self-test, and the repo-wide acceptance
pin (src/ is qdlint-clean)."""

import json
import pathlib

import pytest

from repro.analysis import (
    CHECKER_CODES,
    DEFAULT_BASELINE,
    analyze_file,
    load_baseline,
    main,
    parse_module,
    run,
    self_test,
    write_baseline,
)
from repro.analysis.core import Finding

REPO = pathlib.Path(__file__).resolve().parent.parent
FIXTURES = REPO / "src" / "repro" / "analysis" / "fixtures"


# ---------------------------------------------------------------------------
# Per-checker fixtures: each rule fires on its true positive and stays
# silent on the idiomatic twin.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("code", CHECKER_CODES)
def test_checker_fires_on_true_positive(code):
    result = analyze_file(FIXTURES / f"{code.lower()}_tp.py")
    assert result.findings, f"{code} fixture produced no findings"
    assert {f.code for f in result.findings} == {code}
    for f in result.findings:
        assert f.line > 0 and f.message


@pytest.mark.parametrize("code", CHECKER_CODES)
def test_checker_silent_on_idiomatic_code(code):
    result = analyze_file(FIXTURES / f"{code.lower()}_ok.py")
    assert result.findings == [], [
        f.render() for f in result.findings
    ]


def test_lock_discipline_details():
    result = analyze_file(FIXTURES / "qd001_tp.py")
    # both the unlocked write (bump) and the unlocked read (value) flag
    symbols = {f.symbol for f in result.findings}
    assert symbols == {"Counter.bump", "Counter.value"}


def test_swap_guard_allows_lockfree_reads():
    tp = analyze_file(FIXTURES / "qd005_tp.py")
    # exactly the unlocked *write* fires; the lock-free read on the next
    # line is the sanctioned atomic-snapshot pattern
    assert len(tp.findings) == 1
    assert "assigned without holding" in tp.findings[0].message


# ---------------------------------------------------------------------------
# Suppressions
# ---------------------------------------------------------------------------
def test_suppression_with_reason_silences_and_is_reported():
    result = analyze_file(FIXTURES / "suppress_ok.py")
    assert result.findings == []
    assert len(result.suppressed) == 1
    assert result.suppressed[0].code == "QD001"


def test_suppression_without_reason_is_inert():
    result = analyze_file(FIXTURES / "suppress_noreason.py")
    assert [f.code for f in result.findings] == ["QD001"]
    assert result.suppressed == []


# ---------------------------------------------------------------------------
# Annotation parsing
# ---------------------------------------------------------------------------
def test_guard_annotation_accepts_trailing_prose(tmp_path):
    mod = tmp_path / "m.py"
    mod.write_text(
        "import threading\n\n\n"
        "class C:\n"
        "    def __init__(self):\n"
        "        self._lock = threading.Lock()\n"
        "        self._x = 0  # guarded by: self._lock -- ring head\n\n"
        "    def peek(self):\n"
        "        return self._x\n"
    )
    info = parse_module(mod)
    (locks, kind), = (info.guards[v] for v in (7,))
    assert locks == ("self._lock",) and kind == "guard"
    result = analyze_file(mod)
    assert [f.code for f in result.findings] == ["QD001"]


def test_constructor_and_holds_lock_are_exempt(tmp_path):
    mod = tmp_path / "m.py"
    mod.write_text(
        "import threading\n\n\n"
        "class C:\n"
        "    def __init__(self):\n"
        "        self._lock = threading.Lock()\n"
        "        self._x = 0  # guarded by: self._lock\n"
        "        self._x += 1\n\n"
        "    def _bump(self):  # qdlint: holds-lock\n"
        "        self._x += 1\n"
    )
    assert analyze_file(mod).findings == []


# ---------------------------------------------------------------------------
# Baseline round-trip and fingerprints
# ---------------------------------------------------------------------------
def test_baseline_round_trip(tmp_path):
    target = FIXTURES / "qd001_tp.py"
    fresh = run([target])
    assert fresh.findings and not fresh.baselined
    baseline = tmp_path / "baseline.json"
    write_baseline(fresh.findings, baseline)
    doc = json.loads(baseline.read_text())
    assert doc["version"] == 1 and len(doc["findings"]) == len(
        fresh.findings
    )
    absorbed = run([target], baseline=baseline)
    assert absorbed.findings == []
    assert len(absorbed.baselined) == len(fresh.findings)
    # each fingerprint absorbs exactly one occurrence
    assert sum(load_baseline(baseline).values()) == len(fresh.findings)


def test_fingerprint_is_line_number_free():
    a = Finding("QD001", "p.py", 10, 0, "C.m", "msg")
    b = Finding("QD001", "p.py", 99, 4, "C.m", "msg")
    assert a.fingerprint() == b.fingerprint()
    c = Finding("QD002", "p.py", 10, 0, "C.m", "msg")
    assert c.fingerprint() != a.fingerprint()


def test_missing_baseline_is_empty():
    assert load_baseline(FIXTURES / "no_such_baseline.json") == {}


# ---------------------------------------------------------------------------
# CLI and meta
# ---------------------------------------------------------------------------
def test_self_test_passes():
    assert self_test(verbose=False)


def test_cli_exit_codes(tmp_path, capsys):
    assert main([str(FIXTURES / "qd001_ok.py")]) == 0
    assert main([str(FIXTURES / "qd001_tp.py")]) == 1
    assert main(["--self-test"]) == 0
    assert main([str(tmp_path / "nope")]) == 2
    report = tmp_path / "report.json"
    code = main([
        str(FIXTURES / "qd002_tp.py"), "--format", "json",
        "--output", str(report),
    ])
    assert code == 1
    doc = json.loads(report.read_text())
    assert doc["counts"]["QD002"] == len(doc["findings"]) >= 1
    capsys.readouterr()


def test_repo_sources_are_qdlint_clean():
    """The acceptance pin: src/ has zero non-baselined findings."""
    report = run([REPO / "src"], baseline=REPO / DEFAULT_BASELINE)
    assert report.findings == [], "\n".join(
        f.render() for f in report.findings
    )
    assert report.files > 50  # the scan actually covered the tree
