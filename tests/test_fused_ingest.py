"""Fused single-pass ingestion: bit-identity properties and lifecycle.

The fused kernels (``kernels/fused_ingest.py``, the fused jax jit, and the
numpy reference) must be indistinguishable from the legacy two-pass
route-then-tighten path — same block ids, same tightened descriptions,
same per-block counts — across every backend, batch size / padding
bucket, random tree geometry, and shard count. These tests pin that
contract property-style, plus the autotune store round trip.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 containers without hypothesis
    from tests._hypothesis_shim import given, settings, st

from repro.core.qdtree import IncrementalTightener
from repro.engine import LayoutEngine, replicate_tree, sharded_ingest
from repro.engine import autotune
from repro.engine.sharded import micro_batches
from repro.kernels.ref import fused_ingest_ref
from tests.test_qdtree import random_tree, small_setup


def _frozen(seed=0):
    schema, records, cuts = small_setup(seed)
    rng = np.random.default_rng(seed)
    tree = random_tree(schema, cuts, records, rng)
    return schema, records, cuts, tree.freeze()


def _partials_identical(a, b) -> bool:
    return (
        np.array_equal(a.counts, b.counts)
        and np.array_equal(a.lo, b.lo)
        and np.array_equal(a.hi, b.hi)
        and np.array_equal(a.cat, b.cat)
        and np.array_equal(a.adv, b.adv)
    )


def _trees_identical(a, b) -> bool:
    return (
        np.array_equal(a.leaf_lo, b.leaf_lo)
        and np.array_equal(a.leaf_hi, b.leaf_hi)
        and np.array_equal(a.leaf_cat, b.leaf_cat)
        and np.array_equal(a.leaf_adv, b.leaf_adv)
    )


# ---------------------------------------------------------------------------
# Backend bit-identity vs the numpy oracle
# ---------------------------------------------------------------------------
@settings(max_examples=24, deadline=None)
@given(st.data())
def test_fused_step_matches_oracle(data):
    """Every backend's single fused pass reproduces the numpy reference
    bit for bit — bids, counts, lo/hi, categorical and adv masks — across
    random trees (leaf counts) and batch sizes (padding buckets)."""
    backend, opts = data.draw(
        st.sampled_from(
            [("numpy", {}), ("jax", {}), ("pallas", {"interpret": True})]
        ),
        label="backend",
    )
    seed = data.draw(st.integers(min_value=0, max_value=5), label="seed")
    _, records, _, base = _frozen(seed)
    # sizes straddle the pad buckets: tiny, LANE-1/LANE/LANE+1, full
    m = data.draw(
        st.sampled_from([1, 7, 63, 64, 65, 127, 128, 129, 500]),
        label="batch",
    )
    batch = records[: min(m, records.shape[0])]
    want_bids, want_partial = fused_ingest_ref(base, batch)
    eng = LayoutEngine(replicate_tree(base), backend=backend)
    bids, partial = eng.fused_step(batch, **opts)
    np.testing.assert_array_equal(bids, want_bids)
    assert _partials_identical(partial, want_partial)


@settings(max_examples=8, deadline=None)
@given(st.data())
def test_fused_ingest_bit_identical_to_two_pass(data):
    """``ingest(fused=True)`` and the legacy two-pass path land the exact
    same tightened tree and per-block counts for any micro-batch size."""
    seed = data.draw(st.integers(min_value=0, max_value=5), label="seed")
    batch = data.draw(st.sampled_from([17, 64, 200, 500]), label="batch")
    backend = data.draw(st.sampled_from(["numpy", "jax"]), label="backend")
    _, records, _, base = _frozen(seed)

    legacy = replicate_tree(base)
    rep2 = LayoutEngine(legacy, backend=backend).ingest(
        micro_batches(records, batch), fused=False
    )
    fused = replicate_tree(base)
    repf = LayoutEngine(fused, backend=backend).ingest(
        micro_batches(records, batch), fused=True
    )
    assert not rep2.fused and repf.fused
    np.testing.assert_array_equal(repf.block_sizes, rep2.block_sizes)
    assert _trees_identical(fused, legacy)


def test_fused_partial_merge_across_batches_matches_one_shot():
    """TightenPartial merge is the associative fold the sharded/streaming
    paths rely on: folding per-batch fused partials equals one fused pass
    over the whole stream."""
    _, records, _, base = _frozen(2)
    _, want = fused_ingest_ref(base, records)
    eng = LayoutEngine(replicate_tree(base), backend="numpy")
    acc = IncrementalTightener(eng.tree)
    for b in micro_batches(records, 77):
        _, part = eng.fused_step(b)
        acc.merge(part)
    assert _partials_identical(acc.as_partial(), want)


def test_fused_step_empty_batch_is_identity():
    _, records, _, base = _frozen(3)
    eng = LayoutEngine(replicate_tree(base), backend="numpy")
    bids, part = eng.fused_step(records[:0])
    assert bids.shape == (0,)
    assert int(part.counts.sum()) == 0
    # identity partial: merging it moves nothing
    acc = IncrementalTightener(eng.tree)
    acc.merge(part)
    assert int(acc.as_partial().counts.sum()) == 0


# ---------------------------------------------------------------------------
# Sharded fused ingestion
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_sharded_fused_bit_identical_to_single_stream(k):
    _, records, _, base = _frozen(5)
    oracle = replicate_tree(base)
    rep1 = LayoutEngine(oracle, backend="numpy").ingest(
        micro_batches(records, 64), fused=True
    )
    replica = replicate_tree(base)
    repk = sharded_ingest(
        LayoutEngine(replica, backend="numpy"), records, k, batch=64,
        fused=True,
    )
    np.testing.assert_array_equal(repk.block_sizes, rep1.block_sizes)
    assert _trees_identical(replica, oracle)


def test_sharded_process_executor_bit_identical():
    """``executor="process"`` spawn workers (pickled tree replica, rebuilt
    engine, worker-side warm) reproduce the thread path bit for bit."""
    _, records, _, base = _frozen(7)
    oracle = replicate_tree(base)
    LayoutEngine(oracle, backend="numpy").ingest(
        micro_batches(records, 97), fused=True
    )
    replica = replicate_tree(base)
    rep = sharded_ingest(
        LayoutEngine(replica, backend="numpy"), records, 2, batch=97,
        executor="process",
    )
    assert rep.published
    assert _trees_identical(replica, oracle)


def test_resident_process_pool_reused_across_runs():
    """Consecutive ``executor="process"`` runs share ONE resident spawn
    pool (workers pay interpreter start + jax import once, not per run);
    the pool never shrinks, and ``shutdown_process_pool`` retires it so
    the next run rebuilds lazily."""
    from repro.engine import process_pool, shutdown_process_pool

    _, records, _, base = _frozen(7)
    oracle = replicate_tree(base)
    LayoutEngine(oracle, backend="numpy").ingest(
        micro_batches(records, 97), fused=True
    )
    pool = process_pool(2)
    assert process_pool(1) is pool  # grow-only: smaller asks don't churn
    for _ in range(2):
        replica = replicate_tree(base)
        rep = sharded_ingest(
            LayoutEngine(replica, backend="numpy"), records, 2, batch=97,
            executor="process",
        )
        assert rep.published
        assert _trees_identical(replica, oracle)
        assert process_pool(1) is pool  # both runs rode the same pool
    shutdown_process_pool()
    fresh = process_pool(1)
    try:
        assert fresh is not pool
    finally:
        shutdown_process_pool()

    with pytest.raises(ValueError):
        process_pool(0)


def test_sharded_rejects_unknown_executor_string():
    _, records, _, base = _frozen(1)
    with pytest.raises(ValueError, match="executor"):
        sharded_ingest(
            LayoutEngine(replicate_tree(base), backend="numpy"),
            records, 2, executor="fork-bomb",
        )


# ---------------------------------------------------------------------------
# Autotune store
# ---------------------------------------------------------------------------
def test_autotune_store_roundtrip(tmp_path, monkeypatch):
    monkeypatch.setenv(
        "REPRO_AUTOTUNE_STORE", str(tmp_path / "tiles.json")
    )
    _, _, _, base = _frozen(0)
    geom = autotune.geometry_key(base)
    assert autotune.lookup("pallas", geom) is None
    cfg = autotune.TileConfig(
        tile_m=512, tile_l=128, interpret=True, records_per_s=123.0
    )
    autotune.record("pallas", geom, cfg)
    got = autotune.lookup("pallas", geom)
    assert got is not None
    assert (got.tile_m, got.tile_l, got.interpret) == (512, 128, True)
    # unknown geometry stays a miss
    assert autotune.lookup("pallas", "c9999-l9999") is None


def test_autotune_fused_validates_and_persists(tmp_path, monkeypatch):
    """A tiny sweep: every surviving candidate is bit-validated against
    the oracle, the fallback mode is recorded (never silent), and the
    chosen tiles land in the store for the backend to pick up."""
    monkeypatch.setenv(
        "REPRO_AUTOTUNE_STORE", str(tmp_path / "tiles.json")
    )
    _, records, _, base = _frozen(4)
    tune = autotune.autotune_fused(
        base, records[:256], tile_grid=((256, 128),), reps=1
    )
    assert tune["rows"] and all(
        r["mode"] in ("compiled", "interpret", "failed")
        for r in tune["rows"]
    )
    chosen = tune["chosen"]
    assert chosen is not None
    got = autotune.lookup("pallas", tune["geometry"])
    assert got is not None and got.tile_m == chosen["tile_m"]
