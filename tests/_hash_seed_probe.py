"""Subprocess probe: digest every merge/signature path that must be
hash-seed independent.

Run with different ``PYTHONHASHSEED`` values (tests/test_hash_determinism
drives it); the printed sha256 must be identical across seeds — str-keyed
set/dict iteration order is exactly what hash randomization perturbs, and
these outputs cross process boundaries in the spawn-worker fleet, where
every worker gets its own seed.  Covers the raw merge monoids (ShardState,
TrackerState, trace_delta, signature features) AND full
coordinator-cadence folds: k ∈ {1, 2, 4, 8} worker partials arriving in
permuted orders, folded into a live service's descriptions and fleet
tracker sketch.
"""

import hashlib

import numpy as np


def main() -> None:
    h = hashlib.sha256()

    # 1. trace_delta: str-keyed counter diff (the fixed set-union hazard)
    from repro.engine.plan import trace_delta

    before = {f"counter_{i}": i for i in range(20)}
    after = {f"counter_{i}": i * 2 for i in range(5, 25)}
    h.update(repr(trace_delta(before, after)).encode())

    # 2. k-way TrackerState merge + inference
    from repro.core import query as qry
    from repro.core.predicates import OP_GE, OP_LT, Column, Schema
    from repro.core.query import InAtom, Query, RangeAtom
    from repro.service.tracker import (
        TrackerConfig,
        WorkloadTracker,
        merge_states,
        query_signatures,
    )

    schema = Schema((
        Column("a", "numeric", 1000),
        Column("b", "numeric", 1000),
        Column("c", "categorical", 6),
    ))

    def workload(seed: int) -> qry.Workload:
        rng = np.random.default_rng(seed)
        queries = []
        for _ in range(6):
            d = int(rng.integers(0, 2))
            lo = int(rng.integers(0, 900))
            atoms = [RangeAtom(d, OP_GE, lo), RangeAtom(d, OP_LT, lo + 50)]
            if rng.random() < 0.5:
                vals = rng.choice(6, size=2, replace=False)
                atoms.append(InAtom(2, tuple(int(v) for v in sorted(vals))))
            queries.append(Query.conjunction(atoms))
        return qry.Workload(schema, tuple(queries))

    cfg = TrackerConfig(n_buckets=64, n_gens=8, decay=0.5)
    trackers = [WorkloadTracker(schema, cfg) for _ in range(4)]
    for i, tracker in enumerate(trackers):
        tracker.record(workload(100 + i))
        tracker.tick()
        tracker.record(workload(200 + i))
    merged = merge_states([t.snapshot() for t in trackers])
    tops = merged.top_signatures(16)
    h.update(repr(tops).encode())
    inferred = merged.infer_workload(schema, top_k=8, budget=16)
    h.update(repr(query_signatures(inferred, 64)).encode())

    # 3. replica signature features over the merged top signatures
    from repro.service.replica import signature_features

    for sig, weight in tops:
        feats = signature_features(sig, schema)
        h.update(np.ascontiguousarray(feats).tobytes())
        h.update(repr(float(weight)).encode())

    # 4. k-way ShardState merge (synthetic but exactly typed aggregates)
    from repro.engine.sharded import ShardState

    def shard(i: int) -> ShardState:
        rng = np.random.default_rng(1000 + i)
        L, D, B, A = 8, 2, 4, 1
        return ShardState(
            shard_ids=(i,),
            n_leaves=L,
            counts=rng.integers(0, 100, L).astype(np.int64),
            lo=rng.integers(-50, 0, (L, D)).astype(np.int64),
            hi=rng.integers(1, 50, (L, D)).astype(np.int64),
            cat=rng.integers(0, 2, (L, B)).astype(bool),
            adv=rng.integers(0, 2, (L, A, 2)).astype(bool),
            n_batches=2,
            n_records=int(rng.integers(10, 50)),
            chunks={
                int(b): [(i, rng.integers(0, 9, (3, D)).astype(np.int32))]
                for b in range(i % 3 + 1)
            },
            wall_s=0.0,
        )

    folded = shard(0)
    for i in (1, 2, 3):
        folded = folded.merge(shard(i))
    h.update(repr(folded.shard_ids).encode())
    for arr in (folded.counts, folded.lo, folded.hi, folded.cat,
                folded.adv):
        h.update(np.ascontiguousarray(arr).tobytes())
    h.update(repr((folded.n_batches, folded.n_records)).encode())
    for bid in sorted(folded.chunks):
        for sid, rows in folded.chunks[bid]:
            h.update(repr((bid, sid)).encode())
            h.update(np.ascontiguousarray(rows).tobytes())

    # 5. coordinator-cadence folds: k worker partials + tracker deltas
    # arriving in an uneven (permuted) order, folded on an off-k cadence
    # into a real service — the published descriptions and the fleet
    # tracker sketch are the bytes that cross the fleet
    from repro.coordinator import FleetCoordinator
    from repro.data import datagen, workload as wl
    from repro.engine import LayoutEngine, replicate_tree
    from repro.engine.sharded import ShardIngestor, micro_batches
    from repro.service import LayoutService, build_layout

    schema5, records5 = datagen.make_tpch_like(1500, seed=5)
    work5, _ = wl.make_tpch_workload(schema5, n_per_template=2, seed=5)
    cuts5 = work5.candidate_cuts(max_adv=4)

    def worker_state(tree, rows):
        eng = LayoutEngine(replicate_tree(tree), backend="numpy")
        return ShardIngestor(eng, shard_id=0).run(micro_batches(rows, 97))

    for k, cadence, order_seed in ((1, 1, 0), (2, 1, 1), (4, 3, 2),
                                   (8, 5, 3)):
        # prefix-built tree: the full stream genuinely tightens it
        svc = LayoutService(build_layout(
            records5[:700], work5, strategy="greedy", cuts=cuts5,
            min_block=40, seed=5,
        ))
        coord = FleetCoordinator(svc, cadence=cadence)
        workers = [coord.register(f"w{i}") for i in range(min(k, 3))]
        states = [
            worker_state(svc.tree, p) for p in np.array_split(records5, k)
        ]
        for j, i in enumerate(
            np.random.default_rng(order_seed).permutation(k)
        ):
            t = svc.workload_tracker()
            t.record(qry.Workload(
                schema5, work5.queries[int(i) % len(work5.queries):][:2]
            ))
            coord.submit(
                workers[j % len(workers)],
                state=states[int(i)],
                tracker_state=t.drain_state(),
            )
        if coord.stats()["pending"] or coord.stats()["pending_tracker"]:
            coord.fold()
        tree5 = svc.tree
        for arr in (tree5.leaf_lo, tree5.leaf_hi, tree5.leaf_cat,
                    tree5.leaf_adv):
            h.update(np.ascontiguousarray(arr).tobytes())
        h.update(
            repr(coord.tracker.snapshot().top_signatures(16)).encode()
        )

    print(h.hexdigest())


if __name__ == "__main__":
    main()
