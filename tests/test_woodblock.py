"""WOODBLOCK (deep-RL construction): env legality, rewards, learning."""

import numpy as np

from repro.core import predicates as preds, rewards
from repro.core.woodblock.agent import WoodblockConfig, build_woodblock
from repro.core.woodblock.env import TreeEnv
from repro.core.woodblock.featurize import Featurizer
from tests.test_greedy import fig3_setup


def test_stopping_condition_legality():
    schema, records, work, cuts = fig3_setup(n=2_000)
    env = TreeEnv(records, work, cuts, min_block_sample=15)
    legal = env.legal_actions(
        __import__("repro.core.qdtree", fromlist=["singleton_tree"])
        .singleton_tree(schema, cuts, np.arange(records.shape[0]))
        .root
    )
    M = preds.eval_cuts(records, cuts)
    left = M.sum(axis=0)
    right = records.shape[0] - left
    np.testing.assert_array_equal(legal, (left >= 15) & (right >= 15))


def test_rewards_normalized():
    schema, records, work, cuts = fig3_setup(n=2_000)
    env = TreeEnv(records, work, cuts, min_block_sample=15)
    rng = np.random.default_rng(0)

    def random_policy(states, legals):
        acts = np.array(
            [rng.choice(np.nonzero(row)[0]) for row in legals], np.int64
        )
        return acts, np.zeros(len(acts)), np.zeros(len(acts))

    res = env.run_episode(random_policy, rng)
    assert res.transitions, "no cuts made"
    for t in res.transitions:
        assert 0.0 <= t.reward <= 1.0
    assert 0.0 <= res.scanned_fraction <= 1.0


def test_woodblock_finds_fig3_layout():
    """RL beats greedy on the paper's Fig-3 disjunction scenario."""
    from repro.core import greedy

    schema, records, work, cuts = fig3_setup(n=8_000)
    g = greedy.build_greedy(
        records, work, cuts, greedy.GreedyConfig(min_block=40)
    )
    g_stats = rewards.evaluate_layout(g.freeze(), records, work)

    cfg = WoodblockConfig(
        min_block_sample=40, n_iters=12, episodes_per_iter=4, seed=0
    )
    res = build_woodblock(records, work, cuts, cfg)
    assert res.best_scanned < 0.6 * g_stats.scanned_fraction, (
        res.best_scanned, g_stats.scanned_fraction,
    )


def test_learning_curve_improves(errorlog_small):
    schema, records, work, cuts = errorlog_small
    cfg = WoodblockConfig(
        min_block_sample=300, n_iters=8, episodes_per_iter=3, seed=1
    )
    res = build_woodblock(records, work, cuts, cfg)
    first = res.curve[0].best_scanned
    assert res.best_scanned <= first
    assert res.n_episodes == len(res.curve)
    # curve's best is monotonically non-increasing
    bests = [p.best_scanned for p in res.curve]
    assert all(b2 <= b1 + 1e-12 for b1, b2 in zip(bests, bests[1:]))


def test_featurizer_binary_encoding():
    from repro.core.qdtree import root_desc

    schema, records, work, cuts = fig3_setup(n=100)
    f = Featurizer(schema, cuts.n_adv)
    desc = root_desc(schema, cuts.n_adv)
    v = f(desc)
    assert v.shape == (f.dim,)
    assert set(np.unique(v)).issubset({0.0, 1.0})
    # restricting a bound changes the encoding
    desc2 = root_desc(schema, cuts.n_adv)
    desc2.hi[0] = 10
    assert not np.array_equal(f(desc2), v)
