import pytest

from repro.core import greedy
from repro.data import datagen
from repro.data import workload as wl


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (multi-device sharding sweeps)"
    )


@pytest.fixture(scope="session")
def tpch_small():
    schema, records = datagen.make_tpch_like(8_000, seed=0)
    work, labels = wl.make_tpch_workload(schema, n_per_template=2, seed=0)
    cuts = work.candidate_cuts(max_adv=4)
    return schema, records, work, cuts


@pytest.fixture(scope="session")
def tpch_tree(tpch_small):
    schema, records, work, cuts = tpch_small
    tree = greedy.build_greedy(
        records, work, cuts, greedy.GreedyConfig(min_block=250)
    )
    frozen = tree.freeze()
    bids = frozen.route(records)
    frozen.tighten(records, bids)
    return frozen, bids


@pytest.fixture(scope="session")
def errorlog_small():
    schema, records = datagen.make_errorlog_int(6_000, seed=1)
    work, _ = wl.make_errorlog_int_workload(schema, n_queries=60, seed=1)
    cuts = work.candidate_cuts()
    return schema, records, work, cuts
