"""Block store, elastic scheduler, and the qd-tree training pipeline."""

import numpy as np
import pytest

from repro.core import greedy, query as qry
from repro.data.blocks import BlockStore
from repro.data.pipeline import (
    ElasticBlockScheduler,
    PipelineConfig,
    QdTreePipeline,
    records_to_tokens,
)


@pytest.fixture(scope="module")
def store(tmp_path_factory, request):
    from repro.data import datagen, workload as wl

    schema, records = datagen.make_errorlog_int(5_000, seed=0)
    work, _ = wl.make_errorlog_int_workload(schema, n_queries=40, seed=0)
    cuts = work.candidate_cuts()
    tree = greedy.build_greedy(
        records, work, cuts, greedy.GreedyConfig(min_block=250)
    )
    path = tmp_path_factory.mktemp("blocks")
    return (
        BlockStore.create(path, tree.freeze(), records),
        schema, records, work,
    )


def test_scan_query_exact(store):
    bs, schema, records, work = store
    for q in work.queries[:10]:
        res = bs.scan_query(q)
        truth = records[q.evaluate(records, schema)]
        got = res.rows[np.lexsort(res.rows.T)] if res.rows.size else res.rows
        want = truth[np.lexsort(truth.T)] if truth.size else truth
        np.testing.assert_array_equal(got, want)
        assert res.blocks_read <= bs.tree.n_leaves
        assert res.bytes_read == res.rows_scanned * bs.row_bytes


def test_scan_skips_blocks(store):
    bs, schema, records, work = store
    reads = [bs.scan_query(q).blocks_read for q in work.queries[:30]]
    # highly selective errorlog queries must skip most blocks
    assert np.mean(reads) < 0.5 * bs.tree.n_leaves


def test_store_roundtrip(store, tmp_path):
    bs, schema, records, work = store
    reopened = BlockStore.open(bs.root)
    assert reopened.tree.n_leaves == bs.tree.n_leaves
    q = work.queries[0]
    np.testing.assert_array_equal(
        np.sort(reopened.scan_query(q).rows, axis=0),
        np.sort(bs.scan_query(q).rows, axis=0),
    )


# ---------------------------------------------------------------------------
# elastic scheduler
# ---------------------------------------------------------------------------
def test_scheduler_work_stealing():
    s = ElasticBlockScheduler(list(range(10)), seed=0)
    w0 = [s.next_block(0) for _ in range(4)]
    w1 = [s.next_block(1) for _ in range(3)]
    lost = s.fail(0)  # worker 0 dies with 4 unacked blocks
    assert sorted(lost) == sorted(w0)
    # its blocks are re-queued at the front
    stolen = [s.next_block(1) for _ in range(4)]
    assert sorted(stolen) == sorted(w0)
    for b in w1 + stolen:
        s.ack(1, b)
    rest = []
    while True:
        b = s.next_block(1)
        if b is None or s.epoch > 0:
            break
        rest.append(b)
        s.ack(1, b)
    assert s.epoch == 1  # epoch advanced exactly once all acked


def test_scheduler_epoch_shuffles_deterministically():
    a = ElasticBlockScheduler(list(range(8)), seed=7)
    b = ElasticBlockScheduler(list(range(8)), seed=7)
    seq_a = [a.next_block(0) for _ in range(8)]
    seq_b = [b.next_block(0) for _ in range(8)]
    assert seq_a == seq_b
    assert sorted(seq_a) == list(range(8))


def test_scheduler_checkpoint_restore():
    s = ElasticBlockScheduler(list(range(6)), seed=1)
    done = [s.next_block(0) for _ in range(2)]
    for b in done:
        s.ack(0, b)
    inflight = s.next_block(0)
    st = s.state()
    s2 = ElasticBlockScheduler(list(range(6)), seed=1)
    s2.restore(st)
    # in-flight blocks come back as pending
    remaining = []
    while True:
        b = s2.next_block(0)
        if b is None or s2.epoch > st.epoch:
            break
        remaining.append(b)
        s2.ack(0, b)
    assert sorted(remaining + done) == list(range(6))
    assert inflight in remaining


# ---------------------------------------------------------------------------
# pipeline
# ---------------------------------------------------------------------------
def test_tokens_deterministic():
    rows = np.arange(12, dtype=np.int32).reshape(3, 4)
    a = records_to_tokens(rows, 16, 1000, seed=1)
    b = records_to_tokens(rows, 16, 1000, seed=1)
    np.testing.assert_array_equal(a, b)
    c = records_to_tokens(rows, 16, 1000, seed=2)
    assert not np.array_equal(a, c)


def test_pipeline_curation_skips_blocks(store):
    bs, schema, records, work = store
    d = schema.dim
    curation = qry.Query.conjunction([
        qry.InAtom(d("event_type"), (0,)),
        qry.InAtom(d("is_valid"), (1,)),
    ])
    cfg = PipelineConfig(
        batch_size=16, seq_len=8, vocab=100, curation_query=curation
    )
    pipe = QdTreePipeline(bs, cfg)
    assert pipe.blocks_skipped > 0
    toks, labels = next(iter(pipe))
    assert toks.shape == (16, 8) and labels.shape == (16, 8)
    assert (toks >= 0).all() and (toks < 100).all()


def test_pipeline_batches_only_matching_records(store):
    bs, schema, records, work = store
    d = schema.dim
    curation = qry.Query.conjunction([qry.InAtom(d("event_type"), (2,))])
    n_match = int(curation.evaluate(records, schema).sum())
    cfg = PipelineConfig(
        batch_size=8, seq_len=4, vocab=50, curation_query=curation,
        epochs=1,
    )
    pipe = QdTreePipeline(bs, cfg)
    total = sum(t.shape[0] for t, _ in pipe)
    # every full batch of 8 comes from matching rows only
    assert total == (n_match // 8) * 8
