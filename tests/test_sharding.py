"""Sharding rules, fitted (divisibility-safe) resolution, and multi-device
numerics — the multi-device cases run in subprocesses so they can set
``xla_force_host_platform_device_count`` before jax initializes."""

import os
import subprocess
import sys
import textwrap

import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding.specs import Rules, fitted_spec


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape

    @property
    def axis_names(self):
        return tuple(self.shape)


RULES = Rules.make()
MESH = _FakeMesh({"data": 16, "model": 16})
POD_MESH = _FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_fitted_divisible():
    spec = fitted_spec((4096, 64, 128), ("fsdp", "heads", None), MESH, RULES)
    assert spec == P("data", "model", None)


def test_fitted_prunes_nondividing():
    # kv=2 can't shard 16 ways → replicated
    spec = fitted_spec((4096, 2, 128), ("fsdp", "kv_heads", None), MESH, RULES)
    assert spec == P("data", None, None)
    # whisper vocab 51865 % 16 != 0
    spec = fitted_spec((51865, 768), ("vocab", "embed"), MESH, RULES)
    assert spec == P(None, None)


def test_fitted_prefix_of_multi_axis():
    Rules.make({"cache_seq": ("pod", "data", "model")})
    # 524288 divides by all 512
    spec = fitted_spec(
        (9, 1, 8, 524288, 128),
        ("layers", "batch", "kv_heads", "cache_seq", None),
        POD_MESH,
        Rules.make({
            "cache_seq": ("pod", "data", "model"), "batch": None,
        }),
    )
    assert spec == P(None, None, None, ("pod", "data", "model"), None)
    # a dim of 6 over (pod=2, data=16): keeps pod only
    spec2 = fitted_spec((6,), ("batch",), POD_MESH, RULES)
    assert spec2 == P("pod")


def test_fitted_no_axis_reuse():
    # batch uses (pod, data); a later fsdp dim can't reuse data... it can,
    # actually — different dims of the same tensor may not reuse an axis
    spec = fitted_spec(
        (32, 4096), ("batch", "fsdp"), POD_MESH, RULES
    )
    assert spec == P(("pod", "data"), None)


def test_rules_drop_missing_axes():
    mesh_1d = _FakeMesh({"data": 4})
    spec = fitted_spec((64, 64), ("fsdp", "mlp"), mesh_1d, RULES)
    assert spec == P("data", None)


def _run(src: str, devices: int = 8):
    code = textwrap.dedent(src)
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
        PYTHONPATH="src",
        JAX_PLATFORMS="cpu",
    )
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, env=env, timeout=480,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """(2 data × 2 model) sharded train step ≡ 1-device numerics."""
    _run("""
    import jax, numpy as np, jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch.mesh import make_smoke_mesh
    from repro.sharding.specs import Rules
    from repro.train import steps
    from repro.train.optimizer import AdamWConfig
    from repro.train.schedule import ScheduleConfig

    cfg = get_config("qwen1.5-32b").reduced(n_layers=2)
    ocfg, scfg = AdamWConfig(), ScheduleConfig(peak_lr=1e-3, warmup_steps=2)
    rng = np.random.default_rng(0)
    t = rng.integers(0, cfg.vocab, (8, 33)).astype(np.int32)
    batch = {"tokens": jnp.asarray(t[:, :-1]), "labels": jnp.asarray(t[:, 1:])}
    bs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}
    bspec = {"tokens": ("batch", None), "labels": ("batch", None)}

    # single device
    s0 = steps.init_train_state(jax.random.PRNGKey(0), cfg, ocfg)
    s1, m1 = jax.jit(lambda s, b: steps.train_step(s, b, cfg, ocfg, scfg))(s0, batch)

    # sharded
    mesh = make_smoke_mesh(data=2, model=2)
    rules = Rules.make()
    step, shapes, ssh, bsh = steps.jit_train_step(
        cfg, ocfg, scfg, mesh, rules, bs, bspec)
    s0b = steps.init_train_state(jax.random.PRNGKey(0), cfg, ocfg)
    s0b = jax.device_put(s0b, ssh)
    s2, m2 = step(s0b, jax.device_put(batch, bsh))
    print("loss", float(m1["loss"]), float(m2["loss"]))
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-3)
    print("SHARDED OK")
    """)


@pytest.mark.slow
def test_compressed_pod_sync_tracks_uncompressed():
    """int8 error-feedback pod sync: loss curve tracks plain training."""
    _run("""
    import jax, numpy as np, jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch.mesh import make_smoke_mesh
    from repro.sharding.specs import Rules
    from repro.train import steps
    from repro.train.optimizer import AdamWConfig
    from repro.train.schedule import ScheduleConfig

    cfg = get_config("qwen1.5-32b").reduced(n_layers=2)
    ocfg = AdamWConfig()
    scfg = ScheduleConfig(peak_lr=1e-3, warmup_steps=2)
    mesh = make_smoke_mesh(data=2, model=2, pod=2)
    rules = Rules.make()
    rng = np.random.default_rng(0)

    def batches():
        while True:
            t = rng.integers(0, cfg.vocab, (8, 33)).astype(np.int32)
            yield {"tokens": jnp.asarray(t[:, :-1]),
                   "labels": jnp.asarray(t[:, 1:])}

    bs = {"tokens": jax.ShapeDtypeStruct((8, 32), np.int32),
          "labels": jax.ShapeDtypeStruct((8, 32), np.int32)}
    bspec = {"tokens": ("batch", None), "labels": ("batch", None)}

    losses = {}
    for compress in (False, True):
        step, shapes, ssh, bsh = steps.jit_train_step(
            cfg, ocfg, scfg, mesh, rules, bs, bspec, compress=compress)
        st = steps.init_train_state(jax.random.PRNGKey(0), cfg, ocfg,
                                    compress=compress)
        st = jax.device_put(st, ssh)
        rng = np.random.default_rng(0)
        it = batches()
        ls = []
        for _ in range(10):
            st, m = step(st, jax.device_put(next(it), bsh))
            ls.append(float(m["loss"]))
        losses[compress] = ls
    print("plain:", losses[False][-1], "compressed:", losses[True][-1])
    assert losses[True][-1] < losses[True][0]
    assert abs(losses[True][-1] - losses[False][-1]) < 0.15
    print("COMPRESS OK")
    """)


@pytest.mark.slow
def test_elastic_checkpoint_reshard():
    """Checkpoint on a (2,2) mesh restores onto (4,1) and 1-device."""
    _run("""
    import jax, numpy as np, jax.numpy as jnp, tempfile
    from repro.configs import get_config
    from repro.launch.mesh import make_smoke_mesh
    from repro.sharding.specs import Rules, fitted_shardings
    from repro.train import steps, checkpoint as ckpt
    from repro.train.optimizer import AdamWConfig

    cfg = get_config("qwen1.5-32b").reduced(n_layers=2)
    ocfg = AdamWConfig()
    rules = Rules.make()
    mesh_a = make_smoke_mesh(data=2, model=2)
    shapes, specs = steps.abstract_state(cfg, ocfg)
    sh_a = fitted_shardings(shapes, specs, mesh_a, rules)
    st = steps.init_train_state(jax.random.PRNGKey(0), cfg, ocfg)
    st = jax.device_put(st, sh_a)
    with tempfile.TemporaryDirectory() as td:
        ckpt.save_checkpoint(td, 5, st)
        mesh_b = make_smoke_mesh(data=4, model=1)
        sh_b = fitted_shardings(shapes, specs, mesh_b, rules)
        rb = ckpt.restore_checkpoint(td, 5, shapes, sh_b)
        rc = ckpt.restore_checkpoint(td, 5, shapes)  # default device
        for a, b, c in zip(jax.tree.leaves(st), jax.tree.leaves(rb),
                           jax.tree.leaves(rc)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
    print("ELASTIC OK")
    """)


@pytest.mark.slow
def test_moe_ep_matches_local_oracle():
    """shard_map EP MoE ≡ single-shard oracle (bit-exact, with grads)."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import ARCHS
    from repro.models import mlp
    from repro.sharding.specs import Rules, use_mesh, fitted_shardings
    from repro.launch.mesh import make_smoke_mesh

    cfg = ARCHS["qwen3-moe-235b-a22b"].reduced(
        n_experts=4, top_k=2, capacity_factor=8.0)
    params, specs = mlp.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model))
    y_ref, aux_ref = jax.jit(lambda p, x: mlp.moe_forward(p, x, cfg))(params, x)
    mesh = make_smoke_mesh(data=2, model=2, pod=2)
    rules = Rules.make()
    def f(p, xx):
        with use_mesh(mesh, rules):
            return mlp.moe_forward(p, xx, cfg)
    y_ep, aux_ep = jax.jit(f)(params, x)
    assert float(jnp.max(jnp.abs(y_ep - y_ref))) < 2e-5
    assert abs(float(aux_ref) - float(aux_ep)) < 1e-6
    g = jax.jit(jax.grad(lambda p: (f(p, x)[0]**2).mean()))(params)
    gn = sum(float(jnp.sum(v**2)) for v in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
    print("MOE EP OK")
    """)
