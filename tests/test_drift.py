"""Drift-triggered auto-rebuild tests: WindowStat merge algebra,
per-batch ingest observation accounting, DriftMonitor trigger policy
(absolute/relative thresholds, hysteresis, cooldown, rebaseline),
RecordReservoir recency semantics, and the AutoRebuilder loop (trigger →
background rebuild → CAS deploy, single in-flight rebuild)."""

import threading
import types
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 containers without hypothesis
    from tests._hypothesis_shim import given, settings, st

from repro.core import query as qry
from repro.core.predicates import OP_GE, OP_LT, Column, Schema
from repro.core.query import Query, RangeAtom
from repro.engine import LayoutEngine, WindowStat
from repro.service import (
    AutoRebuilder,
    DriftConfig,
    DriftMonitor,
    IngestOptions,
    LayoutService,
    RebuildPolicy,
    RecordReservoir,
    build_layout,
)


def _stat(scanned: int, capacity: int) -> WindowStat:
    return WindowStat(
        scanned_tuples=scanned, capacity=capacity, n_records=capacity
    )


def _drift_setup(seed=0, rows=6000):
    """Two orthogonal range workloads over a 2-column schema: a tree
    built for queries on column 0 cannot skip for queries on column 1."""
    rng = np.random.default_rng(seed)
    schema = Schema((
        Column("a", "numeric", 1000), Column("b", "numeric", 1000),
    ))
    records = rng.integers(0, 1000, (rows, 2)).astype(np.int32)

    def workload(dim, wseed, n=8, width=60):
        wrng = np.random.default_rng(wseed)
        qs = tuple(
            Query.conjunction([
                RangeAtom(dim, OP_GE, lo), RangeAtom(dim, OP_LT, lo + width),
            ])
            for lo in (
                int(wrng.integers(0, 1000 - width)) for _ in range(n)
            )
        )
        return qry.Workload(schema, qs)

    return records, workload(0, seed + 1), workload(1, seed + 2)


# ---------------------------------------------------------------------------
# WindowStat algebra
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(st.data())
def test_window_stat_merge_associative_commutative(data):
    stats = [
        _stat(
            data.draw(st.integers(min_value=0, max_value=10**9), label="s"),
            data.draw(st.integers(min_value=0, max_value=10**9), label="c"),
        )
        for _ in range(3)
    ]
    a, b, c = stats
    assert a.merge(b).merge(c) == a.merge(b.merge(c))
    assert a.merge(b) == b.merge(a)
    assert a.merge(WindowStat()) == a  # identity element
    rt = WindowStat.from_array(a.merge(c).to_array())
    assert rt == a.merge(c)


def test_window_stat_fraction():
    assert _stat(25, 100).scanned_fraction == 0.25
    assert WindowStat().scanned_fraction == 0.0


# ---------------------------------------------------------------------------
# Engine-side observation accounting
# ---------------------------------------------------------------------------
def test_ingest_observation_matches_oracle_accounting():
    records, work_a, _ = _drift_setup(3)
    build = build_layout(records, work_a, min_block=150)
    eng = LayoutEngine(build.tree, backend="numpy")

    # oracle: per-leaf query-hit counts against the pre-ingest layout
    # (routing depends only on the frozen topology, so bids are stable)
    per_leaf = eng.query_hits(work_a).sum(axis=1).astype(np.int64)
    bids = eng.route(records)
    want_scanned = int(per_leaf[bids].sum())

    seen = []
    rep = eng.ingest(
        (records[s : s + 97] for s in range(0, records.shape[0], 97)),
        observe=work_a,
        on_observation=seen.append,
    )
    assert rep.observation.scanned_tuples == want_scanned
    assert rep.observation.n_records == records.shape[0]
    assert rep.observation.capacity == records.shape[0] * len(work_a)
    assert len(seen) == rep.n_batches
    folded = WindowStat()
    for s in seen:
        folded = folded.merge(s)
    assert folded == rep.observation
    # plain ingest (no observe) reports no observation
    assert (
        LayoutEngine(build_layout(records, work_a, min_block=150).tree,
                     backend="numpy")
        .ingest([records[:100]]).observation
        is None
    )


# ---------------------------------------------------------------------------
# DriftMonitor policy
# ---------------------------------------------------------------------------
def test_monitor_absolute_threshold_with_hysteresis():
    mon = DriftMonitor(DriftConfig(
        window=4, min_fill=1, abs_threshold=0.5, rel_degradation=None,
        hysteresis=2, cooldown=3,
    ))
    assert not mon.observe(_stat(10, 100)).triggered  # healthy
    d1 = mon.observe(_stat(95, 100))  # first breach: hysteresis holds it
    assert not d1.triggered and d1.breaches == 1 and d1.reason == "abs"
    d2 = mon.observe(_stat(95, 100))  # second consecutive breach: fire
    assert d2.triggered and d2.reason == "abs"
    # cooldown: the next 3 observations cannot trigger however bad
    for _ in range(3):
        d = mon.observe(_stat(100, 100))
        assert not d.triggered and d.reason == "cooldown"
    # after cooldown, hysteresis counts afresh
    assert not mon.observe(_stat(100, 100)).triggered
    assert mon.observe(_stat(100, 100)).triggered


def test_monitor_relative_degradation_and_rebaseline():
    mon = DriftMonitor(DriftConfig(
        window=2, min_fill=1, abs_threshold=None, rel_degradation=1.0,
        hysteresis=1, cooldown=0,
    ))
    mon.observe(_stat(10, 100))
    assert mon.best_rate == pytest.approx(0.10)
    # 0.15 < best * 2.0 — within tolerated degradation
    assert not mon.observe(_stat(20, 100)).triggered
    # window (0.2, 0.9 → 0.55) > 0.1 * 2 — degradation vs best-seen
    d = mon.observe(_stat(90, 100))
    assert d.triggered and d.reason == "rel"
    # rebaseline forgets the old best and refuses to fire while refilling
    mon.rebaseline()
    assert np.isnan(mon.best_rate) and mon.window_stat == WindowStat()
    d = mon.observe(_stat(90, 100))
    assert not d.triggered  # new baseline: 0.9 is the best we know
    assert mon.best_rate == pytest.approx(0.90)


def test_monitor_warmup_and_config_validation():
    mon = DriftMonitor(DriftConfig(
        window=8, min_fill=4, abs_threshold=0.1, rel_degradation=None,
        hysteresis=1, cooldown=0,
    ))
    for _ in range(3):
        d = mon.observe(_stat(100, 100))
        assert not d.triggered and d.reason == "warmup"
    assert mon.observe(_stat(100, 100)).triggered  # min_fill reached
    for bad in (
        dict(window=0),
        dict(min_fill=0),
        dict(min_fill=20, window=10),
        dict(hysteresis=0),
        dict(cooldown=-1),
        dict(abs_threshold=None, rel_degradation=None),
    ):
        with pytest.raises(ValueError):
            DriftConfig(**bad)


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_monitor_hysteresis_cooldown_invariants(data):
    """Policy invariants hold for arbitrary observation sequences: windowed
    rate is the exact fold of the last ``window`` stats, triggers imply
    ``hysteresis`` consecutive breaches, and no trigger lands within
    ``cooldown`` observations of the previous one."""
    cfg = DriftConfig(
        window=data.draw(st.integers(min_value=1, max_value=6), label="w"),
        min_fill=1,
        abs_threshold=0.5,
        rel_degradation=None,
        hysteresis=data.draw(st.integers(min_value=1, max_value=3),
                             label="h"),
        cooldown=data.draw(st.integers(min_value=0, max_value=4), label="c"),
    )
    mon = DriftMonitor(cfg)
    stats, decisions = [], []
    for _ in range(30):
        s = _stat(data.draw(
            st.integers(min_value=0, max_value=100), label="rate"
        ), 100)
        stats.append(s)
        decisions.append(mon.observe(s))

    last_trigger = None
    breach_run = 0
    for i, (s, d) in enumerate(zip(stats, decisions)):
        window = stats[max(0, i + 1 - cfg.window) : i + 1]
        folded = WindowStat()
        for w in window:
            folded = folded.merge(w)
        assert d.window_rate == folded.scanned_fraction  # exact fold
        in_cooldown = (
            last_trigger is not None and i - last_trigger <= cfg.cooldown
        )
        breached = (not in_cooldown) and folded.scanned_fraction > 0.5
        breach_run = breach_run + 1 if breached else 0
        if d.triggered:
            assert breach_run >= cfg.hysteresis  # hysteresis honored
            assert not in_cooldown  # cooldown honored
            last_trigger = i
            breach_run = 0


def test_monitor_is_deterministic():
    seq = [(_stat(s, 100)) for s in (5, 10, 80, 90, 95, 20, 99, 99, 99)]
    cfg = DriftConfig(window=3, min_fill=2, abs_threshold=0.6,
                      rel_degradation=2.0, hysteresis=2, cooldown=2)
    runs = []
    for _ in range(2):
        mon = DriftMonitor(cfg)
        # repr-compare: best_rate is NaN during warmup, and NaN != NaN
        runs.append([repr(mon.observe(s)) for s in seq])
    assert runs[0] == runs[1]


# ---------------------------------------------------------------------------
# RecordReservoir
# ---------------------------------------------------------------------------
def test_reservoir_keeps_most_recent_rows_in_order():
    res = RecordReservoir(capacity=10)
    rows = np.arange(37, dtype=np.int32).reshape(-1, 1)
    for s in range(0, 37, 4):  # batches of 4 with a tail of 1
        res.add(rows[s : s + 4])
    assert len(res) == 10 and res.records_seen == 37
    np.testing.assert_array_equal(res.snapshot()[:, 0], np.arange(27, 37))
    # one oversized batch: only its tail survives, still in order
    res.add(np.arange(100, 125, dtype=np.int32).reshape(-1, 1))
    np.testing.assert_array_equal(res.snapshot()[:, 0], np.arange(115, 125))
    res.clear()
    assert len(res) == 0 and res.snapshot().shape[0] == 0
    with pytest.raises(ValueError):
        RecordReservoir(0)


# ---------------------------------------------------------------------------
# AutoRebuilder loop
# ---------------------------------------------------------------------------
def test_auto_rebuilder_recovers_from_workload_shift():
    records, work_a, work_b = _drift_setup(7)
    svc = LayoutService.build(
        records[:2000], work_a, strategy="greedy", backend="numpy",
        min_block=100,
    )
    gen0 = svc.generation
    with svc.auto_rebuilder(RebuildPolicy(
        workload=work_a,
        drift=DriftConfig(window=4, min_fill=2, abs_threshold=0.5,
                          rel_degradation=None, hysteresis=2, cooldown=4),
        reservoir_capacity=4000,
        executor="sync",
        rebuild_kw=dict(min_block=100),
    )) as rebuilder:
        def batches(rs):
            for s in range(0, rs.shape[0], 500):
                yield rs[s : s + 500]

        rep_a = svc.ingest(
            batches(records[:3000]), IngestOptions(monitor=rebuilder)
        )
        assert rep_a.observation.scanned_fraction < 0.5
        assert svc.generation == gen0 and not rebuilder.events

        rebuilder.set_workload(work_b)  # the query distribution drifts
        svc.ingest(batches(records[3000:]), IngestOptions(monitor=rebuilder))
        assert rebuilder.rebuilds_deployed == 1
        (event,) = [e for e in rebuilder.events if e.deployed]
        assert event.report.swapped and event.decision.triggered
        assert svc.generation > gen0
        # the reservoir held recent records — the deployed tree skips the
        # NEW workload near-oracle-level
        recovered = svc.skip_stats(
            records, work_b, tighten=False
        ).scanned_fraction
        oracle = build_layout(
            records, work_b, min_block=100
        ).scanned_fraction
        assert recovered <= max(1.2 * oracle, oracle + 0.02)
        # the trigger came from the drift window, not the end of stream:
        # phase A was 6 healthy observations, hysteresis needs 2 breaches
        assert event.decision.observations <= 9
        # monitor was rebaselined after the deploy: the window only holds
        # post-swap observations
        assert rebuilder.monitor.window_stat.n_records <= 2500


def test_auto_rebuilder_single_inflight_and_skip_events():
    """Concurrent triggers while one rebuild runs must not stack rebuilds:
    exactly one fires, the rest are recorded as skipped."""
    gate = threading.Event()
    calls = []

    def slow_rebuild(records, workload, **kw):
        calls.append(threading.get_ident())
        assert gate.wait(10)
        return types.SimpleNamespace(swapped=True)

    svc = types.SimpleNamespace(rebuild=slow_rebuild)
    rebuilder = AutoRebuilder(
        svc, workload=None,
        config=DriftConfig(window=1, min_fill=1, abs_threshold=0.1,
                           rel_degradation=None, hysteresis=1, cooldown=0),
        reservoir_capacity=8,
    )
    rebuilder.add_records(np.ones((4, 2), np.int32))
    bad = _stat(100, 100)
    with ThreadPoolExecutor(max_workers=4) as pool:
        futs = [pool.submit(rebuilder.observe, bad) for _ in range(8)]
        for f in futs:
            f.result()
        gate.set()
        rebuilder.drain(timeout=10)
    rebuilder.close()
    assert len(calls) == 1  # one rebuild ran
    deployed = [e for e in rebuilder.events if e.deployed]
    skipped = [e for e in rebuilder.events if e.skipped == "in_flight"]
    assert len(deployed) == 1
    assert len(deployed) + len(skipped) == len(rebuilder.events)
    assert len(rebuilder.events) >= 2  # the hammer produced skips


def test_auto_rebuilder_on_event_may_reenter_the_rebuilder():
    """Regression: events are recorded OUTSIDE the rebuilder lock, so an
    on_event callback that calls back into the rebuilder (drain, status)
    must not deadlock — neither on the deployed event nor on in-flight
    skips."""
    reentered = []

    def on_event(ev):
        # both calls take the rebuilder's internal lock
        assert rebuilder.drain(timeout=5)
        rebuilder.observe(_stat(0, 100))  # healthy: no nested trigger
        reentered.append(ev)

    rebuilder = AutoRebuilder(
        types.SimpleNamespace(
            rebuild=lambda *a, **k: types.SimpleNamespace(swapped=True)
        ),
        workload=None,
        config=DriftConfig(window=2, min_fill=1, abs_threshold=0.5,
                           rel_degradation=None, hysteresis=1, cooldown=0),
        executor="sync",
        on_event=on_event,
    )
    rebuilder.add_records(np.ones((4, 2), np.int32))
    done = []
    t = threading.Thread(
        target=lambda: done.append(rebuilder.observe(_stat(100, 100)))
    )
    t.start()
    t.join(timeout=10)
    assert not t.is_alive(), "on_event callback deadlocked the rebuilder"
    assert len(reentered) == 1 and done[0].triggered
    rebuilder.close()


def test_auto_rebuilder_surfaces_errors_and_empty_reservoir():
    def boom(records, workload, **kw):
        raise RuntimeError("builder exploded")

    cfg = DriftConfig(window=1, min_fill=1, abs_threshold=0.1,
                      rel_degradation=None, hysteresis=1, cooldown=0)
    rebuilder = AutoRebuilder(
        types.SimpleNamespace(rebuild=boom), workload=None, config=cfg,
        executor="sync",
    )
    rebuilder.observe(_stat(100, 100))  # empty reservoir: rebuild skipped
    assert rebuilder.events[-1].skipped == "empty_reservoir"
    rebuilder.add_records(np.ones((4, 2), np.int32))
    rebuilder.observe(_stat(100, 100))
    ev = rebuilder.events[-1]
    assert "RuntimeError: builder exploded" in ev.error
    assert not ev.deployed
    rebuilder.close()
