"""Trivial layout baselines from the paper's evaluation (Sec 7.3).

* Random — shuffle records into fixed-size blocks (TPC-H baseline).
* Range  — range-partition on one column, e.g. ingest time (ErrorLog
  default scheme).

Both return the same artifacts as qd-tree layouts (BIDs + per-leaf min-max
descriptions packed into a degenerate FrozenQdTree) so every downstream
metric/benchmark treats all layouts uniformly.  They register as the
``"random"`` / ``"range"`` strategies of ``repro.service.build_layout``.
"""

from __future__ import annotations

import numpy as np

from repro.core.qdtree import FrozenQdTree
from repro.core.predicates import CutTable, Schema


def _flat_tree(
    schema: Schema, cuts: CutTable, n_blocks: int
) -> FrozenQdTree:
    """A degenerate 'forest of leaves' container for baseline layouts.

    Routing through it is meaningless (baselines assign BIDs directly); it
    exists so tighten()/query intersection/scan benchmarks are shared.  The
    node arrays encode a left-spine comb tree purely for shape validity.
    """
    nn = 2 * n_blocks - 1
    cut_id = np.full(nn, -1, np.int32)
    left = np.full(nn, -1, np.int32)
    right = np.full(nn, -1, np.int32)
    leaf_bid = np.full(nn, -1, np.int32)
    # comb: internal nodes 0..n_blocks-2; leaf i hangs off internal i
    for i in range(n_blocks - 1):
        cut_id[i] = 0
        left[i] = nn - 1 - i  # a leaf
        right[i] = i + 1 if i + 1 < n_blocks - 1 else nn - n_blocks
    for j in range(n_blocks):
        leaf_bid[nn - 1 - j] = j
    bits = max(schema.total_cat_bits, 1)
    return FrozenQdTree(
        schema=schema,
        cuts=cuts,
        cut_id=cut_id,
        left=left,
        right=right,
        leaf_bid=leaf_bid,
        leaf_lo=np.zeros((n_blocks, schema.ndims), np.int32),
        leaf_hi=np.tile(schema.doms, (n_blocks, 1)).astype(np.int32),
        leaf_cat=np.ones((n_blocks, bits), bool),
        leaf_adv=np.ones((n_blocks, cuts.n_adv, 2), bool),
        depth=max(n_blocks - 1, 1),
    )


def random_layout(
    records: np.ndarray,
    schema: Schema,
    cuts: CutTable,
    block_size: int,
    seed: int = 0,
) -> tuple[FrozenQdTree, np.ndarray]:
    """Random shuffler: fixed-size blocks, arrival-order agnostic."""
    rng = np.random.default_rng(seed)
    m = records.shape[0]
    n_blocks = max(1, m // block_size)
    bids = rng.permutation(m) % n_blocks
    tree = _flat_tree(schema, cuts, n_blocks)
    tree.tighten(records, bids.astype(np.int32))
    return tree, bids.astype(np.int32)


def range_layout(
    records: np.ndarray,
    schema: Schema,
    cuts: CutTable,
    block_size: int,
    column: int,
) -> tuple[FrozenQdTree, np.ndarray]:
    """Range partitioning on ``column`` (e.g. ingest time)."""
    m = records.shape[0]
    n_blocks = max(1, m // block_size)
    order = np.argsort(records[:, column], kind="stable")
    bids = np.empty(m, np.int32)
    bids[order] = (np.arange(m) * n_blocks) // m
    tree = _flat_tree(schema, cuts, n_blocks)
    tree.tighten(records, bids)
    return tree, bids
