"""Baseline layouts the paper compares against (Sec 7.3)."""

from repro.baselines.partitioners import random_layout, range_layout  # noqa: F401
from repro.baselines.bottom_up import (  # noqa: F401
    BottomUpConfig,
    build_bottom_up,
)
