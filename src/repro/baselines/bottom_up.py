"""Bottom-Up row grouping — Sun et al. [45], the paper's state-of-the-art
baseline (Sec 2.2.2, Sec 7.3).

Pipeline: (1) feature selection from the candidate-cut set via frequency
with subsumption discounting (the paper's configuration: ≤ 15 features;
the BU+ tuning additionally drops features with selectivity > threshold);
(2) records → binary feature vectors, deduplicated with row weights;
(3) greedy bottom-up merging: repeatedly merge the pair of blocks with the
lowest heuristic penalty until every block has ≥ b rows.

The penalty follows Sun et al.'s approximation: a block's scan cost is the
sum of *column weights* (number of queries subsumed) over its set feature
bits; merging i,j costs

    (w_i + w_j)·c(v_i ∨ v_j) − w_i·c(v_i) − w_j·c(v_j).

As the paper notes, this only matches the true objective when feature-
subsumed query sets are disjoint — exactly the weakness qd-tree fixes.
"""

from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.core import predicates as preds
from repro.core import query as qry
from repro.baselines.partitioners import _flat_tree
from repro.core.predicates import CutTable, Schema
from repro.core.qdtree import FrozenQdTree


@dataclasses.dataclass
class BottomUpConfig:
    block_size: int
    max_features: int = 15
    # BU+ (paper Sec 7.5): ignore features with selectivity above this
    selectivity_ceiling: float | None = None
    frequency_floor: int = 1


def _subsumes(cuts: CutTable, wt: qry.WorkloadTensors, schema: Schema):
    """(n_cuts, n_queries) bool: feature f subsumes query q (q ⇒ f)."""
    n_cuts, n_q = cuts.n_cuts, wt.n_queries
    out = np.zeros((n_cuts, wt.n_conjuncts), bool)
    for c in range(n_cuts):
        k = int(cuts.kind[c])
        if k == preds.KIND_RANGE:
            d, cp = int(cuts.dim[c]), int(cuts.cutpoint[c])
            out[c] = wt.q_hi[:, d] <= cp  # conjunct box ⊆ {v < cp}
        elif k == preds.KIND_IN:
            d = int(cuts.dim[c])
            seg = schema.cat_segment(d)
            q_seg = wt.q_cat[:, seg]
            f_seg = cuts.in_mask[c, seg]
            out[c] = (q_seg & ~f_seg[None, :]).sum(axis=1) == 0
        else:
            a = int(cuts.adv_id[c])
            out[c] = wt.q_adv[:, a] == qry.ADV_TRUE
    # a DNF query is subsumed iff every conjunct is
    byq = np.ones((n_cuts, n_q), bool)
    np.logical_and.at(byq, (slice(None), wt.conj_query), out)
    return byq


def select_features(
    cuts: CutTable,
    workload: qry.Workload,
    records: np.ndarray,
    cfg: BottomUpConfig,
) -> np.ndarray:
    """Frequency-based selection with subsumption discounting (Sec 7.3)."""
    wt = workload.tensorize(cuts)
    sub = _subsumes(cuts, wt, workload.schema)  # (n_cuts, n_q)
    freq = sub.sum(axis=1).astype(np.float64)
    if cfg.selectivity_ceiling is not None:  # the BU+ tuning
        M = preds.eval_cuts(records, cuts)
        sel = M.mean(axis=0)
        freq[sel > cfg.selectivity_ceiling] = 0.0
    chosen: list[int] = []
    covered = np.zeros(sub.shape[1], bool)
    live = freq.copy()
    while len(chosen) < cfg.max_features:
        i = int(np.argmax(live))
        if live[i] < cfg.frequency_floor:
            break
        chosen.append(i)
        covered |= sub[i]
        # discount features sharing queries with the chosen one
        overlap = (sub & sub[i][None, :]).sum(axis=1)
        live = live - overlap
        live[i] = -np.inf
    return np.asarray(chosen, np.int64)


def build_bottom_up(
    records: np.ndarray,
    workload: qry.Workload,
    cuts: CutTable,
    cfg: BottomUpConfig,
) -> tuple[FrozenQdTree, np.ndarray]:
    """Returns (layout-as-flat-tree with tightened descriptions, BIDs)."""
    schema = workload.schema
    feats = select_features(cuts, workload, records, cfg)
    wt = workload.tensorize(cuts)
    sub = _subsumes(cuts, wt, schema)[feats]  # (F, n_q)
    colweight = sub.sum(axis=1).astype(np.float64)  # queries subsumed per f

    M = preds.eval_cuts(records, cuts)[:, feats]  # (m, F) feature vectors
    # dedupe to unique vectors with weights
    key = np.packbits(M, axis=1)
    uniq, inv, counts = np.unique(
        key, axis=0, return_inverse=True, return_counts=True
    )
    n_u = uniq.shape[0]
    vecs = np.unpackbits(uniq, axis=1)[:, : M.shape[1]].astype(bool)
    weights = counts.astype(np.int64)

    # greedy merging with a lazy heap over pair penalties
    def cost(v):  # scan cost proxy of a block with OR-vector v
        return float((v * colweight).sum())

    group_vec = [vecs[i].copy() for i in range(n_u)]
    group_w = weights.tolist()
    alive = [True] * n_u
    small = [i for i in range(n_u) if group_w[i] < cfg.block_size]

    heap: list[tuple[float, int, int]] = []

    def push_pairs(i):
        for j in range(len(group_vec)):
            if j != i and alive[j] and (
                group_w[i] < cfg.block_size or group_w[j] < cfg.block_size
            ):
                vi, vj = group_vec[i], group_vec[j]
                pen = (
                    (group_w[i] + group_w[j]) * cost(vi | vj)
                    - group_w[i] * cost(vi)
                    - group_w[j] * cost(vj)
                )
                heapq.heappush(heap, (pen, min(i, j), max(i, j)))

    for i in small:
        push_pairs(i)

    merged_into = list(range(n_u))
    while any(
        alive[i] and group_w[i] < cfg.block_size for i in range(len(alive))
    ):
        if not heap:
            # merge the two smallest alive groups as a fallback
            live = [i for i in range(len(alive)) if alive[i]]
            if len(live) < 2:
                break
            live.sort(key=lambda i: group_w[i])
            i, j = live[0], live[1]
        else:
            pen, i, j = heapq.heappop(heap)
            if not (alive[i] and alive[j]):
                continue
            if (
                group_w[i] >= cfg.block_size
                and group_w[j] >= cfg.block_size
            ):
                continue
        # merge j into i
        group_vec[i] = group_vec[i] | group_vec[j]
        group_w[i] += group_w[j]
        alive[j] = False
        merged_into[j] = i
        if group_w[i] < cfg.block_size:
            push_pairs(i)

    # resolve merge chains → block ids
    def find(i):
        while merged_into[i] != i:
            merged_into[i] = merged_into[merged_into[i]]
            i = merged_into[i]
        return i

    roots = sorted({find(i) for i in range(n_u)})
    bid_of_root = {r: b for b, r in enumerate(roots)}
    uniq_bid = np.array([bid_of_root[find(i)] for i in range(n_u)], np.int32)
    bids = uniq_bid[inv]
    tree = _flat_tree(schema, cuts, len(roots))
    tree.tighten(records, bids)
    return tree, bids
