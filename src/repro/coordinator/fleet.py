"""Fleet coordinator: one process owns the layout epoch for many workers.

Sharded ingest (engine/sharded.py) made block assignment parallel inside
one process; drift detection (service/drift.py) and workload inference
(service/tracker.py) closed the monitor→trigger→rebuild loop — but each
only saw one process's traffic.  The missing piece for the paper's
layout quality story at fleet scale is a single authority that folds
EVERY worker's observations before deciding anything, the continuous
analogue of Lachesis-style background re-optimization (arXiv 2006.16529)
under the dynamic-relayout framing of arXiv 2405.04984.

:class:`FleetCoordinator` is that authority.  Workers — ingest rounds in
resident spawn workers (``ProcessShardSession``), serving threads with
local ``WorkloadTracker`` sketches, remote hosts shipping npz'd states —
compute associative partials and :meth:`submit` them; on a cadence the
coordinator drains and folds:

* **ShardState partials** merge through the exact int monoid
  (sum/min/max/or) and the merged tightening publishes into the live
  tree under the service lock, compare-and-checked against the
  generation the partials routed — the same stale-generation discipline
  as ``sharded_ingest``, so a rebuild that lands mid-cadence can never
  be polluted by partials of the superseded tree.
* **TrackerState deltas** (``WorkloadTracker.drain_state``) fold into
  the fleet tracker, so workload inference reflects every worker's
  query mix.
* The merged Eq. 1 window partial feeds the fleet
  :class:`~repro.service.drift.AutoRebuilder`, so drift triggers — and
  the rebuilds they fire — see the whole fleet's traffic.

Every fold is associative and commutative on exact ints, so the result
is bit-identical across process boundaries, arrival orders, and fold
cadences (``tests/test_hash_determinism.py`` pins this under hash-seed
randomization; qdlint QD001/QD002/QD005 enforce the lock and
determinism contracts statically).
"""

from __future__ import annotations

# qdlint: deterministic-module

import dataclasses
import threading
from typing import Callable, Optional

from repro.engine.sharded import ShardState
from repro.service.epoch import Epoch
from repro.service.tracker import TrackerState, WorkloadTracker


@dataclasses.dataclass(frozen=True)
class WorkerHandle:
    """One registered fleet worker (identity only — workers hold no
    coordinator state; their partials carry everything)."""

    worker_id: int
    name: str = ""


@dataclasses.dataclass(frozen=True)
class FoldReport:
    """Outcome of one cadence fold."""

    fold: int  # 1-based fold sequence number
    n_partials: int  # shard-state partials drained (incl. stale)
    n_records: int  # records drained into this fold (live partials only)
    published: bool  # merged tightening applied to the live tree
    stale_partials: int  # dropped: routed against a superseded generation
    generation: int  # live generation this fold observed
    tracker_merges: int  # tracker deltas folded into the fleet tracker
    drift: object = None  # DriftDecision | None (fleet rebuilder fed)


class FleetCoordinator:
    """Folds fleet-wide partials on a cadence and drives the layout epoch.

    ``service``    the :class:`~repro.service.service.LayoutService`
                   holding the authoritative epoch (generation ×
                   description version); all publishes and rebuild swaps
                   go through its lock/CAS.
    ``cadence``    submissions per automatic fold (``submit`` returns the
                   FoldReport when its submission completes a cadence;
                   :meth:`fold` drains explicitly at any time).
    ``tracker``    the fleet :class:`WorkloadTracker` (created against
                   the live schema when omitted) — workers ship
                   ``drain_state()`` deltas into it.
    ``rebuilder``  an :class:`~repro.service.drift.AutoRebuilder` fed the
                   merged Eq. 1 window partial each fold; omitted, the
                   coordinator only folds and publishes (drift-less).
    """

    def __init__(
        self,
        service,  # LayoutService (untyped: service does not import us)
        cadence: int = 8,
        tracker: Optional[WorkloadTracker] = None,
        rebuilder=None,  # drift.AutoRebuilder | None
        on_fold: Optional[Callable[[FoldReport], None]] = None,
    ):
        if cadence < 1:
            raise ValueError("cadence must be >= 1")
        self.service = service
        self.cadence = int(cadence)
        self.tracker = (
            tracker if tracker is not None else service.workload_tracker()
        )
        self.rebuilder = rebuilder
        self.on_fold = on_fold
        self._lock = threading.Lock()
        self._next_worker = 0  # guarded by: self._lock
        self._workers: dict[int, WorkerHandle] = {}  # guarded by: self._lock
        self._seq = 0  # guarded by: self._lock -- relabel base for shard ids
        self._pending: list[tuple[int, ShardState]] = []  # guarded by: self._lock
        self._pending_tracker: list[TrackerState] = []  # guarded by: self._lock
        self._since_fold = 0  # guarded by: self._lock
        self._folds = 0  # guarded by: self._lock
        self._stale = 0  # guarded by: self._lock
        # generation-cumulative fold: descriptions published by apply()
        # REPLACE the leaf bounds with the accumulated observation, so a
        # fold must carry every partial of the live generation — else two
        # cadence-1 folds would each erase the other's tightening
        self._acc: Optional[ShardState] = None  # guarded by: self._lock
        self._acc_gen: Optional[int] = None  # guarded by: self._lock

    # -- membership ----------------------------------------------------------
    def register(self, name: str = "") -> WorkerHandle:
        """Join the fleet; returns the handle submissions must carry."""
        with self._lock:
            self._next_worker += 1
            handle = WorkerHandle(
                self._next_worker, name or f"worker-{self._next_worker}"
            )
            self._workers[handle.worker_id] = handle
            return handle

    def leave(self, handle: WorkerHandle) -> None:
        """Leave the fleet.  Partials the worker already submitted stay
        pending — they are valid aggregates of records it really routed —
        only the registration goes; later submits under this handle
        raise."""
        with self._lock:
            self._workers.pop(handle.worker_id, None)

    def workers(self) -> tuple[WorkerHandle, ...]:
        with self._lock:
            return tuple(
                self._workers[k] for k in sorted(self._workers)
            )

    # -- the authoritative epoch --------------------------------------------
    def epoch(self) -> Epoch:
        """The authoritative serving epoch (generation × description
        version of the live primary) every fold publishes against."""
        return self.service.live_epoch()

    # -- submissions ---------------------------------------------------------
    def submit(
        self,
        handle: WorkerHandle,
        state: Optional[ShardState] = None,
        tracker_state: Optional[TrackerState] = None,
        generation: Optional[int] = None,
    ) -> Optional[FoldReport]:
        """Queue one worker's partials; folds when the cadence fills.

        ``state`` — a routing round's :class:`ShardState` (aggregates
        only: the fleet protocol ships partials, never rows, so states
        carrying spill chunks are rejected).  Shard ids are relabeled to
        a coordinator-unique range, so any mix of worker-local shard
        numberings stays mergeable (``ShardState.merge`` rejects
        duplicate ids by contract).

        ``tracker_state`` — a ``WorkloadTracker.drain_state()`` delta.

        ``generation`` — the service generation the partials routed
        against (default: the live generation at submit time).  Partials
        of a superseded generation are dropped at fold time, never
        published.

        Returns the :class:`FoldReport` when this submission completed a
        cadence, else None.
        """
        if state is None and tracker_state is None:
            raise ValueError(
                "submit needs a ShardState and/or a TrackerState"
            )
        if state is not None and state.chunks:
            raise ValueError(
                "coordinator submissions carry aggregates, not rows; "
                "run shards with collect_blocks=False"
            )
        gen = (
            generation
            if generation is not None
            else self.service.generation
        )
        with self._lock:
            if handle.worker_id not in self._workers:
                raise ValueError(
                    f"unregistered worker {handle.name or handle.worker_id}"
                    " (left the fleet?)"
                )
            if state is not None:
                base = self._seq
                self._seq += len(state.shard_ids)
                relabeled = dataclasses.replace(
                    state,
                    shard_ids=tuple(
                        range(base, base + len(state.shard_ids))
                    ),
                )
                self._pending.append((gen, relabeled))
            if tracker_state is not None:
                self._pending_tracker.append(tracker_state)
            self._since_fold += 1
            due = self._since_fold >= self.cadence
        if due:
            return self.fold()
        return None

    # -- the cadence fold ----------------------------------------------------
    def fold(self) -> FoldReport:
        """Drain pending partials: one associative fold, one publish.

        This fold's current-generation partials merge into the
        GENERATION-CUMULATIVE accumulation (``IncrementalTightener.apply``
        replaces descriptions with the accumulated bounds, so every
        publish must carry everything the live generation has seen — two
        cadence-1 folds publishing only their own partials would each
        erase the other's tightening).  The cumulative merge is applied
        to the live tree under the service lock iff that generation is
        STILL live (compare-and-check, exactly the ``sharded_ingest``
        publish discipline); partials routed against a superseded
        generation are dropped and counted — tightening is an
        optimization, so dropping a stale partial only leaves
        descriptions looser, never wrong.  Tracker deltas always fold
        (the query mix outlives any one tree).  The fold-local Eq. 1
        window partial feeds the fleet rebuilder — each observation seen
        exactly once — and a triggered rebuild swaps through the service
        CAS, which resets the accumulation at the next fold.

        Exact int monoid merges all the way down: any drain order or
        cadence partition of the same submissions yields bit-identical
        descriptions, counts, and tracker sketches once all partials
        have folded.
        """
        with self._lock:
            pending, self._pending = self._pending, []
            deltas, self._pending_tracker = self._pending_tracker, []
            self._since_fold = 0
            self._folds += 1
            fold_no = self._folds
        live = self.service.live_version()
        current = [s for g, s in pending if g == live.generation]
        stale = len(pending) - len(current)
        fresh: Optional[ShardState] = None
        for s in current:
            fresh = s if fresh is None else fresh.merge(s)
        with self._lock:
            if self._acc_gen != live.generation:
                # a rebuild swapped the epoch: its tree carries fresh
                # build-time descriptions, so the superseded
                # accumulation has nothing left to say
                self._acc, self._acc_gen = None, live.generation
            if fresh is not None:
                self._acc = (
                    fresh if self._acc is None else self._acc.merge(fresh)
                )
            merged = self._acc
        published = False
        if fresh is not None:
            published = self.service.apply_partial(merged, expected=live)
        for delta in deltas:
            self.tracker.merge_state(delta)
        decision = None
        if (
            self.rebuilder is not None
            and fresh is not None
            and fresh.obs.capacity > 0
        ):
            # the fold-local window partial, not the cumulative merge —
            # the drift window must see each observation exactly once
            decision = self.rebuilder.observe(fresh.obs)
        with self._lock:
            self._stale += stale
        report = FoldReport(
            fold=fold_no,
            n_partials=len(pending),
            n_records=fresh.n_records if fresh is not None else 0,
            published=published,
            stale_partials=stale,
            generation=live.generation,
            tracker_merges=len(deltas),
            drift=decision,
        )
        if self.on_fold is not None:
            self.on_fold(report)
        return report

    # -- introspection -------------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            return {
                "workers": len(self._workers),
                "folds": self._folds,
                "pending": len(self._pending),
                "pending_tracker": len(self._pending_tracker),
                "stale_dropped": self._stale,
                "cadence": self.cadence,
                "accumulated_records": (
                    self._acc.n_records if self._acc is not None else 0
                ),
                "accumulated_generation": self._acc_gen,
            }


__all__ = ["FleetCoordinator", "FoldReport", "WorkerHandle"]
