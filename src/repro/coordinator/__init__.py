"""Fleet coordination: fold worker partials, drive the layout epoch."""

from repro.coordinator.fleet import FleetCoordinator, FoldReport, WorkerHandle

__all__ = ["FleetCoordinator", "FoldReport", "WorkerHandle"]
