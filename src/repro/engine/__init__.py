"""LayoutEngine subsystem: one backend-dispatched routing/query API.

Public surface:
  LayoutEngine   — route / query_hits / route_queries / skip_stats / ingest
                   over a frozen tree
  WindowStat / ObservationProbe — Eq. 1 per-batch skip-rate accounting
                   (associative partials; drift monitoring)
  engine_for     — the per-tree attached engine (shared plan cache)
  register_backend / get_backend / available_backends — backend registry
  PlanCache / pad_bucket / trace_counts — compiled-plan cache + counters
  ShardIngestor / ShardState / MergeCoordinator / sharded_ingest —
                   parallel shard routing with associative merge

The lifecycle layer above (strategy-dispatched construction, versioned
hot-swap rebuild) lives in :mod:`repro.service`.
"""

from repro.engine.autotune import (  # noqa: F401
    TileConfig,
    autotune_fused,
    geometry_key,
)
from repro.engine.backends import (  # noqa: F401
    Backend,
    available_backends,
    get_backend,
    register_backend,
)
from repro.engine.engine import (  # noqa: F401
    IngestReport,
    LayoutEngine,
    ObservationProbe,
    WindowStat,
    engine_for,
)
from repro.engine.plan import (  # noqa: F401
    CompiledPlan,
    PlanCache,
    PlanKey,
    cuts_signature,
    pad_bucket,
    trace_counts,
)
from repro.engine.sharded import (  # noqa: F401
    MergeCoordinator,
    ShardedIngestReport,
    ShardIngestor,
    ShardState,
    process_pool,
    replicate_tree,
    shard_slices,
    sharded_ingest,
    shutdown_process_pool,
)
