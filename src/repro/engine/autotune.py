"""Tile-shape autotuner for the fused Pallas ingestion kernel.

The fused kernel (``kernels/fused_ingest.py``) is tiled over
``tile_m`` record rows × ``tile_l`` leaf columns; the right shapes depend
on the tree geometry (cut/leaf buckets set the operand matrices) and on
whether the platform compiles Pallas at all (TPU) or runs it in interpret
mode (CPU/GPU dev boxes).  This module owns that decision:

* :func:`autotune_fused` sweeps a tile grid against a sample batch,
  validates every candidate bit-identically against the numpy oracle
  (``kernels/ref.fused_ingest_ref``), *probes compiled (non-interpret)
  execution first* and falls back to interpret — recording which mode ran,
  never silently substituting — then persists the fastest valid config.
* :func:`lookup` / :func:`record` read/write the persisted store, keyed by
  ``(backend, geometry-bucket)``; ``PallasBackend.fused_ingest`` consults
  it when the caller does not pin tiles explicitly.

The store is a plain JSON file (``results/autotune_tiles.json`` by
default, override with ``REPRO_AUTOTUNE_STORE``) so tuned tiles survive
across processes and land in benchmark artifacts.
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import time
from typing import Optional

import numpy as np

from repro.engine.plan import LANE, pad_bucket

_ROOT = pathlib.Path(__file__).resolve().parents[3]
DEFAULT_STORE = _ROOT / "results" / "autotune_tiles.json"

# default sweep: record-tile × leaf-tile candidates (leaf tiles are LANE
# multiples; the plan clamps tile_l to the leaf bucket)
DEFAULT_TILE_GRID = (
    (256, LANE),
    (256, 2 * LANE),
    (512, LANE),
    (512, 2 * LANE),
    (1024, LANE),
)


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """One persisted tuning decision for a (backend, geometry) bucket."""

    tile_m: int
    tile_l: int
    interpret: bool  # True ⇒ compiled pallas unavailable, fallback recorded
    records_per_s: float = 0.0
    source: str = "autotune"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "TileConfig":
        return TileConfig(
            tile_m=int(d["tile_m"]),
            tile_l=int(d["tile_l"]),
            interpret=bool(d["interpret"]),
            records_per_s=float(d.get("records_per_s", 0.0)),
            source=str(d.get("source", "autotune")),
        )


def geometry_key(tree) -> str:
    """Padding-bucket geometry signature: trees in the same cut/leaf
    buckets share operand shapes, hence tile behavior."""
    cut_bucket = pad_bucket(tree.cuts.n_cuts, LANE)
    leaf_bucket = pad_bucket(tree.n_leaves, LANE)
    return f"c{cut_bucket}-l{leaf_bucket}"


def store_path() -> pathlib.Path:
    env = os.environ.get("REPRO_AUTOTUNE_STORE")
    return pathlib.Path(env) if env else DEFAULT_STORE


def _load_store() -> dict:
    path = store_path()
    if path.exists():
        try:
            return json.loads(path.read_text())
        except (json.JSONDecodeError, OSError):
            return {}
    return {}


def lookup(backend: str, geom: str) -> Optional[TileConfig]:
    entry = _load_store().get(f"{backend}:{geom}")
    return TileConfig.from_dict(entry) if entry else None


def record(backend: str, geom: str, cfg: TileConfig) -> None:
    store = _load_store()
    store[f"{backend}:{geom}"] = cfg.to_dict()
    path = store_path()
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(store, indent=2, sort_keys=True) + "\n")


def _partials_identical(a, b) -> bool:
    return (
        bool(np.array_equal(a.counts, b.counts))
        and bool(np.array_equal(a.lo, b.lo))
        and bool(np.array_equal(a.hi, b.hi))
        and bool(np.array_equal(a.cat, b.cat))
        and bool(np.array_equal(a.adv, b.adv))
    )


def autotune_fused(
    tree,
    records: np.ndarray,
    tile_grid=DEFAULT_TILE_GRID,
    reps: int = 3,
    persist: bool = True,
) -> dict:
    """Sweep fused-ingest tile shapes on the pallas backend; persist the win.

    Every candidate is validated bit-identically against the numpy oracle
    before it may win.  Compiled (non-interpret) execution is probed first
    for each tile shape; when the platform cannot compile Pallas the
    candidate reruns in interpret mode and the row records
    ``mode="interpret"`` — the fallback is explicit, never silent.
    """
    from repro.engine.engine import engine_for
    from repro.kernels.ref import fused_ingest_ref

    engine = engine_for(tree)
    oracle_bids, oracle_partial = fused_ingest_ref(tree, records)
    geom = geometry_key(tree)
    rows = []
    for tile_m, tile_l in tile_grid:
        row: dict = {"tile_m": int(tile_m), "tile_l": int(tile_l)}
        result = None
        for interpret in (False, True):
            try:
                bids, partial = engine.fused_step(
                    records, backend="pallas", tile_m=tile_m,
                    tile_l=tile_l, interpret=interpret,
                )
                result = (bids, partial, interpret)
                break
            except Exception as exc:  # lowering/compile unsupported here
                row["compile_error"] = f"{type(exc).__name__}: {exc}"[:200]
        if result is None:
            row["mode"] = "failed"
            row["valid"] = False
            rows.append(row)
            continue
        bids, partial, interpret = result
        row["mode"] = "interpret" if interpret else "compiled"
        row["valid"] = bool(
            np.array_equal(bids, oracle_bids)
        ) and _partials_identical(partial, oracle_partial)
        if row["valid"]:
            t0 = time.perf_counter()
            for _ in range(reps):
                engine.fused_step(
                    records, backend="pallas", tile_m=tile_m,
                    tile_l=tile_l, interpret=interpret,
                )
            dt = (time.perf_counter() - t0) / reps
            row["records_per_s"] = float(records.shape[0] / dt)
        rows.append(row)
    valid = [r for r in rows if r.get("valid")]
    chosen = None
    if valid:
        # compiled rows outrank interpret rows; speed breaks ties
        best = max(
            valid,
            key=lambda r: (r["mode"] == "compiled", r["records_per_s"]),
        )
        chosen = TileConfig(
            tile_m=best["tile_m"],
            tile_l=best["tile_l"],
            interpret=best["mode"] == "interpret",
            records_per_s=best["records_per_s"],
        )
        if persist:
            record("pallas", geom, chosen)
    return {
        "geometry": geom,
        "rows": rows,
        "chosen": chosen.to_dict() if chosen else None,
        "compiled_available": any(r["mode"] == "compiled" for r in rows),
    }


__all__ = [
    "DEFAULT_TILE_GRID",
    "TileConfig",
    "autotune_fused",
    "geometry_key",
    "lookup",
    "record",
    "store_path",
]
