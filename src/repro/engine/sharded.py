"""Sharded ingestion: parallel shard routing with associative merge.

The qd-tree gives a complete semantic description of every block (paper
Sec 3.2), which makes ingestion shardable: any worker holding a replica of
the routing plan can assign records to blocks independently, and the
per-block aggregates — row counts, min/max tightener partials, categorical
presence masks, advanced-cut truth bits — all merge associatively (sum /
min / max / or over int64 and bool are exact, so the fold is bit-identical
regardless of association or order).  Three pieces:

* :class:`ShardIngestor` routes one shard's micro-batches against the
  tree's compiled plans (shared power-of-two plan-cache buckets — a warmed
  bucket never retraces, no matter which shard hits it) and accumulates a
  serializable :class:`ShardState`: per-block row counts, per-leaf min/max
  tightener partials, and (optionally) per-block row chunks — the spill
  manifest a remote shard would ship back alongside its state.
* :class:`MergeCoordinator` folds ShardStates associatively and publishes
  the merged tightening into the tree — bit-identical to single-stream
  ``LayoutEngine.ingest`` over the same records.
* :func:`sharded_ingest` wires both onto a ``concurrent.futures``
  executor.  Thread pools (the default) share the live engine's compiled
  plans; ``executor="process"`` takes the real multi-host shape instead:
  each spawn-context worker rebuilds a ShardIngestor against a pickled
  :func:`replicate_tree` replica, warms its own plans, and ships only the
  (pure-numpy, pickle/npz-serializable) ShardState back to the parent's
  MergeCoordinator.

Shards route + tighten through the fused single-pass path
(``LayoutEngine.fused_step``) by default — bit-identical to the legacy
two-pass loop, each record touched once.

``LayoutService.ingest_sharded`` is the lifecycle facade over this module.
"""

from __future__ import annotations

# qdlint: deterministic-module

import atexit
import contextlib
import dataclasses
import multiprocessing
import threading
import time
from concurrent.futures import Executor, ProcessPoolExecutor, ThreadPoolExecutor
from typing import Iterable, Optional

import numpy as np

from repro.core.qdtree import FrozenQdTree, IncrementalTightener
from repro.engine import plan as planlib
from repro.engine.engine import (
    IngestReport,
    LayoutEngine,
    ObservationProbe,
    WindowStat,
    engine_for,
)


@dataclasses.dataclass
class ShardState:
    """One shard's (or a merged set of shards') ingestion aggregates.

    Pure numpy + builtins: picklable for process pools and npz-serializable
    for cross-host shipping.  ``lo``/``hi`` use the IncrementalTightener's
    int64 identity elements (+inf/-inf analogues), so states merge before
    any narrowing to the tree's dtypes.

    ``chunks`` maps BID → list of ``(shard_id, rows)`` buffered row chunks
    (empty when the ingestor ran with ``collect_blocks=False``).  Chunk
    lists concatenate under merge and are sorted by shard id at publish
    time, so block contents are independent of merge order too.

    ``obs`` carries the shard's Eq. 1 skip-rate accounting partial (a
    :class:`~repro.engine.engine.WindowStat`, all-zero when the ingestor
    ran without an observation probe).  Its merge is an exact int sum, so
    the folded window stats are bit-identical to the single-stream
    per-batch sequence for every shard count.
    """

    shard_ids: tuple[int, ...]
    n_leaves: int
    counts: np.ndarray  # (L,) int64 rows routed per block
    lo: np.ndarray  # (L, D) int64 running minima
    hi: np.ndarray  # (L, D) int64 running maxima (exclusive)
    cat: np.ndarray  # (L, bits) bool observed categorical values
    adv: np.ndarray  # (L, A, 2) bool observed advanced-cut truth bits
    n_batches: int
    n_records: int
    chunks: dict[int, list[tuple[int, np.ndarray]]]
    wall_s: float = 0.0
    obs: WindowStat = dataclasses.field(default_factory=WindowStat)

    def merge(self, other: "ShardState") -> "ShardState":
        """Associative, commutative fold of two shard states.

        Every aggregate is an exact elementwise monoid op on int64/bool,
        so ``merge(merge(a, b), c)`` equals ``merge(a, merge(b, c))``
        bit-identically, and the tightening aggregates commute as well.
        """
        if self.n_leaves != other.n_leaves or self.lo.shape != other.lo.shape:
            raise ValueError("cannot merge shard states of different trees")
        overlap = set(self.shard_ids) & set(other.shard_ids)
        if overlap:
            raise ValueError(f"shards merged twice: {sorted(overlap)}")
        chunks: dict[int, list[tuple[int, np.ndarray]]] = {
            b: list(c) for b, c in self.chunks.items()
        }
        for b, c in other.chunks.items():
            chunks.setdefault(b, []).extend(c)
        return ShardState(
            shard_ids=tuple(sorted(self.shard_ids + other.shard_ids)),
            n_leaves=self.n_leaves,
            counts=self.counts + other.counts,
            lo=np.minimum(self.lo, other.lo),
            hi=np.maximum(self.hi, other.hi),
            cat=self.cat | other.cat,
            adv=self.adv | other.adv,
            n_batches=self.n_batches + other.n_batches,
            n_records=self.n_records + other.n_records,
            chunks=chunks,
            wall_s=max(self.wall_s, other.wall_s),
            obs=self.obs.merge(other.obs),
        )

    # -- serialization (cross-host shipping) --------------------------------
    def save(self, path: str) -> None:
        arrays = {
            "shard_ids": np.asarray(self.shard_ids, np.int64),
            "counts": self.counts,
            "lo": self.lo,
            "hi": self.hi,
            "cat": self.cat,
            "adv": self.adv,
            "meta": np.asarray(
                [self.n_leaves, self.n_batches, self.n_records], np.int64
            ),
            "wall_s": np.asarray(self.wall_s),
            "obs": self.obs.to_array(),
        }
        for b, clist in self.chunks.items():
            for sid, rows in clist:
                arrays[f"chunk_{int(sid)}_{int(b)}"] = rows
        np.savez_compressed(path, **arrays)

    @staticmethod
    def load(path: str) -> "ShardState":
        z = np.load(path, allow_pickle=False)
        chunks: dict[int, list[tuple[int, np.ndarray]]] = {}
        for key in z.files:
            if key.startswith("chunk_"):
                _, sid, b = key.split("_")
                chunks.setdefault(int(b), []).append((int(sid), z[key]))
        for clist in chunks.values():
            clist.sort(key=lambda c: c[0])
        meta = z["meta"]
        return ShardState(
            shard_ids=tuple(int(s) for s in z["shard_ids"]),
            n_leaves=int(meta[0]),
            counts=z["counts"],
            lo=z["lo"],
            hi=z["hi"],
            cat=z["cat"],
            adv=z["adv"],
            n_batches=int(meta[1]),
            n_records=int(meta[2]),
            chunks=chunks,
            wall_s=float(z["wall_s"]),
            obs=(
                WindowStat.from_array(z["obs"])
                if "obs" in z.files
                else WindowStat()
            ),
        )


class ShardIngestor:
    """Routes one shard's micro-batches against a replicated plan.

    Holds no shared mutable state: routing reads the (immutable) frozen
    topology through the engine's plan cache, and all accumulation happens
    in a private :class:`IncrementalTightener` that is *never applied* to
    the tree — its partials are extracted into the returned ShardState.
    """

    def __init__(
        self,
        layout: FrozenQdTree | LayoutEngine,
        shard_id: int = 0,
        backend: Optional[str] = None,
        collect_blocks: bool = False,
        probe: Optional[ObservationProbe] = None,
        fused: bool = True,
    ):
        self.engine = (
            layout
            if isinstance(layout, LayoutEngine)
            else engine_for(layout)
        )
        self.shard_id = int(shard_id)
        self.backend = backend
        self.collect_blocks = collect_blocks
        # replicated per-leaf hit counts (engine.observation_probe): every
        # shard scores against the SAME probe arrays, so the summed
        # window-stat partials are bit-identical to single-stream ingest
        self.probe = probe
        self.fused = fused

    def run(self, batches: Iterable[np.ndarray]) -> ShardState:
        """Route every micro-batch; return this shard's aggregates."""
        from repro.data.blocks import BlockBuffers

        tree = self.engine.tree
        tightener = IncrementalTightener(tree)
        # private per-shard buffers reuse the exact routing-order-preserving
        # scatter of the single-stream path (BlockBuffers.append)
        spill = (
            BlockBuffers.for_tree(tree) if self.collect_blocks else None
        )
        n_batches = n_records = 0
        obs = WindowStat()
        t0 = time.perf_counter()
        for batch in batches:
            if batch.shape[0] == 0:
                continue
            if self.fused:
                bids, part = self.engine.fused_step(
                    batch, backend=self.backend
                )
                tightener.merge(part)
            else:
                bids = self.engine.route(batch, backend=self.backend)
                tightener.update(batch, bids)
            if spill is not None:
                spill.append(batch, bids)
            if self.probe is not None:
                obs = obs.merge(self.probe.observe(bids))
            n_batches += 1
            n_records += batch.shape[0]
        chunks = (
            {}
            if spill is None
            else {
                int(b): [(self.shard_id, spill.block(int(b)))]
                for b in np.nonzero(spill.sizes)[0]
            }
        )
        return ShardState(
            shard_ids=(self.shard_id,),
            n_leaves=tree.n_leaves,
            counts=tightener.counts,
            lo=tightener.lo,
            hi=tightener.hi,
            cat=tightener.cat,
            adv=tightener.adv,
            n_batches=n_batches,
            n_records=n_records,
            chunks=chunks,
            wall_s=time.perf_counter() - t0,
            obs=obs,
        )


class MergeCoordinator:
    """Folds ShardStates and publishes the merged tightening into a tree."""

    def __init__(self, tree: FrozenQdTree):
        self.tree = tree
        self._state: Optional[ShardState] = None

    @property
    def merged(self) -> ShardState:
        if self._state is None:
            raise ValueError("no shard states merged yet")
        return self._state

    def add(self, state: ShardState) -> ShardState:
        self._state = state if self._state is None else self._state.merge(state)
        return self._state

    def publish(self, buffers=None) -> np.ndarray:
        """Apply the merged tightening to the tree; returns block sizes.

        Reuses ``IncrementalTightener.apply`` verbatim, so the published
        leaf descriptions — and the description-version bump that evicts
        stale query plans — are exactly what single-stream ``ingest``
        would have produced.  ``buffers`` is forwarded to
        :meth:`fill_buffers`.
        """
        state = self.merged
        t = IncrementalTightener(self.tree)
        t.lo, t.hi = state.lo, state.hi
        t.cat, t.adv = state.cat, state.adv
        t.counts = state.counts
        t.apply()
        if buffers is not None:
            self.fill_buffers(buffers)
        return state.counts.copy()

    def fill_buffers(self, buffers) -> None:
        """Drain the merged spill chunks into ``buffers`` (a BlockBuffers).

        Chunks are folded in shard-id order, so with a contiguous record
        split the buffered blocks match single-stream ingestion
        row-for-row.  Does not touch the tree — usable for what-if runs
        alongside ``tighten=False``.
        """
        state = self.merged
        for b in sorted(state.chunks):
            for _, rows in sorted(state.chunks[b], key=lambda c: c[0]):
                buffers.append_block(b, rows)


@dataclasses.dataclass
class ShardedIngestReport(IngestReport):
    """IngestReport plus shard-parallel accounting.

    (Defaults exist only because the base class now carries a defaulted
    ``observation`` field; :func:`sharded_ingest` always sets these.)

    ``published`` is True iff the merged tightening was applied to the
    tree; ``stale_generation`` is True when a requested publish was
    *skipped* because the caller's ``publish_check`` reported that the
    tree is no longer the live generation (hot-swapped out mid-run) — the
    aggregates in this report are still valid for the captured tree, but
    nothing was mutated.
    """

    n_shards: int = 0
    shard_wall_s: tuple[float, ...] = ()  # per-shard routing wall clock
    merge_s: float = 0.0  # associative fold + publish
    published: bool = False
    stale_generation: bool = False

    @property
    def shard_records_per_s(self) -> float:
        """Aggregate routing throughput of the shard pool (merge excluded)."""
        slowest = max(self.shard_wall_s) if self.shard_wall_s else 0.0
        return self.n_records / slowest if slowest else 0.0


def shard_slices(records: np.ndarray, n_shards: int) -> list[np.ndarray]:
    """Contiguous record split — shard i gets the i-th slice of the stream."""
    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    return np.array_split(records, n_shards)


def micro_batches(records: np.ndarray, batch: int):
    for s in range(0, records.shape[0], batch):
        yield records[s : s + batch]


def warm_sizes(n_rows: int, n_shards: int, batch: int) -> set[int]:
    """Every distinct batch size a sharded run will route.

    Derived from :func:`shard_slices` (floor/ceil contiguous split) +
    :func:`micro_batches` (fixed ``batch`` plus a tail remainder), so
    callers can pre-warm exactly the padding buckets the run will hit —
    the zero-retrace warmup used by ``launch/ingest.py`` and
    ``benchmarks/sharded_ingest.py``.
    """
    slice_sizes = {n_rows // n_shards}
    if n_rows % n_shards:
        slice_sizes.add(n_rows // n_shards + 1)
    sizes = {min(batch, s) for s in slice_sizes}
    sizes |= {s % batch for s in slice_sizes}
    return {s for s in sizes if s}


def _run_shard(ingestor: ShardIngestor, batches) -> ShardState:
    """Module-level executor target (keeps futures introspectable)."""
    return ingestor.run(batches)


# -- resident spawn pool -----------------------------------------------------
# ``executor="process"`` used to build (and tear down) a fresh spawn-context
# ProcessPoolExecutor per run, so every run re-paid interpreter start + jax
# import in each worker — the fixed cost that ate the k-shard win in
# BENCH_sharded_ingest.json's process columns.  Workers are stateless
# (each task ships its own tree replica and returns a pure-numpy
# ShardState), so one module-level pool can serve every run; it is built
# lazily at the first ``process_pool`` call, grows (never shrinks) to the
# largest shard count requested, and lives until ``shutdown_process_pool``
# or interpreter exit.
_pool_lock = threading.Lock()
_pool: Optional[ProcessPoolExecutor] = None  # guarded by: _pool_lock
_pool_workers = 0  # guarded by: _pool_lock


def process_pool(min_workers: int = 1) -> ProcessPoolExecutor:
    """The resident spawn pool backing ``executor="process"`` runs.

    Returns a ProcessPoolExecutor with at least ``min_workers`` workers,
    creating or growing it as needed (growth replaces the pool — spawn
    pools cannot resize — after draining the old one).  A pool whose
    workers died (BrokenProcessPool) is rebuilt transparently.
    """
    global _pool, _pool_workers
    if min_workers < 1:
        raise ValueError("min_workers must be >= 1")
    with _pool_lock:
        broken = _pool is not None and getattr(_pool, "_broken", False)
        if _pool is None or broken or _pool_workers < min_workers:
            old = _pool
            workers = max(min_workers, _pool_workers)
            _pool = ProcessPoolExecutor(
                max_workers=workers,
                mp_context=multiprocessing.get_context("spawn"),
            )
            _pool_workers = workers
            if old is not None:
                old.shutdown(wait=not broken)
        return _pool


def shutdown_process_pool(wait: bool = True) -> None:
    """Tear down the resident spawn pool (next use rebuilds it lazily)."""
    global _pool, _pool_workers
    with _pool_lock:
        pool, _pool, _pool_workers = _pool, None, 0
    if pool is not None:
        pool.shutdown(wait=wait)


atexit.register(shutdown_process_pool, wait=False)


def _process_shard_worker(
    tree: FrozenQdTree,
    part: np.ndarray,
    shard_id: int,
    batch: int,
    backend: Optional[str],
    collect_blocks: bool,
    probe: Optional[ObservationProbe],
    fused: bool,
) -> ShardState:
    """Process-pool target: rebuild a ShardIngestor against the replica.

    Runs in a spawn-context worker with no shared state: the tree replica,
    the shard's record slice, and the (pure-numpy) probe all arrive by
    pickle; only the ShardState ships back.  Plans are warmed before the
    timed run so a worker's first-compile cost never lands in ``wall_s``
    (the parent's trace counters are untouched either way — compiles
    happen in the worker process).
    """
    engine = engine_for(tree)
    if fused:
        engine.warm_ingest(
            warm_sizes(part.shape[0], 1, batch), backend=backend
        )
    else:
        for s in sorted(warm_sizes(part.shape[0], 1, batch)):
            engine.route(
                np.zeros((s, tree.leaf_lo.shape[1]), np.int32),
                backend=backend,
            )
    ingestor = ShardIngestor(
        engine, shard_id=shard_id, backend=backend,
        collect_blocks=collect_blocks, probe=probe, fused=fused,
    )
    return ingestor.run(micro_batches(part, batch))


def sharded_ingest(
    layout: FrozenQdTree | LayoutEngine,
    records: np.ndarray,
    n_shards: int,
    batch: int = 2048,
    executor: "Executor | str | None" = None,
    collect_blocks: bool = False,
    buffers=None,  # data.blocks.BlockBuffers | None
    tighten: bool = True,
    backend: Optional[str] = None,
    lock=None,  # context manager guarding the publish step
    observe=None,  # Workload | WorkloadTensors | ObservationProbe | None
    publish_check=None,  # Callable[[], bool], evaluated under ``lock``
    fused: bool = True,
) -> ShardedIngestReport:
    """Shard ``records`` across parallel ingestors and merge associatively.

    Contiguously splits the stream into ``n_shards``, runs one
    :class:`ShardIngestor` per shard on ``executor`` (a private thread pool
    by default), folds the resulting ShardStates through a
    :class:`MergeCoordinator`, and (when ``tighten``) publishes the merged
    tightening — bit-identical to ``LayoutEngine.ingest`` over the same
    records for every k.  With ``tighten=False`` the tree is left
    untouched (same contract as ``ingest``): buffers still fill and the
    merged counts/partials are still computed and reported.

    With ``observe`` set, one :class:`ObservationProbe` is built from the
    engine's compiled plan and replicated to every shard; the merged
    Eq. 1 window-stat partial lands in ``report.observation`` —
    bit-identical to the single-stream ``ingest(observe=...)`` totals.

    ``publish_check`` guards against publishing into a tree that was
    hot-swapped out mid-run: it is evaluated under ``lock`` immediately
    before the tightening is applied, and if it returns False the publish
    is skipped and the report carries ``stale_generation=True`` (see
    ``LayoutService.ingest_sharded``).

    ``executor`` selects the pool: ``None`` / ``"thread"`` (or any
    thread-based Executor instance) shares the live engine's compiled
    plans across shards; ``"process"`` (or a ProcessPoolExecutor
    instance) takes the multi-host shape — spawn-context workers rebuild
    ShardIngestors against a pickled :func:`replicate_tree` replica and
    ship ShardStates back, so nothing unpicklable ever crosses the
    process boundary and shard routing escapes the GIL.  The string form
    uses the RESIDENT module pool (:func:`process_pool`, grown to
    ``n_shards``): spawn + jax-import cost is paid once per worker for
    the whole interpreter lifetime, not once per run.
    """
    engine = (
        layout if isinstance(layout, LayoutEngine) else engine_for(layout)
    )
    if isinstance(executor, str):
        if executor not in ("thread", "process"):
            raise ValueError(
                f"executor must be 'thread', 'process', an Executor, or "
                f"None — got {executor!r}"
            )
    use_process = executor == "process" or isinstance(
        executor, ProcessPoolExecutor
    )
    if buffers is not None:
        collect_blocks = True
    traces0 = planlib.trace_counts()
    probe = (
        engine.observation_probe(observe, backend=backend)
        if observe is not None
        else None
    )
    shard_parts = shard_slices(records, n_shards)
    t0 = time.perf_counter()
    if use_process:
        replica = replicate_tree(engine.tree)
        args = [
            (replica, shard_parts[i], i, batch, backend, collect_blocks,
             probe, fused)
            for i in range(n_shards)
        ]
        if isinstance(executor, ProcessPoolExecutor):
            states = [
                f.result()
                for f in [
                    executor.submit(_process_shard_worker, *a) for a in args
                ]
            ]
        else:
            # the resident spawn pool: first use pays spawn + jax import
            # once per worker, later runs reuse the warm interpreters
            pool = process_pool(n_shards)
            states = [
                f.result()
                for f in [
                    pool.submit(_process_shard_worker, *a) for a in args
                ]
            ]
    else:
        ingestors = [
            ShardIngestor(
                engine, shard_id=i, backend=backend,
                collect_blocks=collect_blocks, probe=probe, fused=fused,
            )
            for i in range(n_shards)
        ]
        shard_batches = [micro_batches(part, batch) for part in shard_parts]
        if executor is None or executor == "thread":
            with ThreadPoolExecutor(max_workers=n_shards) as pool:
                states = list(
                    pool.map(_run_shard, ingestors, shard_batches)
                )
        else:
            states = list(
                executor.map(_run_shard, ingestors, shard_batches)
            )
    t_merge = time.perf_counter()
    coordinator = MergeCoordinator(engine.tree)
    for state in states:
        coordinator.add(state)
    published = stale = False
    if tighten:
        # publish under the caller's lock; re-check liveness there — the
        # tree may have been hot-swapped out while the shards were routing,
        # and tightening a non-live tree would go unannounced otherwise
        with (lock if lock is not None else contextlib.nullcontext()):
            if publish_check is None or publish_check():
                sizes = coordinator.publish(buffers=buffers)
                published = True
            else:
                stale = True
    if not published:
        if buffers is not None:
            coordinator.fill_buffers(buffers)
        sizes = coordinator.merged.counts.copy()
    t1 = time.perf_counter()
    delta = planlib.trace_delta(traces0, planlib.trace_counts())
    merged = coordinator.merged
    return ShardedIngestReport(
        n_batches=merged.n_batches,
        n_records=merged.n_records,
        block_sizes=sizes,
        wall_s=t1 - t0,
        backend=backend or engine.backend,
        plan_cache=engine.plans.stats(),
        traces=delta,
        observation=merged.obs if probe is not None else None,
        n_shards=n_shards,
        shard_wall_s=tuple(s.wall_s for s in states),
        merge_s=t1 - t_merge,
        published=published,
        stale_generation=stale,
    )


def replicate_tree(tree: FrozenQdTree) -> FrozenQdTree:
    """A routing-identical replica with private leaf descriptions.

    The copy a shard host (or a what-if run) would hold: topology and cut
    table are shared (immutable), leaf descriptions are cloned so the
    replica can be tightened without touching the original.  The replica
    gets its own tree signature, hence its own plan-cache entries.
    """
    return FrozenQdTree(
        schema=tree.schema,
        cuts=tree.cuts,
        cut_id=tree.cut_id.copy(),
        left=tree.left.copy(),
        right=tree.right.copy(),
        leaf_bid=tree.leaf_bid.copy(),
        leaf_lo=tree.leaf_lo.copy(),
        leaf_hi=tree.leaf_hi.copy(),
        leaf_cat=tree.leaf_cat.copy(),
        leaf_adv=tree.leaf_adv.copy(),
        depth=tree.depth,
    )


def states_bit_identical(a: ShardState, b: ShardState) -> bool:
    """True iff two states' tightening aggregates are bit-identical."""
    return (
        bool(np.array_equal(a.counts, b.counts))
        and bool(np.array_equal(a.lo, b.lo))
        and bool(np.array_equal(a.hi, b.hi))
        and bool(np.array_equal(a.cat, b.cat))
        and bool(np.array_equal(a.adv, b.adv))
    )


__all__ = [
    "MergeCoordinator",
    "ShardIngestor",
    "ShardState",
    "ShardedIngestReport",
    "micro_batches",
    "replicate_tree",
    "shard_slices",
    "sharded_ingest",
    "states_bit_identical",
    "warm_sizes",
]
