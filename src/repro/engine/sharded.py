"""Sharded ingestion: parallel shard routing with associative merge.

The qd-tree gives a complete semantic description of every block (paper
Sec 3.2), which makes ingestion shardable: any worker holding a replica of
the routing plan can assign records to blocks independently, and the
per-block aggregates — row counts, min/max tightener partials, categorical
presence masks, advanced-cut truth bits — all merge associatively (sum /
min / max / or over int64 and bool are exact, so the fold is bit-identical
regardless of association or order).  Three pieces:

* :class:`ShardIngestor` routes one shard's micro-batches against the
  tree's compiled plans (shared power-of-two plan-cache buckets — a warmed
  bucket never retraces, no matter which shard hits it) and accumulates a
  serializable :class:`ShardState`: per-block row counts, per-leaf min/max
  tightener partials, and (optionally) per-block row chunks — the spill
  manifest a remote shard would ship back alongside its state.
* :class:`MergeCoordinator` folds ShardStates associatively and publishes
  the merged tightening into the tree — bit-identical to single-stream
  ``LayoutEngine.ingest`` over the same records.
* :func:`sharded_ingest` wires both onto a ``concurrent.futures``
  executor.  ``executor="process"`` — the default for ``n_shards >= 2``
  — takes the real multi-host shape: spawn-context workers in the
  resident module pool hold a :class:`ProcessShardSession` replica of
  the routing plan and ship only the (pure-numpy, pickle/npz-
  serializable) ShardState back to the parent's MergeCoordinator.
  ``executor="thread"`` shares the live engine's compiled plans but
  contends on the GIL (the documented 0.44× footgun —
  :class:`PerformanceWarning`).

The process path streams rounds through a :class:`ProcessShardSession`:
the tree replica is shipped AT MOST ONCE per pool worker per tree
generation (round tasks carry a session token; an unseeded worker raises
:class:`ReplicaMissing` and the parent retries that one task with the
payload attached), and the parent folds ShardStates as they complete —
merge overlaps the slower shards' routing.

Shards route + tighten through the fused single-pass path
(``LayoutEngine.fused_step``) by default — bit-identical to the legacy
two-pass loop, each record touched once.  A shard with no spill buffer
and no observation probe skips the per-row block-id device→host
transfer entirely (``return_bids=False``): the partials it streams back
are aggregates, never rows.

``LayoutService.ingest`` (``IngestOptions(shards=k)``) is the lifecycle
facade over this module; ``repro.coordinator`` folds the same
ShardStates fleet-wide.
"""

from __future__ import annotations

# qdlint: deterministic-module

import atexit
import contextlib
import dataclasses
import itertools
import multiprocessing
import os
import tempfile
import threading
import time
import warnings
from concurrent.futures import (
    Executor,
    ProcessPoolExecutor,
    ThreadPoolExecutor,
    as_completed,
)
from typing import Iterable, Optional

import numpy as np

from repro.core.qdtree import FrozenQdTree, IncrementalTightener
from repro.engine import plan as planlib
from repro.engine.engine import (
    IngestReport,
    LayoutEngine,
    ObservationProbe,
    WindowStat,
    engine_for,
)


class PerformanceWarning(UserWarning):
    """A requested configuration is known to lose wall-clock."""


_THREAD_FOOTGUN = (
    "executor='thread' with n_shards={k}: shard routing shares one GIL, "
    "measured at 0.44x single-stream wall-clock at k=8 "
    "(BENCH_sharded_ingest.json); executor='process' (the default for "
    "n_shards >= 2) routes shards in resident spawn workers instead"
)


def resolve_executor(
    executor: "Executor | str | None",
    n_shards: int,
    stacklevel: int = 3,
) -> "Executor | str":
    """Resolve the sharded-ingest executor default.

    ``None`` picks ``"process"`` for ``n_shards >= 2`` — the only
    executor that wins wall-clock off the GIL — and ``"thread"`` for a
    single shard (no parallelism to lose, no pool to keep resident).
    An explicit ``executor="thread"`` with multiple shards emits
    :class:`PerformanceWarning` citing the measured 0.44× regression,
    but is honored: shared-plan thread shards remain the right tool for
    deterministic tests and for custom ``Executor`` protocols.
    """
    if executor is None:
        return "process" if n_shards >= 2 else "thread"
    if isinstance(executor, str):
        if executor not in ("thread", "process"):
            raise ValueError(
                f"executor must be 'thread', 'process', an Executor, or "
                f"None — got {executor!r}"
            )
        if executor == "thread" and n_shards > 1:
            warnings.warn(
                _THREAD_FOOTGUN.format(k=n_shards),
                PerformanceWarning,
                stacklevel=stacklevel,
            )
    return executor


@dataclasses.dataclass
class ShardState:
    """One shard's (or a merged set of shards') ingestion aggregates.

    Pure numpy + builtins: picklable for process pools and npz-serializable
    for cross-host shipping.  ``lo``/``hi`` use the IncrementalTightener's
    int64 identity elements (+inf/-inf analogues), so states merge before
    any narrowing to the tree's dtypes.

    ``chunks`` maps BID → list of ``(shard_id, rows)`` buffered row chunks
    (empty when the ingestor ran with ``collect_blocks=False``).  Chunk
    lists concatenate under merge and are sorted by shard id at publish
    time, so block contents are independent of merge order too.

    ``obs`` carries the shard's Eq. 1 skip-rate accounting partial (a
    :class:`~repro.engine.engine.WindowStat`, all-zero when the ingestor
    ran without an observation probe).  Its merge is an exact int sum, so
    the folded window stats are bit-identical to the single-stream
    per-batch sequence for every shard count.
    """

    shard_ids: tuple[int, ...]
    n_leaves: int
    counts: np.ndarray  # (L,) int64 rows routed per block
    lo: np.ndarray  # (L, D) int64 running minima
    hi: np.ndarray  # (L, D) int64 running maxima (exclusive)
    cat: np.ndarray  # (L, bits) bool observed categorical values
    adv: np.ndarray  # (L, A, 2) bool observed advanced-cut truth bits
    n_batches: int
    n_records: int
    chunks: dict[int, list[tuple[int, np.ndarray]]]
    wall_s: float = 0.0
    obs: WindowStat = dataclasses.field(default_factory=WindowStat)

    def merge(self, other: "ShardState") -> "ShardState":
        """Associative, commutative fold of two shard states.

        Every aggregate is an exact elementwise monoid op on int64/bool,
        so ``merge(merge(a, b), c)`` equals ``merge(a, merge(b, c))``
        bit-identically, and the tightening aggregates commute as well.
        """
        if self.n_leaves != other.n_leaves or self.lo.shape != other.lo.shape:
            raise ValueError("cannot merge shard states of different trees")
        overlap = set(self.shard_ids) & set(other.shard_ids)
        if overlap:
            raise ValueError(f"shards merged twice: {sorted(overlap)}")
        chunks: dict[int, list[tuple[int, np.ndarray]]] = {
            b: list(c) for b, c in self.chunks.items()
        }
        for b, c in other.chunks.items():
            chunks.setdefault(b, []).extend(c)
        return ShardState(
            shard_ids=tuple(sorted(self.shard_ids + other.shard_ids)),
            n_leaves=self.n_leaves,
            counts=self.counts + other.counts,
            lo=np.minimum(self.lo, other.lo),
            hi=np.maximum(self.hi, other.hi),
            cat=self.cat | other.cat,
            adv=self.adv | other.adv,
            n_batches=self.n_batches + other.n_batches,
            n_records=self.n_records + other.n_records,
            chunks=chunks,
            wall_s=max(self.wall_s, other.wall_s),
            obs=self.obs.merge(other.obs),
        )

    # -- serialization (cross-host shipping) --------------------------------
    def save(self, path: str) -> None:
        arrays = {
            "shard_ids": np.asarray(self.shard_ids, np.int64),
            "counts": self.counts,
            "lo": self.lo,
            "hi": self.hi,
            "cat": self.cat,
            "adv": self.adv,
            "meta": np.asarray(
                [self.n_leaves, self.n_batches, self.n_records], np.int64
            ),
            "wall_s": np.asarray(self.wall_s),
            "obs": self.obs.to_array(),
        }
        for b, clist in self.chunks.items():
            for sid, rows in clist:
                arrays[f"chunk_{int(sid)}_{int(b)}"] = rows
        np.savez_compressed(path, **arrays)

    @staticmethod
    def load(path: str) -> "ShardState":
        z = np.load(path, allow_pickle=False)
        chunks: dict[int, list[tuple[int, np.ndarray]]] = {}
        for key in z.files:
            if key.startswith("chunk_"):
                _, sid, b = key.split("_")
                chunks.setdefault(int(b), []).append((int(sid), z[key]))
        for clist in chunks.values():
            clist.sort(key=lambda c: c[0])
        meta = z["meta"]
        return ShardState(
            shard_ids=tuple(int(s) for s in z["shard_ids"]),
            n_leaves=int(meta[0]),
            counts=z["counts"],
            lo=z["lo"],
            hi=z["hi"],
            cat=z["cat"],
            adv=z["adv"],
            n_batches=int(meta[1]),
            n_records=int(meta[2]),
            chunks=chunks,
            wall_s=float(z["wall_s"]),
            obs=(
                WindowStat.from_array(z["obs"])
                if "obs" in z.files
                else WindowStat()
            ),
        )


class ShardIngestor:
    """Routes one shard's micro-batches against a replicated plan.

    Holds no shared mutable state: routing reads the (immutable) frozen
    topology through the engine's plan cache, and all accumulation happens
    in a private :class:`IncrementalTightener` that is *never applied* to
    the tree — its partials are extracted into the returned ShardState.
    """

    def __init__(
        self,
        layout: FrozenQdTree | LayoutEngine,
        shard_id: int = 0,
        backend: Optional[str] = None,
        collect_blocks: bool = False,
        probe: Optional[ObservationProbe] = None,
        fused: bool = True,
    ):
        self.engine = (
            layout
            if isinstance(layout, LayoutEngine)
            else engine_for(layout)
        )
        self.shard_id = int(shard_id)
        self.backend = backend
        self.collect_blocks = collect_blocks
        # replicated per-leaf hit counts (engine.observation_probe): every
        # shard scores against the SAME probe arrays, so the summed
        # window-stat partials are bit-identical to single-stream ingest
        self.probe = probe
        self.fused = fused

    def run(self, batches: Iterable[np.ndarray]) -> ShardState:
        """Route every micro-batch; return this shard's aggregates."""
        from repro.data.blocks import BlockBuffers

        tree = self.engine.tree
        tightener = IncrementalTightener(tree)
        # private per-shard buffers reuse the exact routing-order-preserving
        # scatter of the single-stream path (BlockBuffers.append)
        spill = (
            BlockBuffers.for_tree(tree) if self.collect_blocks else None
        )
        n_batches = n_records = 0
        obs = WindowStat()
        # a partials-only shard (no spill, no probe) streams aggregates,
        # never rows — skip the per-row block-id device→host transfer
        need_bids = spill is not None or self.probe is not None
        t0 = time.perf_counter()
        for batch in batches:
            if batch.shape[0] == 0:
                continue
            if self.fused:
                bids, part = self.engine.fused_step(
                    batch, backend=self.backend, return_bids=need_bids
                )
                tightener.merge(part)
            else:
                bids = self.engine.route(batch, backend=self.backend)
                tightener.update(batch, bids)
            if spill is not None:
                spill.append(batch, bids)
            if self.probe is not None:
                obs = obs.merge(self.probe.observe(bids))
            n_batches += 1
            n_records += batch.shape[0]
        chunks = (
            {}
            if spill is None
            else {
                int(b): [(self.shard_id, spill.block(int(b)))]
                for b in np.nonzero(spill.sizes)[0]
            }
        )
        return ShardState(
            shard_ids=(self.shard_id,),
            n_leaves=tree.n_leaves,
            counts=tightener.counts,
            lo=tightener.lo,
            hi=tightener.hi,
            cat=tightener.cat,
            adv=tightener.adv,
            n_batches=n_batches,
            n_records=n_records,
            chunks=chunks,
            wall_s=time.perf_counter() - t0,
            obs=obs,
        )


class MergeCoordinator:
    """Folds ShardStates and publishes the merged tightening into a tree."""

    def __init__(self, tree: FrozenQdTree):
        self.tree = tree
        self._state: Optional[ShardState] = None

    @property
    def merged(self) -> ShardState:
        if self._state is None:
            raise ValueError("no shard states merged yet")
        return self._state

    def add(self, state: ShardState) -> ShardState:
        self._state = state if self._state is None else self._state.merge(state)
        return self._state

    def publish(self, buffers=None) -> np.ndarray:
        """Apply the merged tightening to the tree; returns block sizes.

        Reuses ``IncrementalTightener.apply`` verbatim, so the published
        leaf descriptions — and the description-version bump that evicts
        stale query plans — are exactly what single-stream ``ingest``
        would have produced.  ``buffers`` is forwarded to
        :meth:`fill_buffers`.
        """
        state = self.merged
        t = IncrementalTightener(self.tree)
        t.lo, t.hi = state.lo, state.hi
        t.cat, t.adv = state.cat, state.adv
        t.counts = state.counts
        t.apply()
        if buffers is not None:
            self.fill_buffers(buffers)
        return state.counts.copy()

    def fill_buffers(self, buffers) -> None:
        """Drain the merged spill chunks into ``buffers`` (a BlockBuffers).

        Chunks are folded in shard-id order, so with a contiguous record
        split the buffered blocks match single-stream ingestion
        row-for-row.  Does not touch the tree — usable for what-if runs
        alongside ``tighten=False``.
        """
        state = self.merged
        for b in sorted(state.chunks):
            for _, rows in sorted(state.chunks[b], key=lambda c: c[0]):
                buffers.append_block(b, rows)


@dataclasses.dataclass
class ShardedIngestReport(IngestReport):
    """IngestReport plus shard-parallel accounting.

    (Defaults exist only because the base class now carries a defaulted
    ``observation`` field; :func:`sharded_ingest` always sets these.)

    ``published`` is True iff the merged tightening was applied to the
    tree; ``stale_generation`` is True when a requested publish was
    *skipped* because the caller's ``publish_check`` reported that the
    tree is no longer the live generation (hot-swapped out mid-run) — the
    aggregates in this report are still valid for the captured tree, but
    nothing was mutated.
    """

    n_shards: int = 0
    shard_wall_s: tuple[float, ...] = ()  # per-shard routing wall clock
    merge_s: float = 0.0  # publish step (the fold itself streams,
    # overlapped with routing, so it no longer shows up here)
    published: bool = False
    stale_generation: bool = False
    state: "Optional[ShardState]" = None  # merged partial (keep_state=True)

    @property
    def shard_records_per_s(self) -> float:
        """Aggregate routing throughput of the shard pool (merge excluded)."""
        slowest = max(self.shard_wall_s) if self.shard_wall_s else 0.0
        return self.n_records / slowest if slowest else 0.0


def shard_slices(records: np.ndarray, n_shards: int) -> list[np.ndarray]:
    """Contiguous record split — shard i gets the i-th slice of the stream."""
    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    return np.array_split(records, n_shards)


def micro_batches(records: np.ndarray, batch: int):
    for s in range(0, records.shape[0], batch):
        yield records[s : s + batch]


def warm_sizes(n_rows: int, n_shards: int, batch: int) -> set[int]:
    """Every distinct batch size a sharded run will route.

    Derived from :func:`shard_slices` (floor/ceil contiguous split) +
    :func:`micro_batches` (fixed ``batch`` plus a tail remainder), so
    callers can pre-warm exactly the padding buckets the run will hit —
    the zero-retrace warmup used by ``launch/ingest.py`` and
    ``benchmarks/sharded_ingest.py``.
    """
    slice_sizes = {n_rows // n_shards}
    if n_rows % n_shards:
        slice_sizes.add(n_rows // n_shards + 1)
    sizes = {min(batch, s) for s in slice_sizes}
    sizes |= {s % batch for s in slice_sizes}
    return {s for s in sizes if s}


def _run_shard(ingestor: ShardIngestor, batches) -> ShardState:
    """Module-level executor target (keeps futures introspectable)."""
    return ingestor.run(batches)


# -- resident spawn pool -----------------------------------------------------
# ``executor="process"`` used to build (and tear down) a fresh spawn-context
# ProcessPoolExecutor per run, so every run re-paid interpreter start + jax
# import in each worker — the fixed cost that ate the k-shard win in
# BENCH_sharded_ingest.json's process columns.  Workers are stateless
# (each task ships its own tree replica and returns a pure-numpy
# ShardState), so one module-level pool can serve every run; it is built
# lazily at the first ``process_pool`` call, grows (never shrinks) to the
# largest shard count requested, and lives until ``shutdown_process_pool``
# or interpreter exit.
_pool_lock = threading.Lock()
_pool: Optional[ProcessPoolExecutor] = None  # guarded by: _pool_lock
_pool_workers = 0  # guarded by: _pool_lock


def process_pool(min_workers: int = 1) -> ProcessPoolExecutor:
    """The resident spawn pool backing ``executor="process"`` runs.

    Returns a ProcessPoolExecutor with at least ``min_workers`` workers,
    creating or growing it as needed (growth replaces the pool — spawn
    pools cannot resize — after draining the old one).  A pool whose
    workers died (BrokenProcessPool) is rebuilt transparently.
    """
    global _pool, _pool_workers
    if min_workers < 1:
        raise ValueError("min_workers must be >= 1")
    with _pool_lock:
        broken = _pool is not None and getattr(_pool, "_broken", False)
        if _pool is None or broken or _pool_workers < min_workers:
            old = _pool
            workers = max(min_workers, _pool_workers)
            _pool = ProcessPoolExecutor(
                max_workers=workers,
                mp_context=multiprocessing.get_context("spawn"),
            )
            _pool_workers = workers
            if old is not None:
                old.shutdown(wait=not broken)
        return _pool


def shutdown_process_pool(wait: bool = True) -> None:
    """Tear down the resident spawn pool (next use rebuilds it lazily)."""
    global _pool, _pool_workers
    with _pool_lock:
        pool, _pool, _pool_workers = _pool, None, 0
    if pool is not None:
        pool.shutdown(wait=wait)


atexit.register(shutdown_process_pool, wait=False)


# -- resident worker replicas (the session protocol) -------------------------
# Worker-process-side session cache.  Each spawn worker is single-threaded
# (ProcessPoolExecutor runs one task at a time per worker) and the parent
# never touches this dict, so no lock guards it.  Keyed by session token;
# bounded by insertion-order eviction so abandoned sessions cannot pin
# engines forever.
_WORKER_KEEP = 4
_WORKER_STATE: dict[str, dict] = {}

#: parent-side token counter — tokens are identity, never folded into data
_session_ids = itertools.count(1)


class ReplicaMissing(RuntimeError):
    """A pool worker was handed a round for a session it has not been
    seeded with.  The parent catches this and retries that ONE task with
    the ``(tree, records_path)`` payload attached — the
    ship-until-confirmed protocol that bounds replica pickling to at
    most once per worker per session."""


def _worker_entry(token: str, tree, records_path: Optional[str]) -> dict:
    """Fetch-or-install this worker's session entry (idempotent)."""
    entry = _WORKER_STATE.get(token)
    if entry is None:
        entry = {
            "engine": engine_for(tree),
            "records": None,
            "warmed": set(),
        }
        _WORKER_STATE[token] = entry
        while len(_WORKER_STATE) > _WORKER_KEEP:
            evict = next(iter(_WORKER_STATE))
            if evict == token:
                break
            del _WORKER_STATE[evict]
    if records_path is not None and entry["records"] is None:
        # memory-map: k workers on one host share the page cache instead
        # of holding k private copies of the staged stream
        entry["records"] = np.load(records_path, mmap_mode="r")
    return entry


def _worker_seed(
    token: str, tree, records_path: Optional[str], linger_s: float = 0.0
) -> int:
    """Idempotently install the session replica in this pool worker.

    ``linger_s``: an already-seeded worker naps briefly before returning,
    so a wave of seed tasks drains toward the workers that still need
    one (a ProcessPoolExecutor cannot target a specific worker).
    Returns this worker's pid, the parent's coverage receipt.
    """
    if token in _WORKER_STATE and linger_s > 0.0:
        time.sleep(linger_s)
    _worker_entry(token, tree, records_path)
    return os.getpid()


def _worker_round(
    token: str,
    shard_id: int,
    n_shards: int,
    rows: Optional[np.ndarray],
    batch: int,
    backend: Optional[str],
    collect_blocks: bool,
    probe: Optional[ObservationProbe],
    fused: bool,
    seed=None,  # (tree, records_path) | None — ReplicaMissing retry payload
) -> tuple[int, ShardState]:
    """Run one shard round against this worker's resident session engine.

    ``rows`` is the shard's record slice (shipped mode) or None (staged
    mode: the worker slices its resident record array locally, so the
    task carries no rows at all).  Plans warm incrementally per distinct
    batch size, once per worker per session — a warmed bucket never
    retraces, no matter which shard lands here next round.
    """
    if token not in _WORKER_STATE and seed is None:
        raise ReplicaMissing(token)
    entry = _worker_entry(
        token, *(seed if seed is not None else (None, None))
    )
    engine = entry["engine"]
    if rows is None:
        if entry["records"] is None:
            raise ReplicaMissing(token)  # staged round, nothing staged here
        rows = shard_slices(entry["records"], n_shards)[shard_id]
    need = warm_sizes(rows.shape[0], 1, batch) - entry["warmed"]
    if need:
        if fused:
            engine.warm_ingest(need, backend=backend)
        else:
            d = engine.tree.leaf_lo.shape[1]
            for s in sorted(need):
                engine.route(np.zeros((s, d), np.int32), backend=backend)
        entry["warmed"] |= need
    ingestor = ShardIngestor(
        engine, shard_id=shard_id, backend=backend,
        collect_blocks=collect_blocks, probe=probe, fused=fused,
    )
    return os.getpid(), ingestor.run(micro_batches(rows, batch))


def _unlink_quiet(path: str) -> None:
    with contextlib.suppress(OSError):
        os.unlink(path)


class ProcessShardSession:
    """Parent-side handle streaming sharded rounds to the resident pool.

    The old process path re-pickled the tree replica into every task of
    every run — the fixed cost that made ``executor="process"`` lose
    wall-clock (BENCH_sharded_ingest.json).  A session ships the replica
    AT MOST ONCE per pool worker per tree generation: round tasks carry
    only a token; a worker that has not been seeded raises
    :class:`ReplicaMissing` and the parent retries that one task with
    the payload attached.  Ingest/routing plan keys do not include leaf
    descriptions, so a worker's warm plans stay valid across the
    parent's tightening publishes — a session lives until the tree
    object itself is replaced (rebuild / hot swap), when the owner
    builds a new session (``LayoutService`` does this automatically).

    :meth:`stage` additionally spills the stream to a temp ``.npy`` once
    and has workers memory-map it, so steady-state rounds move only the
    token-sized task and one ~25 KB ShardState reply per shard — the
    fleet-worker shape ``benchmarks/coordinator.py`` measures.

    Thread-safe: concurrent :meth:`round` calls are independent; the
    shared counters below are folded under the session lock.
    """

    def __init__(
        self,
        layout: FrozenQdTree | LayoutEngine,
        n_shards: int,
        batch: int = 2048,
        backend: Optional[str] = None,
        fused: bool = True,
    ):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.engine = (
            layout
            if isinstance(layout, LayoutEngine)
            else engine_for(layout)
        )
        self.n_shards = int(n_shards)
        self.batch = int(batch)
        self.backend = backend
        self.fused = bool(fused)
        self.replica = replicate_tree(self.engine.tree)
        self.token = f"shardsess-{os.getpid()}-{next(_session_ids)}"
        self._lock = threading.Lock()
        self._records_path: Optional[str] = None  # guarded by: self._lock
        self._seeded: set[int] = set()  # guarded by: self._lock -- confirmed worker pids
        self._reseeds = 0  # guarded by: self._lock -- ReplicaMissing retries served
        self._rounds = 0  # guarded by: self._lock
        self._closed = False  # guarded by: self._lock

    @property
    def pool(self) -> ProcessPoolExecutor:
        """The resident module pool, grown to this session's shard count."""
        return process_pool(self.n_shards)

    def stats(self) -> dict:
        with self._lock:
            return {
                "token": self.token,
                "rounds": self._rounds,
                "reseeds": self._reseeds,
                "seeded_workers": len(self._seeded),
                "staged": self._records_path is not None,
            }

    def stage(self, records: np.ndarray, max_waves: int = 16) -> int:
        """Make ``records`` resident in the pool workers.

        Spills the array to a temp ``.npy`` once (workers memory-map it
        — one page-cache copy per host, not one per worker), then
        pre-seeds the pool.  Subsequent ``round(None)`` calls slice the
        staged stream worker-side.  Returns confirmed worker count.
        """
        fd, path = tempfile.mkstemp(prefix="qdshard-", suffix=".npy")
        os.close(fd)
        np.save(path, np.ascontiguousarray(records))
        with self._lock:
            if self._closed:
                _unlink_quiet(path)
                raise RuntimeError("session is closed")
            old, self._records_path = self._records_path, path
        if old is not None:
            _unlink_quiet(old)
        return self.seed(max_waves=max_waves)

    def seed(self, max_waves: int = 16, linger_s: float = 0.02) -> int:
        """Best-effort pre-seed of every pool worker.

        Waves of idempotent seed tasks; already-seeded workers linger
        briefly so the queue drains toward unseeded ones.  Correctness
        never depends on coverage — an unseeded worker is caught by the
        ReplicaMissing retry in :meth:`round` — this just keeps
        first-round timings honest.  Returns confirmed worker count.
        """
        pool = self.pool
        with self._lock:
            path = self._records_path
        procs = getattr(pool, "_processes", None)
        target = len(procs) if procs else self.n_shards
        for _ in range(max_waves):
            with self._lock:
                if len(self._seeded) >= target:
                    break
            futs = [
                pool.submit(
                    _worker_seed, self.token, self.replica, path, linger_s
                )
                for _ in range(self.n_shards)
            ]
            pids = [f.result() for f in futs]
            with self._lock:
                self._seeded.update(pids)
        with self._lock:
            return len(self._seeded)

    def round(
        self,
        records: Optional[np.ndarray] = None,
        collect_blocks: bool = False,
        probe: Optional[ObservationProbe] = None,
        fold=None,  # Callable[[ShardState], None] | None
    ) -> list[ShardState]:
        """Run one k-shard routing round; returns states in shard order.

        ``records=None`` uses the staged stream (each worker slices its
        resident copy locally); otherwise the given array is split and
        its slices shipped with the tasks.  ``fold`` (if given) is
        called with each ShardState as it completes, so the parent's
        associative merge overlaps the slower shards' routing instead of
        waiting for the full barrier (the merge commutes bit-exactly, so
        completion order cannot change the result).
        """
        with self._lock:
            if self._closed:
                raise RuntimeError("session is closed")
            path = self._records_path
        if records is None and path is None:
            raise ValueError(
                "no records given and none staged; call stage() first"
            )
        parts = (
            shard_slices(records, self.n_shards)
            if records is not None
            else None
        )
        pool = self.pool

        def _submit(i: int, seed):
            rows = parts[i] if parts is not None else None
            return pool.submit(
                _worker_round, self.token, i, self.n_shards, rows,
                self.batch, self.backend, collect_blocks, probe,
                self.fused, seed,
            )

        pending = {_submit(i, None): i for i in range(self.n_shards)}
        states: dict[int, ShardState] = {}
        pids: list[int] = []
        reseeds = 0
        while pending:
            for fut in as_completed(list(pending)):
                i = pending.pop(fut)
                try:
                    pid, state = fut.result()
                except ReplicaMissing:
                    # that worker has not seen this session yet: re-ship
                    # the replica (and staged-records path) to it once
                    reseeds += 1
                    pending[_submit(i, (self.replica, path))] = i
                    continue
                states[i] = state
                pids.append(pid)
                if fold is not None:
                    fold(state)
        with self._lock:
            self._rounds += 1
            self._reseeds += reseeds
            self._seeded.update(pids)
        return [states[i] for i in range(self.n_shards)]

    def close(self) -> None:
        """Release the staged temp file; the pool (shared) stays up and
        the workers' cached engines age out via the bounded session
        cache."""
        with self._lock:
            self._closed = True
            path, self._records_path = self._records_path, None
        if path is not None:
            _unlink_quiet(path)

    def __enter__(self) -> "ProcessShardSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _process_shard_worker(
    tree: FrozenQdTree,
    part: np.ndarray,
    shard_id: int,
    batch: int,
    backend: Optional[str],
    collect_blocks: bool,
    probe: Optional[ObservationProbe],
    fused: bool,
) -> ShardState:
    """Process-pool target: rebuild a ShardIngestor against the replica.

    Runs in a spawn-context worker with no shared state: the tree replica,
    the shard's record slice, and the (pure-numpy) probe all arrive by
    pickle; only the ShardState ships back.  Plans are warmed before the
    timed run so a worker's first-compile cost never lands in ``wall_s``
    (the parent's trace counters are untouched either way — compiles
    happen in the worker process).
    """
    engine = engine_for(tree)
    if fused:
        engine.warm_ingest(
            warm_sizes(part.shape[0], 1, batch), backend=backend
        )
    else:
        for s in sorted(warm_sizes(part.shape[0], 1, batch)):
            engine.route(
                np.zeros((s, tree.leaf_lo.shape[1]), np.int32),
                backend=backend,
            )
    ingestor = ShardIngestor(
        engine, shard_id=shard_id, backend=backend,
        collect_blocks=collect_blocks, probe=probe, fused=fused,
    )
    return ingestor.run(micro_batches(part, batch))


def sharded_ingest(
    layout: FrozenQdTree | LayoutEngine,
    records: np.ndarray,
    n_shards: int,
    batch: int = 2048,
    executor: "Executor | str | None" = None,
    collect_blocks: bool = False,
    buffers=None,  # data.blocks.BlockBuffers | None
    tighten: bool = True,
    backend: Optional[str] = None,
    lock=None,  # context manager guarding the publish step
    observe=None,  # Workload | WorkloadTensors | ObservationProbe | None
    publish_check=None,  # Callable[[], bool], evaluated under ``lock``
    fused: bool = True,
    session: Optional[ProcessShardSession] = None,
    keep_state: bool = False,
) -> ShardedIngestReport:
    """Shard ``records`` across parallel ingestors and merge associatively.

    Contiguously splits the stream into ``n_shards``, runs one
    :class:`ShardIngestor` per shard on ``executor`` (resident spawn
    workers by default for ``n_shards >= 2``; see below), folds the
    resulting ShardStates through a
    :class:`MergeCoordinator`, and (when ``tighten``) publishes the merged
    tightening — bit-identical to ``LayoutEngine.ingest`` over the same
    records for every k.  With ``tighten=False`` the tree is left
    untouched (same contract as ``ingest``): buffers still fill and the
    merged counts/partials are still computed and reported.

    With ``observe`` set, one :class:`ObservationProbe` is built from the
    engine's compiled plan and replicated to every shard; the merged
    Eq. 1 window-stat partial lands in ``report.observation`` —
    bit-identical to the single-stream ``ingest(observe=...)`` totals.

    ``publish_check`` guards against publishing into a tree that was
    hot-swapped out mid-run: it is evaluated under ``lock`` immediately
    before the tightening is applied, and if it returns False the publish
    is skipped and the report carries ``stale_generation=True`` (see
    ``LayoutService.ingest``).  ``keep_state=True`` attaches the merged
    :class:`ShardState` to ``report.state`` — the seam fleet callers use
    to forward the partial to a ``repro.coordinator.FleetCoordinator``
    (typically with ``tighten=False``: route here, publish there).

    ``executor`` selects the pool.  ``None`` resolves via
    :func:`resolve_executor`: ``"process"`` for ``n_shards >= 2``,
    ``"thread"`` for one shard.  ``"process"`` takes the multi-host
    shape — spawn-context workers in the RESIDENT module pool
    (:func:`process_pool`) run against a :class:`ProcessShardSession`
    replica (shipped at most once per worker — pass ``session=`` to
    reuse a seeded session across runs; a fresh per-run session is
    created otherwise) and ship ShardStates back, so nothing unpicklable
    ever crosses the process boundary and shard routing escapes the GIL.
    ``"thread"`` shares the live engine's compiled plans but serializes
    routing on the GIL — the documented 0.44× footgun
    (:class:`PerformanceWarning`).  A ``ProcessPoolExecutor`` instance
    keeps the legacy per-task replica shipping; any other ``Executor``
    instance drives the shared-plan ``.map`` protocol.

    The parent folds ShardStates AS THEY COMPLETE (``as_completed``
    streaming into the MergeCoordinator), so the associative merge
    overlaps the slower shards' routing; the fold commutes bit-exactly,
    so completion order cannot change the published result.
    """
    engine = (
        layout if isinstance(layout, LayoutEngine) else engine_for(layout)
    )
    if session is not None:
        if (
            session.n_shards != n_shards
            or session.batch != batch
            or session.fused != fused
            or session.engine.tree is not engine.tree
        ):
            raise ValueError(
                "session does not match this run's tree/shards/batch/fused"
            )
        executor = "process"
    else:
        executor = resolve_executor(executor, n_shards)
    if buffers is not None:
        collect_blocks = True
    traces0 = planlib.trace_counts()
    probe = (
        engine.observation_probe(observe, backend=backend)
        if observe is not None
        else None
    )
    coordinator = MergeCoordinator(engine.tree)
    t0 = time.perf_counter()
    if executor == "process" and session is None:
        session_own = ProcessShardSession(
            engine, n_shards, batch=batch, backend=backend, fused=fused
        )
    else:
        session_own = None
    if executor == "process":
        sess = session if session is not None else session_own
        try:
            states = sess.round(
                records, collect_blocks=collect_blocks, probe=probe,
                fold=coordinator.add,
            )
        finally:
            if session_own is not None:
                session_own.close()
    elif isinstance(executor, ProcessPoolExecutor):
        # legacy stateless shape: the replica ships with every task
        replica = replicate_tree(engine.tree)
        shard_parts = shard_slices(records, n_shards)
        args = [
            (replica, shard_parts[i], i, batch, backend, collect_blocks,
             probe, fused)
            for i in range(n_shards)
        ]
        states = [
            f.result()
            for f in [
                executor.submit(_process_shard_worker, *a) for a in args
            ]
        ]
        for state in states:
            coordinator.add(state)
    else:
        shard_parts = shard_slices(records, n_shards)
        ingestors = [
            ShardIngestor(
                engine, shard_id=i, backend=backend,
                collect_blocks=collect_blocks, probe=probe, fused=fused,
            )
            for i in range(n_shards)
        ]
        shard_batches = [micro_batches(part, batch) for part in shard_parts]
        if executor == "thread":
            by_shard: dict[int, ShardState] = {}
            with ThreadPoolExecutor(max_workers=n_shards) as pool:
                futs = {
                    pool.submit(_run_shard, ing, b): i
                    for i, (ing, b) in enumerate(
                        zip(ingestors, shard_batches)
                    )
                }
                for fut in as_completed(futs):
                    state = fut.result()
                    by_shard[futs[fut]] = state
                    coordinator.add(state)
            states = [by_shard[i] for i in range(n_shards)]
        else:
            # custom Executor instances keep the .map protocol (tests
            # interpose here to exercise swap-during-run races)
            states = list(
                executor.map(_run_shard, ingestors, shard_batches)
            )
            for state in states:
                coordinator.add(state)
    t_merge = time.perf_counter()
    published = stale = False
    if tighten:
        # publish under the caller's lock; re-check liveness there — the
        # tree may have been hot-swapped out while the shards were routing,
        # and tightening a non-live tree would go unannounced otherwise
        with (lock if lock is not None else contextlib.nullcontext()):
            if publish_check is None or publish_check():
                sizes = coordinator.publish(buffers=buffers)
                published = True
            else:
                stale = True
    if not published:
        if buffers is not None:
            coordinator.fill_buffers(buffers)
        sizes = coordinator.merged.counts.copy()
    t1 = time.perf_counter()
    delta = planlib.trace_delta(traces0, planlib.trace_counts())
    merged = coordinator.merged
    return ShardedIngestReport(
        n_batches=merged.n_batches,
        n_records=merged.n_records,
        block_sizes=sizes,
        wall_s=t1 - t0,
        backend=backend or engine.backend,
        plan_cache=engine.plans.stats(),
        traces=delta,
        observation=merged.obs if probe is not None else None,
        n_shards=n_shards,
        shard_wall_s=tuple(s.wall_s for s in states),
        merge_s=t1 - t_merge,
        published=published,
        stale_generation=stale,
        # the merged partial itself, for callers that forward it to a
        # fleet coordinator (repro.coordinator) instead of publishing here
        state=merged if keep_state else None,
    )


def replicate_tree(tree: FrozenQdTree) -> FrozenQdTree:
    """A routing-identical replica with private leaf descriptions.

    The copy a shard host (or a what-if run) would hold: topology and cut
    table are shared (immutable), leaf descriptions are cloned so the
    replica can be tightened without touching the original.  The replica
    gets its own tree signature, hence its own plan-cache entries.
    """
    return FrozenQdTree(
        schema=tree.schema,
        cuts=tree.cuts,
        cut_id=tree.cut_id.copy(),
        left=tree.left.copy(),
        right=tree.right.copy(),
        leaf_bid=tree.leaf_bid.copy(),
        leaf_lo=tree.leaf_lo.copy(),
        leaf_hi=tree.leaf_hi.copy(),
        leaf_cat=tree.leaf_cat.copy(),
        leaf_adv=tree.leaf_adv.copy(),
        depth=tree.depth,
    )


def states_bit_identical(a: ShardState, b: ShardState) -> bool:
    """True iff two states' tightening aggregates are bit-identical."""
    return (
        bool(np.array_equal(a.counts, b.counts))
        and bool(np.array_equal(a.lo, b.lo))
        and bool(np.array_equal(a.hi, b.hi))
        and bool(np.array_equal(a.cat, b.cat))
        and bool(np.array_equal(a.adv, b.adv))
    )


__all__ = [
    "MergeCoordinator",
    "PerformanceWarning",
    "ProcessShardSession",
    "ReplicaMissing",
    "ShardIngestor",
    "ShardState",
    "ShardedIngestReport",
    "micro_batches",
    "process_pool",
    "replicate_tree",
    "resolve_executor",
    "shard_slices",
    "sharded_ingest",
    "shutdown_process_pool",
    "states_bit_identical",
    "warm_sizes",
]
