"""Routing/intersection backends for the LayoutEngine.

Each backend registers itself under a name (replacing the stringly-typed
``routing.route(..., backend=...)`` dispatch) and implements the same two
operations against a ``FrozenQdTree``:

  * ``route(tree, cache, records)``      — record batch → BIDs (int32)
  * ``query_hits(tree, cache, wt)``      — (n_leaves, n_queries) bool

All backends are bit-identical to the numpy oracles in ``repro.core``; the
jitted jnp and Pallas paths additionally pull their packed operands from the
engine's :class:`~repro.engine.plan.PlanCache`, so same-bucket batches reuse
compilations (zero retracing — asserted via ``plan.trace_counts``).
"""

from __future__ import annotations

# qdlint: deterministic-module

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import query as qry
from repro.core.qdtree import FrozenQdTree
from repro.engine import plan as planlib
from repro.engine.plan import (
    LANE,
    CompiledPlan,
    PlanCache,
    PlanKey,
    count_trace,
    interpret_default,
    pad_bucket,
)

_REGISTRY: dict[str, "Backend"] = {}


def register_backend(name: str):
    """Class decorator: instantiate and register a backend under ``name``."""

    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls()
        return cls

    return deco


def get_backend(name: str) -> "Backend":
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; available: {available_backends()}"
        ) from None


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


class Backend:
    """Interface: route records and intersect queries for one frozen tree."""

    name: str = "?"

    def route(
        self,
        tree: FrozenQdTree,
        cache: PlanCache,
        records: np.ndarray,
        **opts,
    ) -> np.ndarray:
        raise NotImplementedError

    def query_hits(
        self,
        tree: FrozenQdTree,
        cache: PlanCache,
        wt: qry.WorkloadTensors,
        **opts,
    ) -> np.ndarray:
        raise NotImplementedError

    def fused_ingest(
        self,
        tree: FrozenQdTree,
        cache: PlanCache,
        records: np.ndarray,
        return_bids: bool = True,
        **opts,
    ):
        """One single-pass route + tighten step.

        Returns ``(bids int32 (m,), TightenPartial)`` — the per-leaf
        tightening aggregates of this batch, bit-identical to routing
        followed by ``IncrementalTightener.update``.  The base
        implementation is the legacy two-pass fallback, so every backend
        has a fused entry point even before it grows a fused kernel.

        ``return_bids=False`` lets a caller that only folds partials
        (shard workers streaming aggregates, tighten-only ingest) skip
        the per-row block-id device→host transfer — the largest host
        sync of the warm loop; the first tuple element is then ``None``.
        The compiled plan is identical either way (no retrace).
        """
        from repro.core.qdtree import IncrementalTightener

        bids = self.route(tree, cache, records, **opts)
        t = IncrementalTightener(tree)
        t.update(records, bids)
        return (bids if return_bids else None), t.as_partial()


# ---------------------------------------------------------------------------
# numpy oracle
# ---------------------------------------------------------------------------
@register_backend("numpy")
class NumpyBackend(Backend):
    def route(self, tree, cache, records, **opts):
        return tree.route(records)

    def query_hits(self, tree, cache, wt, **opts):
        conj = qry.conjuncts_intersect(
            tree.leaf_lo, tree.leaf_hi, tree.leaf_cat, tree.leaf_adv, wt,
            tree.schema,
        )
        return qry.queries_intersect(conj, wt)

    def fused_ingest(self, tree, cache, records, return_bids=True, **opts):
        # the numpy oracle IS the bit-identity reference for every fused
        # backend (kernels/ref.py)
        from repro.kernels.ref import fused_ingest_ref

        bids, partial = fused_ingest_ref(tree, records)
        return (bids if return_bids else None), partial


# ---------------------------------------------------------------------------
# jitted jnp level-synchronous descent
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("depth",))
def _route_jax_padded(records, ta, ca, depth):
    count_trace("route:jax")
    from repro.core.routing import eval_cuts_jax

    M = eval_cuts_jax(records, ca)
    m = records.shape[0]
    node = jnp.zeros(m, jnp.int32)

    def body(_, node):
        cid = ta["cut_id"][node]
        pred = jnp.take_along_axis(
            M, jnp.clip(cid, 0)[:, None].astype(jnp.int32), axis=1
        )[:, 0]
        nxt = jnp.where(pred, ta["left"][node], ta["right"][node])
        return jnp.where(cid >= 0, nxt, node)

    node = jax.lax.fori_loop(0, depth, body, node)
    return ta["leaf_bid"][node]


@functools.partial(
    jax.jit,
    static_argnames=("depth", "l_dump", "cat_cols", "cat_gemm", "bits",
                     "n_adv"),
)
def _ingest_jax_padded(records, valid, ta, ca, depth, l_dump, cat_cols,
                       cat_gemm, bits, n_adv):
    """Fused single-pass ingest: routing descent + segment reductions.

    One jit replaces the two-pass hot path (jitted route, then the numpy
    tightener's ``np.minimum.at``/``bincount`` scatters): the descent and
    all per-leaf reductions trace into a single compiled program, so each
    record is touched once.  Two structural optimizations carry the
    speedup over the two-pass baseline on CPU:

    * ``ca`` holds only the cuts the tree's internal nodes reference
      (pruned + remapped by ``_ingest_plan``) — the route plan evaluates
      the full candidate table, most of which no descent ever reads;
    * the pruned table arrives *grouped by kind* (``[range | IN | adv]``
      segments, each padded to its own bucket), so range cuts are pure
      vector compares and the expensive per-cut bit gathers run only
      over the IN segment instead of the whole table;
    * counts / categorical bits / adv flags all come out of ONE one-hot
      matmul (``leaf-onehotᵀ @ [1 | value-onehots | t | ~t]``) instead of
      per-element scatters, which XLA:CPU executes serially.  The f32
      accumulations are exact: 0/1 summands, totals < 2**24.

    Padding rows are redirected to a dump row (``l_dump - 1``) that the
    caller slices off; dictionary codes are int32 throughout, so the
    aggregates convert to the tightener's int64 partials exactly.
    ``cat_cols`` is ``((dim, bit_offset, cardinality), ...)``;
    ``cat_gemm`` is False when the schema's bit layout is not contiguous
    in dim order, falling back to per-dim scatters.
    """
    count_trace("ingest:jax")
    from repro.core.routing import _in_lookup

    m = records.shape[0]
    if n_adv:
        adv = ca["adv"]
        va = records[:, adv[:, 0]]
        vb = records[:, adv[:, 2]]
        op = adv[:, 1][None, :]
        t = jnp.select(
            [op == 0, op == 1, op == 2, op == 3, op == 4, op == 5],
            [va < vb, va <= vb, va > vb, va >= vb, va == vb, va != vb],
        ).astype(bool)  # select's int default would break ~t

    # kind-grouped predicate matrix: [range | IN | adv] segment columns
    rng_m = records[:, ca["dim_r"]] < ca["cut_r"][None, :]
    vals_i = records[:, ca["dim_i"]]
    bitpos = jnp.clip(
        vals_i + ca["off_i"][None, :], 0, ca["mask_i"].shape[1] - 1
    )
    inm = _in_lookup(ca["mask_i"], bitpos)
    if n_adv:
        advm = t[:, ca["advsel"]]
    else:
        advm = jnp.zeros((m, ca["advsel"].shape[0]), bool)
    M = jnp.concatenate([rng_m, inm, advm], axis=1)

    bitvec = None
    if cat_gemm and cat_cols:
        # per-record categorical one-hot at the schema's bit layout,
        # feeding the stats matmul below
        bitvec = jnp.concatenate(
            [
                (
                    records[:, dd, None]
                    == jnp.arange(card, dtype=records.dtype)[None, :]
                ).astype(jnp.float32)
                for dd, _off, card in cat_cols
            ],
            axis=1,
        )
    node = jnp.zeros(m, jnp.int32)

    def body(_, node):
        cid = ta["cut_id"][node]
        pred = jnp.take_along_axis(
            M, jnp.clip(cid, 0)[:, None].astype(jnp.int32), axis=1
        )[:, 0]
        nxt = jnp.where(pred, ta["left"][node], ta["right"][node])
        return jnp.where(cid >= 0, nxt, node)

    node = jax.lax.fori_loop(0, depth, body, node)
    bids = ta["leaf_bid"][node]

    d = records.shape[1]
    i32 = jnp.iinfo(jnp.int32)
    agg = jnp.where(valid, bids, l_dump - 1).astype(jnp.int32)
    lo = (
        jnp.full((l_dump, d), i32.max, jnp.int32).at[agg].min(records)
    )
    hi = (
        jnp.full((l_dump, d), i32.min, jnp.int32).at[agg].max(records)
    )

    cols = [jnp.ones((m, 1), jnp.float32)]
    if bitvec is not None:
        cols.append(bitvec)
    if n_adv:
        cols.append(t.astype(jnp.float32))
        cols.append((~t).astype(jnp.float32))
    onehot = (
        agg[:, None] == jnp.arange(l_dump, dtype=jnp.int32)[None, :]
    ).astype(jnp.float32)
    stats = onehot.T @ jnp.concatenate(cols, axis=1)
    counts = stats[:, 0].astype(jnp.int32)
    pos = 1
    if bitvec is not None:
        cat = stats[:, pos : pos + bits] > 0
        pos += bits
    else:
        cat = jnp.zeros((l_dump, bits), bool)
        for dd, off, _card in cat_cols:
            cat = cat.at[agg, off + records[:, dd]].max(True)
    if n_adv:
        advt = stats[:, pos : pos + n_adv] > 0
        advf = stats[:, pos + n_adv : pos + 2 * n_adv] > 0
    else:
        advt = advf = jnp.zeros((l_dump, 1), bool)
    return bids, counts, lo, hi, cat, advt, advf


@functools.partial(
    jax.jit, static_argnames=("numeric_dims", "cat_segments", "n_adv")
)
def _conj_intersect_jax(leaf, q, numeric_dims, cat_segments, n_adv):
    count_trace("query:jax")
    lo = jnp.maximum(leaf["leaf_lo"][:, None, :], q["q_lo"][None, :, :])
    hi = jnp.minimum(leaf["leaf_hi"][:, None, :], q["q_hi"][None, :, :])
    boxes = lo < hi  # (L, C, D)
    shape = boxes.shape[:2]
    if numeric_dims:
        box_ok = boxes[:, :, jnp.asarray(numeric_dims)].all(axis=2)
    else:
        box_ok = jnp.ones(shape, bool)
    cat_ok = jnp.ones(shape, bool)
    for s, e in cat_segments:
        cat_ok &= (
            leaf["leaf_cat"][:, None, s:e] & q["q_cat"][None, :, s:e]
        ).any(axis=2)
    adv_ok = jnp.ones(shape, bool)
    if n_adv:
        req = q["q_adv"][:, :n_adv]  # (C, A)
        may_t = leaf["leaf_adv"][:, :, 0]  # (L, A)
        may_f = leaf["leaf_adv"][:, :, 1]
        ok = ~((req == qry.ADV_TRUE)[None, :, :] & ~may_t[:, None, :])
        ok &= ~((req == qry.ADV_FALSE)[None, :, :] & ~may_f[:, None, :])
        adv_ok = ok.all(axis=2)
    return box_ok & cat_ok & adv_ok


def _padded_workload_tensors(wt: qry.WorkloadTensors) -> dict:
    """Conjunct tensors padded to their bucket, device-resident.

    Cached on the (immutable) WorkloadTensors object itself, so scoring
    loops reuse the upload instead of re-padding and re-transferring.
    """
    cached = getattr(wt, "_jax_padded", None)
    if cached is not None:
        return cached
    nc = wt.n_conjuncts
    c_bucket = pad_bucket(nc, 8)

    def _padq(x, fill):
        out = np.full((c_bucket,) + x.shape[1:], fill, x.dtype)
        out[:nc] = x
        return out

    q = {
        "q_lo": jnp.asarray(_padq(wt.q_lo, 0)),
        "q_hi": jnp.asarray(_padq(wt.q_hi, 0)),  # empty box ⇒ no hit
        "q_cat": jnp.asarray(_padq(wt.q_cat, False)),
        "q_adv": jnp.asarray(_padq(wt.q_adv, 0)),
    }
    object.__setattr__(wt, "_jax_padded", q)
    return q


@register_backend("jax")
class JaxBackend(Backend):
    min_batch_bucket = 64

    def _route_plan(self, tree, cache):
        sig = planlib.tree_signature(tree)
        node_bucket = pad_bucket(tree.n_nodes, 16)
        cut_bucket = pad_bucket(tree.cuts.n_cuts, 16)
        depth_bucket = pad_bucket(tree.depth, 1)
        key = PlanKey(
            sig, "jax", 0, node_bucket, 0, cut_bucket, ("route", depth_bucket)
        )

        def build():
            ta = {
                k: jnp.asarray(v)
                for k, v in planlib.pack_tree_arrays(tree, node_bucket).items()
            }
            ca = {
                k: jnp.asarray(v)
                for k, v in planlib.pack_cut_arrays(tree, cut_bucket).items()
            }
            fn = functools.partial(
                _route_jax_padded, ta=ta, ca=ca, depth=depth_bucket
            )
            return CompiledPlan(key=key, fn=fn, operands={"ta": ta, "ca": ca},
                                meta={"depth": depth_bucket})

        return cache.get(key, build)

    def route(self, tree, cache, records, **opts):
        plan = self._route_plan(tree, cache)
        m = records.shape[0]
        m_bucket = pad_bucket(m, self.min_batch_bucket)
        padded = np.zeros((m_bucket, records.shape[1]), np.int32)
        padded[:m] = records
        out = plan.fn(jnp.asarray(padded))
        return np.asarray(out[:m]).astype(np.int32)

    def _ingest_plan(self, tree, cache):
        sig = planlib.tree_signature(tree)
        node_bucket = pad_bucket(tree.n_nodes, 16)
        leaf_bucket = pad_bucket(tree.n_leaves, 8)
        depth_bucket = pad_bucket(tree.depth, 1)
        # the ingest plan evaluates only the cuts the tree references —
        # the candidate table is typically several times larger — and
        # groups them by kind so range cuts stay pure compares and the
        # per-cut bit gathers run only over the IN segment
        from repro.core import predicates as preds

        used = np.unique(tree.cut_id[tree.cut_id >= 0]).astype(np.int64)
        kind_u = tree.cuts.kind[used]
        seg_r = used[kind_u == preds.KIND_RANGE]
        seg_i = used[kind_u == preds.KIND_IN]
        seg_a = used[kind_u == preds.KIND_ADV]
        nr_pad = pad_bucket(int(seg_r.size), 4)
        ni_pad = pad_bucket(int(seg_i.size), 4)
        na_pad = pad_bucket(int(seg_a.size), 4)
        cut_bucket = nr_pad + ni_pad + na_pad
        # dump row past the bucketed leaf axis absorbs padding rows
        l_dump = leaf_bucket + 1
        key = PlanKey(
            sig, "jax", 0, node_bucket, leaf_bucket, cut_bucket,
            ("ingest", depth_bucket, nr_pad, ni_pad, na_pad),
        )

        def build():
            schema = tree.schema
            ta_np = planlib.pack_tree_arrays(tree, node_bucket)
            # remap node cut ids into the grouped table: segment base +
            # position within segment
            remap = np.full(max(tree.cuts.n_cuts, 1), -1, np.int64)
            remap[seg_r] = np.arange(seg_r.size)
            remap[seg_i] = nr_pad + np.arange(seg_i.size)
            remap[seg_a] = nr_pad + ni_pad + np.arange(seg_a.size)
            cid = ta_np["cut_id"]
            ta_np["cut_id"] = np.where(
                cid >= 0, remap[np.maximum(cid, 0)], -1
            ).astype(cid.dtype)
            ca_full = planlib.pack_cut_arrays(
                tree, pad_bucket(tree.cuts.n_cuts, 16)
            )

            def _segpad(x, seg, n_pad, fill):
                out = np.full((n_pad,) + x.shape[1:], fill, x.dtype)
                out[: seg.size] = x[seg]
                return out

            off_full = ca_full["cat_offset"][ca_full["dim"]]
            ca_np = {
                "dim_r": _segpad(ca_full["dim"], seg_r, nr_pad, 0),
                "cut_r": _segpad(ca_full["cutpoint"], seg_r, nr_pad, 0),
                "dim_i": _segpad(ca_full["dim"], seg_i, ni_pad, 0),
                "off_i": _segpad(off_full, seg_i, ni_pad, 0),
                "mask_i": _segpad(ca_full["in_mask"], seg_i, ni_pad,
                                  False),
                "advsel": _segpad(ca_full["adv_id"], seg_a, na_pad, 0),
                "adv": ca_full["adv"],
            }
            if ca_np["mask_i"].shape[1] == 0:  # no cat bits anywhere
                ca_np["mask_i"] = np.zeros((ni_pad, 1), bool)
            ta = {k: jnp.asarray(v) for k, v in ta_np.items()}
            ca = {k: jnp.asarray(v) for k, v in ca_np.items()}
            off = np.maximum(schema.cat_offsets, 0)
            bits = max(int(schema.total_cat_bits), 1)
            cat_cols = []
            running = 0
            cat_gemm = True
            for dd in np.nonzero(schema.is_categorical)[0]:
                card = int(schema.doms[dd])
                if int(off[dd]) != running:
                    cat_gemm = False  # unusual layout: scatter fallback
                cat_cols.append((int(dd), int(off[dd]), card))
                running += card
            cat_gemm = cat_gemm and (
                not cat_cols or running == int(schema.total_cat_bits)
            )
            fn = functools.partial(
                _ingest_jax_padded, ta=ta, ca=ca, depth=depth_bucket,
                l_dump=l_dump, cat_cols=tuple(cat_cols),
                cat_gemm=cat_gemm, bits=bits, n_adv=tree.cuts.n_adv,
            )
            return CompiledPlan(
                key=key, fn=fn, operands={"ta": ta, "ca": ca},
                meta={"depth": depth_bucket, "l_dump": l_dump},
            )

        return cache.get(key, build)

    def fused_ingest(self, tree, cache, records, return_bids=True, **opts):
        from repro.kernels.ref import partial_from_fused

        plan = self._ingest_plan(tree, cache)
        m = records.shape[0]
        L = tree.n_leaves
        m_bucket = pad_bucket(m, self.min_batch_bucket)
        padded = np.zeros((m_bucket, records.shape[1]), np.int32)
        padded[:m] = records
        valid = np.zeros(m_bucket, bool)
        valid[:m] = True
        bids, counts, lo, hi, cat, advt, advf = plan.fn(
            jnp.asarray(padded), jnp.asarray(valid)
        )
        partial = partial_from_fused(
            tree,
            np.asarray(counts)[:L],
            np.asarray(lo)[:L],
            np.asarray(hi)[:L],
            np.asarray(cat)[:L],
            np.asarray(advt)[:L],
            np.asarray(advf)[:L],
        )
        # partials-only callers skip the per-row D2H (the plan still
        # computes bids on device; only the host conversion is elided)
        if not return_bids:
            return None, partial
        return np.asarray(bids[:m]).astype(np.int32), partial

    def query_hits(self, tree, cache, wt, **opts):
        sig = planlib.tree_signature(tree)
        L = tree.n_leaves
        leaf_bucket = pad_bucket(L, 8)
        version = planlib.desc_version(tree)
        key = PlanKey(sig, "jax", 0, 0, leaf_bucket, 0, ("query", version))

        def build():
            schema = tree.schema
            leaf = {
                k: jnp.asarray(v)
                for k, v in planlib.pack_leaf_descs(tree, leaf_bucket).items()
            }
            off = schema.cat_offsets
            meta = {
                "numeric_dims": tuple(
                    int(i) for i in np.nonzero(~schema.is_categorical)[0]
                ),
                "cat_segments": tuple(
                    (int(off[d]), int(off[d]) + schema.columns[d].dom)
                    for d in np.nonzero(schema.is_categorical)[0]
                ),
            }
            # tighten superseded any older leaf-description plan — drop it
            # so long-lived ingest/score loops don't accumulate device copies
            cache.evict(
                lambda k: (
                    isinstance(k, PlanKey)
                    and k.sig == sig
                    and k.opts[:1] == ("query",)
                    and k.opts != ("query", version)
                )
            )
            return CompiledPlan(key=key, fn=None, operands=leaf, meta=meta)

        plan = cache.get(key, build)
        q = _padded_workload_tensors(wt)
        conj = _conj_intersect_jax(
            plan.operands, q,
            numeric_dims=plan.meta["numeric_dims"],
            cat_segments=plan.meta["cat_segments"],
            n_adv=tree.leaf_adv.shape[1],
        )
        conj_hits = np.asarray(conj)[:L, : wt.n_conjuncts]
        return qry.queries_intersect(conj_hits, wt)


# ---------------------------------------------------------------------------
# Pallas TPU kernels (interpret mode off-TPU)
# ---------------------------------------------------------------------------
@functools.partial(
    jax.jit,
    static_argnames=("tile_m", "tile_l", "n_cat_bits", "n_adv", "interpret"),
)
def _route_pallas_padded(
    records_f32, k, *, tile_m, tile_l, n_cat_bits, n_adv, interpret
):
    count_trace("route:pallas")
    from repro.kernels import route_records as rk

    m_mat = rk.eval_cuts_pallas(
        records_f32,
        k["dim_onehot"],
        k["cutpoint"],
        k["in_mask_t"],
        k["is_cat"],
        k["cat_off"],
        k["adv_cols"],
        k["adv_sel"],
        k["kind"],
        tile_m=tile_m,
        n_cat_bits=n_cat_bits,
        n_adv=n_adv,
        interpret=interpret,
    )
    return rk.locate_leaf_pallas(
        m_mat,
        k["pathpos"],
        k["pathneg"],
        k["leafid"],
        tile_m=tile_m,
        tile_l=tile_l,
        interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=("tile_m", "tile_l", "n_cat_bits", "n_adv", "interpret"),
)
def _ingest_pallas_padded(
    records_f32, valid, k, *, tile_m, tile_l, n_cat_bits, n_adv, interpret
):
    count_trace("ingest:pallas")
    from repro.kernels import fused_ingest as fk

    return fk.fused_ingest_pallas(
        records_f32,
        valid,
        k["dim_onehot"],
        k["cutpoint"],
        k["in_mask_t"],
        k["is_cat"],
        k["cat_off"],
        k["adv_cols"],
        k["adv_sel"],
        k["kind"],
        k["pathpos"],
        k["pathneg"],
        k["leafid"],
        tile_m=tile_m,
        tile_l=tile_l,
        n_cat_bits=n_cat_bits,
        n_adv=n_adv,
        interpret=interpret,
    )


@register_backend("pallas")
class PallasBackend(Backend):
    min_batch_bucket = 256

    def _route_plan(self, tree, cache, tile_m, tile_l, interpret):
        sig = planlib.tree_signature(tree)
        cut_bucket = pad_bucket(tree.cuts.n_cuts, LANE)
        leaf_bucket = pad_bucket(tree.n_leaves, LANE)
        tile_l = min(tile_l, leaf_bucket)
        key = PlanKey(
            sig, "pallas", 0, 0, leaf_bucket, cut_bucket,
            ("route", tile_m, tile_l, interpret),
        )

        def build():
            packed = planlib.pack_route_constants(
                tree, cut_bucket, leaf_bucket
            )
            meta = {
                "n_adv": packed.pop("n_adv"),
                "n_cat_bits": packed.pop("n_cat_bits"),
                "tile_l": tile_l,
            }
            operands = {kk: jnp.asarray(v) for kk, v in packed.items()}
            fn = functools.partial(
                _route_pallas_padded,
                k=operands,
                tile_m=tile_m,
                tile_l=tile_l,
                n_cat_bits=meta["n_cat_bits"],
                n_adv=meta["n_adv"],
                interpret=interpret,
            )
            return CompiledPlan(key=key, fn=fn, operands=operands, meta=meta)

        return cache.get(key, build)

    def route(
        self, tree, cache, records, tile_m: int = 256, tile_l: int = LANE,
        interpret: bool | None = None, **opts,
    ):
        if interpret is None:
            interpret = interpret_default()
        plan = self._route_plan(tree, cache, tile_m, tile_l, interpret)
        m = records.shape[0]
        m_bucket = pad_bucket(m, max(self.min_batch_bucket, tile_m))
        if m_bucket % tile_m:  # non-power-of-two tile_m
            m_bucket = ((m_bucket + tile_m - 1) // tile_m) * tile_m
        padded = np.zeros((m_bucket, records.shape[1]), np.float32)
        padded[:m] = records
        bids = plan.fn(jnp.asarray(padded))
        return np.asarray(bids[:m]).astype(np.int32)

    def _ingest_plan(self, tree, cache, tile_m, tile_l, interpret):
        sig = planlib.tree_signature(tree)
        cut_bucket = pad_bucket(tree.cuts.n_cuts, LANE)
        leaf_bucket = pad_bucket(tree.n_leaves, LANE)
        tile_l = min(tile_l, leaf_bucket)
        if leaf_bucket % tile_l:  # non-divisor tile (autotuned oddball)
            tile_l = LANE
        key = PlanKey(
            sig, "pallas", 0, 0, leaf_bucket, cut_bucket,
            ("ingest", tile_m, tile_l, interpret),
        )

        def build():
            packed = planlib.pack_route_constants(
                tree, cut_bucket, leaf_bucket
            )
            meta = {
                "n_adv": packed.pop("n_adv"),
                "n_cat_bits": packed.pop("n_cat_bits"),
                "tile_l": tile_l,
            }
            operands = {kk: jnp.asarray(v) for kk, v in packed.items()}
            fn = functools.partial(
                _ingest_pallas_padded,
                k=operands,
                tile_m=tile_m,
                tile_l=tile_l,
                n_cat_bits=meta["n_cat_bits"],
                n_adv=meta["n_adv"],
                interpret=interpret,
            )
            return CompiledPlan(key=key, fn=fn, operands=operands, meta=meta)

        return cache.get(key, build)

    def fused_ingest(
        self, tree, cache, records, tile_m: int | None = None,
        tile_l: int | None = None, interpret: bool | None = None,
        return_bids: bool = True, **opts,
    ):
        from repro.kernels.ref import partial_from_fused

        if interpret is None:
            interpret = interpret_default()
        if tile_m is None or tile_l is None:
            from repro.engine import autotune

            cfg = autotune.lookup("pallas", autotune.geometry_key(tree))
            tile_m = tile_m or (cfg.tile_m if cfg else 256)
            tile_l = tile_l or (cfg.tile_l if cfg else LANE)
        plan = self._ingest_plan(tree, cache, tile_m, tile_l, interpret)
        m = records.shape[0]
        L = tree.n_leaves
        m_bucket = pad_bucket(m, max(self.min_batch_bucket, tile_m))
        if m_bucket % tile_m:  # non-power-of-two tile_m
            m_bucket = ((m_bucket + tile_m - 1) // tile_m) * tile_m
        padded = np.zeros((m_bucket, records.shape[1]), np.float32)
        padded[:m] = records
        valid = np.zeros((m_bucket, 1), np.float32)
        valid[:m] = 1.0
        bids, counts, lo, hi, cat, advt, advf = plan.fn(
            jnp.asarray(padded), jnp.asarray(valid)
        )
        partial = partial_from_fused(
            tree,
            np.asarray(counts)[0, :L],
            np.asarray(lo)[:L],
            np.asarray(hi)[:L],
            np.asarray(cat)[:L],
            np.asarray(advt)[:L],
            np.asarray(advf)[:L],
        )
        if not return_bids:
            return None, partial
        bids_np = (np.asarray(bids)[:m, 0] - 1.0).astype(np.int32)
        return bids_np, partial

    def query_hits(self, tree, cache, wt, interpret: bool | None = None,
                   **opts):
        from repro.kernels import ops

        hits, _ = ops.query_intersect(tree, wt, interpret=interpret)
        return hits
