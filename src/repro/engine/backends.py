"""Routing/intersection backends for the LayoutEngine.

Each backend registers itself under a name (replacing the stringly-typed
``routing.route(..., backend=...)`` dispatch) and implements the same two
operations against a ``FrozenQdTree``:

  * ``route(tree, cache, records)``      — record batch → BIDs (int32)
  * ``query_hits(tree, cache, wt)``      — (n_leaves, n_queries) bool

All backends are bit-identical to the numpy oracles in ``repro.core``; the
jitted jnp and Pallas paths additionally pull their packed operands from the
engine's :class:`~repro.engine.plan.PlanCache`, so same-bucket batches reuse
compilations (zero retracing — asserted via ``plan.trace_counts``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import query as qry
from repro.core.qdtree import FrozenQdTree
from repro.engine import plan as planlib
from repro.engine.plan import (
    LANE,
    CompiledPlan,
    PlanCache,
    PlanKey,
    count_trace,
    interpret_default,
    pad_bucket,
)

_REGISTRY: dict[str, "Backend"] = {}


def register_backend(name: str):
    """Class decorator: instantiate and register a backend under ``name``."""

    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls()
        return cls

    return deco


def get_backend(name: str) -> "Backend":
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; available: {available_backends()}"
        ) from None


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


class Backend:
    """Interface: route records and intersect queries for one frozen tree."""

    name: str = "?"

    def route(
        self,
        tree: FrozenQdTree,
        cache: PlanCache,
        records: np.ndarray,
        **opts,
    ) -> np.ndarray:
        raise NotImplementedError

    def query_hits(
        self,
        tree: FrozenQdTree,
        cache: PlanCache,
        wt: qry.WorkloadTensors,
        **opts,
    ) -> np.ndarray:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# numpy oracle
# ---------------------------------------------------------------------------
@register_backend("numpy")
class NumpyBackend(Backend):
    def route(self, tree, cache, records, **opts):
        return tree.route(records)

    def query_hits(self, tree, cache, wt, **opts):
        conj = qry.conjuncts_intersect(
            tree.leaf_lo, tree.leaf_hi, tree.leaf_cat, tree.leaf_adv, wt,
            tree.schema,
        )
        return qry.queries_intersect(conj, wt)


# ---------------------------------------------------------------------------
# jitted jnp level-synchronous descent
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("depth",))
def _route_jax_padded(records, ta, ca, depth):
    count_trace("route:jax")
    from repro.core.routing import eval_cuts_jax

    M = eval_cuts_jax(records, ca)
    m = records.shape[0]
    node = jnp.zeros(m, jnp.int32)

    def body(_, node):
        cid = ta["cut_id"][node]
        pred = jnp.take_along_axis(
            M, jnp.clip(cid, 0)[:, None].astype(jnp.int32), axis=1
        )[:, 0]
        nxt = jnp.where(pred, ta["left"][node], ta["right"][node])
        return jnp.where(cid >= 0, nxt, node)

    node = jax.lax.fori_loop(0, depth, body, node)
    return ta["leaf_bid"][node]


@functools.partial(
    jax.jit, static_argnames=("numeric_dims", "cat_segments", "n_adv")
)
def _conj_intersect_jax(leaf, q, numeric_dims, cat_segments, n_adv):
    count_trace("query:jax")
    lo = jnp.maximum(leaf["leaf_lo"][:, None, :], q["q_lo"][None, :, :])
    hi = jnp.minimum(leaf["leaf_hi"][:, None, :], q["q_hi"][None, :, :])
    boxes = lo < hi  # (L, C, D)
    shape = boxes.shape[:2]
    if numeric_dims:
        box_ok = boxes[:, :, jnp.asarray(numeric_dims)].all(axis=2)
    else:
        box_ok = jnp.ones(shape, bool)
    cat_ok = jnp.ones(shape, bool)
    for s, e in cat_segments:
        cat_ok &= (
            leaf["leaf_cat"][:, None, s:e] & q["q_cat"][None, :, s:e]
        ).any(axis=2)
    adv_ok = jnp.ones(shape, bool)
    if n_adv:
        req = q["q_adv"][:, :n_adv]  # (C, A)
        may_t = leaf["leaf_adv"][:, :, 0]  # (L, A)
        may_f = leaf["leaf_adv"][:, :, 1]
        ok = ~((req == qry.ADV_TRUE)[None, :, :] & ~may_t[:, None, :])
        ok &= ~((req == qry.ADV_FALSE)[None, :, :] & ~may_f[:, None, :])
        adv_ok = ok.all(axis=2)
    return box_ok & cat_ok & adv_ok


def _padded_workload_tensors(wt: qry.WorkloadTensors) -> dict:
    """Conjunct tensors padded to their bucket, device-resident.

    Cached on the (immutable) WorkloadTensors object itself, so scoring
    loops reuse the upload instead of re-padding and re-transferring.
    """
    cached = getattr(wt, "_jax_padded", None)
    if cached is not None:
        return cached
    nc = wt.n_conjuncts
    c_bucket = pad_bucket(nc, 8)

    def _padq(x, fill):
        out = np.full((c_bucket,) + x.shape[1:], fill, x.dtype)
        out[:nc] = x
        return out

    q = {
        "q_lo": jnp.asarray(_padq(wt.q_lo, 0)),
        "q_hi": jnp.asarray(_padq(wt.q_hi, 0)),  # empty box ⇒ no hit
        "q_cat": jnp.asarray(_padq(wt.q_cat, False)),
        "q_adv": jnp.asarray(_padq(wt.q_adv, 0)),
    }
    object.__setattr__(wt, "_jax_padded", q)
    return q


@register_backend("jax")
class JaxBackend(Backend):
    min_batch_bucket = 64

    def _route_plan(self, tree, cache):
        sig = planlib.tree_signature(tree)
        node_bucket = pad_bucket(tree.n_nodes, 16)
        cut_bucket = pad_bucket(tree.cuts.n_cuts, 16)
        depth_bucket = pad_bucket(tree.depth, 1)
        key = PlanKey(
            sig, "jax", 0, node_bucket, 0, cut_bucket, ("route", depth_bucket)
        )

        def build():
            ta = {
                k: jnp.asarray(v)
                for k, v in planlib.pack_tree_arrays(tree, node_bucket).items()
            }
            ca = {
                k: jnp.asarray(v)
                for k, v in planlib.pack_cut_arrays(tree, cut_bucket).items()
            }
            fn = functools.partial(
                _route_jax_padded, ta=ta, ca=ca, depth=depth_bucket
            )
            return CompiledPlan(key=key, fn=fn, operands={"ta": ta, "ca": ca},
                                meta={"depth": depth_bucket})

        return cache.get(key, build)

    def route(self, tree, cache, records, **opts):
        plan = self._route_plan(tree, cache)
        m = records.shape[0]
        m_bucket = pad_bucket(m, self.min_batch_bucket)
        padded = np.zeros((m_bucket, records.shape[1]), np.int32)
        padded[:m] = records
        out = plan.fn(jnp.asarray(padded))
        return np.asarray(out[:m]).astype(np.int32)

    def query_hits(self, tree, cache, wt, **opts):
        sig = planlib.tree_signature(tree)
        L = tree.n_leaves
        leaf_bucket = pad_bucket(L, 8)
        version = planlib.desc_version(tree)
        key = PlanKey(sig, "jax", 0, 0, leaf_bucket, 0, ("query", version))

        def build():
            schema = tree.schema
            leaf = {
                k: jnp.asarray(v)
                for k, v in planlib.pack_leaf_descs(tree, leaf_bucket).items()
            }
            off = schema.cat_offsets
            meta = {
                "numeric_dims": tuple(
                    int(i) for i in np.nonzero(~schema.is_categorical)[0]
                ),
                "cat_segments": tuple(
                    (int(off[d]), int(off[d]) + schema.columns[d].dom)
                    for d in np.nonzero(schema.is_categorical)[0]
                ),
            }
            # tighten superseded any older leaf-description plan — drop it
            # so long-lived ingest/score loops don't accumulate device copies
            cache.evict(
                lambda k: (
                    isinstance(k, PlanKey)
                    and k.sig == sig
                    and k.opts[:1] == ("query",)
                    and k.opts != ("query", version)
                )
            )
            return CompiledPlan(key=key, fn=None, operands=leaf, meta=meta)

        plan = cache.get(key, build)
        q = _padded_workload_tensors(wt)
        conj = _conj_intersect_jax(
            plan.operands, q,
            numeric_dims=plan.meta["numeric_dims"],
            cat_segments=plan.meta["cat_segments"],
            n_adv=tree.leaf_adv.shape[1],
        )
        conj_hits = np.asarray(conj)[:L, : wt.n_conjuncts]
        return qry.queries_intersect(conj_hits, wt)


# ---------------------------------------------------------------------------
# Pallas TPU kernels (interpret mode off-TPU)
# ---------------------------------------------------------------------------
@functools.partial(
    jax.jit,
    static_argnames=("tile_m", "tile_l", "n_cat_bits", "n_adv", "interpret"),
)
def _route_pallas_padded(
    records_f32, k, *, tile_m, tile_l, n_cat_bits, n_adv, interpret
):
    count_trace("route:pallas")
    from repro.kernels import route_records as rk

    m_mat = rk.eval_cuts_pallas(
        records_f32,
        k["dim_onehot"],
        k["cutpoint"],
        k["in_mask_t"],
        k["is_cat"],
        k["cat_off"],
        k["adv_cols"],
        k["adv_sel"],
        k["kind"],
        tile_m=tile_m,
        n_cat_bits=n_cat_bits,
        n_adv=n_adv,
        interpret=interpret,
    )
    return rk.locate_leaf_pallas(
        m_mat,
        k["pathpos"],
        k["pathneg"],
        k["leafid"],
        tile_m=tile_m,
        tile_l=tile_l,
        interpret=interpret,
    )


@register_backend("pallas")
class PallasBackend(Backend):
    min_batch_bucket = 256

    def _route_plan(self, tree, cache, tile_m, tile_l, interpret):
        sig = planlib.tree_signature(tree)
        cut_bucket = pad_bucket(tree.cuts.n_cuts, LANE)
        leaf_bucket = pad_bucket(tree.n_leaves, LANE)
        tile_l = min(tile_l, leaf_bucket)
        key = PlanKey(
            sig, "pallas", 0, 0, leaf_bucket, cut_bucket,
            ("route", tile_m, tile_l, interpret),
        )

        def build():
            packed = planlib.pack_route_constants(
                tree, cut_bucket, leaf_bucket
            )
            meta = {
                "n_adv": packed.pop("n_adv"),
                "n_cat_bits": packed.pop("n_cat_bits"),
                "tile_l": tile_l,
            }
            operands = {kk: jnp.asarray(v) for kk, v in packed.items()}
            fn = functools.partial(
                _route_pallas_padded,
                k=operands,
                tile_m=tile_m,
                tile_l=tile_l,
                n_cat_bits=meta["n_cat_bits"],
                n_adv=meta["n_adv"],
                interpret=interpret,
            )
            return CompiledPlan(key=key, fn=fn, operands=operands, meta=meta)

        return cache.get(key, build)

    def route(
        self, tree, cache, records, tile_m: int = 256, tile_l: int = LANE,
        interpret: bool | None = None, **opts,
    ):
        if interpret is None:
            interpret = interpret_default()
        plan = self._route_plan(tree, cache, tile_m, tile_l, interpret)
        m = records.shape[0]
        m_bucket = pad_bucket(m, max(self.min_batch_bucket, tile_m))
        if m_bucket % tile_m:  # non-power-of-two tile_m
            m_bucket = ((m_bucket + tile_m - 1) // tile_m) * tile_m
        padded = np.zeros((m_bucket, records.shape[1]), np.float32)
        padded[:m] = records
        bids = plan.fn(jnp.asarray(padded))
        return np.asarray(bids[:m]).astype(np.int32)

    def query_hits(self, tree, cache, wt, interpret: bool | None = None,
                   **opts):
        from repro.kernels import ops

        hits, _ = ops.query_intersect(tree, wt, interpret=interpret)
        return hits
