"""Compiled-plan cache for the LayoutEngine (ROADMAP: caching/multi-backend).

A *plan* is everything a backend needs to route/intersect against one frozen
tree at one padded geometry: the packed device operands plus a callable whose
jit/Pallas compilation is reused across calls.  Plans are keyed by

    (tree signature, backend, batch bucket, node bucket,
     leaf bucket, cut bucket, backend options)

where every size is rounded up to a power-of-two *padding bucket*, so online
ingestion of varying batch sizes hits the same compiled executable instead of
retracing per shape.  Tree signatures are identity tokens: routing operands
depend only on the frozen topology (immutable), while query operands also
depend on the leaf descriptions, which ``tighten`` mutates — those plans key
on a description version that ``tighten`` bumps.

Trace counters (`trace_counts`) increment inside the jitted entry points at
*trace* time only, so benchmarks and tests can assert that a warm cache
performs zero recompilation.
"""

from __future__ import annotations

# qdlint: deterministic-module

import dataclasses
import hashlib
import itertools
import threading
from collections import Counter
from typing import Any, Callable, Hashable

import numpy as np

from repro.core.predicates import CutTable
from repro.core.qdtree import FrozenQdTree

LANE = 128  # TPU lane width; leaf/cut buckets must be multiples of this

_SIG_COUNTER = itertools.count()  # guarded by: _SIG_LOCK
_SIG_LOCK = threading.Lock()

TRACE_COUNTS: Counter = Counter()


def count_trace(name: str) -> None:
    """Called from inside jitted bodies — runs once per (re)trace."""
    TRACE_COUNTS[name] += 1


def trace_counts() -> dict[str, int]:
    return dict(TRACE_COUNTS)


def trace_delta(before: dict[str, int], after: dict[str, int]) -> dict:
    """Counters that moved between two ``trace_counts`` snapshots."""
    return {
        k: after.get(k, 0) - before.get(k, 0)
        for k in sorted(set(before) | set(after))
        if after.get(k, 0) != before.get(k, 0)
    }


def pad_bucket(n: int, minimum: int = 1) -> int:
    """Smallest power of two ≥ max(n, minimum)."""
    target = max(int(n), int(minimum), 1)
    return 1 << (target - 1).bit_length()


def interpret_default() -> bool:
    """Pallas kernels run in interpret mode wherever there is no TPU."""
    import jax

    return jax.default_backend() != "tpu"


def tree_signature(tree: FrozenQdTree) -> int:
    """Stable per-object token (frozen topology is immutable)."""
    sig = getattr(tree, "_engine_sig", None)
    if sig is None:
        with _SIG_LOCK:
            sig = getattr(tree, "_engine_sig", None)
            if sig is None:
                sig = next(_SIG_COUNTER)
                object.__setattr__(tree, "_engine_sig", sig)
    return sig


def desc_version(tree: FrozenQdTree) -> int:
    """Leaf-description version; ``FrozenQdTree.tighten`` bumps it."""
    return getattr(tree, "_desc_version", 0)


def cuts_signature(cuts: CutTable) -> int:
    """Content hash of a cut table (plus its schema), cached on the object.

    Unlike :func:`tree_signature` (an identity token), this is a *content*
    signature: two generations whose trees were built from equal cut tables
    share it, so workload tensorizations (which depend only on schema +
    cuts) survive a hot swap (ROADMAP: workload-tensor reuse).
    """
    sig = getattr(cuts, "_cuts_sig", None)
    if sig is None:
        h = hashlib.blake2b(digest_size=8)
        for a in (cuts.kind, cuts.dim, cuts.cutpoint, cuts.in_mask,
                  cuts.adv_id):
            h.update(np.ascontiguousarray(a).tobytes())
        h.update(repr(tuple(
            (a.col_a, a.op, a.col_b) for a in cuts.adv
        )).encode())
        h.update(repr(tuple(
            (c.name, c.kind, c.dom) for c in cuts.schema.columns
        )).encode())
        sig = int.from_bytes(h.digest(), "little")
        object.__setattr__(cuts, "_cuts_sig", sig)
    return sig


@dataclasses.dataclass(frozen=True)
class PlanKey:
    sig: int
    backend: str
    m_bucket: int
    node_bucket: int
    leaf_bucket: int
    cut_bucket: int
    opts: tuple[Hashable, ...] = ()


@dataclasses.dataclass
class CompiledPlan:
    """A backend-ready routing/intersection plan.

    ``operands`` are device-resident packed arrays; ``fn`` closes over them
    and accepts the padded batch.  ``meta`` carries static sizes the caller
    needs to slice padding back off.
    """

    key: PlanKey
    fn: Callable[..., Any]
    operands: dict
    meta: dict


class PlanCache:
    """Keyed plan store with hit/miss accounting (thread-safe)."""

    def __init__(self):
        self._plans: dict[Any, Any] = {}  # guarded by: self._lock
        self._lock = threading.Lock()
        self.hits = 0  # guarded by: self._lock
        self.misses = 0  # guarded by: self._lock

    def get(self, key: Any, builder: Callable[[], Any]) -> Any:
        with self._lock:
            if key in self._plans:
                self.hits += 1
                return self._plans[key]
        # build outside the lock (builders may trigger compilation)
        plan = builder()
        with self._lock:
            self.misses += 1
            self._plans.setdefault(key, plan)
            return self._plans[key]

    def __len__(self) -> int:
        with self._lock:
            return len(self._plans)

    def evict(self, predicate: Callable[[Any], bool]) -> int:
        """Drop every entry whose key satisfies ``predicate``."""
        with self._lock:
            stale = [k for k in self._plans if predicate(k)]
            for k in stale:
                del self._plans[k]
            return len(stale)

    def stats(self) -> dict:
        # len(self._plans) inlined: __len__ takes this same non-reentrant lock
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "size": len(self._plans),
            }


# ---------------------------------------------------------------------------
# Operand packing (host side) — formerly scattered across
# core/routing.tree_arrays and kernels/ops.route_constants, now built once
# per (tree, bucket geometry) and owned by cached plans.
# ---------------------------------------------------------------------------
def pack_tree_arrays(tree: FrozenQdTree, node_bucket: int) -> dict:
    """Padded flat-tree arrays for the jnp descent backend (numpy, host)."""
    n = tree.n_nodes
    if node_bucket < n:
        raise ValueError("node_bucket < n_nodes")

    def _pad(x: np.ndarray, fill) -> np.ndarray:
        out = np.full((node_bucket,) + x.shape[1:], fill, x.dtype)
        out[:n] = x
        return out

    return {
        "cut_id": _pad(tree.cut_id, -1),
        "left": _pad(tree.left, 0),
        "right": _pad(tree.right, 0),
        "leaf_bid": _pad(tree.leaf_bid, -1),
    }


def pack_cut_arrays(tree: FrozenQdTree, cut_bucket: int) -> dict:
    """Padded cut-table arrays for jnp predicate evaluation (numpy, host).

    Padded cut columns are never consulted by the descent (internal nodes
    reference only real cut ids), so their values are arbitrary-but-fixed.
    """
    cuts = tree.cuts
    n = cuts.n_cuts
    if cut_bucket < n:
        raise ValueError("cut_bucket < n_cuts")

    def _pad1(x: np.ndarray, fill) -> np.ndarray:
        out = np.full((cut_bucket,) + x.shape[1:], fill, x.dtype)
        out[:n] = x
        return out

    adv = np.array(
        [(a.col_a, a.op, a.col_b) for a in cuts.adv], np.int32
    ).reshape(-1, 3)
    return {
        "kind": _pad1(cuts.kind, -1),
        "dim": _pad1(np.maximum(cuts.dim, 0), 0),
        "cutpoint": _pad1(cuts.cutpoint, 0),
        "in_mask": _pad1(cuts.in_mask, False),
        "adv_id": _pad1(np.maximum(cuts.adv_id, 0), 0),
        "adv": adv,
        "cat_offset": np.maximum(cuts.schema.cat_offsets, 0),
    }


def path_matrices(tree: FrozenQdTree) -> tuple[np.ndarray, np.ndarray]:
    """PathPos/PathNeg (n_cuts, n_leaves): leaf path constraints."""
    n_cuts = tree.cuts.n_cuts
    pos = np.zeros((n_cuts, tree.n_leaves), np.float32)
    neg = np.zeros((n_cuts, tree.n_leaves), np.float32)
    stack: list[tuple[int, list[tuple[int, bool]]]] = [(0, [])]
    while stack:
        node, cons = stack.pop()
        bid = int(tree.leaf_bid[node])
        if bid >= 0:
            for c, d in cons:
                (pos if d else neg)[c, bid] = 1.0
        else:
            c = int(tree.cut_id[node])
            stack.append((int(tree.left[node]), cons + [(c, True)]))
            stack.append((int(tree.right[node]), cons + [(c, False)]))
    return pos, neg


def pack_route_constants(
    tree: FrozenQdTree, cut_bucket: int, leaf_bucket: int
) -> dict:
    """Dense Pallas-kernel operands at a padded geometry (numpy, host).

    ``cut_bucket``/``leaf_bucket`` must be LANE multiples ≥ the tree's
    actual counts (power-of-two buckets ≥ LANE satisfy this).
    """
    cuts, schema = tree.cuts, tree.schema
    if cut_bucket % LANE or leaf_bucket % LANE:
        raise ValueError("buckets must be LANE multiples")
    if cut_bucket < cuts.n_cuts or leaf_bucket < tree.n_leaves:
        raise ValueError("bucket smaller than tree geometry")
    d = schema.ndims
    c_pad, l_pad = cut_bucket, leaf_bucket
    dim_onehot = np.zeros((d, c_pad), np.float32)
    valid = np.arange(cuts.n_cuts)
    dim_onehot[np.maximum(cuts.dim, 0), valid] = (
        cuts.kind != 2
    ).astype(np.float32)[valid]
    cutpoint = np.zeros((1, c_pad), np.float32)
    cutpoint[0, : cuts.n_cuts] = cuts.cutpoint
    bits = max(schema.total_cat_bits, 1)
    b_pad = max(((bits + LANE - 1) // LANE) * LANE, LANE)
    in_mask_t = np.zeros((b_pad, c_pad), np.float32)
    in_mask_t[: cuts.in_mask.shape[1], : cuts.n_cuts] = (
        cuts.in_mask.T.astype(np.float32)
    )
    is_cat = schema.is_categorical.astype(np.float32)[None, :]
    cat_off = np.maximum(schema.cat_offsets, 0).astype(np.float32)[None, :]
    n_adv = cuts.n_adv
    a3 = max(n_adv, 1)
    adv_cols = np.zeros((a3, 3), np.float32)
    adv_sel = np.zeros((a3, c_pad), np.float32)
    for j, a in enumerate(cuts.adv):
        adv_cols[j] = (a.col_a, a.op, a.col_b)
    advc = np.nonzero(cuts.kind == 2)[0]
    adv_sel[cuts.adv_id[advc], advc] = 1.0
    kind = np.zeros((1, c_pad), np.float32)
    kind[0, : cuts.n_cuts] = cuts.kind

    pos, neg = path_matrices(tree)
    pos = np.pad(pos, ((0, c_pad - pos.shape[0]), (0, 0)))
    neg = np.pad(neg, ((0, c_pad - neg.shape[0]), (0, 0)))
    leafid = np.zeros((1, l_pad), np.float32)
    leafid[0, : tree.n_leaves] = np.arange(tree.n_leaves) + 1.0
    pos = np.pad(pos, ((0, 0), (0, l_pad - pos.shape[1])))
    neg = np.pad(neg, ((0, 0), (0, l_pad - neg.shape[1])))
    # padded leaf columns must always register ≥1 violation: require cut 0
    # both true and false
    pos[0, tree.n_leaves :] = 1.0
    neg[0, tree.n_leaves :] = 1.0

    return dict(
        dim_onehot=dim_onehot,
        cutpoint=cutpoint,
        in_mask_t=in_mask_t,
        is_cat=is_cat,
        cat_off=cat_off,
        adv_cols=adv_cols,
        adv_sel=adv_sel,
        kind=kind,
        pathpos=pos,
        pathneg=neg,
        leafid=leafid,
        n_adv=n_adv,
        n_cat_bits=b_pad,
    )


def pack_leaf_descs(
    tree: FrozenQdTree, leaf_bucket: int
) -> dict:
    """Padded leaf-description arrays for query intersection backends."""
    L = tree.n_leaves
    if leaf_bucket < L:
        raise ValueError("leaf_bucket < n_leaves")

    def _pad(x: np.ndarray, fill) -> np.ndarray:
        out = np.full((leaf_bucket,) + x.shape[1:], fill, x.dtype)
        out[:L] = x
        return out

    return {
        "leaf_lo": _pad(tree.leaf_lo, 0),
        "leaf_hi": _pad(tree.leaf_hi, 0),  # empty box ⇒ padded leaves miss
        "leaf_cat": _pad(tree.leaf_cat, False),
        "leaf_adv": _pad(tree.leaf_adv, False),
    }
