"""LayoutEngine: the single serving interface over a frozen qd-tree.

Consolidates the routing backends (core/routing.py), the Pallas operand
packing (kernels/ops.py), query↔block intersection (core/rewards.py) and
streaming ingestion into block buffers (data/blocks.py) behind one object:

    eng = LayoutEngine(frozen_tree, backend="jax")
    bids = eng.route(records)                   # any registered backend
    hits = eng.query_hits(workload)             # (n_leaves, n_queries) bool
    lists = eng.route_queries(workload)         # per-query BID IN (...) lists
    stats = eng.skip_stats(records, workload)   # paper Eq. 1 metrics
    report = eng.ingest(batch_iter)             # online micro-batch ingestion

All backends are bit-identical; compiled plans (jit/Pallas executables plus
their packed operands) are cached per power-of-two padding bucket so online
ingestion of varying batch sizes never retraces (``eng.stats()`` exposes the
plan-cache and trace counters).
"""

from __future__ import annotations

# qdlint: deterministic-module

import collections
import dataclasses
import threading
import time
from typing import Iterable, Iterator, Optional

import numpy as np

from repro.core import query as qry
from repro.core.qdtree import FrozenQdTree, IncrementalTightener
from repro.engine import backends as be
from repro.engine import plan as planlib
from repro.engine.plan import PlanCache


@dataclasses.dataclass(frozen=True)
class WindowStat:
    """Associative Eq. 1 accounting partial over one or more batches.

    ``scanned_tuples`` is the paper's Σ_q Σ_{P ∩ q} |P| restricted to the
    observed records; ``capacity`` the matching denominator
    Σ_batches (n_records · n_queries).  All fields are exact int64-range
    Python ints, so :meth:`merge` (elementwise sum) is associative *and*
    commutative bit-identically — shard partials fold in any order to the
    same totals as the single-stream per-batch sequence.
    """

    scanned_tuples: int = 0
    capacity: int = 0  # Σ n_records * n_queries over observed batches
    n_records: int = 0

    @property
    def scanned_fraction(self) -> float:
        """Eq. 1 fraction of tuples the standing workload would scan."""
        return self.scanned_tuples / self.capacity if self.capacity else 0.0

    def merge(self, other: "WindowStat") -> "WindowStat":
        return WindowStat(
            scanned_tuples=self.scanned_tuples + other.scanned_tuples,
            capacity=self.capacity + other.capacity,
            n_records=self.n_records + other.n_records,
        )

    # -- serialization (ShardState npz shipping) -----------------------------
    def to_array(self) -> np.ndarray:
        return np.asarray(
            [self.scanned_tuples, self.capacity, self.n_records], np.int64
        )

    @staticmethod
    def from_array(a: np.ndarray) -> "WindowStat":
        return WindowStat(*(int(x) for x in a))


@dataclasses.dataclass(frozen=True)
class ObservationProbe:
    """Per-leaf hit accounting against one standing workload.

    ``per_leaf[b]`` is the number of queries whose ``BID IN (...)`` list
    contains block ``b`` — ``query_hits(workload).sum(axis=1)`` computed
    once through the compiled plan.  Per-batch accounting is then a pure
    numpy gather+sum (``observe``): O(m) per batch, no backend dispatch,
    so ingest-time skip-rate monitoring never retraces a warm plan.
    """

    per_leaf: np.ndarray  # (n_leaves,) int64 queries scanning each block
    n_queries: int

    def observe(self, bids: np.ndarray) -> WindowStat:
        """Eq. 1 partial for one routed batch."""
        m = int(bids.shape[0])
        return WindowStat(
            scanned_tuples=int(self.per_leaf[bids].sum()),
            capacity=m * self.n_queries,
            n_records=m,
        )


@dataclasses.dataclass
class IngestReport:
    """Summary of one streaming-ingestion run."""

    n_batches: int
    n_records: int
    block_sizes: np.ndarray  # (n_leaves,) records routed per block
    wall_s: float
    backend: str
    plan_cache: dict  # hits/misses/size snapshot
    traces: dict  # trace-counter deltas during the run
    observation: Optional[WindowStat] = None  # set iff ``observe`` was given
    fused: bool = False  # True when the single-pass route+tighten path ran

    @property
    def records_per_s(self) -> float:
        return self.n_records / self.wall_s if self.wall_s else 0.0


class WorkloadTensorCache(collections.OrderedDict):
    """LRU of tensorized workloads with its own lock.

    Concurrent query threads (and generations sharing one cache across a
    hot swap) interleave get / move_to_end / popitem — the lock keeps the
    multi-step LRU update atomic.  Tensorization itself runs outside it.
    """

    def __init__(self):
        super().__init__()
        self.lock = threading.Lock()


class LayoutEngine:
    """Backend-dispatched routing/query API with a compiled-plan cache."""

    WT_CACHE_CAP = 16  # live workload-tensor entries kept per engine

    def __init__(
        self,
        tree: FrozenQdTree,
        backend: str = "jax",
        interpret: Optional[bool] = None,
        plan_cache: Optional[PlanCache] = None,
        wt_cache: Optional[WorkloadTensorCache] = None,
    ):
        be.get_backend(backend)  # validate eagerly
        self.tree = tree
        self.backend = backend
        self.interpret = interpret
        self.plans = plan_cache if plan_cache is not None else PlanCache()
        # LRU of tensorized workloads, keyed by (cut-table content
        # signature, workload id).  Values keep a strong reference to the
        # workload itself: while an entry lives its id() cannot be reused
        # by CPython, so two distinct workloads can never alias the same
        # key (the identity check in _tensorize is belt and braces).
        # LayoutService passes one shared dict to every generation's
        # engine: tensorization depends only on schema + cuts, so a hot
        # swap to a tree built from an equal cut table reuses standing
        # workload tensors instead of re-tensorizing them.
        self._wt_cache: WorkloadTensorCache = (
            wt_cache if wt_cache is not None else WorkloadTensorCache()
        )

    # -- dispatch -----------------------------------------------------------
    def _backend(self, override: Optional[str]) -> be.Backend:
        return be.get_backend(override or self.backend)

    def _opts(self) -> dict:
        return {} if self.interpret is None else {"interpret": self.interpret}

    # -- routing ------------------------------------------------------------
    def route(  # qdlint: hot-path
        self, records: np.ndarray, backend: Optional[str] = None, **opts
    ) -> np.ndarray:
        """Record batch → (m,) int32 BIDs (paper Sec 3.1)."""
        if records.shape[0] == 0:
            return np.zeros(0, np.int32)
        kw = {**self._opts(), **opts}
        return self._backend(backend).route(
            self.tree, self.plans, records, **kw
        )

    # -- query processing ---------------------------------------------------
    def _tensorize(self, workload: qry.Workload) -> qry.WorkloadTensors:
        key = (planlib.cuts_signature(self.tree.cuts), id(workload))
        with self._wt_cache.lock:
            hit = self._wt_cache.get(key)
            if hit is not None and hit[0] is workload:
                self._wt_cache.move_to_end(key)
                return hit[1]
        wt = workload.tensorize(self.tree.cuts)  # expensive: outside lock
        with self._wt_cache.lock:
            self._wt_cache[key] = (workload, wt)
            self._wt_cache.move_to_end(key)
            while len(self._wt_cache) > self.WT_CACHE_CAP:
                self._wt_cache.popitem(last=False)  # evict LRU entry
        return wt

    def query_hits(  # qdlint: hot-path
        self,
        workload: qry.Workload | qry.WorkloadTensors,
        backend: Optional[str] = None,
        **opts,
    ) -> np.ndarray:
        """(n_leaves, n_queries) bool — blocks each query must scan."""
        wt = (
            workload
            if isinstance(workload, qry.WorkloadTensors)
            else self._tensorize(workload)
        )
        kw = {**self._opts(), **opts}
        return self._backend(backend).query_hits(
            self.tree, self.plans, wt, **kw
        )

    def route_queries(  # qdlint: hot-path
        self,
        workload: qry.Workload | qry.WorkloadTensors,
        backend: Optional[str] = None,
        track=None,  # service.tracker.WorkloadTracker | None
        **opts,
    ) -> list[np.ndarray]:
        """Per-query BID IN (...) lists for a whole workload (Sec 3.3).

        The batched counterpart of :meth:`route_query` — one tensorization
        and one ``query_hits`` dispatch serve every query, so the jitted
        backends amortize compilation across the workload (the p50 latency
        fix flagged in ROADMAP; see ``benchmarks/query_routing.py``).

        ``track`` is the workload auto-detection observation hook: each
        served query's canonical predicate signature is recorded into the
        given :class:`~repro.service.tracker.WorkloadTracker` (pure host
        numpy — no backend dispatch, no plan-cache traffic, so tracking a
        warm serving path never retraces).
        """
        wt = (
            workload
            if isinstance(workload, qry.WorkloadTensors)
            else self._tensorize(workload)
        )
        if track is not None:
            track.record(workload, cuts=self.tree.cuts)
        hits = self.query_hits(wt, backend=backend, **opts)
        return [
            np.nonzero(hits[:, q])[0].astype(np.int32)
            for q in range(wt.n_queries)
        ]

    def route_query(self, query: qry.Query, track=None) -> np.ndarray:
        """BID IN (...) list for one query — 1-query ``route_queries``.

        Stays on the numpy backend (a single query never amortizes a jit
        dispatch) and tensorizes directly so one-shot queries don't churn
        the workload-tensor LRU.  ``track`` records the query into a
        :class:`~repro.service.tracker.WorkloadTracker` exactly as the
        batched path does.
        """
        wl = qry.Workload(self.tree.schema, (query,))
        if track is not None:
            track.record(wl, cuts=self.tree.cuts)
        return self.route_queries(
            wl.tensorize(self.tree.cuts), backend="numpy"
        )[0]

    def skip_stats(
        self,
        records: np.ndarray,
        workload: qry.Workload,
        tighten: bool = True,
        backend: Optional[str] = None,
    ):
        """Route + (optionally) tighten + score: paper Eq. 1 SkipStats."""
        from repro.core import rewards

        bids = self.route(records, backend=backend)
        if tighten:
            self.tree.tighten(records, bids)
        sizes = np.bincount(bids, minlength=self.tree.n_leaves).astype(
            np.int64
        )
        hits = self.query_hits(workload, backend=backend)
        scanned = int((hits * sizes[:, None]).sum())
        total = records.shape[0] * len(workload)
        return rewards.SkipStats(
            n_records=records.shape[0],
            n_queries=len(workload),
            n_blocks=self.tree.n_leaves,
            scanned_tuples=scanned,
            skipped_tuples=total - scanned,
            block_sizes=sizes,
            query_hits=hits,
        )

    # -- streaming ingestion -------------------------------------------------
    def fused_step(  # qdlint: hot-path
        self, records: np.ndarray, backend: Optional[str] = None, **opts
    ):
        """One single-pass route + tighten step (no tree mutation).

        Returns ``(bids int32 (m,), TightenPartial)`` — bit-identical to
        :meth:`route` followed by ``IncrementalTightener.update`` over the
        same records, but each record is touched once (the fused kernels;
        see ``kernels/fused_ingest.py``).  The caller folds the partial
        into a tightener (``merge``) or a shard reduction.
        """
        if records.shape[0] == 0:
            return (
                np.zeros(0, np.int32),
                IncrementalTightener(self.tree).as_partial(),
            )
        kw = {**self._opts(), **opts}
        return self._backend(backend).fused_ingest(
            self.tree, self.plans, records, **kw
        )

    def warm_ingest(
        self,
        sizes: Iterable[int],
        backend: Optional[str] = None,
        **opts,
    ) -> None:
        """Compile fused-ingest plans for these batch sizes.

        Routes zero-filled dummy batches through :meth:`fused_step` so the
        per-bucket compilations land in the plan cache before real data
        arrives; the tree itself is never mutated (the partials are
        discarded).  Callers that also serve queries should warm those
        separately via :meth:`query_hits`.
        """
        d = self.tree.leaf_lo.shape[1]
        for s in sorted({int(s) for s in sizes if int(s) > 0}):
            self.fused_step(np.zeros((s, d), np.int32), backend=backend,
                            **opts)

    def observation_probe(
        self,
        workload: "qry.Workload | qry.WorkloadTensors | ObservationProbe",
        backend: Optional[str] = None,
    ) -> ObservationProbe:
        """Per-leaf hit counts for ``workload`` against the current layout.

        One ``query_hits`` through the compiled plan (warm: zero retraces),
        reduced to ``(n_leaves,) int64``.  Already-built probes pass
        through, so shard fan-outs can compute once and replicate.
        """
        if isinstance(workload, ObservationProbe):
            return workload
        hits = self.query_hits(workload, backend=backend)
        return ObservationProbe(
            per_leaf=hits.sum(axis=1).astype(np.int64),
            n_queries=int(hits.shape[1]),
        )

    def ingest(
        self,
        batches: Iterable[np.ndarray] | Iterator[np.ndarray],
        tighten: bool = True,
        buffers=None,  # data.blocks.BlockBuffers | None
        backend: Optional[str] = None,
        observe=None,  # Workload | WorkloadTensors | ObservationProbe | None
        on_observation=None,  # Callable[[WindowStat], None] | None
        fused: bool = True,
    ) -> IngestReport:
        """Route arriving micro-batches and fold them into the layout.

        Per batch: route → append to ``buffers`` (if given) → incrementally
        min-max-tighten leaf descriptions.  The incremental tightener is
        exactly equivalent to one-shot ``FrozenQdTree.tighten`` over the
        concatenation of all batches (min/max/any are associative).

        With ``observe`` set (a standing workload or a pre-built
        :class:`ObservationProbe`), every routed batch is additionally
        scored against the workload's per-leaf hit counts — the paper's
        Eq. 1 restricted to that batch — and the resulting
        :class:`WindowStat` is passed to ``on_observation`` (the seam a
        drift monitor plugs into; see ``repro.service.drift``).  The run's
        aggregate lands in ``IngestReport.observation``.  The probe is
        built once per call from the layout as of the start of the run, so
        the accounting itself is a pure numpy gather — no retraces.

        ``fused=True`` (the default) takes the single-pass route+tighten
        path — :meth:`fused_step` per batch, partials folded into the
        tightener via ``merge`` — which is bit-identical to the legacy
        two-pass loop but touches each record once.  ``fused=False``
        restores the two-pass path (route, then host-side tighten).
        """
        traces0 = planlib.trace_counts()
        probe = (
            self.observation_probe(observe, backend=backend)
            if observe is not None
            else None
        )
        observed = WindowStat() if probe is not None else None
        tightener = IncrementalTightener(self.tree) if tighten else None
        # the tightener already keeps per-leaf counts; only maintain a
        # separate accumulator when there is no tightener to read back
        sizes = None if tighten else np.zeros(self.tree.n_leaves, np.int64)
        use_fused = fused and tightener is not None
        # nothing downstream reads per-row block ids when there is no
        # spill buffer and no observation probe: skip their device→host
        # transfer per batch (the dominant host sync of the warm loop)
        need_bids = buffers is not None or probe is not None
        n_batches = n_records = 0
        t0 = time.perf_counter()
        for batch in batches:
            if batch.shape[0] == 0:
                continue
            if use_fused:
                bids, part = self.fused_step(
                    batch, backend=backend, return_bids=need_bids
                )
            else:
                bids = self.route(batch, backend=backend)
            if buffers is not None:
                buffers.append(batch, bids)
            if use_fused:
                tightener.merge(part)
            elif tightener is not None:
                tightener.update(batch, bids)
            else:
                sizes += np.bincount(bids, minlength=sizes.shape[0])
            if probe is not None:
                stat = probe.observe(bids)
                observed = observed.merge(stat)
                if on_observation is not None:
                    on_observation(stat)
            n_batches += 1
            n_records += batch.shape[0]
        if tightener is not None:
            tightener.apply()
            sizes = tightener.counts.copy()
        wall = time.perf_counter() - t0
        delta = planlib.trace_delta(traces0, planlib.trace_counts())
        return IngestReport(
            n_batches=n_batches,
            n_records=n_records,
            block_sizes=sizes,
            wall_s=wall,
            backend=backend or self.backend,
            plan_cache=self.plans.stats(),
            traces=delta,
            observation=observed,
            fused=use_fused,
        )

    # -- introspection -------------------------------------------------------
    def stats(self) -> dict:
        return {
            "backend": self.backend,
            "plan_cache": self.plans.stats(),
            "traces": planlib.trace_counts(),
        }


def engine_for(
    tree: FrozenQdTree, backend: str = "jax", **kw
) -> LayoutEngine:
    """The tree's attached engine (created on first use).

    Attaching keeps the plan cache alive across the legacy free-function
    callsites (``routing.route``, ``BlockStore.create``, benchmarks) without
    threading an engine object through every signature.
    """
    eng = getattr(tree, "_layout_engine", None)
    if eng is None:
        eng = LayoutEngine(tree, backend=backend, **kw)
        object.__setattr__(tree, "_layout_engine", eng)
    return eng
