"""LayoutEngine: the single serving interface over a frozen qd-tree.

Consolidates the routing backends (core/routing.py), the Pallas operand
packing (kernels/ops.py), query↔block intersection (core/rewards.py) and
streaming ingestion into block buffers (data/blocks.py) behind one object:

    eng = LayoutEngine(frozen_tree, backend="jax")
    bids = eng.route(records)                   # any registered backend
    hits = eng.query_hits(workload)             # (n_leaves, n_queries) bool
    lists = eng.route_queries(workload)         # per-query BID IN (...) lists
    stats = eng.skip_stats(records, workload)   # paper Eq. 1 metrics
    report = eng.ingest(batch_iter)             # online micro-batch ingestion

All backends are bit-identical; compiled plans (jit/Pallas executables plus
their packed operands) are cached per power-of-two padding bucket so online
ingestion of varying batch sizes never retraces (``eng.stats()`` exposes the
plan-cache and trace counters).
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Iterable, Iterator, Optional

import numpy as np

from repro.core import query as qry
from repro.core.qdtree import FrozenQdTree, IncrementalTightener
from repro.engine import backends as be
from repro.engine import plan as planlib
from repro.engine.plan import PlanCache


@dataclasses.dataclass
class IngestReport:
    """Summary of one streaming-ingestion run."""

    n_batches: int
    n_records: int
    block_sizes: np.ndarray  # (n_leaves,) records routed per block
    wall_s: float
    backend: str
    plan_cache: dict  # hits/misses/size snapshot
    traces: dict  # trace-counter deltas during the run

    @property
    def records_per_s(self) -> float:
        return self.n_records / self.wall_s if self.wall_s else 0.0


class WorkloadTensorCache(collections.OrderedDict):
    """LRU of tensorized workloads with its own lock.

    Concurrent query threads (and generations sharing one cache across a
    hot swap) interleave get / move_to_end / popitem — the lock keeps the
    multi-step LRU update atomic.  Tensorization itself runs outside it.
    """

    def __init__(self):
        super().__init__()
        self.lock = threading.Lock()


class LayoutEngine:
    """Backend-dispatched routing/query API with a compiled-plan cache."""

    WT_CACHE_CAP = 16  # live workload-tensor entries kept per engine

    def __init__(
        self,
        tree: FrozenQdTree,
        backend: str = "jax",
        interpret: Optional[bool] = None,
        plan_cache: Optional[PlanCache] = None,
        wt_cache: Optional[WorkloadTensorCache] = None,
    ):
        be.get_backend(backend)  # validate eagerly
        self.tree = tree
        self.backend = backend
        self.interpret = interpret
        self.plans = plan_cache if plan_cache is not None else PlanCache()
        # LRU of tensorized workloads, keyed by (cut-table content
        # signature, workload id).  Values keep a strong reference to the
        # workload itself: while an entry lives its id() cannot be reused
        # by CPython, so two distinct workloads can never alias the same
        # key (the identity check in _tensorize is belt and braces).
        # LayoutService passes one shared dict to every generation's
        # engine: tensorization depends only on schema + cuts, so a hot
        # swap to a tree built from an equal cut table reuses standing
        # workload tensors instead of re-tensorizing them.
        self._wt_cache: WorkloadTensorCache = (
            wt_cache if wt_cache is not None else WorkloadTensorCache()
        )

    # -- dispatch -----------------------------------------------------------
    def _backend(self, override: Optional[str]) -> be.Backend:
        return be.get_backend(override or self.backend)

    def _opts(self) -> dict:
        return {} if self.interpret is None else {"interpret": self.interpret}

    # -- routing ------------------------------------------------------------
    def route(
        self, records: np.ndarray, backend: Optional[str] = None, **opts
    ) -> np.ndarray:
        """Record batch → (m,) int32 BIDs (paper Sec 3.1)."""
        if records.shape[0] == 0:
            return np.zeros(0, np.int32)
        kw = {**self._opts(), **opts}
        return self._backend(backend).route(
            self.tree, self.plans, records, **kw
        )

    # -- query processing ---------------------------------------------------
    def _tensorize(self, workload: qry.Workload) -> qry.WorkloadTensors:
        key = (planlib.cuts_signature(self.tree.cuts), id(workload))
        with self._wt_cache.lock:
            hit = self._wt_cache.get(key)
            if hit is not None and hit[0] is workload:
                self._wt_cache.move_to_end(key)
                return hit[1]
        wt = workload.tensorize(self.tree.cuts)  # expensive: outside lock
        with self._wt_cache.lock:
            self._wt_cache[key] = (workload, wt)
            self._wt_cache.move_to_end(key)
            while len(self._wt_cache) > self.WT_CACHE_CAP:
                self._wt_cache.popitem(last=False)  # evict LRU entry
        return wt

    def query_hits(
        self,
        workload: qry.Workload | qry.WorkloadTensors,
        backend: Optional[str] = None,
        **opts,
    ) -> np.ndarray:
        """(n_leaves, n_queries) bool — blocks each query must scan."""
        wt = (
            workload
            if isinstance(workload, qry.WorkloadTensors)
            else self._tensorize(workload)
        )
        kw = {**self._opts(), **opts}
        return self._backend(backend).query_hits(
            self.tree, self.plans, wt, **kw
        )

    def route_queries(
        self,
        workload: qry.Workload | qry.WorkloadTensors,
        backend: Optional[str] = None,
        **opts,
    ) -> list[np.ndarray]:
        """Per-query BID IN (...) lists for a whole workload (Sec 3.3).

        The batched counterpart of :meth:`route_query` — one tensorization
        and one ``query_hits`` dispatch serve every query, so the jitted
        backends amortize compilation across the workload (the p50 latency
        fix flagged in ROADMAP; see ``benchmarks/query_routing.py``).
        """
        wt = (
            workload
            if isinstance(workload, qry.WorkloadTensors)
            else self._tensorize(workload)
        )
        hits = self.query_hits(wt, backend=backend, **opts)
        return [
            np.nonzero(hits[:, q])[0].astype(np.int32)
            for q in range(wt.n_queries)
        ]

    def route_query(self, query: qry.Query) -> np.ndarray:
        """BID IN (...) list for one query — 1-query ``route_queries``.

        Stays on the numpy backend (a single query never amortizes a jit
        dispatch) and tensorizes directly so one-shot queries don't churn
        the workload-tensor LRU.
        """
        wl = qry.Workload(self.tree.schema, (query,))
        return self.route_queries(
            wl.tensorize(self.tree.cuts), backend="numpy"
        )[0]

    def skip_stats(
        self,
        records: np.ndarray,
        workload: qry.Workload,
        tighten: bool = True,
        backend: Optional[str] = None,
    ):
        """Route + (optionally) tighten + score: paper Eq. 1 SkipStats."""
        from repro.core import rewards

        bids = self.route(records, backend=backend)
        if tighten:
            self.tree.tighten(records, bids)
        sizes = np.bincount(bids, minlength=self.tree.n_leaves).astype(
            np.int64
        )
        hits = self.query_hits(workload, backend=backend)
        scanned = int((hits * sizes[:, None]).sum())
        total = records.shape[0] * len(workload)
        return rewards.SkipStats(
            n_records=records.shape[0],
            n_queries=len(workload),
            n_blocks=self.tree.n_leaves,
            scanned_tuples=scanned,
            skipped_tuples=total - scanned,
            block_sizes=sizes,
            query_hits=hits,
        )

    # -- streaming ingestion -------------------------------------------------
    def ingest(
        self,
        batches: Iterable[np.ndarray] | Iterator[np.ndarray],
        tighten: bool = True,
        buffers=None,  # data.blocks.BlockBuffers | None
        backend: Optional[str] = None,
    ) -> IngestReport:
        """Route arriving micro-batches and fold them into the layout.

        Per batch: route → append to ``buffers`` (if given) → incrementally
        min-max-tighten leaf descriptions.  The incremental tightener is
        exactly equivalent to one-shot ``FrozenQdTree.tighten`` over the
        concatenation of all batches (min/max/any are associative).
        """
        traces0 = planlib.trace_counts()
        tightener = IncrementalTightener(self.tree) if tighten else None
        # the tightener already keeps per-leaf counts; only maintain a
        # separate accumulator when there is no tightener to read back
        sizes = None if tighten else np.zeros(self.tree.n_leaves, np.int64)
        n_batches = n_records = 0
        t0 = time.perf_counter()
        for batch in batches:
            if batch.shape[0] == 0:
                continue
            bids = self.route(batch, backend=backend)
            if buffers is not None:
                buffers.append(batch, bids)
            if tightener is not None:
                tightener.update(batch, bids)
            else:
                sizes += np.bincount(bids, minlength=sizes.shape[0])
            n_batches += 1
            n_records += batch.shape[0]
        if tightener is not None:
            tightener.apply()
            sizes = tightener.counts.copy()
        wall = time.perf_counter() - t0
        delta = planlib.trace_delta(traces0, planlib.trace_counts())
        return IngestReport(
            n_batches=n_batches,
            n_records=n_records,
            block_sizes=sizes,
            wall_s=wall,
            backend=backend or self.backend,
            plan_cache=self.plans.stats(),
            traces=delta,
        )

    # -- introspection -------------------------------------------------------
    def stats(self) -> dict:
        return {
            "backend": self.backend,
            "plan_cache": self.plans.stats(),
            "traces": planlib.trace_counts(),
        }


def engine_for(
    tree: FrozenQdTree, backend: str = "jax", **kw
) -> LayoutEngine:
    """The tree's attached engine (created on first use).

    Attaching keeps the plan cache alive across the legacy free-function
    callsites (``routing.route``, ``BlockStore.create``, benchmarks) without
    threading an engine object through every signature.
    """
    eng = getattr(tree, "_layout_engine", None)
    if eng is None:
        eng = LayoutEngine(tree, backend=backend, **kw)
        object.__setattr__(tree, "_layout_engine", eng)
    return eng
