"""qdlint command line.

    PYTHONPATH=src python -m repro.analysis [paths] [options]

Exit codes: 0 clean (or everything baselined/suppressed), 1 actionable
findings, 2 usage / internal error.

``--self-test`` runs the bundled fixture corpus through every checker
and asserts each rule still fires on its true-positive fixture and
stays silent on its idiomatic twin — a meta-test wired into CI so a
refactor of qdlint itself cannot quietly stop enforcing a contract.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Optional, Sequence

from repro.analysis.core import (
    CHECKER_CODES,
    Report,
    analyze_file,
    run,
    write_baseline,
)

DEFAULT_BASELINE = "qdlint-baseline.json"
FIXTURES_DIR = pathlib.Path(__file__).resolve().parent / "fixtures"


def _render_text(report: Report) -> str:
    lines = [f.render() for f in report.findings]
    counts = report.counts()
    summary = ", ".join(
        f"{code}={n}" for code, n in counts.items() if n
    ) or "clean"
    lines.append(
        f"qdlint: {len(report.findings)} finding(s) [{summary}] across "
        f"{report.files} file(s); {len(report.baselined)} baselined, "
        f"{len(report.suppressed)} suppressed"
    )
    return "\n".join(lines)


def self_test(verbose: bool = True) -> bool:
    """Assert the fixture corpus still flags/passes per checker."""
    ok = True

    def expect(name: str, codes: set, min_findings: int,
               max_findings: Optional[int] = None,
               min_suppressed: int = 0) -> None:
        nonlocal ok
        path = FIXTURES_DIR / name
        result = analyze_file(path)
        got_codes = {f.code for f in result.findings}
        n = len(result.findings)
        good = (
            n >= min_findings
            and (max_findings is None or n <= max_findings)
            and got_codes <= codes
            and (min_findings == 0 or got_codes == codes)
            and len(result.suppressed) >= min_suppressed
        )
        if not good:
            ok = False
        if verbose or not good:
            status = "ok" if good else "FAIL"
            detail = "; ".join(f.render() for f in result.findings)
            print(
                f"[qdlint self-test] {status} {name}: {n} finding(s) "
                f"{sorted(got_codes)} suppressed="
                f"{len(result.suppressed)}"
                + (f" :: {detail}" if not good and detail else "")
            )

    for code in CHECKER_CODES:
        stem = code.lower()
        expect(f"{stem}_tp.py", {code}, min_findings=1)
        expect(f"{stem}_ok.py", set(), min_findings=0, max_findings=0)
    expect("suppress_ok.py", set(), min_findings=0, max_findings=0,
           min_suppressed=1)
    expect("suppress_noreason.py", {"QD001"}, min_findings=1)
    if verbose:
        print(
            "[qdlint self-test] PASS"
            if ok else "[qdlint self-test] FAIL"
        )
    return ok


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="qdlint: invariant-aware static analysis "
        "(lock, determinism, retrace, host-sync, CAS contracts)",
    )
    ap.add_argument(
        "paths", nargs="*",
        help="files or directories to scan (default: src)",
    )
    ap.add_argument(
        "--format", choices=("text", "json"), default="text",
        dest="fmt", help="stdout report format",
    )
    ap.add_argument(
        "--baseline", nargs="?", const=DEFAULT_BASELINE, default=None,
        metavar="PATH",
        help="absorb findings fingerprinted in PATH "
        f"(default when flag given: {DEFAULT_BASELINE})",
    )
    ap.add_argument(
        "--write-baseline", action="store_true",
        help="rewrite the baseline file from the current findings "
        "and exit 0",
    )
    ap.add_argument(
        "--output", metavar="PATH",
        help="also write the JSON report to PATH (for CI artifacts)",
    )
    ap.add_argument(
        "--self-test", action="store_true",
        help="run the bundled fixture corpus through every checker",
    )
    args = ap.parse_args(argv)

    if args.self_test:
        return 0 if self_test() else 1

    paths = args.paths or ["src"]
    missing = [p for p in paths if not pathlib.Path(p).exists()]
    if missing:
        print(f"qdlint: no such path(s): {', '.join(missing)}",
              file=sys.stderr)
        return 2

    if args.write_baseline:
        baseline_path = args.baseline or DEFAULT_BASELINE
        report = run(paths, baseline=None)
        write_baseline(report.findings, baseline_path)
        print(
            f"qdlint: wrote {len(report.findings)} fingerprint(s) to "
            f"{baseline_path}"
        )
        return 0

    report = run(paths, baseline=args.baseline)
    doc = report.as_dict()
    if args.output:
        pathlib.Path(args.output).write_text(
            json.dumps(doc, indent=2) + "\n", encoding="utf-8"
        )
    if args.fmt == "json":
        print(json.dumps(doc, indent=2))
    else:
        print(_render_text(report))
    return 1 if report.findings else 0
