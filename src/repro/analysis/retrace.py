"""QD003/QD004: retrace hazards in jit bodies and host syncs in hot paths.

QD003 has two legs:

* **Branching on traced values.**  Inside a jit-compiled function,
  ``if``/``while`` on a traced argument forces a concretization error
  at best and a silent retrace-per-value at worst.  Checked bodies are
  functions decorated ``@jax.jit`` / ``@functools.partial(jax.jit,
  static_argnames=...)``, plus pallas kernel bodies marked
  ``# qdlint: jit-body`` on the ``def`` line (convention: positional
  parameters are traced refs, keyword-only parameters are static —
  exactly how the kernels in ``repro.kernels`` are closed over).
  Branches on static parameters and on locals are allowed (locals are
  under-approximated as safe; the repo idiom computes static shape
  predicates into locals before branching).
* **PlanKey buckets bypassing pad_bucket.**  The zero-warm-retraces
  contract holds because every compiled-plan cache key quantizes its
  shape coordinates (``m_bucket``/``node_bucket``/``leaf_bucket``/
  ``cut_bucket``) through :func:`repro.engine.plan.pad_bucket`.  A
  ``PlanKey(...)`` whose bucket argument is a raw value keys the cache
  on exact shapes — one compile per batch size.  Accepted: integer
  literals, expressions containing a ``pad_bucket`` call, and names
  assigned (transitively) from such expressions within the function.

QD004 flags host-synchronizing calls — ``float(x)``, ``x.item()``,
``np.asarray`` / ``np.array`` / ``jax.device_get`` — inside functions
whose ``def`` line is marked ``# qdlint: hot-path``.  Each one blocks
on device completion and drags the result across the host boundary;
hot paths must stay device-side (``jnp.asarray`` is fine).
"""

from __future__ import annotations

import ast
from typing import Optional

from repro.analysis.core import Finding, ModuleInfo

_BUCKET_FIELDS = ("m_bucket", "node_bucket", "leaf_bucket", "cut_bucket")
# PlanKey(sig, backend, m_bucket, node_bucket, leaf_bucket, cut_bucket, opts)
_BUCKET_POSITIONS = {2: "m_bucket", 3: "node_bucket",
                     4: "leaf_bucket", 5: "cut_bucket"}

_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef)


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of an expression ('' when not a name)."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    return ""


def _is_jax_jit(node: ast.AST) -> bool:
    return _dotted(node) in ("jax.jit", "jit")


def _static_names_from_kwargs(
    keywords: list[ast.keyword], fn
) -> set[str]:
    statics: set[str] = set()
    params = [a.arg for a in fn.args.args]
    for kw in keywords:
        if kw.arg == "static_argnames":
            v = kw.value
            elts = v.elts if isinstance(v, (ast.Tuple, ast.List)) else [v]
            for e in elts:
                if isinstance(e, ast.Constant) and isinstance(
                    e.value, str
                ):
                    statics.add(e.value)
        elif kw.arg == "static_argnums":
            v = kw.value
            elts = v.elts if isinstance(v, (ast.Tuple, ast.List)) else [v]
            for e in elts:
                if isinstance(e, ast.Constant) and isinstance(
                    e.value, int
                ) and 0 <= e.value < len(params):
                    statics.add(params[e.value])
    return statics


def _jit_traced_params(info: ModuleInfo, fn) -> Optional[set[str]]:
    """Traced parameter names if ``fn`` is a jit body, else None."""
    statics: Optional[set[str]] = None
    for dec in fn.decorator_list:
        if _is_jax_jit(dec):
            statics = set()
            break
        if isinstance(dec, ast.Call):
            callee = _dotted(dec.func)
            if callee in ("functools.partial", "partial") and dec.args \
                    and _is_jax_jit(dec.args[0]):
                statics = _static_names_from_kwargs(dec.keywords, fn)
                break
            if _is_jax_jit(dec.func):
                statics = _static_names_from_kwargs(dec.keywords, fn)
                break
    if statics is None:
        if "jit-body" in info.markers_on(fn.lineno):
            # kernel convention: positional refs traced, kwonly static
            return {a.arg for a in fn.args.args}
        return None
    params = {a.arg for a in fn.args.args}
    params |= {a.arg for a in fn.args.kwonlyargs}
    return params - statics


class _NameFinder(ast.NodeVisitor):
    def __init__(self):
        self.names: set[str] = set()
        self.calls: set[str] = set()

    def visit_Name(self, node: ast.Name) -> None:
        self.names.add(node.id)
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        dotted = _dotted(node.func)
        if dotted:
            self.calls.add(dotted)
        self.generic_visit(node)


def _expr_names(node: ast.AST) -> tuple[set[str], set[str]]:
    finder = _NameFinder()
    finder.visit(node)
    return finder.names, finder.calls


def _has_pad_bucket_call(calls: set[str]) -> bool:
    return any(
        c == "pad_bucket" or c.endswith(".pad_bucket") for c in calls
    )


def _pad_derived_names(fn) -> set[str]:
    """Names assigned (transitively) from pad_bucket expressions."""
    assigns: list[tuple[str, ast.AST]] = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            assigns.append((node.targets[0].id, node.value))
        elif isinstance(node, ast.AnnAssign) and isinstance(
            node.target, ast.Name
        ) and node.value is not None:
            assigns.append((node.target.id, node.value))
    derived: set[str] = set()
    changed = True
    while changed:
        changed = False
        for name, value in assigns:
            if name in derived:
                continue
            names, calls = _expr_names(value)
            if _has_pad_bucket_call(calls) or (names & derived):
                derived.add(name)
                changed = True
    return derived


def _bucket_arg_ok(node: ast.AST, derived: set[str]) -> bool:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return True
    names, calls = _expr_names(node)
    return _has_pad_bucket_call(calls) or bool(names & derived)


def check_retrace(info: ModuleInfo) -> list[Finding]:
    findings: list[Finding] = []

    def flag(code: str, node: ast.AST, symbol: str, message: str):
        findings.append(
            Finding(
                code=code,
                path=info.rel,
                line=node.lineno,
                col=node.col_offset,
                symbol=symbol,
                message=message,
            )
        )

    for fn in [
        n for n in ast.walk(info.tree) if isinstance(n, _FUNC_NODES)
    ]:
        # QD003a: Python branches on traced values inside jit bodies
        traced = _jit_traced_params(info, fn)
        if traced:
            for node in ast.walk(fn):
                if isinstance(node, (ast.If, ast.While)):
                    names, _ = _expr_names(node.test)
                    hot = sorted(names & traced)
                    if hot:
                        flag(
                            "QD003", node, fn.name,
                            "Python-level branch on traced value(s) "
                            f"{', '.join(hot)} inside a jit body — "
                            "hoist to a static argument or use "
                            "jnp.where/lax.cond",
                        )

        # QD003b: PlanKey buckets must flow through pad_bucket
        derived: Optional[set[str]] = None
        for node in ast.walk(fn):
            if not (
                isinstance(node, ast.Call)
                and _dotted(node.func).split(".")[-1] == "PlanKey"
            ):
                continue
            if derived is None:
                derived = _pad_derived_names(fn)
            suspects: list[tuple[str, ast.AST]] = []
            for pos, name in _BUCKET_POSITIONS.items():
                if pos < len(node.args):
                    suspects.append((name, node.args[pos]))
            for kw in node.keywords:
                if kw.arg in _BUCKET_FIELDS:
                    suspects.append((kw.arg, kw.value))
            for name, arg in suspects:
                if not _bucket_arg_ok(arg, derived):
                    flag(
                        "QD003", arg, fn.name,
                        f"PlanKey {name} not derived from pad_bucket — "
                        "raw shapes defeat the padding-bucket plan "
                        "cache (one retrace per distinct size)",
                    )

        # QD004: host syncs inside hot-path functions
        if "hot-path" not in info.markers_on(fn.lineno):
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            if isinstance(func, ast.Name) and func.id == "float" \
                    and len(node.args) == 1:
                flag(
                    "QD004", node, fn.name,
                    "float(...) in a hot-path function forces a host "
                    "sync on device arrays",
                )
            elif isinstance(func, ast.Attribute):
                if func.attr == "item" and not node.args:
                    flag(
                        "QD004", node, fn.name,
                        ".item() in a hot-path function forces a host "
                        "sync",
                    )
                else:
                    dotted = _dotted(func)
                    if dotted in (
                        "np.asarray", "numpy.asarray",
                        "np.array", "numpy.array",
                        "jax.device_get",
                    ):
                        flag(
                            "QD004", node, fn.name,
                            f"{dotted}(...) in a hot-path function "
                            "pulls device arrays to host",
                        )
    return findings
