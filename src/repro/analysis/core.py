"""qdlint core: findings, source annotations, suppressions, baseline, runner.

qdlint is an AST-based static-analysis pass for the invariants the rest
of the stack *assumes* but nothing else enforces at the source level:

* **QD001 lock discipline** — attributes declared ``# guarded by:
  self._lock`` touched outside a ``with self._lock:`` block.
* **QD002 determinism** — unsorted iteration over set expressions, and
  wall-clock / unseeded randomness, inside modules declared
  ``# qdlint: deterministic-module`` (the bit-identity contract behind
  every ShardState/TrackerState merge and replica fold).
* **QD003 retrace hazard** — Python branches on traced values inside
  jit bodies, and ``PlanKey`` bucket arguments that bypass
  ``pad_bucket`` (the zero-warm-retraces contract).
* **QD004 host-sync hazard** — ``float()`` / ``.item()`` /
  ``np.asarray()`` device syncs inside functions marked
  ``# qdlint: hot-path``.
* **QD005 epoch/CAS discipline** — writes to ``# swap-guarded by:``
  state (the atomically-snapshotted live pointer) outside the lock;
  lock-free *reads* of such state are sanctioned by design.

Annotations are plain comments so the checked modules carry no runtime
dependency on this package; the package itself is stdlib-only so the
ruff-only CI lint job can run it with nothing but ``PYTHONPATH=src``.

Suppression: ``# qdlint: disable=QD001,QD002 <reason>`` on the finding
line.  The reason text is REQUIRED — a bare disable is ignored (and the
finding still fires), so every suppression documents *why* the contract
does not apply.

Baseline: a committed JSON file of finding fingerprints
(``{code}::{path}::{symbol}::{message}`` — line-number-free so it
survives unrelated edits).  Findings absorbed by the baseline are
reported separately and do not fail the run.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import os
import pathlib
import re
from collections import Counter
from typing import Iterable, Optional, Sequence

CHECKER_CODES = ("QD001", "QD002", "QD003", "QD004", "QD005")

#: path fragments never scanned (the fixture corpus is deliberately
#: full of violations; scanning it would drown real findings)
EXCLUDED_FRAGMENTS = ("repro/analysis/fixtures/",)

_LOCK_LIST = r"[A-Za-z_][\w.]*(?:\s*,\s*[A-Za-z_][\w.]*)*"
_SWAP_RE = re.compile(rf"#\s*swap-guarded by:\s*(?P<locks>{_LOCK_LIST})")
_GUARD_RE = re.compile(rf"#\s*guarded by:\s*(?P<locks>{_LOCK_LIST})")
_MARKER_RE = re.compile(
    r"#\s*qdlint:\s*(?P<marker>hot-path|holds-lock|jit-body|"
    r"deterministic-module)\b"
)
_SUPPRESS_RE = re.compile(
    r"#\s*qdlint:\s*disable=(?P<codes>QD\d{3}(?:\s*,\s*QD\d{3})*)"
    r"\s*(?P<reason>.*)$"
)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    code: str
    path: str
    line: int
    col: int
    symbol: str
    message: str

    def fingerprint(self) -> str:
        """Line-number-free identity used by the baseline file."""
        return f"{self.code}::{self.path}::{self.symbol}::{self.message}"

    def render(self) -> str:
        return (
            f"{self.path}:{self.line}:{self.col}: {self.code} "
            f"[{self.symbol}] {self.message}"
        )

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ModuleInfo:
    """A parsed module plus its comment-level qdlint annotations."""

    path: pathlib.Path
    rel: str
    tree: ast.Module
    lines: list[str]
    deterministic: bool
    # lineno -> (lock expressions, kind: "guard" | "swap")
    guards: dict[int, tuple[tuple[str, ...], str]]
    # lineno -> marker names on that line (hot-path / holds-lock / jit-body)
    markers: dict[int, set[str]]
    # lineno -> (suppressed codes, reason text)
    suppressions: dict[int, tuple[frozenset, str]]

    def markers_on(self, lineno: int) -> set[str]:
        return self.markers.get(lineno, set())


@dataclasses.dataclass
class FileResult:
    findings: list[Finding]
    suppressed: list[Finding]


@dataclasses.dataclass
class Report:
    """Aggregate result of one qdlint run."""

    findings: list[Finding]  # actionable (not suppressed, not baselined)
    baselined: list[Finding]
    suppressed: list[Finding]
    files: int

    def counts(self) -> dict[str, int]:
        out = {code: 0 for code in CHECKER_CODES}
        for f in self.findings:
            out[f.code] = out.get(f.code, 0) + 1
        return out

    def as_dict(self) -> dict:
        return {
            "version": 1,
            "files": self.files,
            "findings": [f.as_dict() for f in self.findings],
            "baselined": len(self.baselined),
            "suppressed": len(self.suppressed),
            "counts": self.counts(),
        }


def _split_locks(raw: str) -> tuple[str, ...]:
    return tuple(
        lock.strip() for lock in raw.split(",") if lock.strip()
    )


def parse_module(
    path: os.PathLike, rel: Optional[str] = None
) -> ModuleInfo:
    """Parse ``path`` and extract its qdlint comment annotations.

    The AST carries no comments, so annotations are recovered from the
    raw source lines and keyed by 1-based line number; checkers join
    them to AST nodes via ``node.lineno``.
    """
    p = pathlib.Path(path)
    source = p.read_text(encoding="utf-8")
    tree = ast.parse(source, filename=str(p))
    lines = source.splitlines()
    guards: dict[int, tuple[tuple[str, ...], str]] = {}
    markers: dict[int, set[str]] = {}
    suppressions: dict[int, tuple[frozenset, str]] = {}
    deterministic = False
    for lineno, text in enumerate(lines, start=1):
        if "#" not in text:
            continue
        m = _SUPPRESS_RE.search(text)
        if m:
            codes = frozenset(
                c.strip() for c in m.group("codes").split(",")
            )
            suppressions[lineno] = (codes, m.group("reason").strip())
        m = _SWAP_RE.search(text)
        if m:
            guards[lineno] = (_split_locks(m.group("locks")), "swap")
        else:
            m = _GUARD_RE.search(text)
            if m:
                guards[lineno] = (
                    _split_locks(m.group("locks")), "guard"
                )
        for m in _MARKER_RE.finditer(text):
            marker = m.group("marker")
            if marker == "deterministic-module":
                deterministic = True
            else:
                markers.setdefault(lineno, set()).add(marker)
    if rel is None:
        try:
            rel = os.path.relpath(p)
        except ValueError:  # different drive (windows)
            rel = str(p)
    return ModuleInfo(
        path=p,
        rel=pathlib.PurePath(rel).as_posix(),
        tree=tree,
        lines=lines,
        deterministic=deterministic,
        guards=guards,
        markers=markers,
        suppressions=suppressions,
    )


def analyze_file(
    path: os.PathLike, rel: Optional[str] = None
) -> FileResult:
    """Run every checker over one file and apply inline suppressions."""
    # imported here so checker modules can import Finding from core
    from repro.analysis.determinism import check_determinism
    from repro.analysis.lock_check import check_locks
    from repro.analysis.retrace import check_retrace

    info = parse_module(path, rel=rel)
    raw: list[Finding] = []
    raw.extend(check_locks(info))
    raw.extend(check_determinism(info))
    raw.extend(check_retrace(info))
    raw.sort(key=lambda f: (f.line, f.col, f.code, f.message))
    findings: list[Finding] = []
    suppressed: list[Finding] = []
    for f in raw:
        entry = info.suppressions.get(f.line)
        if entry is not None:
            codes, reason = entry
            # a reason is mandatory: an undocumented disable is inert
            if f.code in codes and reason:
                suppressed.append(f)
                continue
        findings.append(f)
    return FileResult(findings=findings, suppressed=suppressed)


def iter_python_files(
    paths: Sequence[os.PathLike],
) -> Iterable[pathlib.Path]:
    """Expand files/directories into the .py files qdlint scans."""
    for raw in paths:
        p = pathlib.Path(raw)
        if p.is_dir():
            # the exclusion only applies to directory expansion: naming
            # a fixture file explicitly (tests, self-test) still scans it
            for c in sorted(p.rglob("*.py")):
                posix = c.as_posix()
                if any(frag in posix for frag in EXCLUDED_FRAGMENTS):
                    continue
                yield c
        else:
            yield p


def load_baseline(path: os.PathLike) -> Counter:
    """The committed fingerprint multiset (empty if the file is absent)."""
    p = pathlib.Path(path)
    if not p.exists():
        return Counter()
    doc = json.loads(p.read_text(encoding="utf-8"))
    return Counter(doc.get("findings", []))


def write_baseline(
    findings: Iterable[Finding], path: os.PathLike
) -> None:
    fps = sorted(f.fingerprint() for f in findings)
    doc = {"version": 1, "findings": fps}
    pathlib.Path(path).write_text(
        json.dumps(doc, indent=2) + "\n", encoding="utf-8"
    )


def run(
    paths: Sequence[os.PathLike],
    baseline: Optional[os.PathLike] = None,
) -> Report:
    """Scan ``paths`` and return a :class:`Report`.

    With ``baseline``, findings whose fingerprints appear in the
    committed multiset are absorbed (each baseline entry absorbs one
    occurrence) and reported under ``baselined`` instead.
    """
    budget = load_baseline(baseline) if baseline is not None else Counter()
    findings: list[Finding] = []
    baselined: list[Finding] = []
    suppressed: list[Finding] = []
    files = 0
    for path in iter_python_files(paths):
        files += 1
        result = analyze_file(path)
        suppressed.extend(result.suppressed)
        for f in result.findings:
            fp = f.fingerprint()
            if budget.get(fp, 0) > 0:
                budget[fp] -= 1
                baselined.append(f)
            else:
                findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.code))
    return Report(
        findings=findings,
        baselined=baselined,
        suppressed=suppressed,
        files=files,
    )
