"""qdlint fixture: QD005 true positive — live pointer swapped unlocked."""

import threading


class Service:
    def __init__(self):
        self._lock = threading.Lock()
        self._live = object()  # swap-guarded by: self._lock

    def swap(self, version):
        self._live = version
        return self._live
