"""qdlint fixture: QD001 must-not-flag — every access holds the lock."""

import threading


class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self._count = 0  # guarded by: self._lock
        self._count += 1  # constructor is exempt: not yet shared

    def bump(self):
        with self._lock:
            self._count += 1

    def value(self):
        with self._lock:
            return self._count

    def _bump_locked(self):  # qdlint: holds-lock
        self._count += 1

    def snapshot(self):
        with self._lock:
            return [self._count for _ in range(2)]
