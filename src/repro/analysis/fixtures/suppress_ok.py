"""qdlint fixture: suppression with a reason silences the finding."""

import threading


class Gauge:
    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0  # guarded by: self._lock

    def update(self, value):
        with self._lock:
            self._value = value

    def peek(self):
        return self._value  # qdlint: disable=QD001 racy read is fine for a monitoring gauge
