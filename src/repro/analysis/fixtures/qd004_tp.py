"""qdlint fixture: QD004 true positives — host syncs on the hot path."""

import numpy as np


def route(records):  # qdlint: hot-path
    total = float(records.sum())
    host = np.asarray(records)
    return total, host
