"""qdlint fixture: QD003 true positives — traced branch, raw PlanKey."""

import functools

import jax


@functools.partial(jax.jit, static_argnames=("depth",))
def descend(records, depth):
    if records.sum() > 0:
        return records * depth
    return records


def route_plan(PlanKey, sig, m):
    return PlanKey(sig, "jax", m, 0, 0, 0)
