"""qdlint fixture: QD005 must-not-flag — locked swaps, lock-free reads."""

import threading


class Service:
    def __init__(self):
        self._lock = threading.Lock()
        self._live = object()  # swap-guarded by: self._lock

    def swap(self, version):
        with self._lock:
            self._live = version

    def live(self):
        # lock-free read is the point of the atomic-snapshot pattern
        return self._live
