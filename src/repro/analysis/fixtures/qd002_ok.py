"""qdlint fixture: QD002 must-not-flag — sorted sets, sanctioned clocks."""
# qdlint: deterministic-module

import time

import numpy as np


def merge_keys(before, after):
    out = [k for k in sorted(set(before) | set(after))]
    elapsed = time.perf_counter()
    rng = np.random.default_rng(7)
    for name in {"a": 1, "b": 2}:  # plain dict order is deterministic
        out.append(name)
    return out, elapsed, rng
