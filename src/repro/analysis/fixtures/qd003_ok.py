"""qdlint fixture: QD003 must-not-flag — static branches, padded buckets."""

import functools

import jax


@functools.partial(jax.jit, static_argnames=("depth",))
def descend(records, depth):
    if depth > 2:
        return records * depth
    return records


def pad_bucket(n):
    return max(1, int(n))


def route_plan(PlanKey, sig, m):
    m_bucket = pad_bucket(m)
    padded = m_bucket + 0
    return PlanKey(sig, "jax", padded, 0, 0, pad_bucket(8))
