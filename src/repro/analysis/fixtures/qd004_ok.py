"""qdlint fixture: QD004 must-not-flag — device-side hot path."""

import jax.numpy as jnp


def route(records):  # qdlint: hot-path
    return jnp.asarray(records).sum()


def summarize(records):
    # not marked hot-path: host syncs are fine off the serving path
    return float(records.sum()), records.item()
