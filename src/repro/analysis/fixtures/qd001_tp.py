"""qdlint fixture: QD001 true positive — guarded field touched unlocked."""

import threading


class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self._count = 0  # guarded by: self._lock

    def bump(self):
        self._count += 1

    def value(self):
        return self._count
