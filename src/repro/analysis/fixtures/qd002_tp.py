"""qdlint fixture: QD002 true positive — unsorted set iteration."""
# qdlint: deterministic-module


def merge_keys(before, after):
    out = []
    for k in set(before) | set(after):
        out.append(k)
    return out
