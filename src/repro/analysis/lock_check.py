"""QD001/QD005: lock-guarded attribute and swap-guarded CAS discipline.

Field declarations carry a comment on the assignment line:

* ``# guarded by: self._lock`` — every read *and* write of the field
  must happen inside ``with self._lock:`` (QD001).
* ``# swap-guarded by: self._lock`` — only *writes* need the lock
  (QD005).  This is the atomic-pointer-snapshot pattern used by the
  ``LayoutService`` live-version CAS: readers take one reference
  lock-free (safe under the GIL's atomic attribute load) while every
  swap/rollback serializes through the lock and
  ``_swap_if_live_is``-style compare-and-set.

Module-level globals use the same convention with a module-level lock
(e.g. ``# guarded by: _pool_lock`` on the resident process-pool state).

Scoping rules:

* ``__init__`` / ``__new__`` / ``__post_init__`` are exempt — the
  object is not yet shared during construction.
* A method whose ``def`` line carries ``# qdlint: holds-lock`` is
  exempt: its contract is that every caller already holds the lock.
* Nested function and lambda bodies are skipped — they execute later,
  usually under a lock the enclosing scope arranges (callbacks,
  executor submissions), so flagging them would be noise.
"""

from __future__ import annotations

import ast
from typing import Optional

from repro.analysis.core import Finding, ModuleInfo

_CTOR_NAMES = frozenset({"__init__", "__new__", "__post_init__"})
_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef)


def _unparse(node: ast.AST) -> str:
    try:
        return ast.unparse(node)
    except Exception:  # pragma: no cover - defensive
        return ""


class _AccessVisitor(ast.NodeVisitor):
    """Walk one function body tracking which lock expressions are held.

    ``fields`` maps a guarded name to ``(locks, kind)``; ``attr_mode``
    selects whether guarded names are ``self.<name>`` attributes
    (class pass) or bare module globals (module pass).
    """

    def __init__(
        self,
        info: ModuleInfo,
        fields: dict[str, tuple[tuple[str, ...], str]],
        symbol: str,
        attr_mode: bool,
    ):
        self.info = info
        self.fields = fields
        self.symbol = symbol
        self.attr_mode = attr_mode
        self.held: set[str] = set()
        self.findings: list[Finding] = []

    # -- lock tracking -------------------------------------------------
    def _visit_with(self, node) -> None:
        added = set()
        for item in node.items:
            expr = _unparse(item.context_expr)
            if expr and expr not in self.held:
                added.add(expr)
            if item.optional_vars is not None:
                self.visit(item.optional_vars)
        self.held |= added
        for stmt in node.body:
            self.visit(stmt)
        self.held -= added

    def visit_With(self, node: ast.With) -> None:
        self._visit_with(node)

    def visit_AsyncWith(self, node: ast.AsyncWith) -> None:
        self._visit_with(node)

    # -- deferred-execution scopes are out of bounds -------------------
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        pass

    def visit_AsyncFunctionDef(self, node) -> None:
        pass

    def visit_Lambda(self, node: ast.Lambda) -> None:
        pass

    def visit_Global(self, node: ast.Global) -> None:
        # `global _pool` re-declares the name without touching it
        pass

    # -- guarded accesses ----------------------------------------------
    def _check(self, node: ast.AST, name: str, is_store: bool) -> None:
        locks, kind = self.fields[name]
        if any(lock in self.held for lock in locks):
            return
        if kind == "swap" and not is_store:
            return  # lock-free reads of swap-guarded state are the point
        lock_desc = " or ".join(locks)
        if kind == "swap":
            code = "QD005"
            message = (
                f"swap-guarded attribute '{name}' assigned without "
                f"holding {lock_desc}"
            )
        else:
            code = "QD001"
            access = "written" if is_store else "read"
            message = (
                f"guarded attribute '{name}' {access} without "
                f"holding {lock_desc}"
            )
        self.findings.append(
            Finding(
                code=code,
                path=self.info.rel,
                line=node.lineno,
                col=node.col_offset,
                symbol=self.symbol,
                message=message,
            )
        )

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if (
            self.attr_mode
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"
            and node.attr in self.fields
        ):
            is_store = isinstance(node.ctx, (ast.Store, ast.Del))
            self._check(node, node.attr, is_store)
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name) -> None:
        if not self.attr_mode and node.id in self.fields:
            is_store = isinstance(node.ctx, (ast.Store, ast.Del))
            self._check(node, node.id, is_store)
        self.generic_visit(node)


def _guard_on_line(
    info: ModuleInfo, lineno: int
) -> Optional[tuple[tuple[str, ...], str]]:
    return info.guards.get(lineno)


def _collect_class_fields(
    info: ModuleInfo, cls: ast.ClassDef
) -> dict[str, tuple[tuple[str, ...], str]]:
    """Guarded ``self.<name>`` declarations anywhere in the class."""
    fields: dict[str, tuple[tuple[str, ...], str]] = {}
    for node in ast.walk(cls):
        targets: list[ast.expr] = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AnnAssign):
            targets = [node.target]
        else:
            continue
        guard = _guard_on_line(info, node.lineno)
        if guard is None:
            continue
        for tgt in targets:
            if (
                isinstance(tgt, ast.Attribute)
                and isinstance(tgt.value, ast.Name)
                and tgt.value.id == "self"
            ):
                fields[tgt.attr] = guard
    return fields


def _collect_module_globals(
    info: ModuleInfo,
) -> dict[str, tuple[tuple[str, ...], str]]:
    fields: dict[str, tuple[tuple[str, ...], str]] = {}
    for node in info.tree.body:
        targets: list[ast.expr] = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AnnAssign):
            targets = [node.target]
        else:
            continue
        guard = _guard_on_line(info, node.lineno)
        if guard is None:
            continue
        for tgt in targets:
            if isinstance(tgt, ast.Name):
                fields[tgt.id] = guard
    return fields


def _method_exempt(info: ModuleInfo, fn) -> bool:
    if fn.name in _CTOR_NAMES:
        return True
    return "holds-lock" in info.markers_on(fn.lineno)


def check_locks(info: ModuleInfo) -> list[Finding]:
    findings: list[Finding] = []

    # class pass: guarded self.<attr> fields per class
    for cls in [
        n for n in ast.walk(info.tree) if isinstance(n, ast.ClassDef)
    ]:
        fields = _collect_class_fields(info, cls)
        if not fields:
            continue
        for fn in cls.body:
            if not isinstance(fn, _FUNC_NODES):
                continue
            if _method_exempt(info, fn):
                continue
            visitor = _AccessVisitor(
                info, fields, f"{cls.name}.{fn.name}", attr_mode=True
            )
            for stmt in fn.body:
                visitor.visit(stmt)
            findings.extend(visitor.findings)

    # module pass: guarded globals across every function in the module
    globals_map = _collect_module_globals(info)
    if globals_map:
        for fn in [
            n for n in ast.walk(info.tree) if isinstance(n, _FUNC_NODES)
        ]:
            if _method_exempt(info, fn):
                continue
            visitor = _AccessVisitor(
                info, globals_map, fn.name, attr_mode=False
            )
            for stmt in fn.body:
                visitor.visit(stmt)
            findings.extend(visitor.findings)

    return findings
