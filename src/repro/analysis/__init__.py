"""qdlint: invariant-aware static analysis for the qd-tree stack.

Stdlib-only on purpose — the CI lint job runs it with nothing
installed beyond ruff (``PYTHONPATH=src python -m repro.analysis src``).
See :mod:`repro.analysis.core` for the rule catalogue and annotation
conventions.
"""

from repro.analysis.core import (
    CHECKER_CODES,
    EXCLUDED_FRAGMENTS,
    FileResult,
    Finding,
    ModuleInfo,
    Report,
    analyze_file,
    iter_python_files,
    load_baseline,
    parse_module,
    run,
    write_baseline,
)
from repro.analysis.cli import DEFAULT_BASELINE, main, self_test

__all__ = [
    "CHECKER_CODES",
    "DEFAULT_BASELINE",
    "EXCLUDED_FRAGMENTS",
    "FileResult",
    "Finding",
    "ModuleInfo",
    "Report",
    "analyze_file",
    "iter_python_files",
    "load_baseline",
    "main",
    "parse_module",
    "run",
    "self_test",
    "write_baseline",
]
