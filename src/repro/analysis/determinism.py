"""QD002: hash-seed / wall-clock determinism inside deterministic modules.

Modules carrying ``# qdlint: deterministic-module`` promise bit-identical
outputs across processes — the contract every ShardState/TrackerState
merge, replica signature, and plan fingerprint relies on.  Two bug
classes silently break it:

* **Unsorted set iteration.**  ``for k in set(a) | set(b)`` iterates in
  hash order, which varies per process under ``PYTHONHASHSEED``
  randomization for str keys — exactly the spawn-worker topology the
  process executor uses.  Any iteration over a set expression must go
  through ``sorted(...)``.  Plain ``dict`` (and ``.keys()``) iteration
  is insertion-ordered and therefore deterministic; ``.keys()`` only
  counts as set-ish inside set algebra (``a.keys() & b.keys()``), where
  the result is a real set again.
* **Wall clock / unseeded randomness.**  ``time.time()`` /
  ``time.time_ns()`` and ``random.*`` / unseeded ``np.random.*`` calls
  leak nondeterminism into outputs.  ``time.perf_counter()`` is fine
  (used for reported timings, never for data), and seeded generator
  *construction* (``np.random.default_rng(seed)``, ``Generator``,
  ``SeedSequence``, bit generators) is the sanctioned idiom.
"""

from __future__ import annotations

import ast

from repro.analysis.core import Finding, ModuleInfo

# iteration wrappers that materialize their argument's order
_ORDER_SINKS = frozenset({"list", "tuple", "enumerate", "iter"})

# np.random constructors that take an explicit seed — allowed
_SEEDED_RNG = frozenset(
    {"default_rng", "Generator", "SeedSequence", "PCG64", "Philox",
     "MT19937", "SFC64"}
)

_SET_OPS = (ast.BitOr, ast.BitAnd, ast.BitXor, ast.Sub)


def _is_keys_call(node: ast.AST) -> bool:
    return (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Attribute)
        and node.func.attr == "keys"
    )


def _is_set_expr(node: ast.AST) -> bool:
    """Does ``node`` evaluate to a set/frozenset?"""
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Name)
        and node.func.id in ("set", "frozenset")
    ):
        return True
    if isinstance(node, ast.BinOp) and isinstance(node.op, _SET_OPS):
        # dict-view algebra (a.keys() & b) also yields a set
        for side in (node.left, node.right):
            if _is_set_expr(side) or _is_keys_call(side):
                return True
    return False


def check_determinism(info: ModuleInfo) -> list[Finding]:
    if not info.deterministic:
        return []
    findings: list[Finding] = []
    symbol_stack: list[str] = []

    def symbol() -> str:
        return ".".join(symbol_stack) if symbol_stack else "<module>"

    def flag(node: ast.AST, message: str) -> None:
        findings.append(
            Finding(
                code="QD002",
                path=info.rel,
                line=node.lineno,
                col=node.col_offset,
                symbol=symbol(),
                message=message,
            )
        )

    def check_iter_source(node: ast.AST) -> None:
        if _is_set_expr(node):
            flag(
                node,
                "iteration over an unordered set expression; wrap it "
                "in sorted(...) for hash-seed-independent order",
            )

    def visit(node: ast.AST) -> None:
        pushed = False
        if isinstance(
            node,
            (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef),
        ):
            symbol_stack.append(node.name)
            pushed = True

        if isinstance(node, (ast.For, ast.AsyncFor)):
            check_iter_source(node.iter)
        elif isinstance(
            node,
            (ast.ListComp, ast.SetComp, ast.DictComp, ast.GeneratorExp),
        ):
            for gen in node.generators:
                check_iter_source(gen.iter)
        elif isinstance(node, ast.Call):
            func = node.func
            if (
                isinstance(func, ast.Name)
                and func.id in _ORDER_SINKS
                and len(node.args) >= 1
            ):
                check_iter_source(node.args[0])
            if isinstance(func, ast.Attribute):
                base = func.value
                if isinstance(base, ast.Name):
                    if base.id == "time" and func.attr in (
                        "time", "time_ns"
                    ):
                        flag(
                            node,
                            f"wall-clock call time.{func.attr}() in a "
                            "deterministic module",
                        )
                    elif base.id == "random":
                        flag(
                            node,
                            f"unseeded random.{func.attr}() in a "
                            "deterministic module",
                        )
                elif (
                    isinstance(base, ast.Attribute)
                    and base.attr == "random"
                    and isinstance(base.value, ast.Name)
                    and base.value.id in ("np", "numpy")
                    and func.attr not in _SEEDED_RNG
                ):
                    flag(
                        node,
                        f"unseeded {base.value.id}.random.{func.attr}()"
                        " in a deterministic module; construct a seeded"
                        " Generator via default_rng(seed) instead",
                    )

        for child in ast.iter_child_nodes(node):
            visit(child)
        if pushed:
            symbol_stack.pop()

    visit(info.tree)
    return findings
