"""End-to-end training driver: qd-tree data pipeline → sharded train loop.

The paper's layout engine is the data tier: records are laid out by a
greedy/WOODBLOCK qd-tree into a block store; a curation query selects the
training mixture and the qd-tree prunes non-matching blocks before any I/O;
blocks feed the elastic scheduler → tokenizer → train step.

On this CPU container the driver defaults to a reduced config; pass
``--full-arch`` to build the real config (only sensible on a TPU fleet).

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-32b \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import pathlib
import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.core import greedy
from repro.core.query import InAtom, Query
from repro.data import datagen, workload as wl
from repro.data.blocks import BlockStore
from repro.data.pipeline import PipelineConfig, QdTreePipeline
from repro.launch.mesh import make_smoke_mesh
from repro.sharding.specs import Rules
from repro.train import steps
from repro.train.loop import LoopConfig, maybe_restore, train_loop
from repro.train.optimizer import AdamWConfig
from repro.train.schedule import ScheduleConfig


def build_data_tier(tmp: str, n_rows: int, block: int, seed: int = 0):
    """Synthetic corpus + workload → greedy qd-tree → block store."""
    schema, records = datagen.make_errorlog_int(n_rows, seed=seed)
    work, _ = wl.make_errorlog_int_workload(schema, n_queries=50, seed=seed)
    cuts = work.candidate_cuts()
    tree = greedy.build_greedy(
        records, work, cuts, greedy.GreedyConfig(min_block=block)
    )
    store = BlockStore.create(
        pathlib.Path(tmp) / "blocks", tree.freeze(), records
    )
    return schema, store


def batches_from_pipeline(store, schema, batch: int, seq: int, vocab: int,
                          curated: bool, epochs: int = 1_000_000):
    """Infinite batch iterator with qd-tree block skipping."""
    curation = None
    if curated:
        # the mixture filter: only valid events of the two dominant types
        curation = Query.conjunction([
            InAtom(schema.dim("event_type"), (0, 1)),
            InAtom(schema.dim("is_valid"), (1,)),
        ])
    cfg = PipelineConfig(
        batch_size=batch, seq_len=seq, vocab=vocab,
        curation_query=curation, epochs=epochs,
    )
    pipe = QdTreePipeline(store, cfg)
    print(
        f"pipeline: {store.tree.n_leaves} blocks, "
        f"{pipe.blocks_skipped} skipped by the curation query"
    )

    def gen():
        import jax.numpy as jnp

        while True:
            for toks, labels in pipe:
                yield {
                    "tokens": jnp.asarray(toks),
                    "labels": jnp.asarray(labels),
                }

    return gen()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-32b")
    ap.add_argument("--full-arch", action="store_true",
                    help="use the full config (TPU fleet only)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--layers", type=int, default=0,
                    help="override reduced layer count")
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--rows", type=int, default=50_000)
    ap.add_argument("--no-curation", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (restart demo)")
    ap.add_argument("--data", type=int, default=1, help="data-axis size")
    ap.add_argument("--model-par", type=int, default=1,
                    help="model-axis size")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full_arch:
        over = {}
        if args.layers:
            over["n_layers"] = args.layers
        if args.d_model:
            over["d_model"] = args.d_model
            over["head_dim"] = max(args.d_model // max(cfg.n_heads, 1), 8)
        cfg = cfg.reduced(**over)
    print(f"arch {cfg.name}: {cfg.n_layers}L d={cfg.d_model}")

    mesh = make_smoke_mesh(data=args.data, model=args.model_par)
    rules = Rules.make()
    ocfg = AdamWConfig(eight_bit=cfg.opt_8bit)
    scfg = ScheduleConfig(
        peak_lr=3e-4, warmup_steps=max(args.steps // 10, 2),
        total_steps=args.steps,
    )

    tmp = tempfile.mkdtemp(prefix="qdtree_data_")
    schema, store = build_data_tier(
        tmp, n_rows=args.rows, block=2_000, seed=args.seed
    )
    batches = batches_from_pipeline(
        store, schema, args.batch, args.seq, cfg.vocab,
        curated=not args.no_curation,
    )

    batch_sds = {
        "tokens": jax.ShapeDtypeStruct((args.batch, args.seq), np.int32),
        "labels": jax.ShapeDtypeStruct((args.batch, args.seq), np.int32),
    }
    batch_specs = {"tokens": ("batch", None), "labels": ("batch", None)}
    step_fn, state_shapes, state_sh, _ = steps.jit_train_step(
        cfg, ocfg, scfg, mesh, rules, batch_sds, batch_specs
    )

    state, start = maybe_restore(args.ckpt_dir, state_shapes, state_sh)
    if state is None:
        state = steps.init_train_state(jax.random.PRNGKey(args.seed), cfg,
                                       ocfg)
        state = jax.device_put(state, state_sh)
        print("cold start")
    else:
        print(f"resumed from step {start}")

    from repro.train.loop import FailureInjector

    lcfg = LoopConfig(
        total_steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        log_every=5,
    )
    failure = FailureInjector(args.fail_at)
    state, history = train_loop(step_fn, state, batches, lcfg, failure)
    print(
        f"done: step={int(np.asarray(state['step']))} "
        f"final loss={history[-1]['loss']:.4f}"
    )
    return history


if __name__ == "__main__":
    main()
