"""The serving tier as a long-lived front end over a LayoutService.

Drives a paced (open-loop) query stream — a Zipf-repeated mix, the shape
real dashboards produce — through :class:`repro.serve.QueryServer`:
admission, micro-batch coalescing, and the semantic result cache, with
every served query recorded into a WorkloadTracker.

    PYTHONPATH=src python -m repro.launch.serve \
        --rows 30000 --qps 500 --duration 10 --cache-size 4096

    # tracker-inferred mid-run rebuild: at half time the layout is rebuilt
    # from the workload the tracker inferred off the serving path alone,
    # hot-swapped live, and the cache invalidates by generation epoch
    PYTHONPATH=src python -m repro.launch.serve --workload auto

Prints per-phase progress plus a final JSON summary (achieved qps, cache
hit rate, p50/p99 latency, admission + staleness counters) like
``repro.launch.ingest``.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import query as qry
from repro.engine import trace_counts
from repro.engine.plan import trace_delta
from repro.launch.ingest import make_workload
from repro.serve import AdmissionError, QueryServer, ServeConfig
from repro.service import LayoutService


def zipf_mix(work: qry.Workload, n: int, s: float, seed: int) -> list[qry.Query]:
    """``n`` queries drawn Zipf(s)-by-rank from the workload's templates —
    a few hot predicates dominate, a long tail repeats rarely (the mix a
    semantic cache exists for)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, len(work) + 1, dtype=np.float64)
    p = ranks**-s
    p /= p.sum()
    order = rng.permutation(len(work))  # hot set is seed-dependent
    idx = order[rng.choice(len(work), size=n, p=p)]
    return [work.queries[int(i)] for i in idx]


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--rows", type=int, default=30_000)
    ap.add_argument("--qps", type=float, default=500.0,
                    help="open-loop submit rate target")
    ap.add_argument("--duration", type=float, default=10.0,
                    help="serving run length, seconds")
    ap.add_argument("--cache-size", type=int, default=4096,
                    help="semantic result cache capacity (LRU entries)")
    ap.add_argument("--workload", default="tpch",
                    choices=("tpch", "errorlog_int", "auto"),
                    help="query mix; 'auto' additionally rebuilds the "
                         "layout MID-RUN from the tracker-inferred mix "
                         "and hot-swaps it (the cache invalidates by "
                         "generation epoch)")
    ap.add_argument("--zipf", type=float, default=1.1,
                    help="Zipf skew of the repeated query mix")
    ap.add_argument("--max-batch", type=int, default=64,
                    help="coalesced dispatch size trigger")
    ap.add_argument("--max-delay-ms", type=float, default=2.0,
                    help="coalescing deadline per request")
    ap.add_argument("--backend", default="jax",
                    choices=("numpy", "jax", "pallas"))
    ap.add_argument("--strategy", default="greedy")
    ap.add_argument("--min-block", type=int, default=600)
    ap.add_argument("--replicas", type=int, default=1,
                    help="replica count for the mid-run rebuild: k>1 "
                         "clusters the inferred mix into k workload "
                         "clusters and deploys one qd-tree per cluster "
                         "with cheapest-replica routing (k x storage)")
    ap.add_argument("--lam", type=float, default=0.25,
                    help="uniform-prior blend weight for per-replica "
                         "workload clusters")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    schema, records, work, cuts = make_workload(
        args.workload, args.rows, args.seed
    )
    service = LayoutService.build(
        records, work, strategy=args.strategy, backend=args.backend,
        cuts=cuts, min_block=args.min_block, seed=args.seed,
    )
    print(
        f"[serve] built {args.strategy} layout: {service.tree.n_leaves} "
        f"blocks over {records.shape[0]} rows, backend={args.backend}"
    )
    tracker = service.workload_tracker()
    config = ServeConfig(
        max_batch=args.max_batch,
        max_delay_s=args.max_delay_ms / 1e3,
        cache_capacity=args.cache_size,
    )
    server = QueryServer(service, config, tracker=tracker).start()
    server.warm(work)
    t_warm = trace_counts()
    print(
        f"[serve] serving at {args.qps:,.0f} qps target for "
        f"{args.duration:.0f}s (zipf s={args.zipf}, max_batch="
        f"{args.max_batch}, deadline {args.max_delay_ms}ms, cache "
        f"{args.cache_size})"
    )

    n_target = max(int(args.qps * args.duration), 1)
    mix = zipf_mix(work, n_target, args.zipf, args.seed + 1)
    tickets = []
    rejected = 0
    swap_note = None
    burst = max(int(args.qps * 0.005), 1)  # pace in ~5ms bursts
    t0 = time.perf_counter()
    swap_at = t0 + args.duration / 2
    i = 0
    while i < len(mix):
        if args.workload == "auto" and swap_note is None and (
            time.perf_counter() >= swap_at
        ):
            # rebuild from what the serving path inferred — no declared
            # workload in the loop — and hot-swap under live traffic
            inferred = tracker.infer_workload()
            target = inferred if len(inferred) else work
            if args.replicas > 1:
                rep = service.rebuild_replicas(
                    records, workload=target, k=args.replicas,
                    lam=args.lam, min_block=args.min_block,
                    seed=args.seed,
                )
                server.warm(work)  # every replica's plans: swap cost
                swap_note = {
                    "swapped": rep.swapped,
                    "replicas": rep.k,
                    "generation": service.generation,
                    "replica_generations": list(
                        service.replica_generations()
                    ),
                    "inferred_queries": len(inferred),
                }
                print(
                    f"[serve] mid-run replica rebuild from inferred mix "
                    f"({len(inferred)} weighted queries, k={rep.k}): "
                    f"{'deployed gens ' + str(rep.new_generations) if rep.swapped else 'kept gens ' + str(rep.old_generations)}"
                )
            else:
                rep = service.rebuild(
                    records, target, min_block=args.min_block,
                    seed=args.seed,
                )
                server.warm(work)  # new generation's plans: swap cost
                swap_note = {
                    "swapped": rep.swapped,
                    "generation": service.generation,
                    "inferred_queries": len(inferred),
                }
                print(
                    f"[serve] mid-run rebuild from inferred mix "
                    f"({len(inferred)} weighted queries): "
                    f"{'swapped to gen ' + str(rep.new_generation) if rep.swapped else 'kept gen ' + str(rep.old_generation)}"
                )
        t_due = t0 + i / args.qps
        now = time.perf_counter()
        if now < t_due:
            time.sleep(t_due - now)
        for q in mix[i : i + burst]:
            try:
                tickets.append(server.submit(q))
            except AdmissionError:
                rejected += 1
        i += burst
    results = [t.result(timeout=30.0) for t in tickets]
    wall = time.perf_counter() - t0
    server.stop()

    stats = server.stats()
    serve_traces = trace_delta(t_warm, trace_counts())
    state = tracker.snapshot()
    print(
        f"[serve] {len(results)} served / {rejected} shed in {wall:.2f}s "
        f"-> {len(results) / wall:,.0f} qps achieved"
    )
    print(
        f"[serve] cache: hit rate {stats['cache']['hit_rate']:.3f} "
        f"({stats['cache']['hits']} hits / {stats['cache']['lookups']} "
        f"lookups), {stats['counters']['engine_dispatches']} engine "
        f"dispatches for {stats['counters']['dispatches']} batches"
    )
    print(
        f"[serve] latency: p50 {stats['latency']['p50_ms']:.2f}ms "
        f"p99 {stats['latency']['p99_ms']:.2f}ms"
    )
    print(
        f"[serve] staleness audit: {stats['counters']['stale_responses']} "
        f"stale responses, {stats['cache']['stale_puts']} stale puts, "
        f"traces during serving (swap compiles excluded at warm): "
        f"{serve_traces or 0}"
    )
    for line in tracker.describe(3):
        print(f"[serve] inferred: {line}")

    summary = {
        "qps_target": args.qps,
        "qps_achieved": len(results) / wall if wall else 0.0,
        "duration_s": wall,
        "served": len(results),
        "rejected": rejected,
        "hit_rate": stats["cache"]["hit_rate"],
        "p50_ms": stats["latency"]["p50_ms"],
        "p99_ms": stats["latency"]["p99_ms"],
        "stale_responses": stats["counters"]["stale_responses"],
        "counters": stats["counters"],
        "admission": stats["admission"],
        "cache": stats["cache"],
        "generation": service.generation,
        "workload": args.workload,
        "swap": swap_note,
        "tracker": {
            "queries_seen": state.queries_seen,
            "n_keys": state.n_keys,
            "inferred_queries": len(tracker.infer_workload()),
        },
    }
    print(json.dumps(summary))
    return summary


if __name__ == "__main__":
    main()
