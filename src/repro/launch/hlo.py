"""Post-SPMD HLO text analysis: collective inventory + operand bytes.

``compiled.cost_analysis()`` has no collective-bytes term, so we parse the
optimized per-device HLO: every ``all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute`` op (sync or ``-start`` async form)
contributes the byte size of its operands (per-device shard shapes, i.e.
bytes leaving the device, modulo algorithm constants).

NOTE (documented in EXPERIMENTS.md §Roofline): XLA's cost analysis counts a
``while`` body ONCE — it does not multiply by trip count — and the same
holds for text parsing of scanned models.  The dry-run therefore derives
per-step cost terms from 1-group/2-group *unrolled* variants and
extrapolates linearly in the group count; the scanned full-model compile is
used for memory analysis and compile-validity only.
"""

from __future__ import annotations

import re

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_DEF = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s+=\s+([^(]*?)([\w\-]+)\(")
_OPERAND_NAME = re.compile(r"%([\w.\-]+)")
_OP = re.compile(
    r"=\s+[^=]*?\b(" + "|".join(COLLECTIVES) + r")(-start)?\("
)


def tensor_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def _shape_bytes(segment: str) -> int:
    return sum(tensor_bytes(dt, dims) for dt, dims in _SHAPE.findall(segment))


def parse_collectives(hlo_text: str) -> dict:
    """→ {kind: {"count": int, "bytes": int}} summed over op *operands*.

    Optimized HLO prints operands as bare names (``all-reduce(%dot)``), so
    a first pass builds a name → output-bytes symbol table; collective
    operand bytes are resolved through it.  Async ``-done`` ops (whose
    operand is the ``-start`` tuple) are skipped to avoid double counting.
    """
    sizes: dict[str, int] = {}
    lines = hlo_text.splitlines()
    for line in lines:
        m = _DEF.match(line)
        if m:
            sizes[m.group(1)] = _shape_bytes(m.group(2))
    out = {k: {"count": 0, "bytes": 0} for k in COLLECTIVES}
    for line in lines:
        m = _OP.search(line)
        if not m:
            continue
        kind = m.group(1)
        start = m.end()
        depth = 1
        i = start
        while i < len(line) and depth:
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
            i += 1
        operands = line[start : i - 1]
        b = _shape_bytes(operands)  # older dumps: inline operand shapes
        if b == 0:
            b = sum(
                sizes.get(name, 0)
                for name in _OPERAND_NAME.findall(operands)
            )
        out[kind]["count"] += 1
        out[kind]["bytes"] += b
    return out


def total_collective_bytes(coll: dict) -> int:
    return sum(v["bytes"] for v in coll.values())


def cost_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return dict(ca)


# ops that still touch HBM after TPU-grade fusion (elementwise/broadcast/
# reduce chains fuse into their consumers; these don't)
_MEM_OPS = (
    "dot", "convolution", "scatter", "gather",
    "dynamic-slice", "dynamic-update-slice", "copy", "transpose",
    "sort", "fusion",
) + COLLECTIVES
_MEM_DEF = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s+=\s+([^(]*?)(" +
    "|".join(_MEM_OPS) + r")(-start)?\("
)


def fused_bytes_estimate(hlo_text: str) -> int:
    """Approximate post-fusion HBM traffic from the per-device HLO.

    XLA:CPU fuses far less than XLA:TPU, so ``cost_analysis()['bytes
    accessed']`` counts every elementwise intermediate at full size.  This
    estimate sums operand+output bytes ONLY for ops that remain memory
    ops after TPU fusion (matmuls, copies/transposes, gathers/scatters,
    dynamic slices, sorts, existing fusions, collectives) — elementwise
    and broadcast/reduce chains are assumed fused into their consumers.
    Documented in EXPERIMENTS.md §Roofline methodology.
    """
    sizes: dict[str, int] = {}
    total = 0
    lines = hlo_text.splitlines()
    for line in lines:
        m = _DEF.match(line)
        if m:
            sizes[m.group(1)] = _shape_bytes(m.group(2))
    for line in lines:
        m = _MEM_DEF.match(line)
        if not m:
            continue
        out_b = _shape_bytes(m.group(1))
        start = m.end()
        depth = 1
        i = start
        while i < len(line) and depth:
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
            i += 1
        operands = line[start : i - 1]
        in_b = sum(
            sizes.get(name, 0) for name in _OPERAND_NAME.findall(operands)
        )
        total += out_b + in_b
    return total
