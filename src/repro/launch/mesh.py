"""Production meshes.

All mesh construction lives behind functions so importing this module never
touches jax device state (the dry-run driver must set XLA_FLAGS before any
jax initialization).
"""

from __future__ import annotations

import jax


def _mesh(shape, axes):
    axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=axis_types)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_smoke_mesh(data: int = 1, model: int = 1, pod: int | None = None):
    """Tiny CPU mesh for tests (1 device by default)."""
    if pod:
        return _mesh((pod, data, model), ("pod", "data", "model"))
    return _mesh((data, model), ("data", "model"))
