"""Serving driver: batched prefill + decode against KV/SSM caches.

  PYTHONPATH=src python -m repro.launch.serve_lm --arch mamba2-780m \
      --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_smoke_mesh
from repro.models import model
from repro.sharding.specs import Rules, use_mesh
from repro.train import steps


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-32b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced(max_positions=args.max_seq)
    mesh = make_smoke_mesh()
    rules = Rules.make({"seq_sp": None})
    key = jax.random.PRNGKey(args.seed)
    params, _ = model.init_model(key, cfg)

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32
    )
    batch = {"tokens": prompts}
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            0.01 * rng.standard_normal((args.batch, 16, cfg.d_model)),
            jnp.float32,
        )
    if cfg.n_image_patches:
        batch["patches"] = jnp.asarray(
            0.01 * rng.standard_normal(
                (args.batch, cfg.n_image_patches, cfg.d_model)
            ),
            jnp.float32,
        )

    with use_mesh(mesh, rules):
        # prefill is run at prompt length; its emitted caches are copied
        # into the fixed-capacity decode caches
        t0 = time.perf_counter()
        logits_last, prefill_caches = jax.jit(
            lambda p, b: model.prefill(p, b, cfg)
        )(params, batch)
        jax.block_until_ready(logits_last)
        t_prefill = time.perf_counter() - t0
        caches, _ = model.init_caches(cfg, args.batch, args.max_seq)
        caches = _splice(cfg, caches, prefill_caches, args.prompt_len)

        decode = jax.jit(
            lambda p, c, t, pos: steps.serve_step(p, c, t, pos, cfg),
            donate_argnums=(1,),
        )
        tok = jnp.argmax(logits_last, axis=-1).astype(jnp.int32)[:, None]
        out = [tok]
        t0 = time.perf_counter()
        for i in range(args.gen - 1):
            tok, _, caches = decode(
                params, caches, tok, jnp.int32(args.prompt_len + i)
            )
            out.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t0

    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    tps = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"prefill: {args.batch}×{args.prompt_len} in {t_prefill*1e3:.0f}ms")
    print(f"decode: {args.gen-1} steps, {tps:.1f} tok/s "
          f"({t_decode/(args.gen-1)*1e3:.1f} ms/step)")
    print("sample generations:", gen[:, :8].tolist())
    return gen


def _splice(cfg, caches, prefill_caches, plen: int):
    """Copy prefill-emitted K/V (B,KV,plen,hd per layer) into decode caches.

    Decoder-only prefill caches arrive stacked (n_groups, ...) per slot
    with the sequence axis at -2; mamba slots carry (state, conv) directly.
    """
    if cfg.is_encdec:
        upd = dict(caches)
        for k in ("k", "v"):
            upd[k] = jax.lax.dynamic_update_slice(
                caches[k], prefill_caches[k].astype(caches[k].dtype),
                (0, 0, 0, 0, 0),
            )
        upd["cross_k"] = prefill_caches["cross_k"].astype(
            caches["cross_k"].dtype
        )
        upd["cross_v"] = prefill_caches["cross_v"].astype(
            caches["cross_v"].dtype
        )
        return upd
    out = {}
    for slot, c in caches.items():
        pc = prefill_caches[slot]
        if "k" in c:
            out[slot] = {
                "k": jax.lax.dynamic_update_slice(
                    c["k"], pc["k"].astype(c["k"].dtype), (0, 0, 0, 0, 0)
                ),
                "v": jax.lax.dynamic_update_slice(
                    c["v"], pc["v"].astype(c["v"].dtype), (0, 0, 0, 0, 0)
                ),
            }
        else:
            out[slot] = {
                "state": pc["state"].astype(c["state"].dtype),
                "conv": pc["conv"].astype(c["conv"].dtype),
            }
    return out


if __name__ == "__main__":
    main()
