"""Per-cell (arch × input-shape) abstract inputs + step builders.

``build_cell`` returns everything the dry-run / drivers need to lower a
cell: the step function, ShapeDtypeStruct arguments, in/out shardings, and
donation indices.  Shapes lower:

  train_4k     → train_step (fwd+bwd+AdamW)
  prefill_32k  → serve_prefill (forward + cache emission)
  decode_32k   → serve_step (one token against a seq_len KV cache)
  long_500k    → serve_step, batch=1, sequence-sharded caches
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, InputShape, ModelConfig
from repro.models import encdec, model
from repro.sharding.specs import (
    LONG_CONTEXT_OVERRIDES,
    Rules,
    fitted_shardings,
    use_mesh,
)
from repro.train import steps
from repro.train.optimizer import AdamWConfig
from repro.train.schedule import ScheduleConfig

IS_AXES = lambda x: isinstance(x, tuple) and all(
    isinstance(e, (str, type(None))) for e in x
)


def rules_for_shape(shape: InputShape) -> Rules:
    if shape.name == "long_500k":
        over = dict(LONG_CONTEXT_OVERRIDES)
        over["seq_sp"] = None  # decode: S=1, nothing to sequence-shard
        return Rules.make(over)
    if shape.kind == "decode":
        # decode caches shard their sequence dim over `model` — robust to
        # any kv-head count (GQA kv heads rarely divide a 16-way TP axis)
        return Rules.make({"seq_sp": None, "cache_seq": ("model",)})
    return Rules.make()


def shaped_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Bind shape-dependent knobs (learned-pos table size)."""
    if cfg.pos_embed == "learned" and cfg.max_positions < shape.seq_len:
        cfg = dataclasses.replace(cfg, max_positions=shape.seq_len)
    return cfg


# ---------------------------------------------------------------------------
# abstract batches
# ---------------------------------------------------------------------------
def train_batch_abstract(cfg: ModelConfig, shape: InputShape):
    b, s = shape.global_batch, shape.seq_len
    sds = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    spc = {"tokens": ("batch", None), "labels": ("batch", None)}
    dt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    if cfg.n_image_patches:
        sds["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.n_image_patches, cfg.d_model), dt
        )
        spc["patches"] = ("batch", None, "embed")
    if cfg.is_encdec:
        sds["frames"] = jax.ShapeDtypeStruct(
            (b, encdec.N_FRAMES, cfg.d_model), dt
        )
        spc["frames"] = ("batch", None, "embed")
    return sds, spc


def prefill_batch_abstract(cfg: ModelConfig, shape: InputShape):
    sds, spc = train_batch_abstract(cfg, shape)
    sds.pop("labels")
    spc.pop("labels")
    return sds, spc


def caches_abstract(cfg: ModelConfig, batch: int, max_seq: int):
    box = {}

    def go(_):
        caches, cspecs = model.init_caches(cfg, batch, max_seq)
        box["s"] = cspecs
        return caches

    shapes = jax.eval_shape(go, 0)
    return shapes, box["s"]


# ---------------------------------------------------------------------------
# cell builder
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Cell:
    arch: str
    shape: InputShape
    cfg: ModelConfig
    rules: Rules
    step_name: str  # train_step | serve_prefill | serve_step
    fn: object
    args: tuple  # ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: object
    donate: tuple


def build_cell(
    cfg: ModelConfig,
    shape_name: str,
    mesh,
    ocfg: AdamWConfig | None = None,
    scfg: ScheduleConfig | None = None,
    rules: Rules | None = None,
) -> Cell:
    shape = SHAPES[shape_name]
    cfg = shaped_config(cfg, shape)
    rules = rules or rules_for_shape(shape)
    ocfg = ocfg or AdamWConfig(eight_bit=cfg.opt_8bit)
    scfg = scfg or ScheduleConfig()

    if shape.kind == "train":
        state_shapes, state_specs = steps.abstract_state(cfg, ocfg)
        batch_sds, batch_specs = train_batch_abstract(cfg, shape)
        fn = functools.partial(
            steps.train_step, cfg=cfg, ocfg=ocfg, scfg=scfg
        )
        state_sh = fitted_shardings(state_shapes, state_specs, mesh, rules)
        batch_sh = fitted_shardings(batch_sds, batch_specs, mesh, rules)
        return Cell(
            arch=cfg.name, shape=shape, cfg=cfg, rules=rules,
            step_name="train_step", fn=fn,
            args=(state_shapes, batch_sds),
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate=(0,),
        )

    # params only (no optimizer) for serving cells
    box = {}

    def go(key):
        p, s = model.init_model(key, cfg)
        box["s"] = s
        return p

    param_shapes = jax.eval_shape(go, jax.random.PRNGKey(0))
    param_sh = fitted_shardings(param_shapes, box["s"], mesh, rules)

    if shape.kind == "prefill":
        batch_sds, batch_specs = prefill_batch_abstract(cfg, shape)
        batch_sh = fitted_shardings(batch_sds, batch_specs, mesh, rules)
        fn = functools.partial(steps.serve_prefill, cfg=cfg)
        return Cell(
            arch=cfg.name, shape=shape, cfg=cfg, rules=rules,
            step_name="serve_prefill", fn=fn,
            args=(param_shapes, batch_sds),
            in_shardings=(param_sh, batch_sh),
            out_shardings=None,
            donate=(),
        )

    # decode: one token against a seq_len cache
    b = shape.global_batch
    cache_sds, cache_specs = caches_abstract(cfg, b, shape.seq_len)
    cache_sh = fitted_shardings(cache_sds, cache_specs, mesh, rules)
    token_sds = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    token_sh = fitted_shardings(
        {"t": token_sds}, {"t": ("batch", None)}, mesh, rules
    )["t"]
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    fn = functools.partial(steps.serve_step, cfg=cfg)
    return Cell(
        arch=cfg.name, shape=shape, cfg=cfg, rules=rules,
        step_name="serve_step", fn=fn,
        args=(param_shapes, cache_sds, token_sds, pos_sds),
        in_shardings=(param_sh, cache_sh, token_sh, None),
        out_shardings=(token_sh, None, cache_sh),
        donate=(1,),
    )


def lower_cell(cell: Cell, mesh):
    """jit + lower under the cell's mesh/rules context."""

    def traced(*args):
        with use_mesh(mesh, cell.rules):
            return cell.fn(*args)

    jitted = jax.jit(
        traced,
        in_shardings=cell.in_shardings,
        out_shardings=cell.out_shardings,
        donate_argnums=cell.donate,
    )
    return jitted.lower(*cell.args)
