"""Sustained online ingestion through the LayoutEngine.

Demonstrates the layout engine as a long-lived service (ROADMAP north star;
cf. the dynamic-layout follow-up work): records arrive as micro-batches of
*varying* sizes, each batch is routed on a compiled backend, appended to
per-block buffers, and leaf descriptions are tightened incrementally — all
without retracing, thanks to the power-of-two plan-cache buckets.

    PYTHONPATH=src python -m repro.launch.ingest \
        --rows 60000 --batch 2048 --backend jax --workload tpch \
        --store /tmp/qd_store

Prints per-phase throughput plus the engine's plan-cache/trace counters and
(optionally) persists the ingested blocks as a BlockStore.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import greedy
from repro.data import datagen, workload as wl
from repro.data.blocks import BlockBuffers
from repro.engine import LayoutEngine, pad_bucket, trace_counts


def make_workload(name: str, rows: int, seed: int):
    if name == "tpch":
        schema, records = datagen.make_tpch_like(rows, seed=seed)
        work, _ = wl.make_tpch_workload(schema, n_per_template=5, seed=seed)
        cuts = work.candidate_cuts(max_adv=4)
    elif name == "errorlog_int":
        schema, records = datagen.make_errorlog_int(rows, seed=seed)
        work, _ = wl.make_errorlog_int_workload(
            schema, n_queries=100, seed=seed
        )
        cuts = work.candidate_cuts()
    else:
        raise SystemExit(f"unknown workload {name!r}")
    return schema, records, work, cuts


def batch_sizes(n_rows: int, mean_batch: int, seed: int) -> list[int]:
    """Arrival-like batch sizes with ±50% jitter (plus the tail remainder)."""
    rng = np.random.default_rng(seed)
    sizes: list[int] = []
    left = n_rows
    while left > 0:
        b = int(rng.integers(max(mean_batch // 2, 1), mean_batch * 3 // 2))
        sizes.append(min(b, left))
        left -= sizes[-1]
    return sizes


def micro_batches(records: np.ndarray, sizes: list[int]):
    i = 0
    for b in sizes:
        yield records[i : i + b]
        i += b


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rows", type=int, default=60_000)
    ap.add_argument("--batch", type=int, default=2048,
                    help="mean micro-batch size (sizes jitter ±50%%)")
    ap.add_argument("--backend", default="jax",
                    choices=("numpy", "jax", "pallas"))
    ap.add_argument("--workload", default="tpch")
    ap.add_argument("--min-block", type=int, default=600)
    ap.add_argument("--store", default=None,
                    help="optional path to persist the ingested BlockStore")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    schema, records, work, cuts = make_workload(
        args.workload, args.rows, args.seed
    )
    # build the layout on a bootstrap sample, then stream the full corpus in
    sample = records[: max(args.rows // 10, 1000)]
    sample_min_block = max(
        args.min_block * sample.shape[0] // max(args.rows, 1), 50
    )
    t0 = time.perf_counter()
    tree = greedy.build_greedy(
        sample, work, cuts, greedy.GreedyConfig(min_block=sample_min_block)
    )
    frozen = tree.freeze()
    build_s = time.perf_counter() - t0
    print(
        f"[ingest] built qd-tree on {sample.shape[0]} bootstrap rows in "
        f"{build_s:.2f}s ({frozen.n_leaves} blocks, depth {frozen.depth})"
    )

    engine = LayoutEngine(frozen, backend=args.backend)
    buffers = BlockBuffers.for_tree(frozen)
    # warmup: compile the routing plan for every padding bucket the jittered
    # stream will produce (incl. the tail remainder), so the ingest loop
    # itself runs fully warm — zero retraces
    sizes = batch_sizes(records.shape[0], args.batch, args.seed)
    buckets = {pad_bucket(s, 64) for s in sizes}
    for m in sorted(min(b, records.shape[0]) for b in buckets):
        engine.route(records[:m])
    report = engine.ingest(micro_batches(records, sizes), buffers=buffers)
    print(
        f"[ingest] {report.n_records} records / {report.n_batches} "
        f"micro-batches in {report.wall_s:.2f}s -> "
        f"{report.records_per_s:,.0f} rec/s on backend={report.backend}"
    )
    print(f"[ingest] plan cache: {report.plan_cache}")
    print(f"[ingest] traces during ingest (0 ⇒ fully warm): {report.traces}")
    print(f"[ingest] all traces: {trace_counts()}")

    stats = engine.skip_stats(records, work, tighten=False)
    print(
        f"[ingest] layout quality: scanned fraction "
        f"{stats.scanned_fraction:.4f} over {stats.n_queries} queries"
    )

    if args.store:
        store = buffers.write_store(args.store, frozen)
        print(
            f"[ingest] persisted {int(store.sizes.sum())} rows in "
            f"{store.sizes.shape[0]} blocks at {store.root}"
        )
    summary = {
        "records_per_s": report.records_per_s,
        "n_records": report.n_records,
        "n_batches": report.n_batches,
        "backend": report.backend,
        "plan_cache": report.plan_cache,
        "ingest_traces": report.traces,
        "scanned_fraction": stats.scanned_fraction,
    }
    print(json.dumps(summary))


if __name__ == "__main__":
    main()
