"""Sustained online ingestion through the LayoutEngine.

Demonstrates the layout engine as a long-lived service (ROADMAP north star;
cf. the dynamic-layout follow-up work): records arrive as micro-batches of
*varying* sizes, each batch is routed on a compiled backend, appended to
per-block buffers, and leaf descriptions are tightened incrementally — all
without retracing, thanks to the power-of-two plan-cache buckets.

    PYTHONPATH=src python -m repro.launch.ingest \
        --rows 60000 --batch 2048 --backend jax --workload tpch \
        --store /tmp/qd_store

Prints per-phase throughput plus the engine's plan-cache/trace counters and
(optionally) persists the ingested blocks as a BlockStore.

Workload auto-detection (``repro.service.tracker``): ``--track-workload``
simulates live traffic — between ingest rounds, query batches sampled from
the workload are *served* through ``LayoutService.serve`` and recorded into
a WorkloadTracker; the inferred top-of-mix is printed at the end.
``--workload auto`` goes further: the drift monitor is given NO declared
workload at all — per-batch Eq. 1 accounting and any auto-rebuild score
against the tracker-inferred live mix (re-inferred at trigger time).

    # observe the serving path, print the inferred mix
    PYTHONPATH=src python -m repro.launch.ingest --rows 30000 \
        --track-workload

    # fully self-optimizing: drift + rebuilds driven by the inferred mix
    PYTHONPATH=src python -m repro.launch.ingest --rows 30000 \
        --workload auto --drift --drift-abs 0.5
"""

from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np

from repro.core import query as qry
from repro.data import datagen, workload as wl
from repro.data.blocks import BlockBuffers
from repro.engine import pad_bucket, trace_counts
from repro.service import (
    DriftConfig,
    IngestOptions,
    LayoutService,
    RebuildPolicy,
)


def make_workload(name: str, rows: int, seed: int):
    if name in ("tpch", "auto"):  # auto: tpch data, tracker-inferred mix
        schema, records = datagen.make_tpch_like(rows, seed=seed)
        work, _ = wl.make_tpch_workload(schema, n_per_template=5, seed=seed)
        cuts = work.candidate_cuts(max_adv=4)
    elif name == "errorlog_int":
        schema, records = datagen.make_errorlog_int(rows, seed=seed)
        work, _ = wl.make_errorlog_int_workload(
            schema, n_queries=100, seed=seed
        )
        cuts = work.candidate_cuts()
    else:
        raise SystemExit(f"unknown workload {name!r}")
    return schema, records, work, cuts


def batch_sizes(n_rows: int, mean_batch: int, seed: int) -> list[int]:
    """Arrival-like batch sizes with ±50% jitter (plus the tail remainder)."""
    rng = np.random.default_rng(seed)
    sizes: list[int] = []
    left = n_rows
    lo = max(mean_batch // 2, 1)
    hi = max(mean_batch * 3 // 2, lo + 1)  # keep lo < hi for mean_batch=1
    while left > 0:
        b = int(rng.integers(lo, hi))
        sizes.append(min(b, left))
        left -= sizes[-1]
    return sizes


def micro_batches(records: np.ndarray, sizes: list[int]):
    i = 0
    for b in sizes:
        yield records[i : i + b]
        i += b


def serve_round(rng, work, n_queries: int) -> "qry.Workload":
    """A live-traffic sample: what users are asking between ingest rounds."""
    idx = rng.integers(0, len(work), n_queries)
    return qry.Workload(
        work.schema, tuple(work.queries[int(i)] for i in idx)
    )


def merge_round_reports(reports):
    """Fold per-round ingest reports into one stream-level summary."""
    traces: dict = {}
    obs = None
    for r in reports:
        for name, n in r.traces.items():
            traces[name] = traces.get(name, 0) + n
        if r.observation is not None:
            obs = r.observation if obs is None else obs.merge(r.observation)
    return dataclasses.replace(
        reports[-1],
        n_records=sum(r.n_records for r in reports),
        n_batches=sum(r.n_batches for r in reports),
        wall_s=sum(r.wall_s for r in reports),
        traces=traces,
        observation=obs,
    )


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--rows", type=int, default=60_000)
    ap.add_argument("--batch", type=int, default=2048,
                    help="mean micro-batch size (sizes jitter ±50%%)")
    ap.add_argument("--backend", default="jax",
                    choices=("numpy", "jax", "pallas"))
    ap.add_argument("--workload", default="tpch",
                    choices=("tpch", "errorlog_int", "auto"),
                    help="query workload; 'auto' serves tpch data but "
                         "gives the drift loop NO declared workload — "
                         "drift accounting and rebuilds score against the "
                         "mix a WorkloadTracker infers from the serving "
                         "path (implies --track-workload)")
    ap.add_argument("--track-workload", action="store_true",
                    help="serve sampled query batches through "
                         "LayoutService.serve between ingest rounds, "
                         "recording each query's predicate signature into "
                         "a WorkloadTracker; prints the inferred mix")
    ap.add_argument("--serve-queries", type=int, default=8,
                    help="queries served (and tracked) per ingest round")
    ap.add_argument("--strategy", default="greedy",
                    help="layout construction strategy "
                         "(repro.service builder registry)")
    ap.add_argument("--min-block", type=int, default=600)
    ap.add_argument("--shards", type=int, default=1,
                    help="ingest with N parallel shard ingestors "
                         "(associative merge; bit-identical to --shards 1)")
    ap.add_argument("--executor", default="auto",
                    choices=("auto", "thread", "process"),
                    help="shard executor (--shards > 1): 'process' routes "
                         "shards in resident spawn workers against a "
                         "shipped tree replica (the 'auto' default for "
                         ">= 2 shards); 'thread' shares the live engine's "
                         "compiled plans but also its GIL — measured "
                         "0.44x single-stream at k=8, so it warns")
    ap.add_argument("--coordinator", action="store_true",
                    help="route sharded rounds through a FleetCoordinator: "
                         "rounds submit ShardState/TrackerState partials "
                         "instead of publishing locally; the coordinator "
                         "folds them on --cadence and owns every publish")
    ap.add_argument("--cadence", type=int, default=4,
                    help="coordinator fold cadence, in submitted partials")
    ap.add_argument("--no-fused", action="store_true",
                    help="use the legacy two-pass route+tighten path "
                         "instead of the fused single-pass kernels")
    ap.add_argument("--rebuild", action="store_true",
                    help="after ingest, rebuild on the full corpus and "
                         "hot-swap if the Eq.1 skip rate improves")
    ap.add_argument("--drift", action="store_true",
                    help="monitor the stream's Eq.1 skip rate against the "
                         "workload and auto-rebuild (hot-swap via CAS) "
                         "when it degrades past the --drift-* policy")
    ap.add_argument("--drift-window", type=int, default=16,
                    help="sliding window length, in observations")
    ap.add_argument("--drift-abs", type=float, default=None,
                    help="absolute scanned-fraction trigger threshold "
                         "(unset: relative rule only)")
    ap.add_argument("--drift-rel", type=float, default=0.5,
                    help="trigger when the window rate degrades past "
                         "best_seen*(1+REL); <=0 disables the rule")
    ap.add_argument("--drift-hysteresis", type=int, default=2,
                    help="consecutive breaching observations required")
    ap.add_argument("--drift-cooldown", type=int, default=16,
                    help="observations blocked after a trigger")
    ap.add_argument("--drift-reservoir", type=int, default=65536,
                    help="recent-record reservoir capacity rebuilds "
                         "train on")
    ap.add_argument("--replicas", type=int, default=1,
                    help="replica count for drift-triggered rebuilds: "
                         "k>1 deploys a k-replica set with cheapest-"
                         "replica routing (k x storage)")
    ap.add_argument("--lam", type=float, default=0.25,
                    help="uniform-prior blend weight for per-replica "
                         "workload clusters (0=pure inferred mix, "
                         "1=pure uniform)")
    ap.add_argument("--store", default=None,
                    help="optional path to persist the ingested BlockStore")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    schema, records, work, cuts = make_workload(
        args.workload, args.rows, args.seed
    )
    # build the layout on a bootstrap sample, then stream the full corpus in
    sample = records[: max(args.rows // 10, 1000)]
    sample_min_block = max(
        args.min_block * sample.shape[0] // max(args.rows, 1), 50
    )
    service = LayoutService.build(
        sample, work, strategy=args.strategy, backend=args.backend,
        cuts=cuts, min_block=sample_min_block, seed=args.seed,
    )
    frozen = service.tree
    print(
        f"[ingest] built {args.strategy} layout on {sample.shape[0]} "
        f"bootstrap rows in {service.version(1).build.build_s:.2f}s "
        f"({frozen.n_leaves} blocks, depth {frozen.depth})"
    )

    tracker = None
    if args.track_workload or args.workload == "auto":
        tracker = service.workload_tracker()
        print(
            "[ingest] workload tracking on: serving "
            f"{args.serve_queries} sampled queries per round through "
            "LayoutService.serve"
        )

    monitor = None
    if args.drift:
        rel = args.drift_rel if args.drift_rel > 0 else None
        monitor = service.auto_rebuilder(RebuildPolicy(
            workload="auto" if args.workload == "auto" else work,
            tracker=tracker,
            drift=DriftConfig(
                window=args.drift_window,
                min_fill=max(args.drift_window // 4, 1),
                abs_threshold=args.drift_abs,
                rel_degradation=rel,
                hysteresis=args.drift_hysteresis,
                cooldown=args.drift_cooldown,
            ),
            replicas=args.replicas,
            lam=args.lam,
            reservoir_capacity=args.drift_reservoir,
            # auto mode derives candidate cuts from the *inferred*
            # workload at trigger time — pinning the declared cut table
            # would defeat the point of inferring the mix
            rebuild_kw=(
                dict(min_block=args.min_block, seed=args.seed)
                if args.workload == "auto"
                else dict(
                    cuts=cuts, min_block=args.min_block, seed=args.seed
                )
            ),
        ))
        print(
            f"[ingest] drift monitor on: window={args.drift_window} "
            f"abs={args.drift_abs} rel={rel} "
            f"hysteresis={args.drift_hysteresis} "
            f"cooldown={args.drift_cooldown} "
            f"reservoir={args.drift_reservoir} "
            f"workload={'auto (tracker-inferred)' if args.workload == 'auto' else 'declared'}"
        )

    engine = service.engine
    buffers = BlockBuffers.for_tree(frozen)
    fused = not args.no_fused
    # warmup: compile the ingest plan for every padding bucket the jittered
    # stream will produce (incl. the tail remainder), so the ingest loop
    # itself runs fully warm — zero retraces
    if args.shards > 1:
        from repro.engine.sharded import warm_sizes

        sizes = sorted(warm_sizes(records.shape[0], args.shards, args.batch))
    else:
        sizes = batch_sizes(records.shape[0], args.batch, args.seed)
    if fused:
        engine.warm_ingest(sizes)
    else:
        buckets = {pad_bucket(s, 64) for s in sizes}
        for m in sorted(min(b, records.shape[0]) for b in buckets):
            engine.route(records[:m])
    qrng = np.random.default_rng(args.seed + 7)
    if tracker is not None:
        # round 0 of live traffic: the tracker must know something before
        # an auto-mode monitor can score batches against an inferred mix
        # (also compiles the serve-round query geometry)
        service.serve(
            serve_round(qrng, work, args.serve_queries), tracker=tracker
        )
    if monitor is not None:
        # drift accounting probes the scored workload's query plan once
        # per ingest run — compile the geometry it will actually probe
        # (auto mode: the fixed-budget inferred mix, not the declared
        # workload) so the stream itself stays warm
        observed = monitor.current_workload()
        engine.query_hits(
            observed if observed is not None and len(observed) else work
        )
    executor = None if args.executor == "auto" else args.executor
    coordinator = None
    if args.coordinator:
        if args.shards <= 1:
            raise SystemExit("--coordinator needs --shards > 1")
        from repro.coordinator import FleetCoordinator

        coordinator = FleetCoordinator(
            service, cadence=args.cadence, tracker=tracker
        )
        print(
            f"[ingest] fleet coordinator on: folds every "
            f"{args.cadence} submitted partial(s); rounds submit "
            "aggregates instead of publishing locally"
        )
    if args.shards > 1:
        if monitor is None and tracker is None and coordinator is None:
            shard_rounds = [service.ingest(
                records, buffers=buffers,
                options=IngestOptions(
                    shards=args.shards, batch=args.batch,
                    executor=executor, fused=fused,
                ),
            )]
            report = shard_rounds[0]
        else:
            # one sharded run yields ONE drift observation — stream in
            # rounds so the monitor sees a sequence it can trigger on
            # (min_fill/hysteresis need consecutive observations), the
            # tracker's decay generations advance with the stream, and a
            # coordinator gets a cadence of partials to fold
            n_rounds = max(args.drift_window, 4)
            chunk = max(-(-records.shape[0] // n_rounds), args.shards)
            shard_rounds = []
            for s in range(0, records.shape[0], chunk):
                if service.tree is not frozen:
                    # a rebuild or coordinator fold deployed: later rounds
                    # route on the new live tree — restart buffers for it
                    frozen = service.tree
                    buffers = BlockBuffers.for_tree(frozen)
                    print(
                        "[ingest] new generation live; block buffers "
                        "restarted for its geometry"
                    )
                if tracker is not None:
                    service.serve(
                        serve_round(qrng, work, args.serve_queries),
                        tracker=tracker,
                    )
                shard_rounds.append(service.ingest(
                    records[s : s + chunk], buffers=buffers,
                    options=IngestOptions(
                        shards=args.shards, batch=args.batch,
                        monitor=monitor, executor=executor, fused=fused,
                        coordinator=coordinator,
                    ),
                ))
            report = merge_round_reports(shard_rounds)
        last = shard_rounds[-1]
        print(
            f"[ingest] {args.shards} shards routed in "
            f"{max(last.shard_wall_s):.2f}s (slowest shard, last round) "
            f"-> {last.shard_records_per_s:,.0f} rec/s pooled; "
            f"merge+publish {last.merge_s*1e3:.1f}ms"
        )
        if any(r.stale_generation for r in shard_rounds):
            print(
                "[ingest] publish skipped for a round: the tree was "
                "hot-swapped out mid-run (stale generation)"
            )
        if coordinator is not None:
            if coordinator.stats()["pending"]:
                coordinator.fold()  # flush partials below the cadence
            cstats = coordinator.stats()
            print(
                f"[ingest] coordinator: {cstats['folds']} fold(s), "
                f"{cstats['stale_dropped']} stale partial(s) dropped, "
                f"live generation {service.generation} "
                f"(desc v{service.live_epoch().desc_version})"
            )
            service.close_ingest_sessions()
    elif tracker is not None:
        # live traffic interleaves with ingestion: serve a sampled query
        # round, then ingest a chunk of the stream — every round closes
        # one tracker decay generation, and an auto-mode monitor
        # re-infers the mix it scores against at each round
        n_rounds = max(args.drift_window, 8)
        per_round = max(-(-len(sizes) // n_rounds), 1)
        round_reports = []
        off = 0
        for r in range(0, len(sizes), per_round):
            if service.tree is not frozen:
                frozen = service.tree
                buffers = BlockBuffers.for_tree(frozen)
                print(
                    "[ingest] drift rebuild deployed; block buffers "
                    "restarted for the new generation"
                )
            round_sizes = sizes[r : r + per_round]
            n_round = sum(round_sizes)
            service.serve(
                serve_round(qrng, work, args.serve_queries),
                tracker=tracker,
            )
            round_reports.append(service.ingest(
                micro_batches(records[off : off + n_round], round_sizes),
                buffers=buffers,
                options=IngestOptions(monitor=monitor, fused=fused),
            ))
            off += n_round
        report = merge_round_reports(round_reports)
    else:
        report = service.ingest(
            micro_batches(records, sizes), buffers=buffers,
            options=IngestOptions(monitor=monitor, fused=fused),
        )
    print(
        f"[ingest] {report.n_records} records / {report.n_batches} "
        f"micro-batches in {report.wall_s:.2f}s -> "
        f"{report.records_per_s:,.0f} rec/s on backend={report.backend}"
    )
    print(f"[ingest] plan cache: {report.plan_cache}")
    print(f"[ingest] traces during ingest (0 ⇒ fully warm): {report.traces}")
    print(f"[ingest] all traces: {trace_counts()}")

    drift_summary = None
    if monitor is not None:
        monitor.drain()
        monitor.close()
        if report.observation is not None:
            print(
                f"[ingest] drift: stream scanned fraction "
                f"{report.observation.scanned_fraction:.4f} over "
                f"{report.observation.n_records} observed records"
            )
        for ev in monitor.events:
            if ev.deployed:
                # single-tree rebuilds carry new_generation; replica
                # rebuilds carry the whole set's new_generations
                gens = getattr(
                    ev.report, "new_generation", None
                ) or tuple(getattr(ev.report, "new_generations", ()))
                deployed_what = f"deployed gen {gens}"
            what = (
                f"skipped ({ev.skipped})" if ev.skipped
                else f"error ({ev.error})" if ev.error
                else deployed_what if ev.deployed
                else "kept live tree (candidate not better)"
            )
            print(
                f"[ingest] drift trigger at obs {ev.observation} "
                f"({ev.decision.reason}, window "
                f"{ev.decision.window_rate:.4f}): {what}"
            )
        drift_summary = {
            "observed_scanned_fraction": (
                report.observation.scanned_fraction
                if report.observation is not None else None
            ),
            "triggers": len(monitor.events),
            "rebuilds_deployed": monitor.rebuilds_deployed,
            "generation": service.generation,
            "workload": (
                "auto" if args.workload == "auto" else "declared"
            ),
        }

    tracker_summary = None
    if tracker is not None:
        state = tracker.snapshot()
        inferred = tracker.infer_workload()
        print(
            f"[ingest] tracker: {state.n_keys} signatures over "
            f"{state.queries_seen} served queries "
            f"({state.generation} decay generations); inferred mix = "
            f"{len(inferred)} weighted queries"
        )
        for line in tracker.describe(5):
            print(f"[ingest] inferred: {line}")
        tracker_summary = {
            "queries_seen": state.queries_seen,
            "n_keys": state.n_keys,
            "generation": state.generation,
            "inferred_queries": len(inferred),
        }

    # score the CURRENT live tree — a drift rebuild may have swapped it
    stats = service.engine.skip_stats(records, work, tighten=False)
    print(
        f"[ingest] layout quality: scanned fraction "
        f"{stats.scanned_fraction:.4f} over {stats.n_queries} queries"
    )

    rebuild_summary = None
    if args.rebuild:
        # the bootstrap tree was built on 10% of the corpus — rebuild on
        # everything and hot-swap behind the serving facade if it wins
        rep = service.rebuild(
            records, work, cuts=cuts, min_block=args.min_block,
            seed=args.seed,
        )
        print(
            f"[ingest] rebuild: live {rep.live_scanned:.4f} vs candidate "
            f"{rep.candidate_scanned:.4f} -> "
            f"{'swapped to gen ' + str(rep.new_generation) if rep.swapped else 'kept gen ' + str(rep.old_generation)}"
        )
        rebuild_summary = {
            "swapped": rep.swapped,
            "live_scanned": rep.live_scanned,
            "candidate_scanned": rep.candidate_scanned,
            "generation": service.generation,
        }

    if args.store:
        store = buffers.write_store(args.store, frozen)
        print(
            f"[ingest] persisted {int(store.sizes.sum())} rows in "
            f"{store.sizes.shape[0]} blocks at {store.root}"
        )
    summary = {
        "records_per_s": report.records_per_s,
        "n_records": report.n_records,
        "n_batches": report.n_batches,
        "backend": report.backend,
        "strategy": args.strategy,
        "n_shards": args.shards,
        "fused": fused,
        "executor": args.executor if args.shards > 1 else None,
        "plan_cache": report.plan_cache,
        "ingest_traces": report.traces,
        "scanned_fraction": stats.scanned_fraction,
        "rebuild": rebuild_summary,
        "coordinator": (
            coordinator.stats() if coordinator is not None else None
        ),
        "drift": drift_summary,
        "workload": args.workload,
        "workload_tracking": tracker_summary,
    }
    print(json.dumps(summary))


if __name__ == "__main__":
    main()
