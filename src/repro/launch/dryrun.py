import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

For every (architecture × input shape) cell this lowers + compiles the
real step function on the production mesh — 16×16 single-pod and 2×16×16
multi-pod — and records:

  * memory_analysis()  (per-device bytes: proves the cell fits),
  * cost_analysis()    (HLO FLOPs / bytes),
  * the collective inventory parsed from the post-SPMD HLO,
  * per-step cost terms extrapolated from 1-group/2-group unrolled
    variants (XLA cost analysis counts while bodies once — hlo.py).

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-32b --shape train_4k
  python -m repro.launch.dryrun --all [--resume] [--multi-pod-only]
"""

import argparse
import dataclasses
import json
import pathlib
import subprocess
import sys
import time

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _cell_json(arch: str, shape: str, multi_pod: bool) -> pathlib.Path:
    pod = "multipod" if multi_pod else "singlepod"
    return RESULTS / f"{arch}__{shape}__{pod}.json"


def _mem_dict(mem) -> dict:
    return {
        k: getattr(mem, k)
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "alias_size_in_bytes",
            "generated_code_size_in_bytes",
        )
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             with_cost: bool = True, cost_only: bool = False) -> dict:
    # imports deferred until after XLA_FLAGS is set
    import jax
    from repro.configs import get_config
    from repro.launch import hlo
    from repro.launch.mesh import make_production_mesh
    from repro.launch.shapes import build_cell, lower_cell
    from repro.models import model as model_lib
    from repro.models import transformer

    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size

    record = {
        "arch": arch, "shape": shape_name,
        "mesh": dict(mesh.shape), "chips": n_chips,
        "jax_version": jax.__version__,
    }

    if cost_only:
        # refresh cost_terms on an existing record (skip the full compile)
        prev = _cell_json(arch, shape_name, multi_pod)
        if prev.exists():
            record = json.loads(prev.read_text())
    else:
        # ---- full-fidelity compile: scanned stack, real chunking --------
        t0 = time.perf_counter()
        cell = build_cell(cfg, shape_name, mesh)
        lowered = lower_cell(cell, mesh)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0
        mem = compiled.memory_analysis()
        print(mem)
        cost_full = hlo.cost_dict(compiled)
        print({k: cost_full.get(k) for k in ("flops", "bytes accessed")})
        text = compiled.as_text()
        coll_full = hlo.parse_collectives(text)
        record.update(
            step=cell.step_name,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory=_mem_dict(mem),
            cost_scanned=({k: cost_full.get(k) for k in
                           ("flops", "bytes accessed")}),
            collectives_scanned=coll_full,
            hlo_bytes=len(text),
        )
        del compiled, lowered, text

    # ---- per-step cost terms via 1g/2g unrolled extrapolation -----------
    if with_cost:
        period = (
            1 if cfg.is_encdec
            else len(transformer.layer_program(cfg))
        )
        ng = (
            cfg.n_layers if cfg.is_encdec else transformer.n_groups(cfg)
        )
        samples = {}
        for g in (1, 2):
            vcfg = dataclasses.replace(
                cfg,
                n_layers=period * g,
                encoder_layers=(g if cfg.is_encdec else cfg.encoder_layers),
                scan_unroll=True,
                attn_chunk=8192,
                ssd_chunk=2048,
                # microbatching splits the same math across a scan whose
                # body XLA costs once; count the full batch instead
                microbatches=1,
            )
            vcell = build_cell(vcfg, shape_name, mesh)
            vlow = lower_cell(vcell, mesh)
            vcomp = vlow.compile()
            c = hlo.cost_dict(vcomp)
            vtext = vcomp.as_text()
            coll = hlo.parse_collectives(vtext)
            samples[g] = {
                "flops": float(c.get("flops", 0.0)),
                "bytes": float(c.get("bytes accessed", 0.0)),
                "fused_bytes": float(hlo.fused_bytes_estimate(vtext)),
                "coll_bytes": float(hlo.total_collective_bytes(coll)),
                "coll": coll,
            }
            del vcomp, vlow, vtext
        keys = ("flops", "bytes", "fused_bytes", "coll_bytes")
        body = {k: samples[2][k] - samples[1][k] for k in keys}
        outside = {k: max(samples[1][k] - body[k], 0.0)
                   for k in body}
        total = {k: outside[k] + ng * max(body[k], 0.0) for k in body}
        record["cost_terms"] = {
            "per_group": body,
            "outside": outside,
            "n_groups": ng,
            "total_flops": total["flops"],
            "total_bytes": total["bytes"],
            "total_fused_bytes": total["fused_bytes"],
            "total_collective_bytes": total["coll_bytes"],
            "collectives_1g": samples[1]["coll"],
            "collectives_2g": samples[2]["coll"],
        }
        record["model_flops"] = model_lib.model_flops_per_token(cfg)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells whose result JSON already exists")
    ap.add_argument("--no-cost", action="store_true",
                    help="skip the cost-extrapolation variants")
    ap.add_argument("--cost-only", action="store_true",
                    help="recompute cost_terms on existing records only")
    args = ap.parse_args()

    RESULTS.mkdir(parents=True, exist_ok=True)

    if args.all:
        # one subprocess per cell: isolates XLA state/memory per compile
        from repro.configs import runnable_cells

        cells = [
            (a, s, mp)
            for (a, s) in runnable_cells()
            for mp in (False, True)
        ]
        failed = []
        for arch, shape, mp in cells:
            out = _cell_json(arch, shape, mp)
            if args.resume and out.exists():
                print(f"skip {out.name}")
                continue
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch, "--shape", shape,
            ]
            if mp:
                # the roofline cost table is single-pod (§Roofline); the
                # multi-pod pass proves the pod axis shards + reports memory
                cmd += ["--multi-pod", "--no-cost"]
            if args.no_cost and "--no-cost" not in cmd:
                cmd.append("--no-cost")
            print(f"=== {arch} × {shape} × "
                  f"{'multi' if mp else 'single'}pod ===", flush=True)
            try:
                r = subprocess.run(cmd, timeout=3600)
                code = r.returncode
            except subprocess.TimeoutExpired:
                code = -1
                print("TIMEOUT")
            if code:
                failed.append((arch, shape, mp))
        print(f"done; {len(failed)} failures: {failed}")
        sys.exit(1 if failed else 0)

    record = run_cell(
        args.arch, args.shape, args.multi_pod,
        with_cost=not args.no_cost, cost_only=args.cost_only,
    )
    out = _cell_json(args.arch, args.shape, args.multi_pod)
    out.write_text(json.dumps(record, indent=1))
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
