"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16 experts top-2 on every other layer,
Mamba:attention 1:7 interleave (1 attention layer per 8, offset 4).
Mamba layers use the Mamba2/SSD formulation (TPU adaptation — DESIGN.md §5).
bf16 params + 8-bit Adam moments.  [arXiv:2403.19887; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    moe_d_ff=24576,
    vocab=65536,
    n_experts=16,
    top_k=2,
    moe_every=2,
    moe_offset=1,
    attn_every=8,
    attn_offset=4,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=128,
    ssm_groups=8,
    ssm_conv=4,
    param_dtype="bfloat16",
    opt_8bit=True,
    microbatches=8,
)
