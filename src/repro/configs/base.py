"""Model configuration dataclass + the assigned input-shape table."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | vlm | moe | ssm | audio | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 ⇒ d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden (qwen3-moe uses 1536)
    moe_every: int = 1  # MoE MLP at layers where i % moe_every == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # "expert": shard the expert axis over `model` (EP; needs n_experts
    # divisible by the model-axis size).  "ff": keep experts replicated and
    # tensor-shard each expert's hidden dim (few-big-experts models).
    moe_shard: str = "expert"
    moe_groups: int = 64  # dispatch groups (GShard-style; ≥ data shards)

    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    attn_every: int = 0  # hybrid: attention at layers i % attn_every == attn_offset
    attn_offset: int = 0

    # --- enc-dec (audio) ---
    is_encdec: bool = False
    encoder_layers: int = 0

    # --- VLM ---
    n_image_patches: int = 0

    # --- numerics / memory policy ---
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"  # large models override to bfloat16
    opt_8bit: bool = False  # int8 block-quantized Adam moments
    remat: bool = True
    microbatches: int = 1
    scan_layers: bool = True

    # --- attention implementation ---
    attn_chunk: int = 1024  # KV-chunked (online-softmax) attention block
    mlp_gated: bool = True  # SwiGLU (False ⇒ plain GELU MLP)
    pos_embed: str = "rope"  # "rope" | "learned"
    norm_type: str = "rmsnorm"  # "rmsnorm" | "layernorm"
    max_positions: int = 0  # learned-pos table size; 0 ⇒ sized per shape
    scan_unroll: bool = False  # unroll all scans (roofline cost variants)
    ssd_chunk: int = 256  # SSD chunk length (mamba2)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def is_attn_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        if self.attn_every:
            return i % self.attn_every == self.attn_offset
        return True

    def is_moe_layer(self, i: int) -> bool:
        if not self.n_experts:
            return False
        return i % self.moe_every == self.moe_offset

    @property
    def expert_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests.

        Keeps every structural switch (GQA grouping, MoE top-k, hybrid
        interleave pattern, enc-dec, biases, norms) while shrinking width,
        depth, vocab, and expert count.
        """
        period = 1
        if self.attn_every:
            period = self.attn_every
        if self.n_experts:
            period = _lcm(period, self.moe_every)
        small = dict(
            name=self.name + "-smoke",
            n_layers=2 * period,
            d_model=64,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=16 if self.n_heads else 0,
            d_ff=128 if self.d_ff else 0,
            moe_d_ff=64 if self.moe_d_ff else 0,
            vocab=256,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_groups=min(self.ssm_groups, 2) if self.ssm_state else 1,
            encoder_layers=2 if self.is_encdec else 0,
            n_image_patches=8 if self.n_image_patches else 0,
            param_dtype="float32",
            compute_dtype="float32",
            opt_8bit=self.opt_8bit,
            attn_chunk=64,
            max_positions=128,
            microbatches=1,
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


def _lcm(a: int, b: int) -> int:
    import math

    return a * b // math.gcd(a, b)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# The assigned shape set (applies to every architecture; long_500k only for
# sub-quadratic archs — see DESIGN.md §5).
SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# archs whose token mixing is sub-quadratic (run long_500k)
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")
