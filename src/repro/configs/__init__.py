"""Assigned-architecture configs (--arch <id>) + paper-workload configs."""

from repro.configs.base import (  # noqa: F401
    SHAPES,
    SUBQUADRATIC_FAMILIES,
    InputShape,
    ModelConfig,
)

from repro.configs.qwen1_5_32b import CONFIG as _qwen32
from repro.configs.starcoder2_3b import CONFIG as _sc3
from repro.configs.starcoder2_15b import CONFIG as _sc15
from repro.configs.qwen1_5_110b import CONFIG as _qwen110
from repro.configs.llava_next_mistral_7b import CONFIG as _llava
from repro.configs.qwen3_moe_235b_a22b import CONFIG as _qwen3moe
from repro.configs.grok_1_314b import CONFIG as _grok
from repro.configs.mamba2_780m import CONFIG as _mamba
from repro.configs.whisper_small import CONFIG as _whisper
from repro.configs.jamba_1_5_large_398b import CONFIG as _jamba

ARCHS = {
    c.name: c
    for c in (
        _qwen32, _sc3, _sc15, _qwen110, _llava,
        _qwen3moe, _grok, _mamba, _whisper, _jamba,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(ARCHS)}"
        )
    return ARCHS[name]


def runnable_cells() -> list[tuple[str, str]]:
    """All (arch, shape) dry-run cells, honoring the long_500k skip rule."""
    cells = []
    for name, cfg in ARCHS.items():
        for shape in SHAPES.values():
            if (
                shape.name == "long_500k"
                and cfg.family not in SUBQUADRATIC_FAMILIES
            ):
                continue
            cells.append((name, shape.name))
    return cells
