"""qwen1.5-110b [dense] — 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064, QKV bias.  [hf:Qwen/Qwen1.5-0.5B family; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab=152064,
    qkv_bias=True,
    param_dtype="bfloat16",  # §Perf B1: bf16 weights halve FSDP gather + kill cast traffic
    microbatches=4,
)
