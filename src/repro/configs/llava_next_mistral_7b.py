"""llava-next-mistral-7b [vlm] — Mistral-7B backbone: 32L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=32000; anyres patch frontend is a STUB —
input_specs() supplies 576 precomputed, projected patch embeddings.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    n_image_patches=576,
    microbatches=2,
)
