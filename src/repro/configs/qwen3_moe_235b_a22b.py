"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4, head_dim 128)
per-expert d_ff=1536, vocab=151936, MoE 128 experts top-8 on every layer.
bf16 params + 8-bit Adam moments to fit 256 chips (DESIGN.md §6).
[hf:Qwen/Qwen3-30B-A3B family; hf]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,          # listed d_ff is the per-expert hidden
    moe_d_ff=1536,
    vocab=151936,
    n_experts=128,
    top_k=8,
    param_dtype="bfloat16",
    opt_8bit=True,
    microbatches=8,
)
