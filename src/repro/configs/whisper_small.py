"""whisper-small [audio] — enc-dec, 12L encoder + 12L decoder, d_model=768
12H (kv=12) d_ff=3072 vocab=51865; conv frontend is a STUB — input_specs()
supplies precomputed frame embeddings.  Learned positions, GELU MLP.
[arXiv:2212.04356; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    is_encdec=True,
    encoder_layers=12,
    mlp_gated=False,
    norm_type="layernorm",
    pos_embed="learned",
)
