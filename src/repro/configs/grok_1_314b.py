"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2 on every layer.  bf16 params + 8-bit
Adam moments to fit 256 chips.  [hf:xai-org/grok-1; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    moe_d_ff=32768,
    vocab=131072,
    n_experts=8,
    top_k=2,
    moe_shard="ff",  # 8 big experts < model-axis 16 => TP the expert hidden
    param_dtype="bfloat16",
    opt_8bit=True,
    microbatches=8,
)
