"""Queries, workloads, and query↔block intersection (paper Sec 3.3).

A query is a DNF over atomic predicates:

  * numeric range atoms  (dim, op, literal)          op ∈ {<, <=, >, >=, ==}
  * categorical atoms    (dim, IN, values)
  * advanced atoms       (adv_id, polarity)          paper Sec 6.1

Each *conjunct* tensorizes to the same shape as a node description —
(q_lo, q_hi, q_cat, q_adv_req) — so intersection is a dense elementwise
check, which is what the ``query_intersect`` Pallas kernel computes.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import predicates as preds
from repro.core.predicates import CutTable, CutTableBuilder, Schema

ADV_ANY, ADV_TRUE, ADV_FALSE = 0, 1, 2


@dataclasses.dataclass(frozen=True)
class RangeAtom:
    dim: int
    op: int  # OP_LT/LE/GT/GE/EQ
    literal: int


@dataclasses.dataclass(frozen=True)
class InAtom:
    dim: int
    values: tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class AdvAtom:
    col_a: int
    op: int
    col_b: int
    polarity: bool = True  # False means the query requires NOT(pred)


Atom = RangeAtom | InAtom | AdvAtom


@dataclasses.dataclass(frozen=True)
class Query:
    """DNF: OR over conjuncts; each conjunct is an AND over atoms."""

    conjuncts: tuple[tuple[Atom, ...], ...]

    @staticmethod
    def conjunction(atoms: Sequence[Atom]) -> "Query":
        return Query(conjuncts=(tuple(atoms),))

    @staticmethod
    def disjunction(conjuncts: Sequence[Sequence[Atom]]) -> "Query":
        return Query(conjuncts=tuple(tuple(c) for c in conjuncts))

    def evaluate(self, records: np.ndarray, schema: Schema) -> np.ndarray:
        """Exact per-record truth (m,) bool — ground truth for selectivity."""
        out = np.zeros(records.shape[0], dtype=bool)
        for conj in self.conjuncts:
            acc = np.ones(records.shape[0], dtype=bool)
            for a in conj:
                if isinstance(a, RangeAtom):
                    acc &= preds._OP_FNS[a.op](records[:, a.dim], a.literal)
                elif isinstance(a, InAtom):
                    acc &= np.isin(records[:, a.dim], np.asarray(a.values))
                else:
                    t = preds.AdvPredicate(a.col_a, a.op, a.col_b).evaluate(
                        records
                    )
                    acc &= t if a.polarity else ~t
            out |= acc
        return out


@dataclasses.dataclass
class WorkloadTensors:
    """Stacked conjunct descriptions for a whole workload.

    q_lo, q_hi  : (n_conj, ndims) int32 — numeric box (hi exclusive)
    q_cat       : (n_conj, bits) bool   — allowed categorical values
    q_adv       : (n_conj, n_adv) int8  — ADV_ANY / ADV_TRUE / ADV_FALSE
    conj_query  : (n_conj,) int32       — owning query index
    n_queries   : int
    """

    q_lo: np.ndarray
    q_hi: np.ndarray
    q_cat: np.ndarray
    q_adv: np.ndarray
    conj_query: np.ndarray
    n_queries: int

    @property
    def n_conjuncts(self) -> int:
        return int(self.q_lo.shape[0])


@dataclasses.dataclass
class Workload:
    schema: Schema
    queries: tuple[Query, ...]

    def __len__(self) -> int:
        return len(self.queries)

    # -- candidate cuts (paper Sec 3.4: all pushed-down unary predicates) ---
    def candidate_cuts(self, max_adv: int | None = None) -> CutTable:
        b = CutTableBuilder(self.schema)
        n_adv = 0
        for q in self.queries:
            for conj in q.conjuncts:
                for a in conj:
                    if isinstance(a, RangeAtom):
                        b.add_range(a.dim, a.op, a.literal)
                    elif isinstance(a, InAtom):
                        b.add_in(a.dim, a.values)
                    else:
                        if max_adv is None or n_adv < max_adv:
                            b.add_adv(a.col_a, a.op, a.col_b)
                            n_adv += 1
        return b.build()

    # -- tensorization -------------------------------------------------------
    def tensorize(self, cuts: CutTable) -> WorkloadTensors:
        schema = self.schema
        doms = schema.doms
        bits = max(schema.total_cat_bits, 1)
        n_adv = cuts.n_adv
        adv_index = {
            (a.col_a, a.op, a.col_b): i for i, a in enumerate(cuts.adv)
        }
        rows_lo, rows_hi, rows_cat, rows_adv, owner = [], [], [], [], []
        for qi, q in enumerate(self.queries):
            for conj in q.conjuncts:
                lo = np.zeros(schema.ndims, np.int64)
                hi = doms.astype(np.int64).copy()
                cat = np.ones(bits, bool)
                adv = np.zeros(max(n_adv, 1), np.int8)
                for a in conj:
                    if isinstance(a, RangeAtom):
                        if a.op == preds.OP_LT:
                            hi[a.dim] = min(hi[a.dim], a.literal)
                        elif a.op == preds.OP_LE:
                            hi[a.dim] = min(hi[a.dim], a.literal + 1)
                        elif a.op == preds.OP_GT:
                            lo[a.dim] = max(lo[a.dim], a.literal + 1)
                        elif a.op == preds.OP_GE:
                            lo[a.dim] = max(lo[a.dim], a.literal)
                        elif a.op == preds.OP_EQ:
                            lo[a.dim] = max(lo[a.dim], a.literal)
                            hi[a.dim] = min(hi[a.dim], a.literal + 1)
                        else:
                            raise ValueError("OP_NE atoms unsupported")
                    elif isinstance(a, InAtom):
                        seg = schema.cat_segment(a.dim)
                        m = np.zeros(seg.stop - seg.start, bool)
                        m[np.asarray(a.values, np.int64)] = True
                        cat[seg] &= m
                    else:
                        key = (a.col_a, a.op, a.col_b)
                        if key in adv_index:
                            adv[adv_index[key]] = (
                                ADV_TRUE if a.polarity else ADV_FALSE
                            )
                        # adv atoms outside the cut table cannot prune blocks
                        # (no metadata for them) — drop, which is conservative.
                rows_lo.append(lo)
                rows_hi.append(hi)
                rows_cat.append(cat)
                rows_adv.append(adv)
                owner.append(qi)
        return WorkloadTensors(
            q_lo=np.asarray(rows_lo, np.int32),
            q_hi=np.asarray(rows_hi, np.int32),
            q_cat=np.asarray(rows_cat, bool),
            q_adv=np.asarray(rows_adv, np.int8),
            conj_query=np.asarray(owner, np.int32),
            n_queries=len(self.queries),
        )


# ---------------------------------------------------------------------------
# Intersection checks (numpy reference; kernel in kernels/query_intersect.py)
# ---------------------------------------------------------------------------
def conjuncts_intersect(
    desc_lo: np.ndarray,  # (L, ndims)
    desc_hi: np.ndarray,
    desc_cat: np.ndarray,  # (L, bits)
    desc_adv: np.ndarray,  # (L, n_adv, 2)
    wt: WorkloadTensors,
    schema: Schema,
) -> np.ndarray:
    """(L, n_conj) bool — does block description L possibly contain records
    matching conjunct c?  Conservative (never false-negative)."""
    # numeric box overlap on every numeric dim: max(lo) < min(hi)
    lo = np.maximum(desc_lo[:, None, :], wt.q_lo[None, :, :])
    hi = np.minimum(desc_hi[:, None, :], wt.q_hi[None, :, :])
    numeric = ~schema.is_categorical
    box_ok = (lo < hi)[:, :, numeric].all(axis=2)
    # categorical: every constrained dim must share at least one value
    cat_ok = np.ones(box_ok.shape, bool)
    off = schema.cat_offsets
    for d in np.nonzero(schema.is_categorical)[0]:
        seg = slice(int(off[d]), int(off[d]) + schema.columns[d].dom)
        inter = (
            desc_cat[:, None, seg] & wt.q_cat[None, :, seg]
        ).any(axis=2)
        cat_ok &= inter
    # advanced bits: required polarity must be possible under the block
    adv_ok = np.ones(box_ok.shape, bool)
    n_adv = desc_adv.shape[1]
    for a in range(n_adv):
        req = wt.q_adv[:, a]  # (n_conj,)
        may_t = desc_adv[:, a, 0]  # (L,)
        may_f = desc_adv[:, a, 1]
        ok = np.ones((desc_adv.shape[0], req.shape[0]), bool)
        ok &= ~((req == ADV_TRUE)[None, :] & ~may_t[:, None])
        ok &= ~((req == ADV_FALSE)[None, :] & ~may_f[:, None])
        adv_ok &= ok
    return box_ok & cat_ok & adv_ok


def queries_intersect(
    conj_hits: np.ndarray, wt: WorkloadTensors
) -> np.ndarray:
    """Reduce conjunct hits to per-query hits: (L, n_conj) → (L, n_queries).

    A DNF query touches a block iff ANY of its conjuncts does.
    """
    L = conj_hits.shape[0]
    out = np.zeros((L, wt.n_queries), bool)
    np.logical_or.at(out, (slice(None), wt.conj_query), conj_hits)
    return out


def route_query(
    tree, query: Query  # tree: FrozenQdTree (avoid import cycle)
) -> np.ndarray:
    """BID IN (...) list for one query (paper Sec 3.3) — compatibility shim.

    Delegates to the tree's attached engine so there is a single
    ``route_query`` implementation (``LayoutEngine.route_query``, itself a
    1-query :meth:`~repro.engine.LayoutEngine.route_queries`).
    """
    from repro.engine import engine_for

    return engine_for(tree).route_query(query)
