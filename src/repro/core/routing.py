"""Batched data routing through a frozen qd-tree (paper Sec 3.1).

Three interchangeable backends, all bit-identical:

* ``FrozenQdTree.route``      — numpy oracle (core/qdtree.py)
* ``route_jax``               — jitted jnp level-synchronous descent (here)
* ``kernels.ops.route_records`` — Pallas TPU kernel (one-hot matmul descent)

The jnp/Pallas paths take the tree as a pytree of arrays so the same
compiled function serves any tree of equal static shape (n_nodes is padded
to a bucket size to maximize jit cache hits during online ingestion).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import predicates as preds
from repro.core.qdtree import FrozenQdTree


def tree_arrays(tree: FrozenQdTree, pad_nodes: int | None = None) -> dict:
    """Pack the frozen tree into jnp-friendly arrays (optionally padded)."""
    n = tree.n_nodes
    pad = pad_nodes or n
    if pad < n:
        raise ValueError("pad_nodes < n_nodes")

    def _pad(x, fill):
        out = np.full((pad,) + x.shape[1:], fill, x.dtype)
        out[:n] = x
        return out

    return {
        "cut_id": jnp.asarray(_pad(tree.cut_id, -1)),
        "left": jnp.asarray(_pad(tree.left, 0)),
        "right": jnp.asarray(_pad(tree.right, 0)),
        "leaf_bid": jnp.asarray(_pad(tree.leaf_bid, -1)),
        "depth": tree.depth,
    }


def cut_arrays(cuts: preds.CutTable) -> dict:
    """Pack the cut table for jnp evaluation."""
    adv = np.array(
        [(a.col_a, a.op, a.col_b) for a in cuts.adv], np.int32
    ).reshape(-1, 3)
    return {
        "kind": jnp.asarray(cuts.kind),
        "dim": jnp.asarray(np.maximum(cuts.dim, 0)),
        "cutpoint": jnp.asarray(cuts.cutpoint),
        "in_mask": jnp.asarray(cuts.in_mask),
        "adv_id": jnp.asarray(np.maximum(cuts.adv_id, 0)),
        "adv": jnp.asarray(adv),
        "cat_offset": jnp.asarray(np.maximum(cuts.schema.cat_offsets, 0)),
    }


def eval_cuts_jax(records: jnp.ndarray, ca: dict) -> jnp.ndarray:
    """(m, n_cuts) bool predicate matrix — jnp mirror of preds.eval_cuts."""
    vals = records[:, ca["dim"]]  # (m, n_cuts) gathered column values
    rng = vals < ca["cutpoint"][None, :]
    # IN: bit lookup at (cut, value + dim offset)
    bitpos = vals + ca["cat_offset"][ca["dim"]][None, :]
    bitpos = jnp.clip(bitpos, 0, ca["in_mask"].shape[1] - 1)
    inm = _in_lookup(ca["in_mask"], bitpos)
    # advanced predicates
    if ca["adv"].shape[0] > 0:
        va = records[:, ca["adv"][:, 0]]
        vb = records[:, ca["adv"][:, 2]]
        op = ca["adv"][:, 1][None, :]
        advt = jnp.select(
            [op == 0, op == 1, op == 2, op == 3, op == 4, op == 5],
            [va < vb, va <= vb, va > vb, va >= vb, va == vb, va != vb],
        )
        advm = advt[:, ca["adv_id"]]
    else:
        advm = jnp.zeros_like(rng)
    k = ca["kind"][None, :]
    return jnp.where(
        k == preds.KIND_RANGE, rng, jnp.where(k == preds.KIND_IN, inm, advm)
    )


def _in_lookup(in_mask: jnp.ndarray, bitpos: jnp.ndarray) -> jnp.ndarray:
    """in_mask[c, bitpos[m, c]] without materializing (m, n_cuts, bits)."""
    # vmap over the cut axis: each cut has its own mask row + position column.
    def per_cut(mask_row, pos_col):
        return mask_row[pos_col]

    return jax.vmap(per_cut, in_axes=(0, 1), out_axes=1)(in_mask, bitpos)


@functools.partial(jax.jit, static_argnames=("depth",))
def _route_jit(
    records: jnp.ndarray, ta: dict, ca: dict, depth: int
) -> jnp.ndarray:
    M = eval_cuts_jax(records, ca)
    m = records.shape[0]
    node = jnp.zeros(m, jnp.int32)

    def body(_, node):
        cid = ta["cut_id"][node]
        pred = jnp.take_along_axis(
            M, jnp.clip(cid, 0)[:, None].astype(jnp.int32), axis=1
        )[:, 0]
        nxt = jnp.where(pred, ta["left"][node], ta["right"][node])
        return jnp.where(cid >= 0, nxt, node)

    node = jax.lax.fori_loop(0, depth, body, node)
    return ta["leaf_bid"][node]


def route_jax(tree: FrozenQdTree, records: np.ndarray) -> np.ndarray:
    """Route a record batch on the jnp backend; returns (m,) int32 BIDs."""
    ta = tree_arrays(tree)
    depth = ta.pop("depth")
    ca = cut_arrays(tree.cuts)
    out = _route_jit(jnp.asarray(records), ta, ca, depth)
    return np.asarray(out)


def route(
    tree: FrozenQdTree, records: np.ndarray, backend: str = "jax"
) -> np.ndarray:
    if backend == "numpy":
        return tree.route(records)
    if backend == "jax":
        return route_jax(tree, records)
    if backend == "pallas":
        from repro.kernels import ops

        return ops.route_records(tree, records)
    raise ValueError(f"unknown backend {backend!r}")
