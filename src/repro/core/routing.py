"""Batched data routing through a frozen qd-tree (paper Sec 3.1).

Three interchangeable backends, all bit-identical:

* ``FrozenQdTree.route``      — numpy oracle (core/qdtree.py)
* engine "jax" backend        — jitted jnp level-synchronous descent
* ``kernels.ops.route_records`` — Pallas TPU kernel (one-hot matmul descent)

Backend dispatch, operand packing, and compilation caching live in the
:mod:`repro.engine` subsystem — ``route`` below is a thin compatibility
shim over the tree's attached :class:`~repro.engine.LayoutEngine`, whose
plan cache pads batch/tree sizes to power-of-two buckets so online
ingestion of varying shapes reuses jit/Pallas compilations.
``eval_cuts_jax``/``cut_arrays`` remain here as the jnp predicate
evaluation the engine's "jax" backend builds on.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import predicates as preds
from repro.core.qdtree import FrozenQdTree


def cut_arrays(cuts: preds.CutTable) -> dict:
    """Pack the cut table for jnp evaluation."""
    adv = np.array(
        [(a.col_a, a.op, a.col_b) for a in cuts.adv], np.int32
    ).reshape(-1, 3)
    return {
        "kind": jnp.asarray(cuts.kind),
        "dim": jnp.asarray(np.maximum(cuts.dim, 0)),
        "cutpoint": jnp.asarray(cuts.cutpoint),
        "in_mask": jnp.asarray(cuts.in_mask),
        "adv_id": jnp.asarray(np.maximum(cuts.adv_id, 0)),
        "adv": jnp.asarray(adv),
        "cat_offset": jnp.asarray(np.maximum(cuts.schema.cat_offsets, 0)),
    }


def eval_cuts_jax(records: jnp.ndarray, ca: dict) -> jnp.ndarray:
    """(m, n_cuts) bool predicate matrix — jnp mirror of preds.eval_cuts."""
    vals = records[:, ca["dim"]]  # (m, n_cuts) gathered column values
    rng = vals < ca["cutpoint"][None, :]
    # IN: bit lookup at (cut, value + dim offset)
    bitpos = vals + ca["cat_offset"][ca["dim"]][None, :]
    bitpos = jnp.clip(bitpos, 0, ca["in_mask"].shape[1] - 1)
    inm = _in_lookup(ca["in_mask"], bitpos)
    # advanced predicates
    if ca["adv"].shape[0] > 0:
        va = records[:, ca["adv"][:, 0]]
        vb = records[:, ca["adv"][:, 2]]
        op = ca["adv"][:, 1][None, :]
        advt = jnp.select(
            [op == 0, op == 1, op == 2, op == 3, op == 4, op == 5],
            [va < vb, va <= vb, va > vb, va >= vb, va == vb, va != vb],
        )
        advm = advt[:, ca["adv_id"]]
    else:
        advm = jnp.zeros_like(rng)
    k = ca["kind"][None, :]
    return jnp.where(
        k == preds.KIND_RANGE, rng, jnp.where(k == preds.KIND_IN, inm, advm)
    )


def _in_lookup(in_mask: jnp.ndarray, bitpos: jnp.ndarray) -> jnp.ndarray:
    """in_mask[c, bitpos[m, c]] without materializing (m, n_cuts, bits)."""
    # vmap over the cut axis: each cut has its own mask row + position column.
    def per_cut(mask_row, pos_col):
        return mask_row[pos_col]

    return jax.vmap(per_cut, in_axes=(0, 1), out_axes=1)(in_mask, bitpos)


def route(
    tree: FrozenQdTree, records: np.ndarray, backend: str = "jax"
) -> np.ndarray:
    """Route ``records`` on a registered backend (compatibility shim).

    Delegates to the tree's attached :class:`~repro.engine.LayoutEngine`,
    so repeated calls share cached compiled plans across callsites.
    """
    from repro.engine import engine_for

    return engine_for(tree).route(records, backend=backend)
