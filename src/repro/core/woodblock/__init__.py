"""WOODBLOCK: deep-RL qd-tree construction (paper Sec 5)."""

from repro.core.woodblock.agent import (  # noqa: F401
    Woodblock,
    WoodblockConfig,
    WoodblockResult,
    build_woodblock,
)
from repro.core.woodblock.env import TreeEnv  # noqa: F401
from repro.core.woodblock.ppo import PPOConfig  # noqa: F401
