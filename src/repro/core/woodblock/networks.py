"""Policy/value networks for WOODBLOCK (paper Sec 5.2.3).

Shared trunk: two fully-connected layers of 512 units with ReLU.  Heads:
|A|-dim linear policy projection + scalar value projection.  Pure JAX —
no flax/optax in this environment.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

HIDDEN = 512


def init_params(key: jax.Array, in_dim: int, n_actions: int, hidden: int = HIDDEN):
    k1, k2, k3, k4 = jax.random.split(key, 4)

    def dense(k, fan_in, fan_out):
        scale = jnp.sqrt(2.0 / fan_in)
        return {
            "w": jax.random.normal(k, (fan_in, fan_out), jnp.float32) * scale,
            "b": jnp.zeros((fan_out,), jnp.float32),
        }

    return {
        "fc1": dense(k1, in_dim, hidden),
        "fc2": dense(k2, hidden, hidden),
        "policy": dense(k3, hidden, n_actions),
        "value": dense(k4, hidden, 1),
    }


def forward(params, x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, in_dim) → (logits (B, A), value (B,))."""
    h = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    h = jax.nn.relu(h @ params["fc2"]["w"] + params["fc2"]["b"])
    logits = h @ params["policy"]["w"] + params["policy"]["b"]
    value = (h @ params["value"]["w"] + params["value"]["b"])[:, 0]
    return logits, value


def masked_log_softmax(logits: jnp.ndarray, legal: jnp.ndarray) -> jnp.ndarray:
    """Log-probabilities with illegal actions forced to ~-inf."""
    neg = jnp.finfo(logits.dtype).min / 2
    masked = jnp.where(legal, logits, neg)
    return jax.nn.log_softmax(masked, axis=-1)
