"""Proximal Policy Optimization in pure JAX (paper Sec 5.2: PPO is the
black-box update rule).

The tree-structured MDP treats each node as an independent state whose
normalized reward *is* its return (no discounting across the tree — Sec
5.2.4), so advantages are simply ``R - V(s)``.  Buffers are padded to a
static capacity so one jitted update function serves every iteration.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.woodblock import networks


@dataclasses.dataclass(frozen=True)
class PPOConfig:
    lr: float = 3e-4
    clip_eps: float = 0.2
    value_coef: float = 0.5
    entropy_coef: float = 0.01
    epochs: int = 4
    buffer_cap: int = 2048
    adam_b1: float = 0.9
    adam_b2: float = 0.999
    adam_eps: float = 1e-8
    max_grad_norm: float = 0.5


# -- minimal Adam (optax is unavailable offline) ----------------------------
def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, cfg: PPOConfig):
    t = state["t"] + 1
    m = jax.tree.map(
        lambda m, g: cfg.adam_b1 * m + (1 - cfg.adam_b1) * g, state["m"], grads
    )
    v = jax.tree.map(
        lambda v, g: cfg.adam_b2 * v + (1 - cfg.adam_b2) * g * g,
        state["v"],
        grads,
    )
    mh = jax.tree.map(lambda m: m / (1 - cfg.adam_b1 ** t), m)
    vh = jax.tree.map(lambda v: v / (1 - cfg.adam_b2 ** t), v)
    new = jax.tree.map(
        lambda p, mh, vh: p - cfg.lr * mh / (jnp.sqrt(vh) + cfg.adam_eps),
        params,
        mh,
        vh,
    )
    return new, {"m": m, "v": v, "t": t}


def _global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(x * x) for x in jax.tree.leaves(tree))
    )


def ppo_loss(params, batch, cfg: PPOConfig):
    logits, values = networks.forward(params, batch["states"])
    logp_all = networks.masked_log_softmax(logits, batch["legal"])
    logp = jnp.take_along_axis(
        logp_all, batch["actions"][:, None], axis=1
    )[:, 0]
    ratio = jnp.exp(logp - batch["old_logp"])
    adv = batch["advantages"]
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1 - cfg.clip_eps, 1 + cfg.clip_eps) * adv
    w = batch["weight"]  # 0 on padding rows
    denom = jnp.maximum(w.sum(), 1.0)
    policy_loss = -(jnp.minimum(unclipped, clipped) * w).sum() / denom
    value_loss = (((values - batch["returns"]) ** 2) * w).sum() / denom
    probs = jnp.exp(logp_all)
    entropy = -(
        (probs * jnp.where(batch["legal"], logp_all, 0.0)).sum(axis=1) * w
    ).sum() / denom
    total = (
        policy_loss
        + cfg.value_coef * value_loss
        - cfg.entropy_coef * entropy
    )
    return total, {
        "policy_loss": policy_loss,
        "value_loss": value_loss,
        "entropy": entropy,
    }


@functools.partial(jax.jit, static_argnames=("cfg",))
def ppo_update(params, opt_state, batch, cfg: PPOConfig):
    (_, aux), grads = jax.value_and_grad(ppo_loss, has_aux=True)(
        params, batch, cfg
    )
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.max_grad_norm / (gnorm + 1e-8))
    grads = jax.tree.map(lambda g: g * scale, grads)
    params, opt_state = adam_update(params, grads, opt_state, cfg)
    aux["grad_norm"] = gnorm
    return params, opt_state, aux


@functools.partial(jax.jit, static_argnames=())
def policy_step(params, states, legal, key):
    """Sample actions for a batch of states (used inside episodes)."""
    logits, values = networks.forward(params, states)
    logp_all = networks.masked_log_softmax(logits, legal)
    actions = jax.random.categorical(key, logp_all, axis=-1)
    logp = jnp.take_along_axis(logp_all, actions[:, None], axis=1)[:, 0]
    return actions, logp, values


def make_batch(transitions, cap: int, n_actions: int, feat_dim: int):
    """Pad a transition list into a static-shape PPO batch."""
    n = min(len(transitions), cap)
    states = np.zeros((cap, feat_dim), np.float32)
    legal = np.zeros((cap, n_actions), bool)
    actions = np.zeros((cap,), np.int32)
    old_logp = np.zeros((cap,), np.float32)
    returns = np.zeros((cap,), np.float32)
    values = np.zeros((cap,), np.float32)
    weight = np.zeros((cap,), np.float32)
    for i, t in enumerate(transitions[:cap]):
        states[i] = t.state
        legal[i] = t.legal
        actions[i] = t.action
        old_logp[i] = t.logp
        returns[i] = t.reward
        values[i] = t.value
        weight[i] = 1.0
    adv = returns - values
    # normalize advantages over valid rows
    if n > 1:
        mu = adv[:n].mean()
        sd = adv[:n].std() + 1e-8
        adv = np.where(weight > 0, (adv - mu) / sd, 0.0)
    legal[weight == 0, 0] = True  # keep padded rows' softmax well-defined
    return {
        "states": jnp.asarray(states),
        "legal": jnp.asarray(legal),
        "actions": jnp.asarray(actions),
        "old_logp": jnp.asarray(old_logp),
        "returns": jnp.asarray(returns),
        "advantages": jnp.asarray(adv),
        "weight": jnp.asarray(weight),
    }
