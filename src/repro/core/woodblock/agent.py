"""WOODBLOCK: the deep-RL qd-tree construction agent (paper Sec 5.2).

Training loop: repeatedly construct trees (episodes), score them with the
workload-skipping reward, and refine the policy with PPO.  The best tree
found is deployed (paper: "After attempting a fixed number of trees or if a
timeout is reached, the best tree found is deployed").  A learning curve of
(wall-clock, best/current scan fraction) is recorded to reproduce Fig 8.

This module is the ``"woodblock"`` strategy behind the unified construction
facade — prefer ``repro.service.build_layout(records, workload,
strategy="woodblock", n_iters=...)`` for the common ``LayoutBuild``
artifact (the learning curve lands in ``build.metrics["curve"]``).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import predicates as preds
from repro.core import query as qry
from repro.core.qdtree import QdTree
from repro.core.woodblock import networks, ppo
from repro.core.woodblock.env import TreeEnv


@dataclasses.dataclass
class WoodblockConfig:
    min_block_sample: int  # s·b — min sample records per block (Sec 5.2.1)
    n_iters: int = 40
    episodes_per_iter: int = 4
    time_budget_s: float | None = None
    seed: int = 0
    max_leaves: int | None = None
    allow_small_child: bool = False  # overlap extension (Sec 6.2)
    ppo: ppo.PPOConfig = dataclasses.field(default_factory=ppo.PPOConfig)


@dataclasses.dataclass
class CurvePoint:
    wall_s: float
    episode: int
    current_scanned: float
    best_scanned: float


@dataclasses.dataclass
class WoodblockResult:
    best_tree: QdTree
    best_scanned: float
    curve: list[CurvePoint]
    n_episodes: int


def _bucket(n: int) -> int:
    b = 8
    while b < n:
        b *= 2
    return b


class Woodblock:
    def __init__(
        self,
        sample: np.ndarray,
        workload: qry.Workload,
        cuts: preds.CutTable,
        cfg: WoodblockConfig,
        reward_override=None,
    ):
        self.env = TreeEnv(
            sample,
            workload,
            cuts,
            cfg.min_block_sample,
            allow_small_child=cfg.allow_small_child,
            max_leaves=cfg.max_leaves,
        )
        if reward_override is not None:
            # two-tree replication (Sec 6.3) plugs in a modified reward
            self.env_reward_override = reward_override
        else:
            self.env_reward_override = None
        self.cfg = cfg
        self.key = jax.random.PRNGKey(cfg.seed)
        self.key, sub = jax.random.split(self.key)
        self.params = networks.init_params(
            sub, self.env.feature_dim, self.env.n_actions
        )
        self.opt_state = ppo.adam_init(self.params)
        self.rng = np.random.default_rng(cfg.seed)

    # -- batched, bucket-padded policy for the env ---------------------------
    def _policy_fn(self, states: np.ndarray, legals: np.ndarray):
        n = states.shape[0]
        cap = _bucket(n)
        s = np.zeros((cap, states.shape[1]), np.float32)
        leg = np.zeros((cap, legals.shape[1]), bool)
        s[:n] = states
        leg[:n] = legals
        leg[n:, 0] = True
        self.key, sub = jax.random.split(self.key)
        a, lp, v = ppo.policy_step(
            self.params, jnp.asarray(s), jnp.asarray(leg), sub
        )
        return np.asarray(a)[:n], np.asarray(lp)[:n], np.asarray(v)[:n]

    # -- main loop -----------------------------------------------------------
    def train(self, verbose: bool = False) -> WoodblockResult:
        cfg = self.cfg
        best_tree, best_scanned = None, float("inf")
        curve: list[CurvePoint] = []
        t0 = time.perf_counter()
        episode = 0
        for it in range(cfg.n_iters):
            transitions = []
            for _ in range(cfg.episodes_per_iter):
                result = self.env.run_episode(self._policy_fn, self.rng)
                if self.env_reward_override is not None:
                    self.env_reward_override(result)
                episode += 1
                transitions.extend(result.transitions)
                if result.scanned_fraction < best_scanned:
                    best_scanned = result.scanned_fraction
                    best_tree = result.tree
                curve.append(
                    CurvePoint(
                        wall_s=time.perf_counter() - t0,
                        episode=episode,
                        current_scanned=result.scanned_fraction,
                        best_scanned=best_scanned,
                    )
                )
            if not transitions:
                break
            batch = ppo.make_batch(
                transitions,
                cap=_bucket(len(transitions)),
                n_actions=self.env.n_actions,
                feat_dim=self.env.feature_dim,
            )
            for _ in range(cfg.ppo.epochs):
                self.params, self.opt_state, aux = ppo.ppo_update(
                    self.params, self.opt_state, batch, cfg.ppo
                )
            if verbose:
                print(
                    f"iter {it}: episodes={episode} "
                    f"best={best_scanned:.4f} "
                    f"cur={result.scanned_fraction:.4f} "
                    f"pi_loss={float(aux['policy_loss']):.4f} "
                    f"v_loss={float(aux['value_loss']):.4f}"
                )
            if (
                cfg.time_budget_s is not None
                and time.perf_counter() - t0 > cfg.time_budget_s
            ):
                break
        assert best_tree is not None, "no legal cuts at the root"
        return WoodblockResult(
            best_tree=best_tree,
            best_scanned=best_scanned,
            curve=curve,
            n_episodes=episode,
        )


def build_woodblock(
    sample: np.ndarray,
    workload: qry.Workload,
    cuts: preds.CutTable,
    cfg: WoodblockConfig,
    verbose: bool = False,
) -> WoodblockResult:
    return Woodblock(sample, workload, cuts, cfg).train(verbose=verbose)
