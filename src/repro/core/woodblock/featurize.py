"""State featurization for WOODBLOCK (paper Sec 5.2.3).

Each state (tree node) is the concatenation of its ``range`` and
``categorical_mask`` description; numeric bounds are binary-encoded ("these
vectors are encoded in bits"), categorical masks are already bits, and the
advanced-cut bit pairs are appended.  Output is a fixed-size float32 vector.
"""

from __future__ import annotations

import numpy as np

from repro.core.predicates import Schema
from repro.core.qdtree import NodeDesc


class Featurizer:
    def __init__(self, schema: Schema, n_adv: int):
        self.schema = schema
        self.n_adv = n_adv
        doms = schema.doms
        # bits needed to binary-encode a bound in [0, dom] (hi can equal dom)
        self.nbits = np.maximum(
            1, np.ceil(np.log2(doms.astype(np.float64) + 1)).astype(np.int64)
        )
        self.numeric = np.nonzero(~schema.is_categorical)[0]
        self.cat_bits = max(schema.total_cat_bits, 0)
        self.dim = int(
            2 * self.nbits[self.numeric].sum() + self.cat_bits + 2 * n_adv
        )
        # precompute bit-shift tables per numeric dim
        self._shifts = [np.arange(self.nbits[d]) for d in self.numeric]

    def __call__(self, desc: NodeDesc) -> np.ndarray:
        parts = []
        for i, d in enumerate(self.numeric):
            sh = self._shifts[i]
            parts.append((desc.lo[d] >> sh) & 1)
            parts.append((desc.hi[d] >> sh) & 1)
        if self.cat_bits:
            parts.append(desc.cat.astype(np.int64))
        if self.n_adv:
            parts.append(desc.adv.reshape(-1).astype(np.int64))
        return np.concatenate(parts).astype(np.float32)

    def batch(self, descs: list[NodeDesc]) -> np.ndarray:
        if not descs:
            return np.zeros((0, self.dim), np.float32)
        return np.stack([self(d) for d in descs])
