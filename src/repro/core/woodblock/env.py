"""Tree-construction MDP (paper Sec 5.2).

State space: subspaces of the data space (tree nodes).  Action space: the
candidate cut set.  Taking a cut on a node produces two child states pushed
onto an exploration queue; a node with no *legal* cut (both children would
need ≥ s·b sample records, Sec 5.2.1) becomes a leaf.  An episode builds one
complete qd-tree; rewards are computed afterwards (Sec 5.2.2).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import predicates as preds
from repro.core import query as qry
from repro.core import rewards as rw
from repro.core.qdtree import Node, QdTree, singleton_tree
from repro.core.woodblock.featurize import Featurizer


@dataclasses.dataclass
class Transition:
    state: np.ndarray  # featurized node
    legal: np.ndarray  # (n_cuts,) bool
    action: int
    logp: float
    value: float
    node_key: int  # id(node) for reward lookup after the episode
    reward: float = 0.0


@dataclasses.dataclass
class EpisodeResult:
    tree: QdTree
    transitions: list[Transition]
    scanned_fraction: float  # on the construction sample


class TreeEnv:
    """One environment instance; episodes share the fixed data sample."""

    def __init__(
        self,
        sample: np.ndarray,
        workload: qry.Workload,
        cuts: preds.CutTable,
        min_block_sample: int,
        allow_small_child: bool = False,
        max_leaves: int | None = None,
    ):
        self.schema = workload.schema
        self.schema.validate_records(sample)
        self.sample = sample
        self.workload = workload
        self.cuts = cuts
        self.b = max(1, min_block_sample)
        self.allow_small_child = allow_small_child
        self.max_leaves = max_leaves
        self.cut_matrix = preds.eval_cuts(sample, cuts)  # (m, n_cuts)
        self.wt = workload.tensorize(cuts)
        self.featurizer = Featurizer(self.schema, cuts.n_adv)

    @property
    def n_actions(self) -> int:
        return self.cuts.n_cuts

    @property
    def feature_dim(self) -> int:
        return self.featurizer.dim

    # -- legality (stopping condition, Sec 5.2.1) ---------------------------
    def legal_actions(self, node: Node) -> np.ndarray:
        if node.size < (self.b if self.allow_small_child else 2 * self.b):
            return np.zeros(self.n_actions, bool)
        left = self.cut_matrix[node.rows].sum(axis=0)
        right = node.size - left
        if self.allow_small_child:
            return (left > 0) & (right > 0) & (
                (left >= self.b) | (right >= self.b)
            )
        return (left >= self.b) & (right >= self.b)

    # -- episode -------------------------------------------------------------
    def run_episode(self, policy_fn, rng: np.random.Generator) -> EpisodeResult:
        """Build one tree.  ``policy_fn(states, legal) -> (actions, logps,
        values)`` is the (batched) agent; we expand the queue level by level
        so network evaluation is batched."""
        tree = singleton_tree(
            self.schema, self.cuts, sample_rows=np.arange(self.sample.shape[0])
        )
        transitions: list[Transition] = []
        queue: list[tuple[Node, np.ndarray]] = []
        legal0 = self.legal_actions(tree.root)
        n_leaves = 1
        if legal0.any():
            queue.append((tree.root, legal0))
        while queue:
            if self.max_leaves is not None and n_leaves >= self.max_leaves:
                break
            nodes = [n for n, _ in queue]
            legals = np.stack([l for _, l in queue])
            states = self.featurizer.batch([n.desc for n in nodes])
            queue = []
            actions, logps, values = policy_fn(states, legals)
            for i, node in enumerate(nodes):
                if self.max_leaves is not None and n_leaves >= self.max_leaves:
                    break
                a = int(actions[i])
                lchild, rchild = tree.split(
                    node, a, cut_matrix=self.cut_matrix
                )
                n_leaves += 1
                transitions.append(
                    Transition(
                        state=states[i],
                        legal=legals[i],
                        action=a,
                        logp=float(logps[i]),
                        value=float(values[i]),
                        node_key=id(node),
                    )
                )
                for child in (lchild, rchild):
                    lg = self.legal_actions(child)
                    if lg.any():
                        queue.append((child, lg))
        # episode done: compute rewards (Sec 5.2.2)
        rewards_by_node, scanned = rw.per_node_rewards(
            tree, self.sample, self.wt
        )
        for t in transitions:
            t.reward = rewards_by_node.get(t.node_key, 0.0)
        return EpisodeResult(
            tree=tree, transitions=transitions, scanned_fraction=scanned
        )
