"""Greedy top-down qd-tree construction (paper Algorithm 1).

Starting from the singleton tree, repeatedly split any leaf with ≥ 2b
records by the candidate cut maximizing C(T ⊕ (p, n)), subject to both
children having ≥ b records; accept only strict improvements.  Because
C decomposes over leaves, maximizing C(T ⊕ (p,n)) is equivalent to
maximizing the split's own contribution

    |n^p|·skip(n^p) + |n^¬p|·skip(n^¬p)

which we evaluate for *all* candidate cuts of a node in one vectorized
shot: child sizes come from one column-sum over the shared predicate
matrix, and child skip counts from one stacked description↔workload
intersection.

This module is the ``"greedy"`` strategy behind the unified construction
facade — prefer ``repro.service.build_layout(records, workload,
strategy="greedy")``, which wraps it into the common ``LayoutBuild``
artifact (tightened frozen tree + metrics + provenance).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import predicates as preds
from repro.core import query as qry
from repro.core.qdtree import Node, QdTree, child_descs_all, singleton_tree


@dataclasses.dataclass
class GreedyConfig:
    min_block: int  # b, in *sample* records (caller scales by sample ratio)
    max_leaves: int | None = None
    allow_small_child: bool = False  # overlap extension (paper Sec 6.2)


def _conj_skips(
    descs: dict[str, np.ndarray],
    wt: qry.WorkloadTensors,
    schema,
) -> np.ndarray:
    """(n_cuts,) — number of workload queries skipped by each description."""
    hits = qry.conjuncts_intersect(
        descs["lo"], descs["hi"], descs["cat"], descs["adv"], wt, schema
    )
    q_hits = qry.queries_intersect(hits, wt)
    return wt.n_queries - q_hits.sum(axis=1)


def best_cut_for_node(
    node: Node,
    tree: QdTree,
    cut_matrix: np.ndarray,  # (m_sample, n_cuts) bool, full sample
    wt: qry.WorkloadTensors,
    cfg: GreedyConfig,
) -> tuple[int, float] | None:
    """argmax_p C(T ⊕ (p, n)) over legal cuts; None if no improving cut.

    Returns (cut_id, split_contribution).
    """
    m = node.size
    if m == 0:
        return None
    rows_m = cut_matrix[node.rows]  # (m, n_cuts)
    left_sizes = rows_m.sum(axis=0).astype(np.int64)
    right_sizes = m - left_sizes
    b = cfg.min_block
    if cfg.allow_small_child:
        legal = (
            (left_sizes > 0)
            & (right_sizes > 0)
            & ((left_sizes >= b) | (right_sizes >= b))
        )
    else:
        legal = (left_sizes >= b) & (right_sizes >= b)
    if not legal.any():
        return None

    L, R = child_descs_all(node.desc, tree.cuts)
    skip_l = _conj_skips(L, wt, tree.schema)
    skip_r = _conj_skips(R, wt, tree.schema)
    contrib = left_sizes * skip_l + right_sizes * skip_r
    contrib = np.where(legal, contrib, -1)

    # current contribution of n as a leaf
    parent = {
        "lo": node.desc.lo[None],
        "hi": node.desc.hi[None],
        "cat": node.desc.cat[None],
        "adv": node.desc.adv[None],
    }
    parent_contrib = m * int(_conj_skips(parent, wt, tree.schema)[0])

    best = int(np.argmax(contrib))
    if contrib[best] <= parent_contrib:
        return None
    return best, float(contrib[best])


def build_greedy(
    sample: np.ndarray,
    workload: qry.Workload,
    cuts: preds.CutTable,
    cfg: GreedyConfig,
    verbose: bool = False,
) -> QdTree:
    """Paper Algorithm 1 over a (sampled) record set."""
    schema = workload.schema
    schema.validate_records(sample)
    tree = singleton_tree(
        schema, cuts, sample_rows=np.arange(sample.shape[0])
    )
    cut_matrix = preds.eval_cuts(sample, cuts)
    wt = workload.tensorize(cuts)

    frontier: list[Node] = [tree.root]
    n_leaves = 1
    while frontier:
        if cfg.max_leaves is not None and n_leaves >= cfg.max_leaves:
            break
        node = frontier.pop(0)
        if node.size < 2 * cfg.min_block and not cfg.allow_small_child:
            continue
        choice = best_cut_for_node(node, tree, cut_matrix, wt, cfg)
        if choice is None:
            continue
        cut_id, contrib = choice
        lchild, rchild = tree.split(node, cut_id, cut_matrix=cut_matrix)
        n_leaves += 1
        if verbose:
            print(
                f"greedy: split m={node.size} with "
                f"[{tree.cuts.describe(cut_id)}] -> "
                f"{lchild.size}/{rchild.size} (contrib={contrib:.0f})"
            )
        frontier.append(lchild)
        frontier.append(rchild)
    return tree
