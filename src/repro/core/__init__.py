"""The paper's primary contribution: qd-tree learned data layouts.

Public surface:
  predicates — Schema / CutTable / predicate evaluation
  qdtree     — Node/QdTree (construction) + FrozenQdTree (serving)
  query      — Query/Workload, tensorization, block intersection
  rewards    — C(P) skip metrics, per-node RL rewards
  greedy     — paper Algorithm 1
  routing    — batched record→BID routing backends
  woodblock  — deep-RL construction agent (paper Sec 5)
  overlap    — data-overlap extension (paper Sec 6.2)
  replication— two-tree replication (paper Sec 6.3)
"""

from repro.core.predicates import (  # noqa: F401
    AdvPredicate,
    Column,
    CutTable,
    CutTableBuilder,
    Schema,
    eval_cuts,
)
from repro.core.qdtree import (  # noqa: F401
    FrozenQdTree,
    Node,
    NodeDesc,
    QdTree,
    child_descs,
    root_desc,
    singleton_tree,
)
from repro.core.query import (  # noqa: F401
    AdvAtom,
    InAtom,
    Query,
    RangeAtom,
    Workload,
    route_query,
)
from repro.core.rewards import (  # noqa: F401
    SkipStats,
    evaluate_layout,
    selectivity_lower_bound,
)
from repro.core.greedy import GreedyConfig, build_greedy  # noqa: F401
from repro.core.routing import route  # noqa: F401
