"""Skipping metrics: C(P), scanned fraction, and per-node rewards.

Implements paper Eq. 1 and Sec 5.2.2.  ``C(P_i) = |P_i| · Σ_q S(P_i, q)``
where S is the min-max/description-based skip indicator.  The scanned
fraction reported in Table 2 is ``Σ_q Σ_{P ∩ q} |P| / (|V|·|W|)``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import query as qry
from repro.core.qdtree import FrozenQdTree, Node, QdTree


@dataclasses.dataclass
class SkipStats:
    n_records: int
    n_queries: int
    n_blocks: int
    scanned_tuples: int  # Σ_q Σ_{P ∩ q} |P|
    skipped_tuples: int  # C(P)
    block_sizes: np.ndarray
    query_hits: np.ndarray  # (n_blocks, n_queries) bool

    @property
    def scanned_fraction(self) -> float:
        denom = self.n_records * self.n_queries
        return float(self.scanned_tuples) / denom if denom else 0.0

    @property
    def skipped_fraction(self) -> float:
        return 1.0 - self.scanned_fraction


def block_query_hits(
    tree: FrozenQdTree, wt: qry.WorkloadTensors
) -> np.ndarray:
    """(n_leaves, n_queries) bool — which blocks each query must scan."""
    conj = qry.conjuncts_intersect(
        tree.leaf_lo, tree.leaf_hi, tree.leaf_cat, tree.leaf_adv, wt,
        tree.schema,
    )
    return qry.queries_intersect(conj, wt)


def evaluate_layout(
    tree: FrozenQdTree,
    records: np.ndarray,
    workload: qry.Workload,
    tighten: bool = True,
    backend: str = "numpy",
) -> SkipStats:
    """Route ``records`` through ``tree`` and score the resulting layout.

    Thin wrapper over ``LayoutEngine.skip_stats`` — pass ``backend`` to
    score on the jitted/Pallas paths (bit-identical to the oracle).
    """
    from repro.engine import engine_for

    return engine_for(tree).skip_stats(
        records, workload, tighten=tighten, backend=backend
    )


def selectivity_lower_bound(
    records: np.ndarray, workload: qry.Workload
) -> float:
    """True workload selectivity — the paper's lower bound for any layout."""
    total = 0
    for q in workload.queries:
        total += int(q.evaluate(records, workload.schema).sum())
    return total / (records.shape[0] * len(workload))


# ---------------------------------------------------------------------------
# Per-node rewards for WOODBLOCK (paper Sec 5.2.2)
# ---------------------------------------------------------------------------
def per_node_rewards(
    tree: QdTree,
    sample: np.ndarray,
    wt: qry.WorkloadTensors,
    tighten: bool = True,
) -> tuple[dict[int, float], float]:
    """Compute R((n, p)) = S(n) / (|W| · |n.records|) for every internal node.

    S(n) is the number of (record, query) skips summed over the leaves below
    n, computed on the construction sample.  Returns ({id(node): reward},
    whole-tree scanned fraction on the sample).
    """
    frozen = tree.freeze()
    leaves = tree.leaves()
    sizes = np.array([n.size for n in leaves], np.int64)
    if tighten:
        bids = np.full(sample.shape[0], -1, np.int32)
        for n in leaves:
            if n.rows is not None:
                bids[n.rows] = n.bid
        keep = bids >= 0
        frozen.tighten(sample[keep], bids[keep])
    hits = block_query_hits(frozen, wt)  # (n_leaves, n_q)
    n_q = hits.shape[1]
    skipped_per_leaf = sizes * (n_q - hits.sum(axis=1))  # C per leaf

    # bottom-up accumulate S(n)
    s_of: dict[int, int] = {}

    def _acc(n: Node) -> int:
        if n.is_leaf:
            s = int(skipped_per_leaf[n.bid])
        else:
            s = _acc(n.left) + _acc(n.right)
        s_of[id(n)] = s
        return s

    _acc(tree.root)
    rewards: dict[int, float] = {}
    for n in tree.nodes():
        if not n.is_leaf and n.size > 0:
            rewards[id(n)] = s_of[id(n)] / (n_q * n.size)
    total = sample.shape[0] * n_q
    scanned_frac = 1.0 - s_of[id(tree.root)] / total if total else 0.0
    return rewards, scanned_frac
