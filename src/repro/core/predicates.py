"""Schema, cuts, and vectorized predicate evaluation for qd-trees.

Everything is dictionary-encoded to int32 up front (the paper encodes
literals; we encode whole columns — see DESIGN.md §3).  A *cut* is one of:

  * range cut   — canonical form ``row[dim] < cutpoint`` (all of <, <=, >, >=
                  from the workload canonicalize to a cutpoint; which side is
                  "left" is immaterial to the tree),
  * IN cut      — ``row[dim] ∈ S`` for a categorical dim, stored as a bit
                  mask over the concatenated categorical bit space,
  * advanced cut— ``row[col_a] op row[col_b]`` (paper Sec 6.1), indexed into
                  a small advanced-predicate table.

The candidate-cut set is shared by every tree node (paper Sec 3.4), which is
what lets routing factorize into "evaluate all cuts once per record" +
"descend by selecting bits" — the TPU-native formulation used by the Pallas
kernels.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

# Cut kinds.
KIND_RANGE = 0
KIND_IN = 1
KIND_ADV = 2

# Comparison ops for advanced (column-vs-column) predicates and query atoms.
OP_LT, OP_LE, OP_GT, OP_GE, OP_EQ, OP_NE = 0, 1, 2, 3, 4, 5

_OP_FNS = {
    OP_LT: lambda a, b: a < b,
    OP_LE: lambda a, b: a <= b,
    OP_GT: lambda a, b: a > b,
    OP_GE: lambda a, b: a >= b,
    OP_EQ: lambda a, b: a == b,
    OP_NE: lambda a, b: a != b,
}

KIND_NUMERIC = "numeric"
KIND_CATEGORICAL = "categorical"


@dataclasses.dataclass(frozen=True)
class Column:
    name: str
    kind: str  # "numeric" | "categorical"
    dom: int  # values live in [0, dom)

    def __post_init__(self):
        if self.kind not in (KIND_NUMERIC, KIND_CATEGORICAL):
            raise ValueError(f"bad column kind {self.kind!r}")
        if self.dom <= 0:
            raise ValueError(f"column {self.name}: dom must be positive")


@dataclasses.dataclass(frozen=True)
class Schema:
    """An ordered set of dictionary-encoded columns."""

    columns: tuple[Column, ...]

    def __post_init__(self):
        names = [c.name for c in self.columns]
        if len(set(names)) != len(names):
            raise ValueError("duplicate column names")

    # -- lookups ---------------------------------------------------------
    @property
    def ndims(self) -> int:
        return len(self.columns)

    def dim(self, name: str) -> int:
        for i, c in enumerate(self.columns):
            if c.name == name:
                return i
        raise KeyError(name)

    @property
    def doms(self) -> np.ndarray:
        return np.array([c.dom for c in self.columns], dtype=np.int32)

    @property
    def is_categorical(self) -> np.ndarray:
        return np.array(
            [c.kind == KIND_CATEGORICAL for c in self.columns], dtype=bool
        )

    # -- categorical bit space -------------------------------------------
    # All categorical domains are concatenated into one bit space so a node's
    # categorical mask is a single vector (fast to AND / intersect).
    @property
    def cat_offsets(self) -> np.ndarray:
        """Per-dim offset into the concatenated categorical bit space.

        -1 for numeric dims.
        """
        off = np.full(self.ndims, -1, dtype=np.int32)
        pos = 0
        for i, c in enumerate(self.columns):
            if c.kind == KIND_CATEGORICAL:
                off[i] = pos
                pos += c.dom
        return off

    @property
    def total_cat_bits(self) -> int:
        return int(
            sum(c.dom for c in self.columns if c.kind == KIND_CATEGORICAL)
        )

    def cat_segment(self, dim: int) -> slice:
        off = self.cat_offsets[dim]
        if off < 0:
            raise ValueError(f"dim {dim} is not categorical")
        return slice(int(off), int(off) + self.columns[dim].dom)

    def validate_records(self, records: np.ndarray) -> None:
        if records.ndim != 2 or records.shape[1] != self.ndims:
            raise ValueError(
                f"records shape {records.shape} != (*, {self.ndims})"
            )
        lo_ok = (records >= 0).all()
        hi_ok = (records < self.doms[None, :]).all()
        if not (lo_ok and hi_ok):
            raise ValueError("records out of declared domains")


@dataclasses.dataclass(frozen=True)
class AdvPredicate:
    """Binary predicate ``col_a op col_b`` (paper Sec 6.1)."""

    col_a: int
    op: int
    col_b: int

    def evaluate(self, records: np.ndarray) -> np.ndarray:
        return _OP_FNS[self.op](records[:, self.col_a], records[:, self.col_b])


@dataclasses.dataclass
class CutTable:
    """The shared candidate-cut set, in struct-of-arrays form.

    ``kind``      (n,)  int32   one of KIND_*
    ``dim``       (n,)  int32   column index (range/IN cuts; -1 for adv)
    ``cutpoint``  (n,)  int32   canonical ``row[dim] < cutpoint`` (range only)
    ``in_mask``   (n, total_cat_bits) bool   membership mask (IN only;
                  bits outside the cut's dim segment are zero)
    ``adv_id``    (n,)  int32   index into ``adv`` (adv cuts only, else -1)
    ``adv``       tuple[AdvPredicate, ...]
    """

    schema: Schema
    kind: np.ndarray
    dim: np.ndarray
    cutpoint: np.ndarray
    in_mask: np.ndarray
    adv_id: np.ndarray
    adv: tuple[AdvPredicate, ...]

    @property
    def n_cuts(self) -> int:
        return int(self.kind.shape[0])

    @property
    def n_adv(self) -> int:
        return len(self.adv)

    def describe(self, c: int) -> str:
        k = int(self.kind[c])
        if k == KIND_RANGE:
            name = self.schema.columns[int(self.dim[c])].name
            return f"{name} < {int(self.cutpoint[c])}"
        if k == KIND_IN:
            d = int(self.dim[c])
            seg = self.schema.cat_segment(d)
            vals = np.nonzero(self.in_mask[c, seg])[0]
            return f"{self.schema.columns[d].name} IN {vals.tolist()}"
        a = self.adv[int(self.adv_id[c])]
        opn = {0: "<", 1: "<=", 2: ">", 3: ">=", 4: "==", 5: "!="}[a.op]
        return (
            f"{self.schema.columns[a.col_a].name} {opn} "
            f"{self.schema.columns[a.col_b].name}"
        )


class CutTableBuilder:
    """Accumulates candidate cuts (with dedup) from a workload."""

    def __init__(self, schema: Schema):
        self.schema = schema
        self._range: dict[tuple[int, int], None] = {}
        self._in: dict[tuple[int, bytes], np.ndarray] = {}
        self._adv: dict[tuple[int, int, int], int] = {}
        self._adv_list: list[AdvPredicate] = []

    # -- adders ------------------------------------------------------------
    def add_range(self, dim: int, op: int, literal: int) -> None:
        """Add the cut(s) induced by a numeric atom ``row[dim] op literal``.

        Canonicalized to split points of the form ``row[dim] < c``.
        """
        col = self.schema.columns[dim]
        if col.kind != KIND_NUMERIC:
            raise ValueError(f"range cut on categorical column {col.name}")
        if op == OP_LT:
            points = [literal]
        elif op == OP_LE:
            points = [literal + 1]
        elif op == OP_GT:
            points = [literal + 1]
        elif op == OP_GE:
            points = [literal]
        elif op == OP_EQ:
            points = [literal, literal + 1]  # isolates [v, v+1)
        else:
            raise ValueError(f"unsupported range op {op}")
        for c in points:
            if 0 < c < col.dom:  # trivial cuts split nothing
                self._range.setdefault((dim, int(c)), None)

    def add_in(self, dim: int, values: Sequence[int]) -> None:
        col = self.schema.columns[dim]
        if col.kind != KIND_CATEGORICAL:
            raise ValueError(f"IN cut on numeric column {col.name}")
        mask = np.zeros(self.schema.total_cat_bits, dtype=bool)
        seg = self.schema.cat_segment(dim)
        vals = np.asarray(sorted(set(int(v) for v in values)), dtype=np.int64)
        if (vals < 0).any() or (vals >= col.dom).any():
            raise ValueError(f"IN values out of domain for {col.name}")
        mask[seg.start + vals] = True
        if mask[seg].all() or not mask[seg].any():
            return  # trivial
        self._in.setdefault((dim, mask.tobytes()), mask)

    def add_adv(self, col_a: int, op: int, col_b: int) -> int:
        key = (col_a, op, col_b)
        if key not in self._adv:
            self._adv[key] = len(self._adv_list)
            self._adv_list.append(AdvPredicate(col_a, op, col_b))
        return self._adv[key]

    # -- finalize ------------------------------------------------------------
    def build(self) -> CutTable:
        n = len(self._range) + len(self._in) + len(self._adv_list)
        bits = self.schema.total_cat_bits
        kind = np.zeros(n, np.int32)
        dim = np.full(n, -1, np.int32)
        cutpoint = np.zeros(n, np.int32)
        in_mask = np.zeros((n, max(bits, 1)), bool)
        adv_id = np.full(n, -1, np.int32)
        i = 0
        for (d, c) in sorted(self._range):
            kind[i], dim[i], cutpoint[i] = KIND_RANGE, d, c
            i += 1
        for (d, _), mask in sorted(self._in.items(), key=lambda kv: kv[0]):
            kind[i], dim[i] = KIND_IN, d
            in_mask[i, :bits] = mask
            i += 1
        for j in range(len(self._adv_list)):
            kind[i], adv_id[i] = KIND_ADV, j
            i += 1
        return CutTable(
            schema=self.schema,
            kind=kind,
            dim=dim,
            cutpoint=cutpoint,
            in_mask=in_mask,
            adv_id=adv_id,
            adv=tuple(self._adv_list),
        )


def eval_cuts(records: np.ndarray, cuts: CutTable) -> np.ndarray:
    """Reference predicate-matrix evaluation: (m, n_cuts) bool.

    M[r, c] == True  iff record r satisfies cut c.  numpy implementation; the
    Pallas kernel (kernels/route_records.py) reproduces this bit-exactly.
    """
    m = records.shape[0]
    out = np.zeros((m, cuts.n_cuts), dtype=bool)
    off = cuts.schema.cat_offsets
    for c in range(cuts.n_cuts):
        k = int(cuts.kind[c])
        if k == KIND_RANGE:
            out[:, c] = records[:, cuts.dim[c]] < cuts.cutpoint[c]
        elif k == KIND_IN:
            d = int(cuts.dim[c])
            bitpos = records[:, d].astype(np.int64) + int(off[d])
            out[:, c] = cuts.in_mask[c, bitpos]
        else:
            out[:, c] = cuts.adv[int(cuts.adv_id[c])].evaluate(records)
    return out


def eval_adv(records: np.ndarray, adv: Sequence[AdvPredicate]) -> np.ndarray:
    """(m, n_adv) bool — advanced-predicate truth per record."""
    if not adv:
        return np.zeros((records.shape[0], 0), dtype=bool)
    return np.stack([a.evaluate(records) for a in adv], axis=1)
