"""The qd-tree data structure (paper Sec 3).

Two representations:

* ``Node``/``QdTree`` — a Python object tree used during *construction*
  (greedy / WOODBLOCK), where the shape is dynamic.
* ``FrozenQdTree`` — flat int32/bool arrays produced by ``QdTree.freeze()``;
  this is what routing, query processing, the Pallas kernels, and
  serialization consume.  Mirrors the paper's "freeze the tree" step
  (Sec 3.2), including min-max tightening of leaf descriptions.
"""

from __future__ import annotations

import collections
import dataclasses
import json
from typing import Iterator, Optional

import numpy as np

from repro.core import predicates as preds
from repro.core.predicates import CutTable, Schema


# ---------------------------------------------------------------------------
# Node semantic descriptions (paper Table 1 + Sec 6.1 advanced-cut bits)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class NodeDesc:
    """Semantic description of a node's subspace.

    lo, hi   : (ndims,) int32 — hypercube, hi exclusive.  Categorical dims
               keep [0, dom) here; their information lives in ``cat``.
    cat      : (total_cat_bits,) bool — 1 = value may appear under this node.
    adv      : (n_adv, 2) bool — [i, 0]: may contain records satisfying
               advanced cut i; [i, 1]: may contain records violating it.
               (The paper stores only the first bit; we add the negation bit
               so query routing handles both polarities — DESIGN.md §8.)
    """

    lo: np.ndarray
    hi: np.ndarray
    cat: np.ndarray
    adv: np.ndarray

    def copy(self) -> "NodeDesc":
        return NodeDesc(
            self.lo.copy(), self.hi.copy(), self.cat.copy(), self.adv.copy()
        )


def root_desc(schema: Schema, n_adv: int) -> NodeDesc:
    return NodeDesc(
        lo=np.zeros(schema.ndims, np.int32),
        hi=schema.doms.copy(),
        cat=np.ones(max(schema.total_cat_bits, 1), bool),
        adv=np.ones((n_adv, 2), bool),
    )


def child_descs(
    desc: NodeDesc, cuts: CutTable, cut_id: int
) -> tuple[NodeDesc, NodeDesc]:
    """Restrict a parent description through cut ``cut_id`` (paper Sec 3.2).

    Left child satisfies the cut; right child satisfies its negation.
    """
    left, right = desc.copy(), desc.copy()
    k = int(cuts.kind[cut_id])
    if k == preds.KIND_RANGE:
        d, c = int(cuts.dim[cut_id]), int(cuts.cutpoint[cut_id])
        left.hi[d] = min(left.hi[d], c)
        right.lo[d] = max(right.lo[d], c)
    elif k == preds.KIND_IN:
        mask = cuts.in_mask[cut_id]
        d = int(cuts.dim[cut_id])
        seg = cuts.schema.cat_segment(d)
        left.cat[seg] &= mask[seg]
        right.cat[seg] &= ~mask[seg]
    else:
        a = int(cuts.adv_id[cut_id])
        left.adv[a] = (True, False)
        right.adv[a] = (False, True)
    return left, right


def child_descs_all(
    desc: NodeDesc, cuts: CutTable
) -> tuple[dict[str, np.ndarray], dict[str, np.ndarray]]:
    """Vectorized ``child_descs`` across *every* candidate cut.

    Returns (left, right), each a dict of stacked arrays:
      lo, hi : (n_cuts, ndims);  cat : (n_cuts, bits);  adv : (n_cuts, n_adv, 2)
    Used by greedy construction to score all cuts in one shot.
    """
    n = cuts.n_cuts
    L = {
        "lo": np.broadcast_to(desc.lo, (n, desc.lo.size)).copy(),
        "hi": np.broadcast_to(desc.hi, (n, desc.hi.size)).copy(),
        "cat": np.broadcast_to(desc.cat, (n, desc.cat.size)).copy(),
        "adv": np.broadcast_to(desc.adv, (n,) + desc.adv.shape).copy(),
    }
    R = {k: v.copy() for k, v in L.items()}

    rng = cuts.kind == preds.KIND_RANGE
    if rng.any():
        idx = np.nonzero(rng)[0]
        dims = cuts.dim[idx]
        cps = cuts.cutpoint[idx]
        L["hi"][idx, dims] = np.minimum(L["hi"][idx, dims], cps)
        R["lo"][idx, dims] = np.maximum(R["lo"][idx, dims], cps)

    inc = cuts.kind == preds.KIND_IN
    if inc.any():
        idx = np.nonzero(inc)[0]
        # in_mask is zero outside the cut's own dim segment, so AND-ing the
        # complement must be limited to the segment.  Build per-cut segment
        # masks once.
        for i in idx:
            seg = cuts.schema.cat_segment(int(cuts.dim[i]))
            L["cat"][i, seg] &= cuts.in_mask[i, seg]
            R["cat"][i, seg] &= ~cuts.in_mask[i, seg]

    advc = cuts.kind == preds.KIND_ADV
    if advc.any():
        idx = np.nonzero(advc)[0]
        aids = cuts.adv_id[idx]
        L["adv"][idx, aids] = np.array([True, False])
        R["adv"][idx, aids] = np.array([False, True])
    return L, R


# ---------------------------------------------------------------------------
# Construction-time object tree
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Node:
    desc: NodeDesc
    rows: Optional[np.ndarray] = None  # indices into the construction sample
    cut_id: int = -1
    left: Optional["Node"] = None
    right: Optional["Node"] = None
    bid: int = -1  # assigned at freeze for leaves

    @property
    def is_leaf(self) -> bool:
        return self.cut_id < 0

    @property
    def size(self) -> int:
        return 0 if self.rows is None else int(self.rows.shape[0])


@dataclasses.dataclass
class QdTree:
    schema: Schema
    cuts: CutTable
    root: Node

    # -- traversal ---------------------------------------------------------
    def nodes(self) -> Iterator[Node]:
        stack = [self.root]
        while stack:
            n = stack.pop()
            yield n
            if not n.is_leaf:
                stack.append(n.right)
                stack.append(n.left)

    def leaves(self) -> list[Node]:
        return [n for n in self.nodes() if n.is_leaf]

    @property
    def n_leaves(self) -> int:
        return len(self.leaves())

    def depth(self) -> int:
        def _d(n: Node) -> int:
            if n.is_leaf:
                return 0
            return 1 + max(_d(n.left), _d(n.right))

        return _d(self.root)

    # -- structural edits (used by greedy / WOODBLOCK) ----------------------
    def split(
        self, node: Node, cut_id: int, sample: Optional[np.ndarray] = None,
        cut_matrix: Optional[np.ndarray] = None,
    ) -> tuple[Node, Node]:
        """Apply cut ``cut_id`` at ``node`` (the paper's ``T ⊕ (p, n)``).

        ``cut_matrix`` is the (m_sample, n_cuts) predicate matrix for the
        construction sample; row sets are split by its ``cut_id`` column.
        """
        if not node.is_leaf:
            raise ValueError("can only split a leaf")
        ld, rd = child_descs(node.desc, self.cuts, cut_id)
        lrows = rrows = None
        if node.rows is not None:
            if cut_matrix is None:
                assert sample is not None
                col = preds.eval_cuts(
                    sample[node.rows],
                    _single_cut(self.cuts, cut_id),
                )[:, 0]
            else:
                col = cut_matrix[node.rows, cut_id]
            lrows = node.rows[col]
            rrows = node.rows[~col]
        node.cut_id = cut_id
        node.left = Node(desc=ld, rows=lrows)
        node.right = Node(desc=rd, rows=rrows)
        return node.left, node.right

    # -- freezing ------------------------------------------------------------
    def freeze(self) -> "FrozenQdTree":
        """Flatten to arrays; assign BIDs to leaves in BFS order."""
        order: list[Node] = []
        bfs = collections.deque([self.root])
        while bfs:
            n = bfs.popleft()
            order.append(n)
            if not n.is_leaf:
                bfs.append(n.left)
                bfs.append(n.right)
        index = {id(n): i for i, n in enumerate(order)}
        nn = len(order)
        cut_id = np.full(nn, -1, np.int32)
        left = np.full(nn, -1, np.int32)
        right = np.full(nn, -1, np.int32)
        leaf_bid = np.full(nn, -1, np.int32)
        leaves = []
        for i, n in enumerate(order):
            if n.is_leaf:
                n.bid = len(leaves)
                leaf_bid[i] = n.bid
                leaves.append(n)
            else:
                cut_id[i] = n.cut_id
                left[i] = index[id(n.left)]
                right[i] = index[id(n.right)]
        ndims = self.schema.ndims
        bits = max(self.schema.total_cat_bits, 1)
        n_adv = self.cuts.n_adv
        nl = len(leaves)
        leaf_lo = np.zeros((nl, ndims), np.int32)
        leaf_hi = np.zeros((nl, ndims), np.int32)
        leaf_cat = np.zeros((nl, bits), bool)
        leaf_adv = np.zeros((nl, n_adv, 2), bool)
        for j, n in enumerate(leaves):
            leaf_lo[j] = n.desc.lo
            leaf_hi[j] = n.desc.hi
            leaf_cat[j] = n.desc.cat
            leaf_adv[j] = n.desc.adv
        # depth of the flattened tree
        depth = self.depth()
        return FrozenQdTree(
            schema=self.schema,
            cuts=self.cuts,
            cut_id=cut_id,
            left=left,
            right=right,
            leaf_bid=leaf_bid,
            leaf_lo=leaf_lo,
            leaf_hi=leaf_hi,
            leaf_cat=leaf_cat,
            leaf_adv=leaf_adv,
            depth=max(depth, 1),
        )


def _single_cut(cuts: CutTable, cut_id: int) -> CutTable:
    sl = slice(cut_id, cut_id + 1)
    return CutTable(
        schema=cuts.schema,
        kind=cuts.kind[sl],
        dim=cuts.dim[sl],
        cutpoint=cuts.cutpoint[sl],
        in_mask=cuts.in_mask[sl],
        adv_id=cuts.adv_id[sl],
        adv=cuts.adv,
    )


# ---------------------------------------------------------------------------
# Frozen (tensorized) tree
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class FrozenQdTree:
    """Flat-array qd-tree for routing / query processing / kernels.

    Node arrays are indexed in BFS order (root = 0).  Leaf description arrays
    are indexed by BID.
    """

    schema: Schema
    cuts: CutTable
    cut_id: np.ndarray  # (n_nodes,) int32, -1 for leaves
    left: np.ndarray  # (n_nodes,) int32
    right: np.ndarray  # (n_nodes,) int32
    leaf_bid: np.ndarray  # (n_nodes,) int32, -1 for internal
    leaf_lo: np.ndarray  # (n_leaves, ndims)
    leaf_hi: np.ndarray  # (n_leaves, ndims)
    leaf_cat: np.ndarray  # (n_leaves, bits)
    leaf_adv: np.ndarray  # (n_leaves, n_adv, 2)
    depth: int

    @property
    def n_nodes(self) -> int:
        return int(self.cut_id.shape[0])

    @property
    def n_leaves(self) -> int:
        return int(self.leaf_lo.shape[0])

    # -- routing (numpy reference; kernels/ops.py provides the TPU path) ----
    def route(self, records: np.ndarray) -> np.ndarray:
        """Record → BID (paper Sec 3.1).  Level-synchronous descent."""
        m = records.shape[0]
        M = preds.eval_cuts(records, self.cuts)
        node = np.zeros(m, np.int64)
        for _ in range(self.depth):
            cid = self.cut_id[node]
            internal = cid >= 0
            if not internal.any():
                break
            pred = M[np.arange(m), np.clip(cid, 0, None)]
            nxt = np.where(pred, self.left[node], self.right[node])
            node = np.where(internal, nxt, node)
        return self.leaf_bid[node].astype(np.int32)

    def tighten(self, records: np.ndarray, bids: np.ndarray) -> None:
        """Min-max-tighten leaf descriptions from routed records (Sec 3.2).

        Numeric ranges become [min, max+1); categorical masks keep only
        values actually present; advanced bits reflect observed truth values.
        Empty leaves get a degenerate description that intersects nothing.

        Vectorized (``np.minimum.at``/``bincount``) and expressed as one
        step of :class:`IncrementalTightener`, so streaming ingestion can
        apply the identical update one micro-batch at a time.
        """
        t = IncrementalTightener(self)
        t.update(records, bids)
        t.apply()

    # -- serialization -------------------------------------------------------
    def save(self, path: str) -> None:
        np.savez_compressed(
            path,
            cut_id=self.cut_id,
            left=self.left,
            right=self.right,
            leaf_bid=self.leaf_bid,
            leaf_lo=self.leaf_lo,
            leaf_hi=self.leaf_hi,
            leaf_cat=self.leaf_cat,
            leaf_adv=self.leaf_adv,
            depth=np.array(self.depth),
            # cut table
            ct_kind=self.cuts.kind,
            ct_dim=self.cuts.dim,
            ct_cutpoint=self.cuts.cutpoint,
            ct_in_mask=self.cuts.in_mask,
            ct_adv_id=self.cuts.adv_id,
            ct_adv=np.array(
                [(a.col_a, a.op, a.col_b) for a in self.cuts.adv], np.int32
            ).reshape(-1, 3),
            schema=json.dumps(
                [(c.name, c.kind, c.dom) for c in self.schema.columns]
            ),
        )

    @staticmethod
    def load(path: str) -> "FrozenQdTree":
        z = np.load(path, allow_pickle=False)
        cols = tuple(
            preds.Column(n, k, int(d)) for n, k, d in json.loads(str(z["schema"]))
        )
        schema = Schema(cols)
        adv = tuple(
            preds.AdvPredicate(int(a), int(o), int(b))
            for a, o, b in z["ct_adv"]
        )
        cuts = CutTable(
            schema=schema,
            kind=z["ct_kind"],
            dim=z["ct_dim"],
            cutpoint=z["ct_cutpoint"],
            in_mask=z["ct_in_mask"],
            adv_id=z["ct_adv_id"],
            adv=adv,
        )
        return FrozenQdTree(
            schema=schema,
            cuts=cuts,
            cut_id=z["cut_id"],
            left=z["left"],
            right=z["right"],
            leaf_bid=z["leaf_bid"],
            leaf_lo=z["leaf_lo"],
            leaf_hi=z["leaf_hi"],
            leaf_cat=z["leaf_cat"],
            leaf_adv=z["leaf_adv"],
            depth=int(z["depth"]),
        )


@dataclasses.dataclass
class TightenPartial:
    """Pre-reduced per-leaf tightening aggregates for one batch.

    The unit of exchange between the fused single-pass ingestion kernels
    (``kernels/fused_ingest.py``, the engine backends) and the tightener:
    the kernel reduces a routed batch to per-leaf partials on device, and
    :meth:`IncrementalTightener.merge` folds them host-side with the same
    elementwise monoid ops (min / max / sum / or) that ``update`` applies
    per record.  ``lo``/``hi`` carry the tightener's int64 identity
    elements on leaves the batch never touched, so merging is exact and
    order-independent — bit-identical to the legacy two-pass route-then-
    ``update`` path for any chunking.
    """

    counts: np.ndarray  # (L,) int64 records routed per leaf
    lo: np.ndarray  # (L, D) int64 batch minima (int64 max where empty)
    hi: np.ndarray  # (L, D) int64 batch maxima, exclusive (int64 min)
    cat: np.ndarray  # (L, bits) bool categorical values present
    adv: np.ndarray  # (L, A, 2) bool advanced-cut truth bits observed


class IncrementalTightener:
    """Streaming min-max tightening of leaf descriptions (Sec 3.2, online).

    Accumulates per-leaf bounds across any number of ``update(records,
    bids)`` micro-batches using vectorized scatter-reductions
    (``np.minimum.at`` / ``np.maximum.at`` / ``bincount``), then ``apply()``
    writes the tightened descriptions into the tree.  Because min, max and
    any are associative, the result is bit-identical to one-shot
    ``FrozenQdTree.tighten`` over the concatenated batches regardless of how
    the stream is chunked.  :meth:`merge` folds a :class:`TightenPartial`
    that a fused kernel already reduced per leaf — same monoid, same bits.
    """

    def __init__(self, tree: "FrozenQdTree"):
        self.tree = tree
        L, d = tree.n_leaves, tree.schema.ndims
        self.lo = np.full((L, d), np.iinfo(np.int64).max, np.int64)
        self.hi = np.full((L, d), np.iinfo(np.int64).min, np.int64)
        self.cat = np.zeros_like(tree.leaf_cat)
        self.adv = np.zeros_like(tree.leaf_adv)
        self.counts = np.zeros(L, np.int64)

    def update(self, records: np.ndarray, bids: np.ndarray) -> None:
        if records.shape[0] == 0:
            return
        tree = self.tree
        bids = np.asarray(bids, np.int64)
        rec64 = records.astype(np.int64, copy=False)
        np.minimum.at(self.lo, bids, rec64)
        np.maximum.at(self.hi, bids, rec64 + 1)  # hi is exclusive
        self.counts += np.bincount(bids, minlength=self.counts.shape[0])
        off = tree.schema.cat_offsets
        for d in np.nonzero(tree.schema.is_categorical)[0]:
            self.cat[bids, off[d] + rec64[:, d]] = True
        if tree.cuts.n_adv:
            t = preds.eval_adv(records, tree.cuts.adv)
            np.logical_or.at(self.adv[:, :, 0], bids, t)
            np.logical_or.at(self.adv[:, :, 1], bids, ~t)

    def merge(self, partial: TightenPartial) -> None:
        """Fold a per-leaf pre-reduced partial (fused kernels, shards)."""
        self.counts += partial.counts
        np.minimum(self.lo, partial.lo, out=self.lo)
        np.maximum(self.hi, partial.hi, out=self.hi)
        self.cat |= partial.cat
        self.adv |= partial.adv

    def as_partial(self) -> TightenPartial:
        """The accumulated state as an exchangeable partial (views)."""
        return TightenPartial(
            counts=self.counts, lo=self.lo, hi=self.hi, cat=self.cat,
            adv=self.adv,
        )

    def apply(self) -> None:
        """Write accumulated bounds into the tree's leaf descriptions."""
        tree = self.tree
        nonempty = self.counts > 0
        ne = nonempty[:, None]
        tree.leaf_lo[:] = np.where(ne, self.lo, 0).astype(
            tree.leaf_lo.dtype, copy=False
        )
        tree.leaf_hi[:] = np.where(ne, self.hi, 0).astype(
            tree.leaf_hi.dtype, copy=False
        )
        tree.leaf_cat[:] = self.cat & ne
        tree.leaf_adv[:] = self.adv & nonempty[:, None, None]
        # invalidate description-dependent cached plans (engine/plan.py)
        object.__setattr__(
            tree, "_desc_version", getattr(tree, "_desc_version", 0) + 1
        )


def singleton_tree(
    schema: Schema, cuts: CutTable, sample_rows: Optional[np.ndarray] = None
) -> QdTree:
    """T_0: the tree with only a root (paper Alg. 1 initialization)."""
    root = Node(desc=root_desc(schema, cuts.n_adv), rows=sample_rows)
    return QdTree(schema=schema, cuts=cuts, root=root)
