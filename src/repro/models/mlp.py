"""Dense MLPs (SwiGLU / GELU) and Mixture-of-Experts.

MoE strategy (DESIGN.md §6, EXPERIMENTS.md §Perf P1/C1): the whole MoE
layer is a hand-written fully-manual ``shard_map`` — GSPMD cannot shard
sort/scatter dispatch (the auto-partitioned form replicates ~720 GB/device
at qwen3-moe train_4k scale).  Tokens arrive sequence-sharded over
``model`` (the residual's SP layout) and (pod, data)-sharded over batch;
top-k / sort / capacity bucketing / combine are shard-local; expert
parallelism is one explicit ``all_to_all`` over ``model`` with FSDP
``all_gather`` of expert weights over ``data``; grok-style few-big-expert
models (``moe_shard="ff"``) tensor-shard the expert hidden dim and
``psum`` partial outputs instead.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import dense_init, split_tree
from repro.sharding.specs import logical_constraint as wsc


# ---------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------
def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = common.pdtype(cfg)
    ks = jax.random.split(key, 3)
    pairs = {
        "w_up": dense_init(ks[0], (d, f), dt, ("fsdp", "mlp")),
        "w_down": dense_init(ks[1], (f, d), dt, ("mlp", "fsdp")),
    }
    if cfg.mlp_gated:
        pairs["w_gate"] = dense_init(ks[2], (d, f), dt, ("fsdp", "mlp"))
    return split_tree(pairs)


def mlp_forward(params, x, cfg: ModelConfig):
    ct = common.cdtype(cfg)
    xc = x.astype(ct)
    up = xc @ params["w_up"].astype(ct)
    if cfg.mlp_gated:
        gate = xc @ params["w_gate"].astype(ct)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    h = wsc(h, ("batch", "seq", "mlp"))
    return h @ params["w_down"].astype(ct)


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------
def init_moe(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.expert_ff, cfg.n_experts
    dt = common.pdtype(cfg)
    ks = jax.random.split(key, 4)
    if cfg.moe_shard == "expert":
        up_axes = ("experts", "fsdp", None)
        down_axes = ("experts", None, "fsdp")
    else:  # "ff": few big experts — TP the hidden dim instead (grok-style)
        up_axes = (None, "fsdp", "mlp")
        down_axes = (None, "mlp", "fsdp")
    pairs = {
        "router": dense_init(ks[0], (d, e), jnp.float32, (None, None)),
        "w_gate": dense_init(ks[1], (e, d, f), dt, up_axes),
        "w_up": dense_init(ks[2], (e, d, f), dt, up_axes),
        "w_down": dense_init(ks[3], (e, f, d), dt, down_axes),
    }
    return split_tree(pairs)


def _capacity(tokens_per_shard: int, cfg: ModelConfig) -> int:
    c = int(
        tokens_per_shard * cfg.top_k * cfg.capacity_factor / cfg.n_experts
    )
    return max(((c + 3) // 4) * 4, 4)


def _route(params, xl, cfg: ModelConfig):
    """Router: (T, D) → (probs (T,E) f32, top_p (T,k), top_e (T,k))."""
    logits = xl.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return probs, top_p, top_e


def _bucket(top_e, tl: int, k: int, e: int, cap: int):
    """Sort-based capacity positions (all shard-local, no collectives)."""
    flat_e = top_e.reshape(tl * k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e))
    pos_in_e = jnp.arange(tl * k, dtype=jnp.int32) - seg_start[sorted_e]
    keep = pos_in_e < cap
    src_tok = order // k
    return order, sorted_e, src_tok, jnp.where(keep, pos_in_e, 0), keep


def _expert_ffn(buf, wg, wu, wd, ct):
    """(E?, C, D) → (E?, C, D) batched expert matmuls."""
    hg = jnp.einsum("ecd,edf->ecf", buf, wg.astype(ct))
    hu = jnp.einsum("ecd,edf->ecf", buf, wu.astype(ct))
    h = jax.nn.silu(hg) * hu
    return jnp.einsum("ecf,efd->ecd", h, wd.astype(ct))


def _aux_loss(counts, prob_sum, total_tokens, cfg: ModelConfig):
    """Switch-style load-balance loss from expert counts + mean probs."""
    density = counts / jnp.maximum(total_tokens * cfg.top_k, 1.0)
    prob_mean = prob_sum / jnp.maximum(total_tokens, 1.0)
    return (
        cfg.router_aux_coef * cfg.n_experts * jnp.sum(density * prob_mean)
    )


def _moe_local(params, xf, cfg: ModelConfig):
    """Single-shard reference path (also the oracle for the EP tests)."""
    ct = common.cdtype(cfg)
    tl, d = xf.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(tl, cfg)
    probs, top_p, top_e = _route(params, xf, cfg)
    order, sorted_e, src, pos, keep = _bucket(top_e, tl, k, e, cap)
    contrib = jnp.where(keep[:, None], xf[src].astype(ct), 0)
    buf = jnp.zeros((e, cap, d), ct).at[sorted_e, pos].add(
        contrib, mode="drop"
    )
    out = _expert_ffn(
        buf, params["w_gate"], params["w_up"], params["w_down"], ct
    )
    gathered = out[sorted_e, pos]
    w = (top_p.reshape(tl * k)[order] * keep).astype(ct)
    y = jnp.zeros((tl, d), ct).at[src].add(gathered * w[:, None])
    counts = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(1.0)
    aux = _aux_loss(counts, probs.sum(0), float(tl), cfg)
    return y, aux


def moe_forward(params, x, cfg: ModelConfig):
    """x: (B, S, D) → (B, S, D) + load-balance aux loss.

    Distribution strategy (hand-written, NOT left to GSPMD): XLA cannot
    shard the sort/scatter dispatch — the auto-partitioned formulation
    replicates an (T·k, D) gather on every device (~600 GB/device at
    qwen3-moe train_4k; §Perf log).  Instead the whole MoE layer runs in a
    fully-manual ``shard_map``:

      * tokens stay in their (pod, data) shard; top-k, sort, capacity
        bucketing and the combine are shard-LOCAL (zero collectives),
      * ``moe_shard="expert"`` (EP): per-expert capacity buffers do one
        explicit ``all_to_all`` over ``model`` (experts↔capacity), expert
        FFNs run on E/|model| local experts with FSDP ``all_gather`` of
        their weights over ``data``,
      * ``moe_shard="ff"`` (grok-style few-big-experts): experts stay
        replicated, each model rank computes its F-slice and a ``psum``
        over ``model`` reduces the partial outputs,
      * the load-balance loss psums token statistics over (pod, data).

    Without an active mesh (CPU smoke tests, 1-device examples) the
    shard-local path runs directly.
    """
    from repro.sharding import specs as sharding_specs
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    mesh = sharding_specs.active_mesh()
    rules = sharding_specs.active_rules()
    if mesh is not None and rules is not None:
        batch_axes = tuple(
            a for a in (rules.lookup("batch") or ())
            if a in mesh.axis_names
        )
    else:
        batch_axes = ()
    n_tok_shards = 1
    for a in batch_axes:
        n_tok_shards *= mesh.shape[a]
    if not batch_axes or b % n_tok_shards:
        y, aux = _moe_local(params, x.reshape(b * s, d), cfg)
        return y.reshape(b, s, d), aux

    e, k = cfg.n_experts, cfg.top_k
    ct = common.cdtype(cfg)
    has_model = "model" in mesh.axis_names
    n_model = mesh.shape["model"] if has_model else 1
    ep = cfg.moe_shard == "expert" and has_model and e % n_model == 0
    ff_tp = cfg.moe_shard == "ff" and has_model and cfg.expert_ff % n_model == 0
    # EP + sequence-parallel dispatch: tokens enter ALREADY seq-sharded
    # over `model` (the residual's SP layout), so each model rank routes
    # only its own token slice.  Without this, tokens are replicated over
    # model and the a2a multiplies expert-FFN rows by n_model — 16×
    # redundant compute measured at qwen3-moe train_4k (§Perf log).
    seq_split = ep and s % n_model == 0 and s >= n_model
    tl = (b // n_tok_shards) * (s // (n_model if seq_split else 1))
    cap = _capacity(tl, cfg)
    tok_axes = batch_axes + (("model",) if seq_split else ())

    if ep:
        w_specs = {
            "router": P(None, None),
            "w_gate": P("model", "data", None),
            "w_up": P("model", "data", None),
            "w_down": P("model", None, "data"),
        }
    elif ff_tp:
        w_specs = {
            "router": P(None, None),
            "w_gate": P(None, "data", "model"),
            "w_up": P(None, "data", "model"),
            "w_down": P(None, "model", "data"),
        }
    else:
        w_specs = {
            "router": P(None, None),
            "w_gate": P(None, "data", None),
            "w_up": P(None, "data", None),
            "w_down": P(None, None, "data"),
        }
    if "data" not in mesh.axis_names:
        w_specs = {k_: P(*[None] * len(v)) for k_, v in w_specs.items()}

    def body(xb, router, wg, wu, wd):
        p = {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}
        xl = xb.reshape(tl, d)
        probs, top_p, top_e = _route(p, xl, cfg)
        order, sorted_e, src, pos, keep = _bucket(top_e, tl, k, e, cap)
        contrib = jnp.where(keep[:, None], xl[src].astype(ct), 0)
        buf = jnp.zeros((e, cap, d), ct).at[sorted_e, pos].add(
            contrib, mode="drop"
        )
        if "data" in mesh.axis_names:
            gather = lambda w, ax: jax.lax.all_gather(
                w, "data", axis=ax, tiled=True
            )
        else:
            gather = lambda w, ax: w
        if ep:
            # experts ↔ capacity all-to-all (the EP boundary)
            buf = jax.lax.all_to_all(
                buf, "model", split_axis=0, concat_axis=1, tiled=True
            )  # (E/n_model, n_model·cap, D)
            out = _expert_ffn(
                buf, gather(wg, 1), gather(wu, 1), gather(wd, 2), ct
            )
            out = jax.lax.all_to_all(
                out, "model", split_axis=1, concat_axis=0, tiled=True
            )  # (E, cap, D)
        elif ff_tp:
            # partial-F expert compute + psum over model
            out = _expert_ffn(
                buf, gather(wg, 1), gather(wu, 1), gather(wd, 2), ct
            )
            out = jax.lax.psum(out, "model")
        else:
            out = _expert_ffn(
                buf, gather(wg, 1), gather(wu, 1), gather(wd, 2), ct
            )
        gathered = out[sorted_e, pos]
        w = (top_p.reshape(tl * k)[order] * keep).astype(ct)
        y = jnp.zeros((tl, d), ct).at[src].add(gathered * w[:, None])
        counts = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(1.0)
        counts = jax.lax.psum(counts, tok_axes)
        prob_sum = jax.lax.psum(probs.sum(0), tok_axes)
        aux = _aux_loss(counts, prob_sum, float(b * s), cfg)
        return y.reshape(xb.shape), aux

    x_spec = P(batch_axes, "model" if seq_split else None, None)
    y, aux = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(
            x_spec,
            w_specs["router"], w_specs["w_gate"],
            w_specs["w_up"], w_specs["w_down"],
        ),
        out_specs=(x_spec, P()),
        axis_names=frozenset(mesh.axis_names),
        check_vma=False,
    )(
        x,
        params["router"], params["w_gate"],
        params["w_up"], params["w_down"],
    )
    return y, aux
