"""Single public entry point for every assigned architecture.

``init_model / train_loss / prefill / init_caches / decode_step`` dispatch
on ``cfg.family`` so the launcher, dry-run driver, trainer, and tests never
special-case architectures.  Batches are plain dicts:

  train:   tokens (B,S) i32, labels (B,S) i32 [+ patches (B,P,D) for vlm,
           frames (B,F,D) for audio]
  prefill: tokens (B,S) [+ patches / frames]
  decode:  token (B,1) i32, pos () i32  [+ caches]
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common, encdec, transformer


def init_model(key, cfg: ModelConfig):
    """→ (params, logical-axis specs) for any family."""
    if cfg.is_encdec:
        return encdec.init_encdec(key, cfg)
    return transformer.init_decoder(key, cfg)


def _forward(params, batch, cfg: ModelConfig, collect_cache: bool):
    if cfg.is_encdec:
        enc_out = encdec.encode(params, batch["frames"], cfg)
        logits, caches = encdec.decode_train(
            params, enc_out, batch["tokens"], cfg, collect_cache
        )
        aux = jnp.zeros((), jnp.float32)
    else:
        logits, aux, caches = transformer.decoder_forward(
            params,
            batch["tokens"],
            cfg,
            patches=batch.get("patches"),
            collect_cache=collect_cache,
        )
    return logits, aux, caches


def train_loss(params, batch, cfg: ModelConfig):
    """→ (scalar loss, metrics dict).  fp32 loss, z-loss regularized."""
    logits, aux, _ = _forward(params, batch, cfg, collect_cache=False)
    labels = batch["labels"]
    weights = batch.get("loss_weights")
    if weights is None and cfg.n_image_patches:
        # VLM: no next-token loss on image-patch positions
        s = labels.shape[1]
        weights = jnp.broadcast_to(
            (jnp.arange(s) >= cfg.n_image_patches).astype(jnp.float32),
            labels.shape,
        )
    loss, nll = common.softmax_cross_entropy(logits, labels, weights)
    total = loss + aux
    return total, {"loss": total, "nll": nll, "aux": aux}


def prefill(params, batch, cfg: ModelConfig):
    """Prefill pass: returns (last-position logits (B,V), caches)."""
    logits, _, caches = _forward(params, batch, cfg, collect_cache=True)
    return logits[:, -1], caches


def init_caches(cfg: ModelConfig, batch: int, max_seq: int):
    if cfg.is_encdec:
        return encdec.init_encdec_caches(cfg, batch, max_seq)
    return transformer.init_decode_caches(cfg, batch, max_seq)


def decode_step(params, caches, token, pos, cfg: ModelConfig):
    """One-token decode: → (logits (B,V), updated caches)."""
    if cfg.is_encdec:
        return encdec.encdec_decode(params, caches, token, pos, cfg)
    return transformer.decoder_decode(params, caches, token, pos, cfg)


def param_count(params) -> int:
    import jax

    return sum(int(x.size) for x in jax.tree.leaves(params))


def model_flops_per_token(cfg: ModelConfig) -> float:
    """MODEL_FLOPS/token = 6·N (dense) or 6·N_active (MoE) — §Roofline."""
    d = cfg.d_model
    n_active = 2 * cfg.vocab * d  # embed + head
    program = transformer.layer_program(cfg) if not cfg.is_encdec else None
    if cfg.is_encdec:
        per_attn = 4 * d * cfg.n_heads * cfg.hd
        per_mlp = (3 if cfg.mlp_gated else 2) * d * cfg.d_ff
        n_active += cfg.n_layers * (2 * per_attn + per_mlp)
        n_active += cfg.encoder_layers * (per_attn + per_mlp)
        return 6.0 * n_active
    ng = transformer.n_groups(cfg)
    for spec in program:
        if spec.mixer == "attn":
            n_active += ng * 2 * d * (cfg.n_heads + cfg.n_kv_heads) * cfg.hd
            n_active += ng * cfg.n_heads * cfg.hd * d  # wo
        else:
            din = cfg.d_inner
            conv_ch = din + 2 * cfg.ssm_groups * cfg.ssm_state
            n_active += ng * (
                d * (2 * din + 2 * cfg.ssm_groups * cfg.ssm_state
                     + cfg.ssm_heads)
                + cfg.ssm_conv * conv_ch
                + din * d
            )
        if spec.mlp == "dense":
            n_active += ng * (3 if cfg.mlp_gated else 2) * d * cfg.d_ff
        elif spec.mlp == "moe":
            n_active += ng * cfg.top_k * 3 * d * cfg.expert_ff
            n_active += ng * d * cfg.n_experts  # router
    return 6.0 * n_active
