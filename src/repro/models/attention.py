"""GQA attention: full, KV-chunked (online-softmax), and decode-with-cache.

Memory policy: anything ≥ ~8k sequence runs the chunked path — a double
``lax.scan`` over (query-chunks × kv-chunks) carrying running max/denominator,
i.e. FlashAttention expressed at the XLA level (the TPU MXU consumes the
per-chunk matmuls; fusion and overlap are XLA's job — see DESIGN.md §3).
Sharding: heads are tensor-parallel over ``model``; the residual stream is
sequence-parallel; KV caches shard batch over ``data`` (and sequence over
``data`` for the 512k cells via rule overrides).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import dense_init, split_tree
from repro.sharding.specs import logical_constraint as wsc

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = common.pdtype(cfg)
    ks = jax.random.split(key, 4)
    pairs = {
        "wq": dense_init(ks[0], (d, h, hd), dt, ("fsdp", "heads", None)),
        "wk": dense_init(ks[1], (d, kv, hd), dt, ("fsdp", "kv_heads", None)),
        "wv": dense_init(ks[2], (d, kv, hd), dt, ("fsdp", "kv_heads", None)),
        "wo": dense_init(
            ks[3], (h, hd, d), dt, ("heads", None, "fsdp"),
            scale=1.0 / jnp.sqrt(h * hd),
        ),
    }
    if cfg.qkv_bias:
        pairs["bq"] = ((jnp.zeros((h, hd), dt)), ("heads", None))
        pairs["bk"] = ((jnp.zeros((kv, hd), dt)), ("kv_heads", None))
        pairs["bv"] = ((jnp.zeros((kv, hd), dt)), ("kv_heads", None))
    return split_tree(pairs)


def _project_qkv(params, x, kv_x, positions, kv_positions, cfg: ModelConfig):
    ct = common.cdtype(cfg)
    xq = x.astype(ct)
    xkv = (kv_x if kv_x is not None else x).astype(ct)
    q = jnp.einsum("bsd,dhk->bshk", xq, params["wq"].astype(ct))
    k = jnp.einsum("bsd,dhk->bshk", xkv, params["wk"].astype(ct))
    v = jnp.einsum("bsd,dhk->bshk", xkv, params["wv"].astype(ct))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(ct)
        k = k + params["bk"].astype(ct)
        v = v + params["bv"].astype(ct)
    if cfg.pos_embed == "rope":
        q = common.apply_rope(q, positions, cfg.rope_theta)
        k = common.apply_rope(k, kv_positions, cfg.rope_theta)
    q = wsc(q, ("batch", "seq", "heads", None))
    k = wsc(k, ("batch", "seq", "kv_heads", None))
    v = wsc(v, ("batch", "seq", "kv_heads", None))
    return q, k, v


def _group_q(q, n_kv: int):
    """(B,S,H,hd) → (B,S,KV,rep,hd) for GQA."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, hd)


def full_attention(q, k, v, q_pos, k_pos, causal: bool):
    """Reference path for short sequences; fp32 softmax."""
    hd = q.shape[-1]
    scores = jnp.einsum(
        "bsgrh,btgh->bgrst", q, k, preferred_element_type=jnp.float32
    ) / jnp.sqrt(hd).astype(jnp.float32)
    if causal:
        mask = q_pos[:, None, None, :, None] >= k_pos[:, None, None, None, :]
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrst,btgh->bsgrh", probs.astype(q.dtype), v)
    return out


def chunked_attention(
    q, k, v, q_pos, k_pos, causal: bool, q_chunk: int, kv_chunk: int,
    unroll: bool = False,
):
    """Online-softmax attention: O(S·chunk) live memory.

    q: (B,S,KV,rep,hd); k/v: (B,T,KV,hd); q_pos (B,S); k_pos (B,T).
    """
    b, s, g, r, hd = q.shape
    t = k.shape[1]
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, t)
    # pad both sequence sides to chunk multiples; padded KV positions get a
    # +inf-like sentinel so they are masked under causal AND non-causal
    # attention (whisper cross-attends to 1500 frames — not a 2^k multiple)
    SENTINEL = jnp.int32(2**30)
    s_pad = (-s) % q_chunk
    t_pad = (-t) % kv_chunk
    if s_pad:
        q = jnp.pad(q, ((0, 0), (0, s_pad), (0, 0), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, s_pad)))
    if t_pad:
        k = jnp.pad(k, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(
            k_pos, ((0, 0), (0, t_pad)), constant_values=SENTINEL
        )
    s_full, t_full = s + s_pad, t + t_pad
    nq, nk = s_full // q_chunk, t_full // kv_chunk
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    qs = q.reshape(b, nq, q_chunk, g, r, hd).swapaxes(0, 1)
    qp = q_pos.reshape(b, nq, q_chunk).swapaxes(0, 1)
    ks = k.reshape(b, nk, kv_chunk, g, hd).swapaxes(0, 1)
    vs = v.reshape(b, nk, kv_chunk, g, hd).swapaxes(0, 1)
    kp = k_pos.reshape(b, nk, kv_chunk).swapaxes(0, 1)

    def q_step(_, q_blk):
        qc, qpc = q_blk  # (b, qc, g, r, hd), (b, qc)

        def kv_step(carry, kv_blk):
            m, l, acc = carry
            kc, vc, kpc = kv_blk
            s_blk = (
                jnp.einsum(
                    "bsgrh,btgh->bgrst", qc, kc,
                    preferred_element_type=jnp.float32,
                )
                * scale
            )  # (b, g, r, qc, kc)
            valid = (kpc < SENTINEL)[:, None, None, None, :]
            if causal:
                valid = valid & (
                    qpc[:, None, None, :, None]
                    >= kpc[:, None, None, None, :]
                )
            s_blk = jnp.where(valid, s_blk, NEG_INF)
            m_new = jnp.maximum(m, s_blk.max(axis=-1))
            p = jnp.exp(s_blk - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bgrst,btgh->bgrsh", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, g, r, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, g, r, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, g, r, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (ks, vs, kp), unroll=unroll
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)  # (b, g, r, qc, hd)
        return None, out.transpose(0, 3, 1, 2, 4)  # (b, qc, g, r, hd)

    _, outs = jax.lax.scan(
        q_step, None, (qs, qp), unroll=unroll
    )  # (nq, b, qc, g, r, hd)
    out = outs.swapaxes(0, 1).reshape(b, s_full, g, r, hd)[:, :s]
    return out.astype(q.dtype)


def attn_forward(
    params,
    x,
    positions,
    cfg: ModelConfig,
    causal: bool = True,
    kv_x=None,
    kv_positions=None,
    return_kv: bool = False,
):
    """Train/prefill attention.  x: (B,S,D) → (B,S,D).

    ``return_kv=True`` additionally returns (k, v) as (B, KV, S, hd) — the
    cache layout — so prefill populates decode caches for free.
    """
    if kv_positions is None:
        kv_positions = positions
    q, k, v = _project_qkv(params, x, kv_x, positions, kv_positions, cfg)
    qg = _group_q(q, cfg.n_kv_heads)
    s, t = x.shape[1], k.shape[1]
    if max(s, t) > 2 * cfg.attn_chunk:
        out = chunked_attention(
            qg, k, v, positions, kv_positions, causal,
            q_chunk=cfg.attn_chunk, kv_chunk=cfg.attn_chunk,
            unroll=cfg.scan_unroll,
        )
    else:
        out = full_attention(qg, k, v, positions, kv_positions, causal)
    b = x.shape[0]
    out = out.reshape(b, s, cfg.n_heads, cfg.hd)
    out = wsc(out, ("batch", "seq", "heads", None))
    ct = common.cdtype(cfg)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(ct), params["wo"].astype(ct))
    if return_kv:
        return y, (k.swapaxes(1, 2), v.swapaxes(1, 2))
    return y


def cross_attn_cached(params, x, k_cache, v_cache, cfg: ModelConfig):
    """Decode-time cross-attention against precomputed (B,KV,F,hd) K/V."""
    ct = common.cdtype(cfg)
    b = x.shape[0]
    q = jnp.einsum(
        "bsd,dhk->bshk", x.astype(ct), params["wq"].astype(ct)
    )
    if cfg.qkv_bias:
        q = q + params["bq"].astype(ct)
    qg = _group_q(q, cfg.n_kv_heads)  # (B,1,KV,rep,hd)
    scores = jnp.einsum(
        "bsgrh,bgth->bgrst", qg, k_cache,
        preferred_element_type=jnp.float32,
    ) / jnp.sqrt(cfg.hd).astype(jnp.float32)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bgrst,bgth->bsgrh", probs.astype(v_cache.dtype), v_cache
    )
    out = out.reshape(b, 1, cfg.n_heads, cfg.hd)
    return jnp.einsum(
        "bshk,hkd->bsd", out.astype(ct), params["wo"].astype(ct)
    )


def init_kv_cache(cfg: ModelConfig, batch: int, max_seq: int, n_layers: int):
    """Stacked KV cache (n_layers leading dim, for scan) + logical specs."""
    shape = (n_layers, batch, cfg.n_kv_heads, max_seq, cfg.hd)
    axes = ("layers", "batch", "kv_heads", "cache_seq", None)
    cache = {
        "k": jnp.zeros(shape, common.cdtype(cfg)),
        "v": jnp.zeros(shape, common.cdtype(cfg)),
    }
    specs = {"k": axes, "v": axes}
    return cache, specs


def attn_decode(params, x, k_cache, v_cache, pos, cfg: ModelConfig):
    """One-token decode.  x: (B,1,D); k/v_cache: (B,KV,S,hd); pos: scalar.

    Returns (y (B,1,D), k_cache, v_cache) with the caches updated at ``pos``.

    Cache write: a dynamic-update-slice at a traced position along the
    SHARDED sequence dim makes GSPMD replicate the whole cache ("involuntary
    full rematerialization" — tens of GB/device at decode_32k scale).  The
    masked elementwise update below is partitionable in place: each shard
    touches only its own slice (§Perf iteration, cell qwen1.5-32b×decode_32k).
    """
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _project_qkv(params, x, None, positions, positions, cfg)
    # write the new entries at pos (masked update, sharding-preserving)
    seq_iota = jax.lax.broadcasted_iota(jnp.int32, k_cache.shape, 2)
    at_pos = seq_iota == pos
    k_cache = jnp.where(at_pos, k.swapaxes(1, 2).astype(k_cache.dtype),
                        k_cache)
    v_cache = jnp.where(at_pos, v.swapaxes(1, 2).astype(v_cache.dtype),
                        v_cache)
    qg = _group_q(q, cfg.n_kv_heads)  # (B,1,KV,rep,hd)
    scores = jnp.einsum(
        "bsgrh,bgth->bgrst", qg, k_cache,
        preferred_element_type=jnp.float32,
    ) / jnp.sqrt(cfg.hd).astype(jnp.float32)  # (B,KV,rep,1,S)
    t_idx = jnp.arange(k_cache.shape[2])
    mask = t_idx[None, None, None, None, :] <= pos
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bgrst,bgth->bsgrh", probs.astype(v_cache.dtype), v_cache
    )
    out = out.reshape(b, 1, cfg.n_heads, cfg.hd)
    ct = common.cdtype(cfg)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(ct), params["wo"].astype(ct))
    return y, k_cache, v_cache
