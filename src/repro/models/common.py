"""Shared model components: norms, RoPE, param init with logical-axis specs.

Every ``init_*`` returns ``(params, specs)`` where ``specs`` mirrors the
param pytree with tuples of logical axis names (resolved to PartitionSpecs
by repro.sharding.specs).  Forward code is pure jnp; mixed precision policy:
params live in ``cfg.param_dtype``, matmuls run in ``cfg.compute_dtype``,
normalizations/softmax/losses in float32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


def cdtype(cfg: ModelConfig):
    return DTYPES[cfg.compute_dtype]


def pdtype(cfg: ModelConfig):
    return DTYPES[cfg.param_dtype]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, axes, scale=None):
    """Truncated-normal init with fan-in scaling + logical axes."""
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    s = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    w = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * s
    return w.astype(dtype), axes


def zeros_init(shape, dtype, axes):
    return jnp.zeros(shape, dtype), axes


def split_tree(pairs: dict):
    """{'name': (param, axes)} → (params dict, specs dict)."""
    params = {k: v[0] for k, v in pairs.items()}
    specs = {k: v[1] for k, v in pairs.items()}
    return params, specs


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rmsnorm(x, scale, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, scale, bias, eps: float):
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(
        x.dtype
    )


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# cross-entropy (fp32, label smoothing-free, z-loss optional)
# ---------------------------------------------------------------------------
def softmax_cross_entropy(
    logits, labels, weights=None, z_loss_coef: float = 1e-4
):
    """logits (..., V) any dtype → fp32 loss; returns (loss, mean_nll).

    ``weights`` (same shape as labels) masks positions (e.g. VLM image
    slots); the mean is over the weighted token count.

    The label log-prob is picked with a one-hot reduction rather than
    ``take_along_axis``: logits are vocab-sharded (TP) and a gather along
    the sharded axis makes GSPMD all-gather the whole logits tensor
    (~13 GB/device at train_4k scale); the one-hot contraction keeps the
    vocab axis sharded and lowers to a cheap masked psum (§Perf iteration 1).
    """
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    hit = labels[..., None] == jax.lax.broadcasted_iota(
        jnp.int32, lg.shape, lg.ndim - 1
    )
    ll = jnp.sum(jnp.where(hit, lg, 0.0), axis=-1)
    nll = lse - ll
    z = z_loss_coef * (lse**2)
    if weights is None:
        return nll.mean() + z.mean(), nll.mean()
    w = weights.astype(jnp.float32)
    denom = jnp.maximum(w.sum(), 1.0)
    mean_nll = (nll * w).sum() / denom
    return mean_nll + (z * w).sum() / denom, mean_nll
