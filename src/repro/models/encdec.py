"""Whisper-style encoder-decoder (audio family).

The conv/mel frontend is a STUB per the brief: ``input_specs()`` supplies
precomputed frame embeddings (B, n_frames, d_model); the encoder adds
learned positions and runs bidirectional self-attention layers.  The
decoder is a causal stack with cross-attention to the encoder output.
Both stacks are scanned (stacked layer params).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, common, mlp
from repro.models.common import dense_init, split_tree
from repro.models.transformer import apply_norm, init_norm
from repro.sharding.specs import logical_constraint as wsc

N_FRAMES = 1500  # whisper's 30 s @ 50 Hz after the conv frontend


def _stack_init(key, one_layer_fn, n_layers: int):
    spec_box = {}

    def shapes_only(k):
        p, s = one_layer_fn(k)
        spec_box["s"] = s
        return p

    keys = jax.random.split(key, n_layers)
    jax.eval_shape(shapes_only, keys[0])
    params = jax.vmap(lambda k: one_layer_fn(k)[0])(keys)
    specs = jax.tree.map(
        lambda axes: ("layers",) + axes,
        spec_box["s"],
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )
    return params, specs


def _init_enc_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    p, s = {}, {}
    p["ln1"], s["ln1"] = init_norm(cfg)
    p["attn"], s["attn"] = attention.init_attention(ks[0], cfg)
    p["ln2"], s["ln2"] = init_norm(cfg)
    p["mlp"], s["mlp"] = mlp.init_mlp(ks[1], cfg)
    return p, s


def _init_dec_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    p, s = {}, {}
    p["ln1"], s["ln1"] = init_norm(cfg)
    p["self"], s["self"] = attention.init_attention(ks[0], cfg)
    p["ln_x"], s["ln_x"] = init_norm(cfg)
    p["cross"], s["cross"] = attention.init_attention(ks[1], cfg)
    p["ln2"], s["ln2"] = init_norm(cfg)
    p["mlp"], s["mlp"] = mlp.init_mlp(ks[2], cfg)
    return p, s


def init_encdec(key, cfg: ModelConfig):
    ks = jax.random.split(key, 6)
    dt = common.pdtype(cfg)
    maxp = cfg.max_positions or 4096
    pairs = {
        "enc_pos": dense_init(
            ks[0], (N_FRAMES, cfg.d_model), dt, (None, "embed"), scale=0.02
        ),
        "tok_embed": dense_init(
            ks[1], (cfg.vocab, cfg.d_model), dt, ("vocab", "embed"), scale=1.0
        ),
        "dec_pos": dense_init(
            ks[2], (maxp, cfg.d_model), dt, (None, "embed"), scale=0.02
        ),
        "head": dense_init(
            ks[3], (cfg.d_model, cfg.vocab), dt, ("embed", "vocab")
        ),
    }
    params, specs = split_tree(pairs)
    params["enc_ln"], specs["enc_ln"] = init_norm(cfg)
    params["dec_ln"], specs["dec_ln"] = init_norm(cfg)
    params["encoder"], specs["encoder"] = _stack_init(
        ks[4], lambda k: _init_enc_layer(k, cfg), cfg.encoder_layers
    )
    params["decoder"], specs["decoder"] = _stack_init(
        ks[5], lambda k: _init_dec_layer(k, cfg), cfg.n_layers
    )
    return params, specs


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------
def encode(params, frames, cfg: ModelConfig):
    """frames (B, F, D) stub embeddings → (B, F, D) encoder states."""
    ct = common.cdtype(cfg)
    b, f, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32), (b, f))
    x = frames.astype(ct) + params["enc_pos"][None, :f].astype(ct)
    x = wsc(x, ("batch", "seq_sp", "embed"))

    def body(x, lp):
        h = apply_norm(lp["ln1"], x, cfg)
        x = x + attention.attn_forward(
            lp["attn"], h, positions, cfg, causal=False
        )
        h = apply_norm(lp["ln2"], x, cfg)
        x = x + mlp.mlp_forward(lp["mlp"], h, cfg)
        x = wsc(x, ("batch", "seq_sp", "embed"))
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder"], unroll=cfg.scan_unroll)
    return apply_norm(params["enc_ln"], x, cfg)


# ---------------------------------------------------------------------------
# decoder (teacher-forced / prefill)
# ---------------------------------------------------------------------------
def decode_train(
    params, enc_out, tokens, cfg: ModelConfig, collect_cache: bool = False
):
    """Teacher-forced decoder pass.  Returns (logits, caches|None)."""
    ct = common.cdtype(cfg)
    b, s = tokens.shape
    f = enc_out.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    enc_positions = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32), (b, f))
    x = jnp.take(params["tok_embed"], tokens, axis=0).astype(ct)
    x = x + jnp.take(params["dec_pos"], positions, axis=0).astype(ct)
    x = wsc(x, ("batch", "seq_sp", "embed"))

    def body(x, lp):
        cache = None
        h = apply_norm(lp["ln1"], x, cfg)
        if collect_cache:
            y, (k, v) = attention.attn_forward(
                lp["self"], h, positions, cfg, causal=True, return_kv=True
            )
            cache = {"k": k, "v": v}
        else:
            y = attention.attn_forward(
                lp["self"], h, positions, cfg, causal=True
            )
        x = x + y
        h = apply_norm(lp["ln_x"], x, cfg)
        if collect_cache:
            y, (ck, cv) = attention.attn_forward(
                lp["cross"], h, positions, cfg, causal=False,
                kv_x=enc_out, kv_positions=enc_positions, return_kv=True,
            )
            cache.update({"cross_k": ck, "cross_v": cv})
        else:
            y = attention.attn_forward(
                lp["cross"], h, positions, cfg, causal=False,
                kv_x=enc_out, kv_positions=enc_positions,
            )
        x = x + y
        h = apply_norm(lp["ln2"], x, cfg)
        x = x + mlp.mlp_forward(lp["mlp"], h, cfg)
        x = wsc(x, ("batch", "seq_sp", "embed"))
        return x, cache

    if cfg.remat:
        body = jax.checkpoint(body)
    x, caches = jax.lax.scan(
        body, x, params["decoder"], unroll=cfg.scan_unroll
    )
    x = apply_norm(params["dec_ln"], x, cfg)
    logits = jnp.einsum("bsd,dv->bsv", x.astype(ct), params["head"].astype(ct))
    logits = wsc(logits, ("batch", None, "vocab"))
    return logits, caches


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------
def init_encdec_caches(cfg: ModelConfig, batch: int, max_seq: int):
    ct = common.cdtype(cfg)
    nl = cfg.n_layers
    self_shape = (nl, batch, cfg.n_kv_heads, max_seq, cfg.hd)
    cross_shape = (nl, batch, cfg.n_kv_heads, N_FRAMES, cfg.hd)
    self_axes = ("layers", "batch", "kv_heads", "cache_seq", None)
    cross_axes = ("layers", "batch", "kv_heads", None, None)
    caches = {
        "k": jnp.zeros(self_shape, ct),
        "v": jnp.zeros(self_shape, ct),
        "cross_k": jnp.zeros(cross_shape, ct),
        "cross_v": jnp.zeros(cross_shape, ct),
    }
    specs = {
        "k": self_axes, "v": self_axes,
        "cross_k": cross_axes, "cross_v": cross_axes,
    }
    return caches, specs


def encdec_decode(params, caches, token, pos, cfg: ModelConfig):
    """One decode step against self + cross caches."""
    ct = common.cdtype(cfg)
    b = token.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    x = jnp.take(params["tok_embed"], token, axis=0).astype(ct)
    x = x + jnp.take(params["dec_pos"], positions, axis=0).astype(ct)

    def body(x, xs):
        lp, cache = xs
        h = apply_norm(lp["ln1"], x, cfg)
        y, k_c, v_c = attention.attn_decode(
            lp["self"], h, cache["k"], cache["v"], pos, cfg
        )
        x = x + y
        h = apply_norm(lp["ln_x"], x, cfg)
        x = x + attention.cross_attn_cached(
            lp["cross"], h, cache["cross_k"], cache["cross_v"], cfg
        )
        h = apply_norm(lp["ln2"], x, cfg)
        x = x + mlp.mlp_forward(lp["mlp"], h, cfg)
        return x, {
            "k": k_c, "v": v_c,
            "cross_k": cache["cross_k"], "cross_v": cache["cross_v"],
        }

    x, new_caches = jax.lax.scan(
        body, x, (params["decoder"], caches), unroll=cfg.scan_unroll
    )
    x = apply_norm(params["dec_ln"], x, cfg)
    logits = jnp.einsum(
        "bsd,dv->bsv", x.astype(ct), params["head"].astype(ct)
    )[:, 0]
    logits = wsc(logits, ("batch", "vocab"))
    return logits, new_caches
