"""Mamba2 blocks via SSD — state-space duality (arXiv:2405.21060).

Training/prefill uses the chunked SSD algorithm: intra-chunk terms are
attention-like batched einsums (MXU-friendly — this is the whole point of
SSD on TPU), inter-chunk state is a short ``lax.scan`` recurrence over
chunk summaries.  Decode is the O(1) recurrent update.

Shapes: x (B,S,D) → in_proj → [z | xBC | dt]; causal depthwise conv over
xBC; SSD over heads (H = d_inner / head_dim) with G B/C groups of state N.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import dense_init, split_tree
from repro.sharding.specs import logical_constraint as wsc

SSD_CHUNK = 256


def _dims(cfg: ModelConfig):
    d_in = cfg.d_inner
    g, n, p = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_head_dim
    h = cfg.ssm_heads
    conv_ch = d_in + 2 * g * n
    proj_out = 2 * d_in + 2 * g * n + h  # z, xBC, dt
    return d_in, g, n, p, h, conv_ch, proj_out


def init_mamba(key, cfg: ModelConfig):
    d = cfg.d_model
    d_in, g, n, p, h, conv_ch, proj_out = _dims(cfg)
    dt = common.pdtype(cfg)
    ks = jax.random.split(key, 4)
    # dt bias initialized so softplus(dt_bias) spans [1e-3, 1e-1]
    u = jax.random.uniform(ks[2], (h,), jnp.float32)
    dt_init = jnp.log(jnp.expm1(jnp.exp(u * 4.6 - 6.9)))
    pairs = {
        "in_proj": dense_init(ks[0], (d, proj_out), dt, ("fsdp", "mlp")),
        "out_proj": dense_init(ks[1], (d_in, d), dt, ("mlp", "fsdp")),
        "conv_w": (
            0.1
            * jax.random.normal(ks[3], (cfg.ssm_conv, conv_ch), jnp.float32).astype(dt),
            (None, "mlp"),
        ),
        "conv_b": (jnp.zeros((conv_ch,), dt), ("mlp",)),
        "A_log": (jnp.zeros((h,), jnp.float32), ("ssm_heads",)),
        "D": (jnp.ones((h,), jnp.float32), ("ssm_heads",)),
        "dt_bias": (dt_init.astype(jnp.float32), ("ssm_heads",)),
        "norm": (jnp.ones((d_in,), dt), ("mlp",)),
    }
    return split_tree(pairs)


def _segsum(a):
    """a: (..., Q) → (..., Q, Q) cumulative sums over segments i≥j."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x, dt, a_log, b_mat, c_mat, init_state=None, chunk=SSD_CHUNK,
    unroll: bool = False,
):
    """SSD over chunks.

    x: (B,S,H,P) — pre-multiplied inputs (x·dt applied here)
    dt: (B,S,H) — softplus'd step sizes
    a_log: (H,) — A = -exp(a_log)
    b_mat/c_mat: (B,S,G,N); heads are grouped G → H by repetition.
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    rep = h // g
    a = -jnp.exp(a_log)  # (H,)
    da = dt * a  # (B,S,H)
    xd = x * dt[..., None]

    def resh(t_, tail):
        return t_.reshape((bsz, nc, chunk) + tail)

    xc = resh(xd, (h, p))
    dac = resh(da, (h,))
    bc = resh(b_mat, (g, n))
    cc = resh(c_mat, (g, n))
    # broadcast groups → heads
    bh = jnp.repeat(bc, rep, axis=3)  # (B,nc,Q,H,N)
    ch = jnp.repeat(cc, rep, axis=3)

    a_cs = jnp.cumsum(dac, axis=2)  # (B,nc,Q,H)
    # intra-chunk (attention-like) term
    l_mat = jnp.exp(_segsum(dac.transpose(0, 1, 3, 2)))  # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcihn,bcjhn->bchij", ch, bh) * l_mat
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", scores, xc)

    # chunk summary states
    decay_states = jnp.exp(a_cs[:, :, -1:, :] - a_cs)  # (B,nc,Q,H)
    states = jnp.einsum("bcjhn,bcjh,bcjhp->bchpn", bh, decay_states, xc)

    # inter-chunk recurrence over chunk summaries
    a_tot = jnp.exp(a_cs[:, :, -1, :])  # (B,nc,H)

    def scan_fn(prev, inp):
        st, atot = inp  # (B,H,P,N), (B,H)
        new = prev * atot[..., None, None] + st
        return new, prev  # emit the state *entering* the chunk

    init = (
        jnp.zeros((bsz, h, p, n), x.dtype)
        if init_state is None
        else init_state
    )
    final, prev_states = jax.lax.scan(
        scan_fn,
        init,
        (states.swapaxes(0, 1), a_tot.swapaxes(0, 1)),
        unroll=unroll,
    )
    prev_states = prev_states.swapaxes(0, 1)  # (B,nc,H,P,N)

    # inter-chunk output term
    state_decay = jnp.exp(a_cs)  # (B,nc,Q,H)
    y_off = jnp.einsum(
        "bcihn,bchpn,bcih->bcihp", ch, prev_states, state_decay
    )
    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, final


def _conv1d_causal(xbc, w, bias):
    """Depthwise causal conv.  xbc: (B,S,C); w: (K,C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    # sum of shifted slices — avoids conv dilation plumbing, K is tiny (4)
    s = xbc.shape[1]
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(k):
        out = out + pad[:, i : i + s, :].astype(jnp.float32) * w[i].astype(
            jnp.float32
        )
    return (out + bias.astype(jnp.float32)).astype(xbc.dtype)


def _split_proj(proj, cfg: ModelConfig):
    d_in, g, n, p, h, conv_ch, _ = _dims(cfg)
    z = proj[..., :d_in]
    xbc = proj[..., d_in : d_in + conv_ch]
    dt = proj[..., d_in + conv_ch :]
    return z, xbc, dt


def mamba_forward(params, x, cfg: ModelConfig, init_state=None):
    """Train/prefill.  x: (B,S,D) → (B,S,D).

    Returns (y, final_state, conv_tail) where conv_tail is the last K-1
    pre-conv activations (B, K-1, C) — the decode conv cache.
    """
    ct = common.cdtype(cfg)
    d_in, g, n, p, h, conv_ch, _ = _dims(cfg)
    bsz, s, _ = x.shape
    proj = x.astype(ct) @ params["in_proj"].astype(ct)
    z, xbc, dt = _split_proj(proj, cfg)
    k = cfg.ssm_conv
    if s >= k - 1:
        conv_tail = xbc[:, s - (k - 1) :, :]
    else:
        conv_tail = jnp.pad(xbc, ((0, 0), (k - 1 - s, 0), (0, 0)))
    xbc = _conv1d_causal(xbc, params["conv_w"], params["conv_b"])
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :d_in].reshape(bsz, s, h, p)
    b_mat = xbc[..., d_in : d_in + g * n].reshape(bsz, s, g, n)
    c_mat = xbc[..., d_in + g * n :].reshape(bsz, s, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    xs = wsc(xs, ("batch", "seq", "ssm_heads", None))
    y, final = ssd_chunked(
        xs.astype(jnp.float32),
        dt,
        params["A_log"],
        b_mat.astype(jnp.float32),
        c_mat.astype(jnp.float32),
        init_state=init_state,
        chunk=cfg.ssd_chunk,
        unroll=cfg.scan_unroll,
    )
    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bsz, s, d_in).astype(ct)
    y = common.rmsnorm(y * jax.nn.silu(z.astype(ct)), params["norm"], cfg.norm_eps)
    return y @ params["out_proj"].astype(ct), final, conv_tail


def init_mamba_cache(cfg: ModelConfig, batch: int, n_layers: int):
    d_in, g, n, p, h, conv_ch, _ = _dims(cfg)
    cache = {
        "state": jnp.zeros((n_layers, batch, h, p, n), jnp.float32),
        "conv": jnp.zeros(
            (n_layers, batch, cfg.ssm_conv - 1, conv_ch),
            common.cdtype(cfg),
        ),
    }
    specs = {
        "state": ("layers", "batch", "ssm_heads", None, None),
        "conv": ("layers", "batch", None, "mlp"),
    }
    return cache, specs


def mamba_decode(params, x, state, conv_state, cfg: ModelConfig):
    """One-token recurrent update.  x: (B,1,D); state: (B,H,P,N);
    conv_state: (B,K-1,C).  Returns (y, state, conv_state)."""
    ct = common.cdtype(cfg)
    d_in, g, n, p, h, conv_ch, _ = _dims(cfg)
    bsz = x.shape[0]
    proj = x.astype(ct) @ params["in_proj"].astype(ct)  # (B,1,proj)
    z, xbc, dt = _split_proj(proj, cfg)
    xbc = xbc[:, 0]  # (B,C)
    window = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)  # (B,K,C)
    conv_state = window[:, 1:]
    w = params["conv_w"].astype(jnp.float32)  # (K,C)
    conv_out = (window.astype(jnp.float32) * w[None]).sum(axis=1) + params[
        "conv_b"
    ].astype(jnp.float32)
    xbc = jax.nn.silu(conv_out)  # (B,C) fp32
    xs = xbc[:, :d_in].reshape(bsz, h, p)
    b_t = xbc[:, d_in : d_in + g * n].reshape(bsz, g, n)
    c_t = xbc[:, d_in + g * n :].reshape(bsz, g, n)
    rep = h // g
    b_h = jnp.repeat(b_t, rep, axis=1)  # (B,H,N)
    c_h = jnp.repeat(c_t, rep, axis=1)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])  # (H,)
    da = jnp.exp(dtv * a)  # (B,H)
    xdt = xs * dtv[..., None]  # (B,H,P)
    state = state * da[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", xdt, b_h
    )
    y = jnp.einsum("bhpn,bhn->bhp", state, c_h) + params["D"][None, :, None] * xs
    y = y.reshape(bsz, 1, d_in).astype(ct)
    y = common.rmsnorm(
        y * jax.nn.silu(z.astype(ct)), params["norm"], cfg.norm_eps
    )
    return y @ params["out_proj"].astype(ct), state, conv_state
