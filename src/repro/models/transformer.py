"""Unified decoder stack for all assigned decoder-only architectures:
dense GQA (qwen/starcoder), VLM (llava — patch-embedding stub frontend),
MoE (qwen3-moe/grok), SSM (mamba2), and hybrid (jamba).

Layer heterogeneity (jamba's 1-attention-per-8 interleave, MoE on alternate
layers) is expressed as a *layer program*: the smallest repeating period of
slot specs.  Parameters are stacked per slot with a leading ``n_groups``
axis and the whole stack runs as ONE ``lax.scan`` over groups — the lowered
HLO is O(period), not O(n_layers), which keeps 94-layer compiles cheap and
is what makes the 512-device dry-run tractable on this container.

Memory policy: the residual stream between layers is sequence-parallel
(logical axis ``seq_sp`` → ``model``); with ``cfg.remat`` the scan body is
wrapped in ``jax.checkpoint`` so live activations are one layer deep.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, common, mlp, ssm
from repro.models.common import dense_init, split_tree
from repro.sharding.specs import logical_constraint as wsc


@dataclasses.dataclass(frozen=True)
class SlotSpec:
    mixer: str  # "attn" | "mamba"
    mlp: str  # "dense" | "moe" | "none"


def layer_program(cfg: ModelConfig) -> tuple[SlotSpec, ...]:
    """The smallest repeating period of layer kinds."""
    period = 1
    if cfg.attn_every:
        period = cfg.attn_every
    if cfg.n_experts:
        period = period * cfg.moe_every // math.gcd(period, cfg.moe_every)
    slots = []
    for i in range(period):
        mixer = "attn" if cfg.is_attn_layer(i) else "mamba"
        if cfg.family == "ssm":
            m = "none"  # mamba2 blocks are mixer-only
        elif cfg.is_moe_layer(i):
            m = "moe"
        else:
            m = "dense"
        slots.append(SlotSpec(mixer, m))
    return tuple(slots)


def n_groups(cfg: ModelConfig) -> int:
    period = len(layer_program(cfg))
    if cfg.n_layers % period:
        raise ValueError(
            f"{cfg.name}: n_layers={cfg.n_layers} not divisible by "
            f"layer-program period {period}"
        )
    return cfg.n_layers // period


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def init_norm(cfg: ModelConfig):
    dt = common.pdtype(cfg)
    if cfg.norm_type == "layernorm":
        p = {"scale": jnp.ones((cfg.d_model,), dt),
             "bias": jnp.zeros((cfg.d_model,), dt)}
        s = {"scale": ("embed",), "bias": ("embed",)}
    else:
        p = {"scale": jnp.ones((cfg.d_model,), dt)}
        s = {"scale": ("embed",)}
    return p, s


def apply_norm(p, x, cfg: ModelConfig):
    if cfg.norm_type == "layernorm":
        return common.layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    return common.rmsnorm(x, p["scale"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# slot init / forward
# ---------------------------------------------------------------------------
def init_slot(key, spec: SlotSpec, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    p, s = {}, {}
    p["ln1"], s["ln1"] = init_norm(cfg)
    if spec.mixer == "attn":
        p["mix"], s["mix"] = attention.init_attention(ks[0], cfg)
    else:
        p["mix"], s["mix"] = ssm.init_mamba(ks[0], cfg)
    if spec.mlp != "none":
        p["ln2"], s["ln2"] = init_norm(cfg)
        if spec.mlp == "moe":
            p["mlp"], s["mlp"] = mlp.init_moe(ks[1], cfg)
        else:
            p["mlp"], s["mlp"] = mlp.init_mlp(ks[1], cfg)
    return p, s


def init_layer_stack(key, cfg: ModelConfig):
    """All layers, stacked (n_groups leading axis per leaf) for lax.scan."""
    program = layer_program(cfg)
    ng = n_groups(cfg)
    params, specs = {}, {}
    for j, spec in enumerate(program):
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, ng)
        spec_box = {}

        def shapes_only(k, _spec=spec, _box=spec_box):
            p, s = init_slot(k, _spec, cfg)
            _box["s"] = s
            return p

        jax.eval_shape(shapes_only, keys[0])  # captures specs, no compute
        params[f"slot{j}"] = jax.vmap(
            lambda k, _spec=spec: init_slot(k, _spec, cfg)[0]
        )(keys)
        specs[f"slot{j}"] = jax.tree.map(
            lambda axes: ("layers",) + axes,
            spec_box["s"],
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(e, (str, type(None))) for e in x),
        )
    return params, specs


def apply_slot(
    p,
    spec: SlotSpec,
    x,
    positions,
    cfg: ModelConfig,
    collect_cache: bool = False,
):
    """One residual block.  Returns (x, aux_loss, cache_or_None)."""
    h = apply_norm(p["ln1"], x, cfg)
    cache = None
    if spec.mixer == "attn":
        if collect_cache:
            y, (k, v) = attention.attn_forward(
                p["mix"], h, positions, cfg, causal=True, return_kv=True
            )
            cache = {"k": k, "v": v}
        else:
            y = attention.attn_forward(
                p["mix"], h, positions, cfg, causal=True
            )
    else:
        y, final_state, conv_tail = ssm.mamba_forward(p["mix"], h, cfg)
        if collect_cache:
            cache = {"state": final_state, "conv": conv_tail}
    x = x + y
    x = wsc(x, ("batch", "seq_sp", "embed"))
    aux = jnp.zeros((), jnp.float32)
    if spec.mlp != "none":
        h = apply_norm(p["ln2"], x, cfg)
        if spec.mlp == "moe":
            y, aux = mlp.moe_forward(p["mlp"], h, cfg)
        else:
            y = mlp.mlp_forward(p["mlp"], h, cfg)
        x = x + y
        x = wsc(x, ("batch", "seq_sp", "embed"))
    return x, aux, cache


def stack_forward(
    layers, x, positions, cfg: ModelConfig, collect_cache: bool = False
):
    """lax.scan over layer groups.  Returns (x, aux_sum, caches|None).

    ``caches`` (when collected) is {slotJ: pytree with leading n_groups}.
    """
    program = layer_program(cfg)

    def body(carry, lp):
        x, aux = carry
        caches = {}
        for j, spec in enumerate(program):
            x, a, cache = apply_slot(
                lp[f"slot{j}"], spec, x, positions, cfg, collect_cache
            )
            aux = aux + a
            if collect_cache:
                caches[f"slot{j}"] = cache
        return (x, aux), (caches if collect_cache else None)

    if cfg.remat:
        body = jax.checkpoint(body)
    (x, aux), caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), layers,
        unroll=cfg.scan_unroll,
    )
    return x, aux, caches


# ---------------------------------------------------------------------------
# full decoder model
# ---------------------------------------------------------------------------
def init_decoder(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    dt = common.pdtype(cfg)
    pairs = {
        "tok_embed": dense_init(
            ks[0], (cfg.vocab, cfg.d_model), dt, ("vocab", "embed"), scale=1.0
        ),
        "head": dense_init(
            ks[1], (cfg.d_model, cfg.vocab), dt, ("embed", "vocab")
        ),
    }
    if cfg.pos_embed == "learned":
        maxp = cfg.max_positions or 4096
        pairs["pos_embed"] = dense_init(
            ks[2], (maxp, cfg.d_model), dt, (None, "embed"), scale=0.02
        )
    if cfg.n_image_patches:
        # VLM adapter: the anyres frontend is a stub (input_specs supplies
        # projected patch embeddings); mm_proj is the trainable projector.
        pairs["mm_proj"] = dense_init(
            ks[3], (cfg.d_model, cfg.d_model), dt, ("fsdp", None)
        )
    params, specs = split_tree(pairs)
    params["final_ln"], specs["final_ln"] = init_norm(cfg)
    params["layers"], specs["layers"] = init_layer_stack(ks[4], cfg)
    return params, specs


def embed_tokens(params, tokens, positions, cfg: ModelConfig):
    ct = common.cdtype(cfg)
    x = jnp.take(params["tok_embed"], tokens, axis=0).astype(ct)
    if cfg.pos_embed == "learned":
        x = x + jnp.take(params["pos_embed"], positions, axis=0).astype(ct)
    return x


def merge_patches(params, x, patches, cfg: ModelConfig):
    """VLM: image patch embeddings occupy the first n_patches positions."""
    ct = common.cdtype(cfg)
    proj = patches.astype(ct) @ params["mm_proj"].astype(ct)
    npat = cfg.n_image_patches
    s = x.shape[1]
    if npat >= s:
        raise ValueError("sequence shorter than patch count")
    pad = jnp.pad(proj, ((0, 0), (0, s - npat), (0, 0)))
    is_img = (jnp.arange(s) < npat)[None, :, None]
    return jnp.where(is_img, pad, x)


def decoder_forward(
    params,
    tokens,
    cfg: ModelConfig,
    patches=None,
    collect_cache: bool = False,
):
    """tokens (B,S) → (logits (B,S,V), aux_loss, caches|None)."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = embed_tokens(params, tokens, positions, cfg)
    if cfg.n_image_patches and patches is not None:
        x = merge_patches(params, x, patches, cfg)
    x = wsc(x, ("batch", "seq_sp", "embed"))
    x, aux, caches = stack_forward(
        params["layers"], x, positions, cfg, collect_cache
    )
    x = apply_norm(params["final_ln"], x, cfg)
    ct = common.cdtype(cfg)
    logits = jnp.einsum("bsd,dv->bsv", x.astype(ct), params["head"].astype(ct))
    logits = wsc(logits, ("batch", None, "vocab"))
    return logits, aux, caches


# ---------------------------------------------------------------------------
# decode (one token against caches)
# ---------------------------------------------------------------------------
def init_decode_caches(cfg: ModelConfig, batch: int, max_seq: int):
    """Stacked per-slot caches + logical specs (leading n_groups axis)."""
    program = layer_program(cfg)
    ng = n_groups(cfg)
    ct = common.cdtype(cfg)
    d_in, g, n, p_, h, conv_ch, _ = (
        ssm._dims(cfg) if any(s.mixer == "mamba" for s in program) else
        (0,) * 7
    )
    caches, specs = {}, {}
    for j, spec in enumerate(program):
        if spec.mixer == "attn":
            shape = (ng, batch, cfg.n_kv_heads, max_seq, cfg.hd)
            axes = ("layers", "batch", "kv_heads", "cache_seq", None)
            caches[f"slot{j}"] = {
                "k": jnp.zeros(shape, ct),
                "v": jnp.zeros(shape, ct),
            }
            specs[f"slot{j}"] = {"k": axes, "v": axes}
        else:
            caches[f"slot{j}"] = {
                "state": jnp.zeros((ng, batch, h, p_, n), jnp.float32),
                "conv": jnp.zeros(
                    (ng, batch, cfg.ssm_conv - 1, conv_ch), ct
                ),
            }
            specs[f"slot{j}"] = {
                "state": ("layers", "batch", "ssm_heads", None, None),
                "conv": ("layers", "batch", None, "mlp"),
            }
    return caches, specs


def decoder_decode(params, caches, token, pos, cfg: ModelConfig):
    """One decode step.  token (B,1) int32, pos scalar int32 (current index).

    Returns (logits (B,V), new_caches).
    """
    program = layer_program(cfg)
    b = token.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    x = embed_tokens(params, token, positions, cfg)

    def body(x, xs):
        lp, cache = xs
        new_cache = {}
        for j, spec in enumerate(program):
            p = lp[f"slot{j}"]
            h = apply_norm(p["ln1"], x, cfg)
            if spec.mixer == "attn":
                y, k_c, v_c = attention.attn_decode(
                    p["mix"], h, cache[f"slot{j}"]["k"],
                    cache[f"slot{j}"]["v"], pos, cfg,
                )
                new_cache[f"slot{j}"] = {"k": k_c, "v": v_c}
            else:
                y, st, cv = ssm.mamba_decode(
                    p["mix"], h, cache[f"slot{j}"]["state"],
                    cache[f"slot{j}"]["conv"], cfg,
                )
                new_cache[f"slot{j}"] = {"state": st, "conv": cv}
            x = x + y
            if spec.mlp != "none":
                h = apply_norm(p["ln2"], x, cfg)
                if spec.mlp == "moe":
                    y, _ = mlp.moe_forward(p["mlp"], h, cfg)
                else:
                    y = mlp.mlp_forward(p["mlp"], h, cfg)
                x = x + y
        return x, new_cache

    x, new_caches = jax.lax.scan(
        body, x, (params["layers"], caches), unroll=cfg.scan_unroll
    )
    x = apply_norm(params["final_ln"], x, cfg)
    ct = common.cdtype(cfg)
    logits = jnp.einsum(
        "bsd,dv->bsv", x.astype(ct), params["head"].astype(ct)
    )[:, 0]
    logits = wsc(logits, ("batch", "vocab"))
    return logits, new_caches
