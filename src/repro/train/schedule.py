"""Learning-rate schedules (pure jnp — trace-safe inside the train step)."""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    final_frac: float = 0.1  # cosine floor as a fraction of peak


def warmup_cosine(step, cfg: ScheduleConfig):
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = (s - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1
    )
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.final_frac + (1 - cfg.final_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog)
    )
    return cfg.peak_lr * jnp.where(s < cfg.warmup_steps, warm, cos)
