"""Train / serve step functions + the sharding plumbing around them.

``train_step`` is a pure function of (state, batch); the launcher jits it
with NamedShardings resolved from the logical-axis spec trees.  Variants:

* microbatch gradient accumulation (``cfg.microbatches``) via lax.scan,
* int8 error-feedback cross-pod gradient sync (``compress=True``): the
  whole step body runs in a shard_map region where ``pod`` is manual and
  ``data``/``model`` stay automatic (see compress.py).

State layout: ``{"params": ..., "opt": adamw state, "step": i32[,"err"]}``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import model
from repro.train import compress as compress_lib
from repro.train import optimizer as opt_lib
from repro.train.optimizer import AdamWConfig
from repro.train.schedule import ScheduleConfig, warmup_cosine


# ---------------------------------------------------------------------------
# state
# ---------------------------------------------------------------------------
def init_train_state(key, cfg: ModelConfig, ocfg: AdamWConfig,
                     compress: bool = False):
    params, _ = model.init_model(key, cfg)
    state = {
        "params": params,
        "opt": opt_lib.adamw_init(params, ocfg),
        "step": jnp.zeros((), jnp.int32),
    }
    if compress:
        state["err"] = compress_lib.init_error_state(params)
    return state


def abstract_state(cfg: ModelConfig, ocfg: AdamWConfig,
                   compress: bool = False):
    """(ShapeDtypeStruct state tree, logical-axis spec tree) — no compute."""
    box = {}

    def go(key):
        params, specs = model.init_model(key, cfg)
        box["specs"] = specs
        return params

    p_shapes = jax.eval_shape(go, jax.random.PRNGKey(0))
    p_specs = box["specs"]
    o_shapes = jax.eval_shape(lambda p: opt_lib.adamw_init(p, ocfg), p_shapes)
    state_shapes = {
        "params": p_shapes,
        "opt": o_shapes,
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    state_specs = {
        "params": p_specs,
        "opt": opt_lib.opt_state_specs(p_specs, ocfg),
        "step": (),
    }
    if compress:
        state_shapes["err"] = jax.eval_shape(
            compress_lib.init_error_state, p_shapes
        )
        is_axes = lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        )
        state_specs["err"] = jax.tree.map(
            lambda a: a, p_specs, is_leaf=is_axes
        )
    return state_shapes, state_specs


# ---------------------------------------------------------------------------
# gradients (with optional microbatch accumulation)
# ---------------------------------------------------------------------------
def _grads_and_metrics(params, batch, cfg: ModelConfig):
    k = max(cfg.microbatches, 1)
    loss_grad = jax.value_and_grad(model.train_loss, has_aux=True)
    if k == 1:
        (loss, metrics), grads = loss_grad(params, batch, cfg)
        return grads, metrics

    def resh(x):
        b = x.shape[0]
        assert b % k == 0, f"batch {b} not divisible by microbatches {k}"
        return x.reshape((k, b // k) + x.shape[1:])

    micro = jax.tree.map(resh, batch)

    def body(acc, mb):
        (loss, metrics), grads = loss_grad(params, mb, cfg)
        acc = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32) / k, acc, grads
        )
        return acc, metrics

    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    grads, metrics = jax.lax.scan(body, zeros, micro)
    metrics = jax.tree.map(lambda m: m.mean(), metrics)
    return grads, metrics


# ---------------------------------------------------------------------------
# the step
# ---------------------------------------------------------------------------
def train_step(state, batch, cfg: ModelConfig, ocfg: AdamWConfig,
               scfg: ScheduleConfig):
    grads, metrics = _grads_and_metrics(state["params"], batch, cfg)
    lr = warmup_cosine(state["step"], scfg)
    params, opt, gnorm = opt_lib.adamw_update(
        state["params"], grads, state["opt"], lr, ocfg
    )
    metrics = dict(metrics, grad_norm=gnorm, lr=lr)
    return (
        {"params": params, "opt": opt, "step": state["step"] + 1},
        metrics,
    )


def make_compressed_train_step(cfg: ModelConfig, ocfg: AdamWConfig,
                               scfg: ScheduleConfig, mesh):
    """Train step with the pod axis manual + int8 grad sync (compress.py).

    Batch must arrive sharded over ('pod','data') on dim 0; inside the
    region each pod computes grads on its local batch half, then syncs.
    """
    sync, auto, n_pods = compress_lib.make_pod_sync(mesh)

    def body(state, batch):
        grads, metrics = _grads_and_metrics(state["params"], batch, cfg)
        grads, err = sync(grads, state["err"])
        lr = warmup_cosine(state["step"], scfg)
        params, opt, gnorm = opt_lib.adamw_update(
            state["params"], grads, state["opt"], lr, ocfg
        )
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, "pod"), metrics)
        new_state = {
            "params": params, "opt": opt,
            "step": state["step"] + 1, "err": err,
        }
        return new_state, metrics

    # state replicated over pod; err is pod-local (manual) so also P() —
    # each pod keeps its own residual, which is exactly error feedback.
    # `axis_names={"pod"}` makes ONLY the pod axis manual: data/model
    # sharding inside stays automatic (GSPMD).
    return jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), P("pod")),
        out_specs=(P(), P()),
        axis_names=frozenset({"pod"}),
        check_vma=False,
    )


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def serve_prefill(params, batch, cfg: ModelConfig):
    return model.prefill(params, batch, cfg)


def serve_step(params, caches, token, pos, cfg: ModelConfig):
    """One decode step; greedy next token.  → (next_token, logits, caches)."""
    logits, caches = model.decode_step(params, caches, token, pos, cfg)
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    return nxt, logits, caches


# ---------------------------------------------------------------------------
# jit plumbing
# ---------------------------------------------------------------------------
def resolve_shardings(spec_tree, mesh, rules):
    from repro.sharding.specs import tree_shardings

    return tree_shardings(spec_tree, mesh, rules)


def jit_train_step(cfg, ocfg, scfg, mesh, rules, batch_shapes, batch_specs,
                   compress: bool = False):
    """→ (jitted step, state_shapes, state_shardings, batch_shardings)."""
    from repro.sharding.specs import fitted_shardings, use_mesh

    state_shapes, state_specs = abstract_state(cfg, ocfg, compress)
    state_sh = fitted_shardings(state_shapes, state_specs, mesh, rules)
    batch_sh = fitted_shardings(batch_shapes, batch_specs, mesh, rules)

    if compress:
        fn = make_compressed_train_step(cfg, ocfg, scfg, mesh)
    else:
        fn = functools.partial(train_step, cfg=cfg, ocfg=ocfg, scfg=scfg)

    # inside the pod-manual region, constraints must not mention `pod`
    trace_rules = rules.without_axis("pod") if compress else rules

    def traced(state, batch):
        # the mesh context must be live while the model traces (it drives
        # every logical_constraint inside the graph)
        with use_mesh(mesh, trace_rules):
            return fn(state, batch)

    step = jax.jit(
        traced,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
        donate_argnums=(0,),
    )
    return step, state_shapes, state_sh, batch_sh
