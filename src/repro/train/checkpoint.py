"""Sharded checkpointing with elastic resharding + atomic async saves.

Format: one ``.npy`` per pytree leaf (keyed by its tree path) + a JSON
manifest.  Leaves are written as *logical* (unsharded) arrays — on restore
they are ``device_put`` with whatever shardings the *current* mesh resolves
to, so a checkpoint taken on a (2,16,16) mesh restores onto (16,16) or a
1-device CPU mesh unchanged (elastic resharding).  On a real fleet each
host would write its shard slice instead; the manifest/rename protocol is
identical (DESIGN.md §6).

Safety: writes go to ``step_<n>.tmp`` and are renamed only when complete —
a crash mid-save never corrupts the latest checkpoint.  ``keep`` bounds
disk use.  ``async_save`` moves serialization off the training thread.
"""

from __future__ import annotations

import json
import pathlib
import re
import shutil
import threading

import jax
import numpy as np


def _leaf_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "__".join(parts) or "root"


def save_checkpoint(
    ckpt_dir: str | pathlib.Path,
    step: int,
    state,
    keep: int = 3,
    async_save: bool = False,
):
    """Atomically persist ``state`` at ``step``.  Returns the final path
    (or a join()-able thread when ``async_save``)."""
    root = pathlib.Path(ckpt_dir)
    root.mkdir(parents=True, exist_ok=True)
    # device_get on the training thread (cheap, bounded by HBM→host) so the
    # async writer never touches live device buffers
    leaves = jax.tree_util.tree_flatten_with_path(state)[0]
    host = [(_leaf_key(p), np.asarray(jax.device_get(x))) for p, x in leaves]

    def _write():
        tmp = root / f"step_{step}.tmp"
        final = root / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        names = []
        for key, arr in host:
            np.save(tmp / f"{key}.npy", arr)
            names.append(key)
        (tmp / "manifest.json").write_text(
            json.dumps({"step": step, "leaves": names})
        )
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        _gc(root, keep)
        return final

    if async_save:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    return _write()


def _gc(root: pathlib.Path, keep: int):
    steps = sorted(all_steps(root))
    for s in steps[:-keep]:
        shutil.rmtree(root / f"step_{s}", ignore_errors=True)


def all_steps(ckpt_dir) -> list[int]:
    root = pathlib.Path(ckpt_dir)
    out = []
    if not root.exists():
        return out
    for p in root.iterdir():
        m = re.fullmatch(r"step_(\d+)", p.name)
        if m and (p / "manifest.json").exists():
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(
    ckpt_dir, step: int, like, shardings=None
):
    """Restore into the structure of ``like`` (a state pytree or
    ShapeDtypeStructs).  ``shardings`` (same structure) targets the current
    mesh; None leaves arrays on the default device."""
    root = pathlib.Path(ckpt_dir) / f"step_{step}"
    manifest = json.loads((root / "manifest.json").read_text())
    assert manifest["step"] == step
    paths_like = jax.tree_util.tree_flatten_with_path(like)
    leaves, treedef = paths_like
    sh_leaves = (
        jax.tree.leaves(
            shardings,
            is_leaf=lambda x: isinstance(x, jax.sharding.Sharding),
        )
        if shardings is not None
        else [None] * len(leaves)
    )
    out = []
    for (path, ref), sh in zip(leaves, sh_leaves):
        arr = np.load(root / f"{_leaf_key(path)}.npy")
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"checkpoint leaf {_leaf_key(path)}: shape {arr.shape} != "
                f"expected {ref.shape}"
            )
        arr = arr.astype(ref.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else
                   jax.device_put(arr))
    struct = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(struct, out)
