"""AdamW in pure JAX, with optional int8 block-quantized moments.

No optax offline — this is the framework's optimizer.  Two state formats:

* fp32 moments (default): ``{"m": f32, "v": f32}`` per param.
* int8 moments (``eight_bit``): each moment is stored as
  ``{"q": int8 (param shape), "scale": f32 (param.shape[:-1] + (1,))}`` —
  per-row (last-axis block) absmax scaling.  For ≥200B-param models this
  cuts optimizer memory 4× (DESIGN.md §6); scalars/vectors stay fp32.

Weight decay is decoupled (AdamW) and skipped for rank-≤1 params (norm
scales, biases).  Sharding: quantized ``q`` inherits the param's logical
axes; ``scale`` gets axes[:-1] + (None,).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0
    eight_bit: bool = False


# ---------------------------------------------------------------------------
# int8 moment quantization
# ---------------------------------------------------------------------------
def _quantizable(p) -> bool:
    return p.ndim >= 2


def quantize(x: jnp.ndarray):
    """Per-row (last-axis) absmax int8 quantization."""
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def dequantize(qs) -> jnp.ndarray:
    return qs["q"].astype(jnp.float32) * qs["scale"]


def _moment_init(p, eight_bit: bool):
    z = jnp.zeros(p.shape, jnp.float32)
    if eight_bit and _quantizable(p):
        return quantize(z)
    return z

def _moment_get(s) -> jnp.ndarray:
    return dequantize(s) if isinstance(s, dict) else s


def _moment_set(old, new: jnp.ndarray):
    return quantize(new) if isinstance(old, dict) else new


def _is_moment(x) -> bool:
    return isinstance(x, dict) and set(x) == {"q", "scale"}


def adamw_init(params, cfg: AdamWConfig):
    mk = lambda p: _moment_init(p, cfg.eight_bit)
    return {
        "m": jax.tree.map(mk, params),
        "v": jax.tree.map(mk, params),
        "count": jnp.zeros((), jnp.int32),
    }


def opt_state_specs(param_specs, cfg: AdamWConfig):
    """Logical-axis specs mirroring ``adamw_init``'s state tree."""

    def one(axes):
        if cfg.eight_bit and len(axes) >= 2:
            return {"q": axes, "scale": axes[:-1] + (None,)}
        return axes

    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x
    )
    mspec = jax.tree.map(one, param_specs, is_leaf=is_axes)
    return {"m": mspec, "v": mspec, "count": ()}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(
            jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree)
        )
    )


def adamw_update(params, grads, state, lr, cfg: AdamWConfig):
    """One AdamW step.  ``lr`` may be a traced scalar (from the schedule)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.max_grad_norm / (gnorm + 1e-12))

    bc1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m_s, v_s):
        g = g.astype(jnp.float32) * clip
        m = _moment_get(m_s)
        v = _moment_get(v_s)
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled decay, matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, _moment_set(m_s, m), _moment_set(v_s, v)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "count": count}, gnorm
