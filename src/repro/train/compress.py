"""Int8 error-feedback gradient compression for the cross-pod all-reduce.

At 1000+ node scale the pod axis crosses DCN (slow inter-pod links); the
gradient sync over it dominates the collective term.  We compress it:

  * gradients are reduced *within* a pod by XLA SPMD as usual (the ``data``
    and ``model`` axes stay automatic),
  * the ``pod`` axis is made *manual* with ``shard_map(..., axes=...)``:
    each pod quantizes (grad + error-feedback residual) to int8 with one
    fp32 absmax scale per row, ``all_gather``s the int8 payload across pods
    (4× fewer wire bytes than fp32), dequantizes and averages locally, and
    keeps the quantization error as next step's residual.

Error feedback makes the compression unbiased over time (momentum-style
residual correction); the numerics test in tests/test_train.py checks a
compressed run tracks the uncompressed loss curve.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _quant(x):
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _sync_leaf(g, err, n_pods):
    """Per-pod body: returns (synced grad, new error residual)."""
    g32 = g.astype(jnp.float32)
    if g.ndim == 0:  # scalars: plain psum, no quantization
        out = jax.lax.pmean(g32, "pod")
        return out.astype(g.dtype), err
    total = g32 + err
    q, scale = _quant(total)
    deq = q.astype(jnp.float32) * scale
    new_err = total - deq
    qs = jax.lax.all_gather(q, "pod")  # int8 on the wire
    ss = jax.lax.all_gather(scale, "pod")
    summed = jnp.sum(qs.astype(jnp.float32) * ss, axis=0)
    return (summed / n_pods).astype(g.dtype), new_err


def make_pod_sync(mesh):
    """→ sync(grads, err) -> (grads, err), manual over 'pod', auto elsewhere.

    Pass pod-LOCAL gradients (see steps.py: the whole grad computation runs
    under the same manual-pod region so XLA never inserts its own pod
    all-reduce first).
    """
    n_pods = mesh.shape["pod"]
    auto = frozenset(a for a in mesh.axis_names if a != "pod")

    def sync(grads, err):
        pairs = jax.tree.map(
            lambda g, e: _sync_leaf(g, e, n_pods), grads, err
        )
        new_g = jax.tree.map(
            lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple)
        )
        new_e = jax.tree.map(
            lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple)
        )
        return new_g, new_e

    return sync, auto, n_pods


def init_error_state(params):
    """Error-feedback residuals (fp32, param-shaped; scalars excluded)."""
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32) if p.ndim else
        jnp.zeros((), jnp.float32),
        params,
    )


def compressed_wire_bytes(params) -> int:
    """Wire bytes per pod-sync with int8 payloads (for §Roofline)."""
    total = 0
    for p in jax.tree.leaves(params):
        if p.ndim == 0:
            total += 4
        else:
            rows = int(p.size // p.shape[-1])
            total += int(p.size) + 4 * rows  # int8 + fp32 row scales
    return total
