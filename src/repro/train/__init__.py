"""Training substrate: optimizer, schedules, steps, checkpoint, loop."""
