"""The training loop: checkpoint/restart, straggler watch, elastic hooks.

Fault-tolerance contract (exercised by tests/test_fault_tolerance.py and
examples/elastic_restart.py):

* every ``ckpt_every`` steps the full state is saved atomically; a restart
  resumes from the latest manifest — including onto a different mesh
  (checkpoint.py reshards on restore),
* a ``FailureInjector`` can kill the process at a chosen step to prove
  restart-exactness (the loss curve continues bit-identically on resume
  when the data cursor is restored),
* the step-time watchdog flags stragglers (EMA z-score); on a fleet the
  callback re-queues the worker's qd-tree blocks through the elastic block
  scheduler (data/pipeline.py) — completeness makes that handoff
  metadata-only, which is the paper's property paying off at runtime.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator, Optional

import jax
import numpy as np

from repro.train import checkpoint as ckpt_lib


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_keep: int = 3
    ckpt_async: bool = False
    log_every: int = 10
    straggler_z: float = 4.0  # flag steps slower than mean + z·std
    straggler_warmup: int = 10


class FailureInjector:
    """Deterministic failure for restart tests."""

    def __init__(self, fail_at_step: Optional[int] = None):
        self.fail_at_step = fail_at_step

    def maybe_fail(self, step: int):
        if self.fail_at_step is not None and step == self.fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")


@dataclasses.dataclass
class StragglerWatch:
    """EMA step-time watchdog; fires ``on_straggle`` for slow steps."""

    z: float
    warmup: int
    on_straggle: Optional[Callable[[int, float, float], None]] = None
    _n: int = 0
    _mean: float = 0.0
    _m2: float = 0.0
    flagged: int = 0

    def observe(self, step: int, dt: float):
        self._n += 1
        delta = dt - self._mean
        self._mean += delta / self._n
        self._m2 += delta * (dt - self._mean)
        if self._n <= self.warmup:
            return
        std = (self._m2 / max(self._n - 1, 1)) ** 0.5
        if std > 0 and dt > self._mean + self.z * std:
            self.flagged += 1
            if self.on_straggle:
                self.on_straggle(step, dt, self._mean)


def train_loop(
    step_fn,
    state,
    batches: Iterator,
    cfg: LoopConfig,
    failure: Optional[FailureInjector] = None,
    on_straggle=None,
    log=print,
):
    """Run ``step_fn(state, batch) -> (state, metrics)`` to total_steps.

    Resumes from the latest checkpoint in ``cfg.ckpt_dir`` if one exists
    (caller passes an already-restored state in that case — see
    ``maybe_restore``).  Returns (state, history list of metric dicts).
    """
    history = []
    watch = StragglerWatch(
        cfg.straggler_z, cfg.straggler_warmup, on_straggle
    )
    start = int(jax.device_get(state["step"]))
    for step in range(start, cfg.total_steps):
        if failure is not None:
            failure.maybe_fail(step)
        batch = next(batches)
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        watch.observe(step, dt)
        m = {k: float(np.asarray(v)) for k, v in metrics.items()}
        m["step"] = step
        m["wall_s"] = dt
        history.append(m)
        if cfg.log_every and step % cfg.log_every == 0:
            log(
                f"step {step}: loss={m['loss']:.4f} "
                f"lr={m.get('lr', 0):.2e} {dt*1e3:.0f}ms"
            )
        if (
            cfg.ckpt_dir
            and cfg.ckpt_every
            and (step + 1) % cfg.ckpt_every == 0
        ):
            ckpt_lib.save_checkpoint(
                cfg.ckpt_dir, step + 1, state, keep=cfg.ckpt_keep,
                async_save=cfg.ckpt_async,
            )
    if cfg.ckpt_dir:
        ckpt_lib.save_checkpoint(
            cfg.ckpt_dir, cfg.total_steps, state, keep=cfg.ckpt_keep
        )
    return state, history


def maybe_restore(ckpt_dir, abstract_state, shardings=None):
    """→ (state or None, step).  None ⇒ cold start."""
    if ckpt_dir is None:
        return None, 0
    step = ckpt_lib.latest_step(ckpt_dir)
    if step is None:
        return None, 0
    state = ckpt_lib.restore_checkpoint(
        ckpt_dir, step, abstract_state, shardings
    )
    return state, step
