"""Latency and throughput accounting for the serving tier.

The recorder keeps raw per-request latencies (float seconds) so the
benchmark can report exact empirical percentiles rather than histogram
approximations; serving volumes here are small enough (≤ millions of
requests per run) that a flat float64 buffer is the simplest correct
thing.  Timings are *never* pinned in CI — only counters are — so this
module's outputs feed the human-facing columns of ``BENCH_serving.json``.
"""

from __future__ import annotations

import threading

import numpy as np


class LatencyRecorder:
    """Thread-safe append-only latency sample buffer with percentiles."""

    def __init__(self):
        self._lock = threading.Lock()
        self._samples: list[float] = []  # guarded by: self._lock

    def record(self, latency_s: float) -> None:
        with self._lock:
            self._samples.append(float(latency_s))

    def extend(self, latencies_s) -> None:
        with self._lock:
            self._samples.extend(float(v) for v in latencies_s)

    def __len__(self) -> int:
        with self._lock:
            return len(self._samples)

    def percentile(self, q: float) -> float:
        with self._lock:
            if not self._samples:
                return 0.0
            return float(np.percentile(np.asarray(self._samples), q))

    def summary(self) -> dict:
        """count/mean/p50/p90/p99/max in milliseconds (0s when empty)."""
        with self._lock:
            if not self._samples:
                return {
                    "count": 0,
                    "mean_ms": 0.0,
                    "p50_ms": 0.0,
                    "p90_ms": 0.0,
                    "p99_ms": 0.0,
                    "max_ms": 0.0,
                }
            arr = np.asarray(self._samples)
        p50, p90, p99 = np.percentile(arr, (50, 90, 99))
        return {
            "count": int(arr.size),
            "mean_ms": float(arr.mean() * 1e3),
            "p50_ms": float(p50 * 1e3),
            "p90_ms": float(p90 * 1e3),
            "p99_ms": float(p99 * 1e3),
            "max_ms": float(arr.max() * 1e3),
        }


__all__ = ["LatencyRecorder"]
