"""The serving tier: async front end + semantic result cache.

Layers (each its own module, composed by :class:`QueryServer`):

* ``coalescer`` — request admission (bounded queue depth, per-tenant
  fairness) and micro-batch coalescing on a size-or-deadline trigger, so
  the batched-routing win reaches individual async callers;
* ``cache`` — the semantic result cache: routed block-ID lists keyed by
  ``(epoch, exact canonical predicate signature)``, invalidated by the
  serving epoch (generation hot swaps AND in-place tighten bumps);
* ``server`` — the dispatch core tying them to a
  :class:`~repro.service.service.LayoutService`, with the staleness
  audit and workload-tracker observation;
* ``stats`` — latency percentiles for the benchmark surface.
"""

from repro.serve.cache import (
    EXACT_RESOLUTION,
    CacheStats,
    Epoch,
    ResultCache,
    exact_signatures,
)
from repro.serve.coalescer import (
    AdmissionError,
    AdmissionStats,
    QueryTicket,
    RequestQueue,
    ServeConfig,
    ServeResult,
)
from repro.serve.server import QueryServer, ServerCounters
from repro.serve.stats import LatencyRecorder

__all__ = [
    "EXACT_RESOLUTION",
    "AdmissionError",
    "AdmissionStats",
    "CacheStats",
    "Epoch",
    "LatencyRecorder",
    "QueryServer",
    "QueryTicket",
    "RequestQueue",
    "ResultCache",
    "ServeConfig",
    "ServeResult",
    "ServerCounters",
    "exact_signatures",
]
