"""Semantic result cache: routed block IDs keyed by predicate signatures.

The qd-tree's whole value proposition is cutting blocks-accessed-per-query
(paper Eq. 1) — but a repeated predicate re-paid full routing on every
arrival.  The PR 5 canonical predicate signatures are precisely a semantic
cache key: two textually different queries that canonicalize to the same
per-conjunct ``(column, op, bound)`` atom set provably route to the same
``BID IN (...)`` list, so the second one can be answered without touching
the engine at all.

Two deliberate choices keep the cache *sound* (worst-case framing of
arXiv 2405.04984: a cache must never serve block IDs from a retired
layout):

* **Exact canonicalization.**  Cache keys use
  :data:`EXACT_RESOLUTION` buckets — ``bucket_lo/bucket_hi`` degenerate to
  the identity, so a signature captures the query's folded conjunct form
  (numeric box, categorical value sets, cut-visible advanced atoms)
  losslessly.  Equal keys ⇒ equal tensorized form ⇒ bit-identical
  ``query_hits`` — a hit can never alias two queries that route
  differently.  (The tracker's *sketch* signatures stay coarsely bucketed
  on purpose: aggregation wants collisions, a result cache must not.)
* **Epoch-keyed entries.**  Every entry is keyed by the serving
  :class:`~repro.service.epoch.Epoch` ``(generation, desc_version,
  replica_id)``: hot swaps bump the generation
  (:meth:`LayoutService.swap`), in-place tightening bumps the leaf
  description version (``FrozenQdTree.tighten``), and either makes every
  prior entry unreachable — exactly the plan-cache eviction rule, applied
  to results.  Lookups always pass the *live* epoch(s), so a retired
  entry cannot be returned even before :meth:`ResultCache.activate`
  purges it.  Replicated layouts activate one epoch PER replica:
  hot-swapping replica r retires only entries whose epoch carries
  ``replica_id == r`` — the other replicas' results stay warm.
"""

from __future__ import annotations

# qdlint: deterministic-module

import dataclasses
import threading
from collections import OrderedDict
from typing import Optional, Sequence, Union

import numpy as np

from repro.core import query as qry
from repro.core import predicates as preds
from repro.service.epoch import Epoch
from repro.service.tracker import adv_filter_for, query_signatures

# bucket_lo/bucket_hi return bounds unchanged once n_buckets >= the column
# domain; this resolution exceeds any int32 domain, so canonicalization is
# lossless (signatures are fixed points trivially).
EXACT_RESOLUTION = 1 << 62

def _as_epoch(e) -> Epoch:
    """Every cache key carries a real :class:`Epoch` — the legacy
    ``(generation, desc_version)`` tuple coercion (``Epoch.of``) is gone,
    and a tuple would silently key its own namespace (every lookup a
    miss), so reject it loudly instead."""
    if not isinstance(e, Epoch):
        raise TypeError(
            f"expected an Epoch, got {type(e).__name__}; legacy "
            "(generation, desc_version) tuples are no longer coerced"
        )
    return e


def exact_signatures(
    workload: qry.Workload,
    cuts: Optional[preds.CutTable] = None,
    adv_filter: Optional[frozenset] = None,
) -> list[tuple]:
    """Per-query lossless cache keys (PR 5 canonicalization, exact bounds).

    ``cuts`` restricts advanced atoms to the cut table's — the tensorized
    routing path cannot see non-cut advanced atoms, so two queries that
    differ only in one must share a key (they route identically).
    ``adv_filter`` passes a pre-computed filter instead (the replica
    path: the UNION of every replica's cut-visible atoms, so one key
    determines the tensorized form — and hence the cheapest-replica
    choice — on every replica).
    """
    if adv_filter is None:
        adv_filter = adv_filter_for(cuts)
    return query_signatures(
        workload, EXACT_RESOLUTION, adv_filter=adv_filter
    )


@dataclasses.dataclass
class CacheStats:
    """Monotonic counters over one :class:`ResultCache` lifetime."""

    hits: int = 0
    misses: int = 0
    insertions: int = 0
    evictions: int = 0  # LRU capacity pressure
    invalidated: int = 0  # entries purged by an epoch change
    stale_puts: int = 0  # inserts rejected: computed at a retired epoch
    epoch_changes: int = 0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def as_dict(self) -> dict:
        return {
            **dataclasses.asdict(self),
            "lookups": self.lookups,
            "hit_rate": self.hit_rate,
        }


class ResultCache:
    """LRU of routed block-ID lists keyed by ``(epoch, signature)``.

    Thread-safe; values are read-only int32 arrays shared by reference
    (routing results are immutable).  :meth:`activate` pins the cache to
    the live epoch: entries from any other epoch are purged, and inserts
    tagged with a non-live epoch are dropped (``stale_puts``) — a racing
    dispatch that routed on a just-retired generation can never poison
    the cache for the new one.
    """

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self.stats = CacheStats()
        self._lock = threading.Lock()
        self._entries: OrderedDict[tuple, np.ndarray] = OrderedDict()  # guarded by: self._lock
        # one activated epoch per replica_id; pre-replica callers only
        # ever populate slot 0
        self._epochs: dict[int, Epoch] = {}  # guarded by: self._lock

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    @property
    def epoch(self) -> Optional[Epoch]:
        """The primary replica's activated epoch (compat surface)."""
        with self._lock:
            return self._epochs.get(0)

    def epochs(self) -> tuple[Epoch, ...]:
        """Every activated epoch, replica order."""
        with self._lock:
            return tuple(
                self._epochs[r] for r in sorted(self._epochs)
            )

    def activate(
        self, epoch: Union[Epoch, Sequence[Epoch]]
    ) -> int:
        """Pin the cache to ``epoch`` (one Epoch, or a sequence — one per
        replica); purge that replica's entries from any other epoch.

        Returns the number of entries invalidated.  Idempotent for the
        current epoch (the fast path is one compare under the lock).
        Invalidation is replica-scoped: activating a new epoch for
        replica r leaves the other replicas' entries untouched — a hot
        swap of one replica cannot cold-start the rest of the fleet.
        Rollbacks re-activate an *older* generation: its entries were
        purged when it was swapped out, so it simply restarts cold —
        correctness never depends on the purge, only hygiene does,
        because lookups key on the live epoch(s).
        """
        if isinstance(epoch, Epoch):
            epochs = (epoch,)
        else:
            epochs = tuple(_as_epoch(e) for e in epoch)
        invalidated = 0
        with self._lock:
            for e in epochs:
                if self._epochs.get(e.replica_id) == e:
                    continue
                stale = [
                    k for k in self._entries
                    if k[0].replica_id == e.replica_id and k[0] != e
                ]
                for k in stale:
                    del self._entries[k]
                self._epochs[e.replica_id] = e
                self.stats.invalidated += len(stale)
                self.stats.epoch_changes += 1
                invalidated += len(stale)
        return invalidated

    def get(self, epoch: Epoch, sig: tuple) -> Optional[np.ndarray]:
        """The cached block IDs for ``sig`` at ``epoch``, or None."""
        key = (_as_epoch(epoch), sig)
        with self._lock:
            bids = self._entries.get(key)
            if bids is None:
                self.stats.misses += 1
                return None
            self._entries.move_to_end(key)
            self.stats.hits += 1
            return bids

    def get_many(
        self, epoch: Epoch, sigs: list[tuple]
    ) -> list[Optional[np.ndarray]]:
        """Batched :meth:`get`: one lock acquisition for a whole dispatch
        (the cache-hit serving path is lock-bound once signatures are
        memoized, so per-signature locking would dominate it)."""
        return [
            pair[1] if pair is not None else None
            for pair in self.lookup((epoch,), sigs)
        ]

    def lookup(
        self, epochs: Sequence[Epoch], sigs: list[tuple]
    ) -> list[Optional[tuple[Epoch, np.ndarray]]]:
        """Batched multi-replica lookup: for each signature, the first
        hit across ``epochs`` (replica order) as ``(epoch, bids)``, else
        None.  Exactly one hit-or-miss is counted per signature no
        matter how many replicas are live — an entry lives under the
        replica that routed it, so replica order is also cheapest-first
        provenance."""
        keys = tuple(_as_epoch(e) for e in epochs)
        out: list[Optional[tuple[Epoch, np.ndarray]]] = []
        hits = 0
        with self._lock:
            entries = self._entries
            # recency only matters once eviction is in sight; below half
            # capacity the per-hit move_to_end is pure overhead (entries
            # keep insertion order, which is what eviction would use
            # anyway for a cache that never filled)
            touch = 2 * len(entries) > self.capacity
            for sig in sigs:
                found = None
                for e in keys:
                    key = (e, sig)
                    bids = entries.get(key)
                    if bids is not None:
                        if touch:
                            entries.move_to_end(key)
                        found = (e, bids)
                        break
                if found is not None:
                    hits += 1
                out.append(found)
            self.stats.hits += hits
            self.stats.misses += len(sigs) - hits
        return out

    def put(self, epoch: Epoch, sig: tuple, bids: np.ndarray) -> bool:
        """Insert a routed result computed at ``epoch``.

        Returns False (and counts ``stale_puts``) when ``epoch`` is not
        the activated one for its replica — the result was computed
        against a layout that was retired while the dispatch was in
        flight.
        """
        epoch = _as_epoch(epoch)
        value = np.asarray(bids, np.int32)
        value.setflags(write=False)
        with self._lock:
            if self._epochs.get(epoch.replica_id) != epoch:
                self.stats.stale_puts += 1
                return False
            key = (epoch, sig)
            if key not in self._entries:
                self.stats.insertions += 1
            self._entries[key] = value
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.stats.evictions += 1
            return True

    def snapshot(self) -> dict:
        with self._lock:
            primary = self._epochs.get(0)
            return {
                "size": len(self._entries),
                "capacity": self.capacity,
                "epoch": list(primary) if primary else None,
                "replicas": len(self._epochs),
                **self.stats.as_dict(),
            }


__all__ = [
    "EXACT_RESOLUTION",
    "CacheStats",
    "Epoch",
    "ResultCache",
    "exact_signatures",
]
