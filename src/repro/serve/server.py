"""QueryServer: the serving tier's front door over a LayoutService.

Request flow (one dispatch):

    submit ──admission──▶ RequestQueue ──size-or-deadline──▶ _dispatch
        capture live replica set ──▶ one Epoch per replica
        exact signatures ──▶ cache.lookup across the live epochs
        misses (deduped by signature) ──▶ route: ONE route_queries
            dispatch per replica, cheapest replica per query (Eq. 1
            block counts) — a single-replica set degrades to exactly
            one dispatch on the primary engine
        cache.put per unique miss under the CHOSEN replica's epoch
        tracker.record(hits + misses) + tick
        complete tickets (latency, provenance epoch, staleness audit)

Soundness protocol (the worst-case framing of arXiv 2405.04984 — never
serve block IDs from a retired layout):

* the live :class:`~repro.service.replica.ReplicaSet` is read ONCE per
  dispatch attempt; epochs, signatures, cache traffic, and routing all
  use that single capture, so a concurrent hot swap cannot mix
  generations within one dispatch;
* under k > 1 replicas, cache keys use signatures built from the UNION
  of every replica's cut-visible advanced atoms — equal keys then imply
  an identical tensorized form on *every* replica, hence an identical
  cheapest-replica choice, so a hit can never alias two queries that
  would have been routed to different replicas;
* a swap *during* routing is harmless for delivery — the outgoing tree is
  never mutated by a swap, so the routed lists stay bit-identical for
  their generation, and a response is only *stale* if its generation was
  retired before the request was submitted (which cannot happen: dispatch
  always routes the version live at-or-after submit) — but the results
  are NOT cached (and :meth:`ResultCache.put` would reject them anyway
  once the next dispatch re-activates the new epoch);
* in-place tightening (``desc_version`` bump) DOES mutate the live tree,
  so a mid-route bump could yield torn results: the dispatcher re-checks
  the description version after routing and re-dispatches
  (``swap_retries``) against the settled epoch.

Cache hits still record into the :class:`WorkloadTracker` — one
``tracker.record`` per dispatch covers hit and miss queries alike, so
workload inference (and the drift rebuilds it feeds) never goes blind to
cached traffic.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Iterable, Optional

import numpy as np

from repro.core import query as qry
from repro.engine import plan as planlib
from repro.serve.cache import Epoch, ResultCache, exact_signatures
from repro.serve.coalescer import (
    QueryTicket,
    RequestQueue,
    ServeConfig,
    ServeResult,
)
from repro.serve.stats import LatencyRecorder


@dataclasses.dataclass
class ServerCounters:
    """Monotonic dispatch-loop counters (all pinnable in CI — no timings)."""

    dispatches: int = 0  # coalesced batches processed
    engine_dispatches: int = 0  # route_queries calls (miss batches)
    queries_served: int = 0
    queries_cached: int = 0  # answered from the result cache
    queries_routed: int = 0  # unique-signature misses routed by the engine
    swap_retries: int = 0  # re-dispatches after a mid-route epoch move
    uncached_dispatches: int = 0  # delivered-but-not-cached miss batches
    stale_responses: int = 0  # the invariant counter: must stay 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class QueryServer:
    """Admission + coalescing + semantic result cache over a LayoutService.

    Two operating modes share one dispatch core:

    * **async** — :meth:`start` spawns a dispatcher thread; callers
      :meth:`submit` and block on the returned ticket.  This is the
      closed-loop serving mode the benchmark drives for timings.
    * **sync** — without :meth:`start`, :meth:`serve_batch` admits a
      burst and drains the queue inline on the calling thread: fully
      deterministic (no thread scheduling in the counters), which is what
      CI pins.

    The server subscribes to the service's swap notifications so the
    result cache invalidates the moment a new generation goes live,
    rather than at the next dispatch.
    """

    def __init__(
        self,
        service,
        config: Optional[ServeConfig] = None,
        tracker=None,
        clock=time.monotonic,
    ):
        self.service = service
        self.config = config if config is not None else ServeConfig()
        self.tracker = tracker
        self.clock = clock
        self.queue = RequestQueue(self.config, clock=clock)
        self.cache = ResultCache(self.config.cache_capacity)
        self.latency = LatencyRecorder()
        self.counters = ServerCounters()
        self._mutate = threading.Lock()  # counters only
        self._running = False
        self._thread: Optional[threading.Thread] = None
        self._closed = False
        self.cache.activate(service.live_epochs())
        service.subscribe(self._on_swap)

    @staticmethod
    def _epoch_of(live) -> Epoch:
        return Epoch(
            live.generation,
            planlib.desc_version(live.tree),
            getattr(live, "replica_id", 0),
        )

    def _on_swap(self, version) -> None:
        # prompt hygiene purge; soundness never depends on it (lookups key
        # on the epoch captured per dispatch)
        self.cache.activate(self._epoch_of(version))

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "QueryServer":
        """Spawn the background dispatcher thread (idempotent)."""
        if self._running:
            return self
        if self._closed:
            raise RuntimeError("server already stopped")
        self._running = True
        self._thread = threading.Thread(
            target=self._loop, name="qd-serve-dispatch", daemon=True
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        """Stop admitting, drain the dispatcher, fail undispatched tickets."""
        if self._closed:
            return
        self._closed = True
        self._running = False
        drained = self.queue.close()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        err = RuntimeError("server stopped before dispatch")
        for t in drained:
            if not t.done():
                t._fail(err)
                self.queue.release(t)
        self.service.unsubscribe(self._on_swap)

    def __enter__(self) -> "QueryServer":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    def _loop(self) -> None:
        while self._running:
            batch = self.queue.next_batch(timeout=0.05)
            if batch:
                self._dispatch(batch)

    # -- request API ---------------------------------------------------------
    def submit(
        self, query: qry.Query, tenant: str = "default"
    ) -> QueryTicket:
        """Admit one query (raises AdmissionError when bounds are hit)."""
        ticket = self.queue.submit(query, tenant)
        ticket.generation_at_submit = self.service.generation
        ticket.gens_at_submit = self.service.replica_generations()
        return ticket

    def serve(
        self,
        query: qry.Query,
        tenant: str = "default",
        timeout: Optional[float] = None,
    ) -> ServeResult:
        """Submit one query and block for its result (sync convenience)."""
        ticket = self.submit(query, tenant)
        if not self._running:
            self.flush()
        return ticket.result(timeout)

    def serve_batch(
        self, queries: Iterable[qry.Query], tenant: str = "default"
    ) -> list[ServeResult]:
        """Admit a burst and drain it inline — the deterministic path.

        With no dispatcher thread running, every dispatch happens on the
        calling thread in admission order, so cache hit/miss counters are
        exactly reproducible (this is what CI smoke pins).  Safe with a
        running dispatcher too; tickets then complete on either thread.
        """
        # admit without enqueueing: the batch is already formed, so the
        # coalescing deque round-trip would be pure overhead — dispatch
        # the admitted tickets directly in max_batch chunks (the same
        # geometry next_batch would have produced)
        tickets = self.queue.submit_many(queries, tenant, enqueue=False)
        gen = self.service.generation
        gens = self.service.replica_generations()
        for t in tickets:
            t.generation_at_submit = gen
            t.gens_at_submit = gens
        mb = self.config.max_batch
        for i in range(0, len(tickets), mb):
            self._dispatch(tickets[i:i + mb])
        self.flush()  # drain anything submitted concurrently
        return [t.result() for t in tickets]

    def flush(self) -> int:
        """Drain pending requests on the calling thread; returns batches."""
        n = 0
        while True:
            batch = self.queue.next_batch(timeout=0)
            if not batch:
                return n
            self._dispatch(batch)
            n += 1

    def warm(self, sample: qry.Workload) -> None:
        """Compile EVERY live replica's query plans for every coalesced
        dispatch geometry (power-of-two batch sizes up to ``max_batch``,
        queries drawn from ``sample``), so steady-state serving performs
        ZERO retraces — call after construction and after each hot swap
        (the benchmark does; compile cost is swap cost, not serve cost).
        The replica router tensorizes the same miss batch per replica,
        so each replica engine needs its own warm plans.
        """
        rset = self.service.live_replica_set()
        if not len(sample):
            return
        sizes = []
        n = 1
        while n < self.config.max_batch:
            sizes.append(n)
            n *= 2
        sizes.append(self.config.max_batch)
        for n in sizes:
            wl = qry.Workload(
                sample.schema,
                tuple(
                    sample.queries[i % len(sample.queries)]
                    for i in range(n)
                ),
            )
            for v in rset.versions:
                v.engine.query_hits(wl.tensorize(v.tree.cuts))

    # -- the dispatch core ---------------------------------------------------
    def _dispatch(self, tickets: list[QueryTicket]) -> None:
        if not tickets:
            return
        cfg = self.config
        try:
            for attempt in range(cfg.max_swap_retries + 1):
                rset = self.service.live_replica_set()
                live = rset.primary
                epochs = rset.epochs()
                self.cache.activate(epochs)
                wl_all = qry.Workload(
                    live.tree.schema, tuple(t.query for t in tickets)
                )
                if rset.k == 1:
                    sigs = exact_signatures(wl_all, live.tree.cuts)
                else:
                    sigs = exact_signatures(
                        wl_all, adv_filter=rset.adv_filter()
                    )
                found = self.cache.lookup(epochs, sigs)
                miss_index: dict[tuple, int] = {}
                miss_queries: list[qry.Query] = []
                for t, sig, h in zip(tickets, sigs, found):
                    if h is None and sig not in miss_index:
                        miss_index[sig] = len(miss_queries)
                        miss_queries.append(t.query)
                routed: list[np.ndarray] = []
                miss_epochs: list[Epoch] = []
                if miss_queries:
                    miss_wl = qry.Workload(
                        live.tree.schema, tuple(miss_queries)
                    )
                    if rset.k == 1:
                        # tensorize against the captured tree's cuts
                        # directly: one dispatch per miss batch, no
                        # wt-LRU churn from ephemeral per-batch
                        # workload objects
                        routed = live.engine.route_queries(
                            miss_wl.tensorize(live.tree.cuts)
                        )
                        miss_epochs = [epochs[0]] * len(routed)
                        n_dispatches = 1
                    else:
                        routes = rset.route_queries(miss_wl)
                        routed = [r.bids for r in routes]
                        miss_epochs = [epochs[r.replica_id]
                                       for r in routes]
                        n_dispatches = rset.k
                    with self._mutate:
                        self.counters.engine_dispatches += n_dispatches
                        self.counters.queries_routed += len(miss_queries)
                    # a desc_version bump mid-route means some tree's
                    # leaf descriptions were tightened UNDER the
                    # dispatch — results may be torn: re-dispatch
                    torn = any(
                        planlib.desc_version(v.tree) != e.desc_version
                        for v, e in zip(rset.versions, epochs)
                    )
                    if torn:
                        if attempt < cfg.max_swap_retries:
                            with self._mutate:
                                self.counters.swap_retries += 1
                            continue
                swapped = self.service.live_replica_set() is not rset
                torn_now = any(
                    planlib.desc_version(v.tree) != e.desc_version
                    for v, e in zip(rset.versions, epochs)
                )
                if miss_queries and (swapped or torn_now):
                    # deliverable (old trees are immutable across a swap)
                    # but the epoch is retired — never cache retired
                    # results
                    with self._mutate:
                        self.counters.uncached_dispatches += 1
                else:
                    for sig, i in miss_index.items():
                        self.cache.put(miss_epochs[i], sig, routed[i])
                self._record(wl_all, live)
                self._complete(tickets, sigs, found, routed, miss_index,
                               miss_epochs)
                return
        except BaseException as e:
            for t in tickets:
                if not t.done():
                    t._fail(e)
                    self.queue.release(t)

    def _record(self, wl_all: qry.Workload, live) -> None:
        """Tracker observation: hits and misses alike, one round per
        ``tick_every`` dispatches."""
        with self._mutate:
            self.counters.dispatches += 1
            n = self.counters.dispatches
        if self.tracker is None:
            return
        self.tracker.record(wl_all, cuts=live.tree.cuts)
        if self.config.tick_every and n % self.config.tick_every == 0:
            self.tracker.tick()

    def _complete(self, tickets, sigs, found, routed, miss_index,
                  miss_epochs):
        done_at = self.clock()
        live_gens = self.service.replica_generations()
        n_cached = 0
        n_stale = 0
        latencies = []
        for t, sig, h in zip(tickets, sigs, found):
            cached = h is not None
            if cached:
                epoch, bids = h
            else:
                i = miss_index[sig]
                epoch, bids = miss_epochs[i], routed[i]
            lat = done_at - t.submitted_at
            n_cached += cached
            latencies.append(lat)
            # the audit, per replica: a response is stale iff the serving
            # replica's generation was retired BEFORE the request was
            # submitted (rollback re-liveness is not staleness — the
            # generation is serving again)
            rid = epoch.replica_id
            gat = t.gens_at_submit
            gen_at_submit = (
                gat[rid] if gat is not None and rid < len(gat)
                else t.generation_at_submit
            )
            live_gen_now = (
                live_gens[rid] if rid < len(live_gens) else live_gens[0]
            )
            if epoch.generation < gen_at_submit and (
                epoch.generation != live_gen_now
            ):
                n_stale += 1
            t._complete(ServeResult(
                bids=bids,
                generation=epoch.generation,
                desc_version=epoch.desc_version,
                cached=cached,
                latency_s=lat,
                replica_id=rid,
            ))
        with self._mutate:
            self.counters.queries_served += len(tickets)
            self.counters.queries_cached += n_cached
            self.counters.stale_responses += n_stale
        self.latency.extend(latencies)
        self.queue.release_many(tickets)

    # -- stats surface -------------------------------------------------------
    def stats(self) -> dict:
        return {
            "queue_depth": len(self.queue),
            "epoch": list(self.cache.epoch) if self.cache.epoch else None,
            "admission": self.queue.stats.as_dict(),
            "cache": self.cache.snapshot(),
            "latency": self.latency.summary(),
            "counters": self.counters.as_dict(),
        }


__all__ = ["QueryServer", "ServerCounters"]
