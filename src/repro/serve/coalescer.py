"""Request admission and micro-batch coalescing for the serving tier.

One query at a time through ``route_queries`` wastes the batching win PR 2
measured (≥5× over the per-query loop): the jitted intersection kernel
amortizes per-dispatch cost across a whole workload.  The coalescer turns
an *asynchronous* stream of individual requests back into batched
dispatches on a size-or-deadline trigger:

* :meth:`RequestQueue.submit` admits a request (bounded queue depth, a
  per-tenant in-flight bound for fairness) and returns a
  :class:`QueryTicket` the caller blocks on;
* the server's dispatcher thread pulls coalesced batches with
  :meth:`RequestQueue.next_batch`: it dispatches as soon as ``max_batch``
  requests are waiting, or when the oldest waiting request has been
  pending ``max_delay_s`` — so a lone query's latency is bounded while a
  burst rides one compiled dispatch.

Admission failures raise :class:`AdmissionError` *at submit time* — load
is shed at the front door, before any routing work is queued, and the
counters distinguish queue-full from tenant-over-fair-share rejections.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Iterable, Optional

import numpy as np

from repro.core import query as qry
from repro.service.epoch import Epoch


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Admission + coalescing + cache policy for one :class:`QueryServer`.

    max_batch       coalesced dispatch size trigger (requests per
                    ``route_queries`` dispatch).
    max_delay_s     deadline trigger: a waiting request is dispatched at
                    most this long after it became the oldest pending one.
    max_queue       bound on queued (admitted, not yet dispatched)
                    requests; submits past it are rejected.
    max_per_tenant  per-tenant in-flight bound (queued + dispatching):
                    one greedy tenant saturating the queue cannot starve
                    admission for the others.
    cache_capacity  :class:`~repro.serve.cache.ResultCache` LRU entries.
    tick_every      serving rounds (dispatches) per tracker decay
                    generation; 0 disables ticking (record-only).
    max_swap_retries  re-dispatch attempts when a hot swap lands while a
                    miss batch is routing (each retry re-captures the
                    live version and re-routes).
    """

    max_batch: int = 64
    max_delay_s: float = 0.002
    max_queue: int = 1024
    max_per_tenant: int = 256
    cache_capacity: int = 4096
    tick_every: int = 1
    max_swap_retries: int = 8

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.max_delay_s < 0:
            raise ValueError("max_delay_s must be >= 0")
        if self.max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        if self.max_per_tenant < 1:
            raise ValueError("max_per_tenant must be >= 1")
        if self.tick_every < 0:
            raise ValueError("tick_every must be >= 0")
        if self.max_swap_retries < 0:
            raise ValueError("max_swap_retries must be >= 0")


class AdmissionError(RuntimeError):
    """A submit was rejected at the front door.

    ``reason`` is ``"queue"`` (global depth bound) or ``"tenant"``
    (per-tenant fairness bound).
    """

    def __init__(self, reason: str, message: str):
        super().__init__(message)
        self.reason = reason


@dataclasses.dataclass(slots=True)
class ServeResult:
    """One served query's answer, tagged with its provenance.

    ``generation``/``desc_version``/``replica_id`` identify the layout
    epoch the block IDs were computed against — the staleness audit
    trail: a response whose generation was retired *before* the request
    was submitted is a stale read, and the serving tier's contract is
    that this never happens.  Under a replica set, ``replica_id`` names
    which replica the cheapest-replica router picked.  Treat instances
    as read-only (``slots`` instead of ``frozen``: one of these is
    allocated per served query, and frozen dataclasses pay
    ``object.__setattr__`` per field on the hit path).
    """

    bids: np.ndarray  # read-only (n,) int32 block IDs
    generation: int
    desc_version: int
    cached: bool
    latency_s: float
    replica_id: int = 0

    @property
    def epoch(self) -> Epoch:
        return Epoch(self.generation, self.desc_version, self.replica_id)


# Guards only the lazy wait-event creation below — never on the
# completion fast path, so it is uncontended except when a caller
# genuinely blocks across threads.
_TICKET_EVENT_LOCK = threading.Lock()


class QueryTicket:
    """The caller's handle on one admitted request (a tiny future).

    The wait event is LAZY: the sync serving path (``serve_batch``)
    completes every ticket before anyone waits, and allocating a
    ``threading.Event`` (lock + condition) per request was the single
    biggest cost on the cache-hit path.  Completion publishes the result
    and then flips ``_finished``; a waiter that finds ``_finished`` unset
    materializes the event under :data:`_TICKET_EVENT_LOCK` and re-checks
    before blocking (Dekker-style store/load ordering — sound under the
    GIL's per-bytecode atomicity), so a completion racing the event's
    creation can never strand the waiter.
    """

    __slots__ = (
        "query", "tenant", "submitted_at", "generation_at_submit",
        "gens_at_submit",
        "_event", "_finished", "_result", "_error",
    )

    def __init__(self, query: qry.Query, tenant: str, submitted_at: float):
        self.query = query
        self.tenant = tenant
        self.submitted_at = submitted_at
        self.generation_at_submit: int = -1  # stamped by the server
        # per-replica generations live at submit time (stamped by the
        # server when a replica set is serving); None when unstamped
        self.gens_at_submit: Optional[tuple[int, ...]] = None
        self._event: Optional[threading.Event] = None
        self._finished = False
        self._result: Optional[ServeResult] = None
        self._error: Optional[BaseException] = None

    def _finish(self) -> None:
        self._finished = True  # AFTER the result/error store: flag implies
        ev = self._event       # the payload is visible
        if ev is not None:
            ev.set()

    def _complete(self, result: ServeResult) -> None:
        self._result = result
        self._finish()

    def _fail(self, error: BaseException) -> None:
        self._error = error
        self._finish()

    def done(self) -> bool:
        return self._finished

    def result(self, timeout: Optional[float] = None) -> ServeResult:
        """Block until served; raises on timeout or server-side failure."""
        if not self._finished:
            with _TICKET_EVENT_LOCK:
                ev = self._event
                if ev is None:
                    ev = self._event = threading.Event()
                if self._finished:
                    # completion raced the event's creation and may have
                    # read ``_event`` as None — don't wait on it
                    ev.set()
            if not ev.wait(timeout):
                raise TimeoutError("query not served within timeout")
        if self._error is not None:
            raise self._error
        assert self._result is not None
        return self._result


@dataclasses.dataclass
class AdmissionStats:
    accepted: int = 0
    rejected_queue: int = 0
    rejected_tenant: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class RequestQueue:
    """Bounded pending-request queue with per-tenant fairness accounting.

    In-flight (queued + currently dispatching) counts are per tenant;
    :meth:`release` returns capacity when a request completes, so the
    fairness bound tracks genuinely outstanding work, not arrival history.
    """

    def __init__(self, config: ServeConfig, clock=time.monotonic):
        self.config = config
        self.clock = clock
        self.stats = AdmissionStats()
        self._lock = threading.Lock()
        self._nonempty = threading.Condition(self._lock)
        self._pending: deque[QueryTicket] = deque()  # guarded by: self._lock
        self._inflight: dict[str, int] = {}  # guarded by: self._lock
        self._closed = False  # guarded by: self._lock

    def __len__(self) -> int:
        with self._lock:
            return len(self._pending)

    def inflight(self, tenant: str) -> int:
        with self._lock:
            return self._inflight.get(tenant, 0)

    def submit(self, query: qry.Query, tenant: str = "default") -> QueryTicket:
        """Admit one request or raise :class:`AdmissionError`."""
        with self._lock:
            if self._closed:
                raise RuntimeError("queue is closed")
            if len(self._pending) >= self.config.max_queue:
                self.stats.rejected_queue += 1
                raise AdmissionError(
                    "queue",
                    f"queue depth {len(self._pending)} at bound "
                    f"{self.config.max_queue}",
                )
            held = self._inflight.get(tenant, 0)
            if held >= self.config.max_per_tenant:
                self.stats.rejected_tenant += 1
                raise AdmissionError(
                    "tenant",
                    f"tenant {tenant!r} holds {held} in-flight requests "
                    f"(bound {self.config.max_per_tenant})",
                )
            ticket = QueryTicket(query, tenant, self.clock())
            self._pending.append(ticket)
            self._inflight[tenant] = held + 1
            self.stats.accepted += 1
            self._nonempty.notify()
            return ticket

    def submit_many(
        self,
        queries: Iterable[qry.Query],
        tenant: str = "default",
        *,
        enqueue: bool = True,
    ) -> list[QueryTicket]:
        """Admit a burst under ONE lock acquisition.

        Identical semantics to a :meth:`submit` loop — same per-request
        bounds, raises on the first rejection with the already-admitted
        prefix kept — minus the per-request lock traffic that would
        otherwise dominate the cache-hit serving path.

        ``enqueue=False`` admits the burst (bounds, in-flight accounting,
        admission stats) WITHOUT appending it to the pending queue: the
        caller takes responsibility for dispatching the returned tickets
        (and they must still be :meth:`release_many`-d).  This is the sync
        ``serve_batch`` path — the batch is already formed, so routing it
        through the coalescing deque would be pure overhead.
        """
        tickets: list[QueryTicket] = []
        with self._lock:
            if self._closed:
                raise RuntimeError("queue is closed")
            now = self.clock()
            cfg = self.config
            try:
                held = self._inflight.get(tenant, 0)
                depth = len(self._pending)
                for query in queries:
                    if depth >= cfg.max_queue:
                        self.stats.rejected_queue += 1
                        raise AdmissionError(
                            "queue",
                            f"queue depth {depth} at bound "
                            f"{cfg.max_queue}",
                        )
                    if held >= cfg.max_per_tenant:
                        self.stats.rejected_tenant += 1
                        raise AdmissionError(
                            "tenant",
                            f"tenant {tenant!r} holds {held} in-flight "
                            f"requests (bound {cfg.max_per_tenant})",
                        )
                    tickets.append(QueryTicket(query, tenant, now))
                    depth += 1
                    held += 1
            finally:
                if tickets:
                    if enqueue:
                        self._pending.extend(tickets)
                        self._nonempty.notify()
                    self._inflight[tenant] = held
                    self.stats.accepted += len(tickets)
        return tickets

    def release(self, ticket: QueryTicket) -> None:
        """Return the ticket's tenant slot (request left the system)."""
        with self._lock:
            held = self._inflight.get(ticket.tenant, 0)
            if held <= 1:
                self._inflight.pop(ticket.tenant, None)
            else:
                self._inflight[ticket.tenant] = held - 1

    def release_many(self, tickets: Iterable[QueryTicket]) -> None:
        """Batched :meth:`release`: one lock acquisition per dispatch."""
        with self._lock:
            for ticket in tickets:
                held = self._inflight.get(ticket.tenant, 0)
                if held <= 1:
                    self._inflight.pop(ticket.tenant, None)
                else:
                    self._inflight[ticket.tenant] = held - 1

    def next_batch(
        self, timeout: Optional[float] = None
    ) -> list[QueryTicket]:
        """Block for the next coalesced batch (size-or-deadline trigger).

        Returns up to ``max_batch`` tickets: immediately once
        ``max_batch`` are pending, otherwise when the oldest pending
        ticket has waited ``max_delay_s``.  An empty list means the
        ``timeout`` expired (or the queue closed) with nothing pending.
        """
        cfg = self.config
        deadline = None if timeout is None else self.clock() + timeout
        with self._lock:
            while not self._pending:
                if self._closed:
                    return []
                wait = (
                    None if deadline is None else deadline - self.clock()
                )
                if wait is not None and wait <= 0:
                    return []
                self._nonempty.wait(wait)
            # coalesce: hold the door open until the batch fills or the
            # oldest waiter's deadline arrives
            dispatch_at = self._pending[0].submitted_at + cfg.max_delay_s
            while (
                len(self._pending) < cfg.max_batch and not self._closed
            ):
                wait = dispatch_at - self.clock()
                if wait <= 0:
                    break
                self._nonempty.wait(wait)
            batch = []
            while self._pending and len(batch) < cfg.max_batch:
                batch.append(self._pending.popleft())
            return batch

    def close(self) -> list[QueryTicket]:
        """Stop admitting; drain and return whatever was still pending."""
        with self._lock:
            self._closed = True
            drained = list(self._pending)
            self._pending.clear()
            self._nonempty.notify_all()
            return drained


__all__ = [
    "AdmissionError",
    "AdmissionStats",
    "QueryTicket",
    "RequestQueue",
    "ServeConfig",
    "ServeResult",
]
