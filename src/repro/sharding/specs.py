"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Models annotate every weight and key activation with *logical* axis names;
``Rules`` maps logical names to mesh axes; resolution drops mesh axes that
don't exist (so the same model code runs on the single-pod (data, model)
mesh, the multi-pod (pod, data, model) mesh, and the 1-device CPU smoke
mesh).  ``logical_constraint`` applies ``with_sharding_constraint`` only
when a mesh context is active, so model code stays mesh-agnostic.

Default placement (DESIGN.md §6):
  * weights: FSDP along ``fsdp``→data, tensor-parallel along heads/mlp/
    vocab/experts→model; ``pod`` is pure data parallel.
  * activations: batch over (pod, data); residual-stream seq over model
    (Megatron-style sequence parallelism) — attention/MLP interiors are
    head-/ff-sharded instead.
  * KV caches: batch over data, kv-heads over model; the 512k decode cells
    override to sequence-sharded caches.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


DEFAULT_RULES: dict[str, tuple[str, ...] | None] = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_sp": ("model",),  # sequence-parallel residual stream
    "embed": None,
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": None,
    "mlp": ("model",),
    "vocab": ("model",),
    "fsdp": ("data",),
    "experts": ("model",),
    "expert_cap": ("data",),
    "ssm_heads": ("model",),
    "state": None,
    "cache_seq": None,
    "frames": None,
    "layers": None,
    "conv": None,
    "patches": None,
    None: None,
}

# per-shape overrides (keyed by input-shape name) — see launch/shapes.py
LONG_CONTEXT_OVERRIDES = {
    "batch": None,  # batch=1: don't shard
    # shard the 512k KV/conv cache over sequence, as many ways as divide
    "cache_seq": ("pod", "data", "model"),
    "seq_sp": ("model",),
}


@dataclasses.dataclass(frozen=True)
class Rules:
    table: tuple[tuple[str | None, tuple[str, ...] | None], ...]

    @staticmethod
    def make(overrides: dict | None = None) -> "Rules":
        t = dict(DEFAULT_RULES)
        if overrides:
            t.update(overrides)
        return Rules(table=tuple(t.items()))

    def lookup(self, name: str | None) -> tuple[str, ...] | None:
        for k, v in self.table:
            if k == name:
                return v
        raise KeyError(f"unknown logical axis {name!r}")

    def without_axis(self, axis: str) -> "Rules":
        """Strip a mesh axis from every rule (for manual shard_map regions,
        where constraints must not mention the manual axis)."""
        table = []
        for k, v in self.table:
            if v is not None:
                v = tuple(a for a in v if a != axis) or None
            table.append((k, v))
        return Rules(table=tuple(table))

    def spec(self, logical_axes: tuple, mesh: Mesh) -> P:
        """Resolve logical axes to a PartitionSpec on ``mesh``."""
        parts = []
        used: set[str] = set()
        for name in logical_axes:
            axes = self.lookup(name)
            if axes is None:
                parts.append(None)
                continue
            present = tuple(
                a for a in axes if a in mesh.axis_names and a not in used
            )
            used.update(present)
            if not present:
                parts.append(None)
            elif len(present) == 1:
                parts.append(present[0])
            else:
                parts.append(present)
        return P(*parts)


# ---------------------------------------------------------------------------
# Mesh context — models call logical_constraint without threading a mesh
# ---------------------------------------------------------------------------
class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[Rules] = None


_CTX = _Ctx()


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Rules):
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def active_rules() -> Optional[Rules]:
    return _CTX.rules


def logical_constraint(x, logical_axes: tuple):
    """with_sharding_constraint by logical names; no-op without a mesh."""
    if _CTX.mesh is None or _CTX.rules is None:
        return x
    spec = _CTX.rules.spec(logical_axes, _CTX.mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, spec)
    )


def named_sharding(logical_axes: tuple, mesh=None, rules=None):
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    assert mesh is not None and rules is not None, "no active mesh context"
    return NamedSharding(mesh, rules.spec(logical_axes, mesh))


def tree_shardings(spec_tree, mesh: Mesh, rules: Rules):
    """Map a pytree of logical-axes tuples to NamedShardings."""
    return jax.tree.map(
        lambda axes: NamedSharding(mesh, rules.spec(axes, mesh)),
        spec_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )


def _is_axes(x) -> bool:
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x
    )


def fitted_spec(shape: tuple, logical_axes: tuple, mesh: Mesh,
                rules: Rules) -> P:
    """Resolve logical axes, pruning mesh axes that don't divide the dim.

    jit input shardings must divide exactly (unlike intermediate
    constraints, which GSPMD pads).  Per dim we keep the longest prefix of
    the rule's mesh axes whose size product divides the dimension — e.g. a
    2-head KV projection on a 16-way ``model`` axis falls back to
    replication, and a 512k cache_seq rule ("pod","data","model") uses as
    many axes as divide.
    """
    if len(shape) != len(logical_axes):
        raise ValueError(
            f"rank mismatch: shape {shape} vs axes {logical_axes}"
        )
    parts = []
    used: set[str] = set()
    for dim, name in zip(shape, logical_axes):
        axes = rules.lookup(name)
        kept: list[str] = []
        if axes:
            size = 1
            for a in axes:
                if a not in mesh.axis_names or a in used:
                    continue
                nxt = size * mesh.shape[a]
                if dim % nxt == 0:
                    kept.append(a)
                    size = nxt
                else:
                    break
        used.update(kept)
        if not kept:
            parts.append(None)
        elif len(kept) == 1:
            parts.append(kept[0])
        else:
            parts.append(tuple(kept))
    return P(*parts)


def fitted_shardings(shape_tree, spec_tree, mesh: Mesh, rules: Rules):
    """NamedShardings for jit inputs: shape-aware, divisibility-safe."""
    return jax.tree.map(
        lambda sds, axes: NamedSharding(
            mesh, fitted_spec(tuple(sds.shape), axes, mesh, rules)
        ),
        shape_tree,
        spec_tree,
        is_leaf=lambda x: _is_axes(x) or hasattr(x, "shape"),
    )
