"""Data substrate: synthetic corpora, workloads, block store, pipeline."""

from repro.data.datagen import (  # noqa: F401
    make_errorlog_ext,
    make_errorlog_int,
    make_tpch_like,
)
from repro.data.workload import (  # noqa: F401
    make_errorlog_ext_workload,
    make_errorlog_int_workload,
    make_tpch_workload,
)
from repro.data.blocks import BlockStore, ScanResult  # noqa: F401
from repro.data.pipeline import (  # noqa: F401
    ElasticBlockScheduler,
    PipelineConfig,
    QdTreePipeline,
    records_to_tokens,
)
