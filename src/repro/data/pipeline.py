"""LM-training data pipeline on qd-tree blocks + elastic block scheduler.

This is where the paper's layout engine becomes a first-class feature of the
training framework (DESIGN.md §2): a *curation query* (mixture filter over
record metadata) selects training data; the qd-tree prunes the block set up
front, so workers never read non-matching blocks.  Blocks — having semantic
descriptions + completeness — are also the unit of data-parallel work
assignment, giving us:

  * straggler mitigation: a slow worker's unread blocks are re-queued and
    stolen by finished peers (handoff is metadata-only),
  * elastic scaling: the scheduler re-balances outstanding blocks when
    workers join/leave,
  * deterministic resume: (epoch, block-cursor) pairs are checkpointable.
"""

from __future__ import annotations

# qdlint: deterministic-module

import dataclasses
import threading
from collections import deque
from typing import Iterator, Optional

import numpy as np

from repro.core import query as qry
from repro.data.blocks import BlockStore


# ---------------------------------------------------------------------------
# Elastic block scheduler
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SchedulerState:
    epoch: int
    pending: list[int]  # block ids not yet handed out
    inflight: dict[int, list[int]]  # worker -> blocks handed out, unacked
    done: list[int]


class ElasticBlockScheduler:
    """Assigns qd-tree blocks to data-parallel workers with work stealing.

    The scheduler is deliberately tiny and deterministic: a shared pending
    deque (shuffled per epoch with a seeded RNG), per-worker in-flight sets,
    and three events — ``next_block`` (pull), ``ack`` (block consumed),
    ``fail`` (worker lost ⇒ its in-flight blocks are re-queued).  At fleet
    scale this runs on the coordinator; workers only pull BIDs.
    """

    def __init__(self, block_ids: list[int], seed: int = 0):
        self._all = list(block_ids)
        self._seed = seed
        self._lock = threading.Lock()
        self._epoch = -1  # guarded by: self._lock
        self._pending: deque[int] = deque()  # guarded by: self._lock
        self._inflight: dict[int, set[int]] = {}  # guarded by: self._lock
        self._done: set[int] = set()  # guarded by: self._lock
        self._start_epoch(0)

    def _start_epoch(self, epoch: int) -> None:  # qdlint: holds-lock
        rng = np.random.default_rng(self._seed + epoch)
        order = np.array(self._all)
        rng.shuffle(order)
        self._epoch = epoch
        self._pending = deque(int(b) for b in order)
        self._inflight = {}
        self._done = set()

    @property
    def epoch(self) -> int:
        with self._lock:
            return self._epoch

    def next_block(self, worker: int) -> Optional[int]:
        """Pull the next block for ``worker``; None ⇒ epoch exhausted."""
        with self._lock:
            if not self._pending:
                return None
            b = self._pending.popleft()
            self._inflight.setdefault(worker, set()).add(b)
            return b

    def ack(self, worker: int, block: int) -> None:
        with self._lock:
            self._inflight.get(worker, set()).discard(block)
            self._done.add(block)
            if (
                not self._pending
                and not any(self._inflight.values())
                and len(self._done) == len(self._all)
            ):
                self._start_epoch(self._epoch + 1)

    def fail(self, worker: int) -> list[int]:
        """Worker lost: re-queue its unacked blocks (straggler mitigation)."""
        with self._lock:
            lost = sorted(self._inflight.pop(worker, set()))
            # stolen blocks go to the FRONT so they finish soonest
            self._pending.extendleft(reversed(lost))
            return lost

    def outstanding(self) -> int:
        with self._lock:
            return len(self._pending) + sum(
                len(v) for v in self._inflight.values()
            )

    # -- checkpointing --------------------------------------------------------
    def state(self) -> SchedulerState:
        with self._lock:
            return SchedulerState(
                epoch=self._epoch,
                pending=list(self._pending),
                inflight={k: sorted(v) for k, v in self._inflight.items()},
                done=sorted(self._done),
            )

    def restore(self, st: SchedulerState) -> None:
        with self._lock:
            self._epoch = st.epoch
            # in-flight blocks of a restored run are treated as pending again
            refill = [b for v in st.inflight.values() for b in v]
            self._pending = deque(refill + list(st.pending))
            self._inflight = {}
            self._done = set(st.done)


# ---------------------------------------------------------------------------
# Tokenization of records (synthetic — records become token sequences)
# ---------------------------------------------------------------------------
def records_to_tokens(
    rows: np.ndarray, seq_len: int, vocab: int, seed: int = 0
) -> np.ndarray:
    """Deterministic record → token-sequence expansion.

    Real deployments would read a text payload column; offline we derive a
    reproducible pseudo-corpus by seeding a Philox stream with each row's
    hash, so tests can assert exact batch equality across workers/restarts.
    """
    # row hash: cheap mixing of the int32 columns
    h = rows.astype(np.uint64)
    mix = np.uint64(0x9E3779B97F4A7C15)
    acc = np.zeros(rows.shape[0], np.uint64)
    for c in range(rows.shape[1]):
        acc = (acc ^ (h[:, c] + mix + (acc << np.uint64(6)))) * np.uint64(
            0x100000001B3
        )
    out = np.empty((rows.shape[0], seq_len), np.int32)
    for i in range(rows.shape[0]):
        rng = np.random.default_rng(np.uint64(seed) ^ acc[i])
        out[i] = rng.integers(0, vocab, seq_len, dtype=np.int32)
    return out


# ---------------------------------------------------------------------------
# The pipeline
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PipelineConfig:
    batch_size: int  # sequences per batch, per worker
    seq_len: int
    vocab: int
    curation_query: Optional[qry.Query] = None  # None ⇒ all blocks
    seed: int = 0
    epochs: int = 1  # scheduler auto-advances; iterate this many epochs


class QdTreePipeline:
    """Per-worker iterator of (tokens, labels) batches with block skipping."""

    def __init__(
        self,
        store: BlockStore,
        cfg: PipelineConfig,
        scheduler: ElasticBlockScheduler | None = None,
        worker: int = 0,
    ):
        self.store = store
        self.cfg = cfg
        self.worker = worker
        if cfg.curation_query is not None:
            bids = store.engine.route_query(cfg.curation_query)
            self.block_ids = [int(b) for b in bids]
        else:
            self.block_ids = list(range(store.tree.n_leaves))
        self.blocks_skipped = store.tree.n_leaves - len(self.block_ids)
        self.scheduler = scheduler or ElasticBlockScheduler(
            self.block_ids, seed=cfg.seed
        )

    def __iter__(self) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        buf: list[np.ndarray] = []
        buffered = 0
        target_epoch = self.scheduler.epoch + self.cfg.epochs
        while self.scheduler.epoch < target_epoch:
            b = self.scheduler.next_block(self.worker)
            if b is None:
                # epoch drained (possibly by peers); the scheduler advances
                # on the final ack — nothing left for this worker here.
                break
            rows = self.store.read_block(b)
            if self.cfg.curation_query is not None and rows.size:
                mask = self.cfg.curation_query.evaluate(
                    rows, self.store.tree.schema
                )
                rows = rows[mask]
            if rows.size:
                toks = records_to_tokens(
                    rows, self.cfg.seq_len + 1, self.cfg.vocab, self.cfg.seed
                )
                buf.append(toks)
                buffered += toks.shape[0]
            self.scheduler.ack(self.worker, b)
            while buffered >= self.cfg.batch_size:
                chunk = np.concatenate(buf)
                batch = chunk[: self.cfg.batch_size]
                rest = chunk[self.cfg.batch_size :]
                buf = [rest] if rest.size else []
                buffered = rest.shape[0] if rest.size else 0
                yield batch[:, :-1], batch[:, 1:]
