"""Synthetic dataset generators mirroring the paper's three workloads
(Sec 7.2).  TPC-H dbgen and the proprietary ErrorLog datasets are not
available offline; these generators match the published *statistics* —
column families, domain cardinalities, correlations that make advanced
cuts useful, and workload selectivities (DESIGN.md §9).

All outputs are dictionary-encoded int32 matrices + a Schema.
"""

from __future__ import annotations

import numpy as np

from repro.core.predicates import Column, Schema

DATE_DOM = 2526  # days in TPC-H's 1992-01-01 .. 1998-12-01 span


def tpch_like_schema() -> Schema:
    """Denormalized line_item-centric table (the paper's 68-column table,
    restricted to the columns its queries actually touch + fillers)."""
    return Schema((
        Column("l_shipdate", "numeric", DATE_DOM),
        Column("l_commitdate", "numeric", DATE_DOM),
        Column("l_receiptdate", "numeric", DATE_DOM),
        Column("l_quantity", "numeric", 51),
        Column("l_discount", "numeric", 11),
        Column("l_extendedprice", "numeric", 10000),
        Column("o_orderdate", "numeric", DATE_DOM),
        Column("o_totalprice", "numeric", 10000),
        Column("p_size", "numeric", 51),
        Column("p_retailprice", "numeric", 2000),
        Column("l_shipmode", "categorical", 7),
        Column("l_shipinstruct", "categorical", 4),
        Column("l_returnflag", "categorical", 3),
        Column("l_linestatus", "categorical", 2),
        Column("p_brand", "categorical", 25),
        Column("p_container", "categorical", 40),
        Column("c_mktsegment", "categorical", 5),
        Column("r_name", "categorical", 5),
        Column("o_orderpriority", "categorical", 5),
        Column("c_nationkey", "categorical", 25),
        Column("s_nationkey", "categorical", 25),
    ))


def make_tpch_like(n_rows: int, seed: int = 0) -> tuple[Schema, np.ndarray]:
    """Uniform-ish TPC-H style data with the date correlations that make the
    paper's advanced cuts (commit < receipt, ship < commit) selective."""
    schema = tpch_like_schema()
    rng = np.random.default_rng(seed)
    n = n_rows
    ship = rng.integers(0, DATE_DOM - 120, n)
    # TPC-H semantics: commit ≈ order + 30..90, receipt = ship + 1..30.
    # Generate so that both advanced-cut polarities are non-trivially present.
    commit = ship + rng.integers(-30, 60, n)
    receipt = ship + rng.integers(1, 31, n)
    commit = np.clip(commit, 0, DATE_DOM - 1)
    receipt = np.clip(receipt, 0, DATE_DOM - 1)
    orderdate = np.clip(ship - rng.integers(1, 121, n), 0, DATE_DOM - 1)
    cols = [
        ship,
        commit,
        receipt,
        rng.integers(1, 51, n),  # quantity
        rng.integers(0, 11, n),  # discount
        rng.integers(0, 10000, n),  # extendedprice
        orderdate,
        rng.integers(0, 10000, n),  # totalprice
        rng.integers(1, 51, n),  # p_size
        rng.integers(0, 2000, n),  # retailprice
        rng.integers(0, 7, n),  # shipmode
        rng.integers(0, 4, n),  # shipinstruct
        rng.integers(0, 3, n),  # returnflag
        rng.integers(0, 2, n),  # linestatus
        rng.integers(0, 25, n),  # brand
        rng.integers(0, 40, n),  # container
        rng.integers(0, 5, n),  # mktsegment
        rng.integers(0, 5, n),  # r_name
        rng.integers(0, 5, n),  # orderpriority
        rng.integers(0, 25, n),  # c_nationkey
        rng.integers(0, 25, n),  # s_nationkey
    ]
    return schema, np.stack(cols, axis=1).astype(np.int32)


# ---------------------------------------------------------------------------
# ErrorLog-Int: 8-value event type, ~1 week of ingest, very selective queries
# ---------------------------------------------------------------------------
def errorlog_int_schema() -> Schema:
    return Schema((
        Column("ingest_date", "numeric", 7 * 24),  # hourly over one week
        Column("build_date", "numeric", 400),
        Column("event_type", "categorical", 8),
        Column("os_version", "categorical", 64),
        Column("is_valid", "categorical", 2),
        Column("severity", "categorical", 6),
        Column("component", "categorical", 32),
        Column("machine_class", "categorical", 12),
        Column("error_code", "numeric", 5000),
        Column("session_len", "numeric", 1000),
    ))


def make_errorlog_int(n_rows: int, seed: int = 0) -> tuple[Schema, np.ndarray]:
    schema = errorlog_int_schema()
    rng = np.random.default_rng(seed)
    n = n_rows

    def zipf_cat(dom, a=1.5):
        """Skewed categorical — real logs are heavily skewed."""
        p = 1.0 / np.arange(1, dom + 1) ** a
        p /= p.sum()
        return rng.choice(dom, size=n, p=p)

    event = zipf_cat(8)
    osv = zipf_cat(64, a=1.2)
    # correlations: event type ↔ component, build date ↔ os version
    component = (osv // 2 + rng.integers(0, 4, n)) % 32
    build = np.clip(
        (osv.astype(np.int64) * 6) + rng.integers(0, 24, n), 0, 399
    )
    cols = [
        rng.integers(0, 7 * 24, n),  # ingest_date
        build,
        event,
        osv,
        (rng.random(n) < 0.98).astype(np.int64),  # is_valid mostly true
        zipf_cat(6),
        component,
        zipf_cat(12),
        zipf_cat(5000, a=1.3),  # error_code: heavily skewed numeric
        rng.integers(0, 1000, n),
    ]
    return schema, np.stack(cols, axis=1).astype(np.int32)


# ---------------------------------------------------------------------------
# ErrorLog-Ext: ~3600 distinct categorical values, 15 days, 0.07% selectivity
# ---------------------------------------------------------------------------
def errorlog_ext_schema() -> Schema:
    return Schema((
        Column("ingest_date", "numeric", 15 * 24),
        Column("build_date", "numeric", 600),
        Column("app_id", "categorical", 3000),  # the big domain
        Column("event_type", "categorical", 16),
        Column("os_version", "categorical", 128),
        Column("country", "categorical", 200),
        Column("severity", "categorical", 6),
        Column("arch", "categorical", 4),
        Column("error_code", "numeric", 8000),
        Column("uptime", "numeric", 2000),
    ))


def make_errorlog_ext(n_rows: int, seed: int = 0) -> tuple[Schema, np.ndarray]:
    schema = errorlog_ext_schema()
    rng = np.random.default_rng(seed)
    n = n_rows

    def zipf_cat(dom, a=1.4):
        p = 1.0 / np.arange(1, dom + 1) ** a
        p /= p.sum()
        return rng.choice(dom, size=n, p=p)

    app = zipf_cat(3000, a=1.1)
    cols = [
        rng.integers(0, 15 * 24, n),
        np.clip(app // 8 + rng.integers(0, 256, n), 0, 599),  # build~app corr
        app,
        zipf_cat(16),
        zipf_cat(128, a=1.2),
        zipf_cat(200, a=1.1),
        zipf_cat(6),
        zipf_cat(4, a=1.0),
        zipf_cat(8000, a=1.2),
        rng.integers(0, 2000, n),
    ]
    return schema, np.stack(cols, axis=1).astype(np.int32)


GENERATORS = {
    "tpch": make_tpch_like,
    "errorlog_int": make_errorlog_int,
    "errorlog_ext": make_errorlog_ext,
}
