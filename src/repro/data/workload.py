"""Query-workload generators (paper Sec 7.2).

TPC-H: 15 templates (the 8 from Sun et al. + 7 extras the paper adds),
each instantiated with multiple random seeds — filters mirror the actual
TPC-H predicates that reach the denormalized line_item table, including
q19's disjunction-of-conjunctions and the advanced (column-vs-column)
predicates the paper highlights for q21/q12.

ErrorLog-Int/Ext: 1000 very selective queries of IN predicates over
categoricals + date ranges (paper: selectivity 0.0005% / 0.0697%).
"""

from __future__ import annotations

import numpy as np

from repro.core.predicates import OP_GE, OP_GT, OP_LE, OP_LT, Schema
from repro.core.query import AdvAtom, InAtom, Query, RangeAtom, Workload
from repro.data import datagen

YEAR = 365


def _date_range(rng, span_days: int) -> tuple[int, int]:
    lo = int(rng.integers(0, datagen.DATE_DOM - span_days))
    return lo, lo + span_days


# --------------------------------------------------------------------------
# TPC-H templates. dim() lookups are by name so schema evolution is safe.
# --------------------------------------------------------------------------
def _tpch_templates(schema: Schema):
    d = schema.dim

    def q1(rng):  # shipdate <= date - delta  (scan-heavy)
        cutoff = int(rng.integers(datagen.DATE_DOM - 120, datagen.DATE_DOM - 1))
        return Query.conjunction([RangeAtom(d("l_shipdate"), OP_LE, cutoff)])

    def q3(rng):  # mktsegment = X and orderdate < D and shipdate > D
        day = int(rng.integers(YEAR, datagen.DATE_DOM - YEAR))
        return Query.conjunction([
            InAtom(d("c_mktsegment"), (int(rng.integers(0, 5)),)),
            RangeAtom(d("o_orderdate"), OP_LT, day),
            RangeAtom(d("l_shipdate"), OP_GT, day),
        ])

    def q4(rng):  # orderdate in quarter and commitdate < receiptdate
        lo, hi = _date_range(rng, 90)
        return Query.conjunction([
            RangeAtom(d("o_orderdate"), OP_GE, lo),
            RangeAtom(d("o_orderdate"), OP_LT, hi),
            AdvAtom(d("l_commitdate"), OP_LT, d("l_receiptdate")),
        ])

    def q5(rng):  # region + orderdate year
        lo, hi = _date_range(rng, YEAR)
        return Query.conjunction([
            InAtom(d("r_name"), (int(rng.integers(0, 5)),)),
            RangeAtom(d("o_orderdate"), OP_GE, lo),
            RangeAtom(d("o_orderdate"), OP_LT, hi),
        ])

    def q6(rng):  # shipdate year + discount band + quantity
        lo, hi = _date_range(rng, YEAR)
        disc = int(rng.integers(1, 9))
        return Query.conjunction([
            RangeAtom(d("l_shipdate"), OP_GE, lo),
            RangeAtom(d("l_shipdate"), OP_LT, hi),
            RangeAtom(d("l_discount"), OP_GE, disc - 1),
            RangeAtom(d("l_discount"), OP_LE, disc + 1),
            RangeAtom(d("l_quantity"), OP_LT, int(rng.integers(24, 26))),
        ])

    def q7(rng):  # two nations + shipdate window
        a, b = rng.choice(25, size=2, replace=False)
        lo, hi = _date_range(rng, 2 * YEAR)
        return Query.conjunction([
            InAtom(d("c_nationkey"), (int(a), int(b))),
            InAtom(d("s_nationkey"), (int(a), int(b))),
            RangeAtom(d("l_shipdate"), OP_GE, lo),
            RangeAtom(d("l_shipdate"), OP_LT, hi),
        ])

    def q8(rng):  # region + orderdate window + brand-ish part filter
        lo, hi = _date_range(rng, 2 * YEAR)
        return Query.conjunction([
            InAtom(d("r_name"), (int(rng.integers(0, 5)),)),
            RangeAtom(d("o_orderdate"), OP_GE, lo),
            RangeAtom(d("o_orderdate"), OP_LT, hi),
            InAtom(d("p_brand"), tuple(rng.choice(25, 3, replace=False).tolist())),
        ])

    def q9(rng):  # supplier nation + container
        return Query.conjunction([
            InAtom(d("s_nationkey"), tuple(rng.choice(25, 4, replace=False).tolist())),
            InAtom(d("p_container"), tuple(rng.choice(40, 8, replace=False).tolist())),
        ])

    def q10(rng):  # returnflag = R + orderdate quarter
        lo, hi = _date_range(rng, 90)
        return Query.conjunction([
            InAtom(d("l_returnflag"), (2,)),
            RangeAtom(d("o_orderdate"), OP_GE, lo),
            RangeAtom(d("o_orderdate"), OP_LT, hi),
        ])

    def q12(rng):  # shipmode pair + commit<receipt + ship<commit + receipt yr
        lo, hi = _date_range(rng, YEAR)
        modes = rng.choice(7, size=2, replace=False)
        return Query.conjunction([
            InAtom(d("l_shipmode"), tuple(int(x) for x in modes)),
            AdvAtom(d("l_commitdate"), OP_LT, d("l_receiptdate")),
            AdvAtom(d("l_shipdate"), OP_LT, d("l_commitdate")),
            RangeAtom(d("l_receiptdate"), OP_GE, lo),
            RangeAtom(d("l_receiptdate"), OP_LT, hi),
        ])

    def q14(rng):  # shipdate month
        lo, hi = _date_range(rng, 30)
        return Query.conjunction([
            RangeAtom(d("l_shipdate"), OP_GE, lo),
            RangeAtom(d("l_shipdate"), OP_LT, hi),
        ])

    def q17(rng):  # brand + container + small quantity
        return Query.conjunction([
            InAtom(d("p_brand"), (int(rng.integers(0, 25)),)),
            InAtom(d("p_container"), (int(rng.integers(0, 40)),)),
            RangeAtom(d("l_quantity"), OP_LT, int(rng.integers(3, 8))),
        ])

    def q18(rng):  # large quantity orders (scan-heavy)
        return Query.conjunction([
            RangeAtom(d("l_quantity"), OP_GT, int(rng.integers(44, 49))),
        ])

    def q19(rng):  # OR of three 6-filter conjunctions (the paper's example)
        def arm(brand_pool, containers, qlo, qspan, size_hi):
            q = int(rng.integers(*qlo))
            return [
                InAtom(d("p_brand"), (int(rng.choice(brand_pool)),)),
                InAtom(d("p_container"), tuple(int(c) for c in containers)),
                RangeAtom(d("l_quantity"), OP_GE, q),
                RangeAtom(d("l_quantity"), OP_LE, q + qspan),
                RangeAtom(d("p_size"), OP_GE, 1),
                RangeAtom(d("p_size"), OP_LE, size_hi),
                InAtom(d("l_shipinstruct"), (0,)),
                InAtom(d("l_shipmode"), (0, 1)),
            ]
        return Query.disjunction([
            arm(range(0, 8), rng.choice(40, 4, replace=False), (1, 11), 10, 5),
            arm(range(8, 16), rng.choice(40, 4, replace=False), (10, 20), 10, 10),
            arm(range(16, 25), rng.choice(40, 4, replace=False), (20, 30), 10, 15),
        ])

    def q21(rng):  # receiptdate > commitdate (self-join accelerator)
        return Query.conjunction([
            AdvAtom(d("l_receiptdate"), OP_GT, d("l_commitdate")),
            InAtom(d("s_nationkey"), (int(rng.integers(0, 25)),)),
            InAtom(d("l_linestatus"), (0,)),
        ])

    return {
        "q1": q1, "q3": q3, "q4": q4, "q5": q5, "q6": q6, "q7": q7,
        "q8": q8, "q9": q9, "q10": q10, "q12": q12, "q14": q14,
        "q17": q17, "q18": q18, "q19": q19, "q21": q21,
    }


def make_tpch_workload(
    schema: Schema, n_per_template: int = 10, seed: int = 0
) -> tuple[Workload, list[str]]:
    rng = np.random.default_rng(seed)
    templates = _tpch_templates(schema)
    queries, labels = [], []
    for name, fn in templates.items():
        for _ in range(n_per_template):
            queries.append(fn(rng))
            labels.append(name)
    return Workload(schema, tuple(queries)), labels


# --------------------------------------------------------------------------
# ErrorLog workloads: IN over categoricals + date ranges, ~5 dims touched
# --------------------------------------------------------------------------
def make_errorlog_int_workload(
    schema: Schema, n_queries: int = 1000, seed: int = 0
) -> tuple[Workload, list[str]]:
    rng = np.random.default_rng(seed)
    d = schema.dim
    queries = []
    for _ in range(n_queries):
        atoms = [
            InAtom(d("event_type"), tuple(
                int(x) for x in rng.choice(8, rng.integers(1, 3), replace=False)
            )),
            InAtom(d("os_version"), tuple(
                int(x) for x in rng.choice(64, rng.integers(1, 4), replace=False)
            )),
            InAtom(d("is_valid"), (1,)),
        ]
        if rng.random() < 0.8:  # date range
            lo = int(rng.integers(0, 7 * 24 - 12))
            atoms += [
                RangeAtom(d("ingest_date"), OP_GE, lo),
                RangeAtom(d("ingest_date"), OP_LT, lo + int(rng.integers(3, 13))),
            ]
        if rng.random() < 0.5:
            atoms.append(
                InAtom(d("component"), tuple(
                    int(x) for x in rng.choice(32, rng.integers(1, 3), replace=False)
                ))
            )
        queries.append(Query.conjunction(atoms))
    return Workload(schema, tuple(queries)), ["int"] * n_queries


def make_errorlog_ext_workload(
    schema: Schema, n_queries: int = 1000, seed: int = 0
) -> tuple[Workload, list[str]]:
    rng = np.random.default_rng(seed)
    d = schema.dim
    queries = []
    # queries concentrate on popular apps (zipf), like real dashboards
    p = 1.0 / np.arange(1, 3001) ** 1.1
    p /= p.sum()
    for _ in range(n_queries):
        atoms = [
            InAtom(d("app_id"), tuple(
                int(x) for x in rng.choice(3000, rng.integers(1, 4), replace=False, p=p)
            )),
        ]
        if rng.random() < 0.7:
            lo = int(rng.integers(0, 15 * 24 - 24))
            atoms += [
                RangeAtom(d("ingest_date"), OP_GE, lo),
                RangeAtom(d("ingest_date"), OP_LT, lo + int(rng.integers(6, 25))),
            ]
        if rng.random() < 0.6:
            atoms.append(InAtom(d("country"), tuple(
                int(x) for x in rng.choice(200, rng.integers(1, 4), replace=False)
            )))
        if rng.random() < 0.4:
            atoms.append(InAtom(d("event_type"), tuple(
                int(x) for x in rng.choice(16, rng.integers(1, 3), replace=False)
            )))
        queries.append(Query.conjunction(atoms))
    return Workload(schema, tuple(queries)), ["ext"] * n_queries


WORKLOADS = {
    "tpch": make_tpch_workload,
    "errorlog_int": make_errorlog_int_workload,
    "errorlog_ext": make_errorlog_ext_workload,
}
