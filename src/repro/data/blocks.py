"""Block store: persists a layout's blocks and serves scan queries.

This is the execution engine for the physical-runtime benchmarks (paper
Sec 7.4/7.5): each leaf block is stored columnar (npz), a manifest carries
sizes + semantic descriptions, and ``scan_query`` reads only the blocks the
qd-tree routes the query to (``BID IN (...)`` — paper Sec 3.3), counting
blocks/bytes/rows touched.  It also backs the LM-training data pipeline
(pipeline.py), where blocks are the unit of work assignment.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import time

import numpy as np

from repro.core import query as qry
from repro.core.qdtree import FrozenQdTree


@dataclasses.dataclass
class ScanResult:
    rows: np.ndarray  # exact matching records
    blocks_considered: int
    blocks_read: int
    bytes_read: int
    rows_scanned: int
    wall_s: float


@dataclasses.dataclass
class BlockStore:
    root: pathlib.Path
    tree: FrozenQdTree
    sizes: np.ndarray  # rows per block
    row_bytes: int

    # -- construction --------------------------------------------------------
    @staticmethod
    def create(
        path: str | pathlib.Path,
        tree: FrozenQdTree,
        records: np.ndarray,
        backend: str = "numpy",
    ) -> "BlockStore":
        """Route all records and persist one npz per block."""
        from repro.core import routing

        root = pathlib.Path(path)
        root.mkdir(parents=True, exist_ok=True)
        bids = routing.route(tree, records, backend=backend)
        tree.tighten(records, bids)
        sizes = np.bincount(bids, minlength=tree.n_leaves)
        order = np.argsort(bids, kind="stable")
        sorted_recs = records[order]
        bounds = np.concatenate([[0], np.cumsum(sizes)])
        row_bytes = records.shape[1] * records.dtype.itemsize
        for b in range(tree.n_leaves):
            np.savez(
                root / f"block_{b:06d}.npz",
                rows=sorted_recs[bounds[b] : bounds[b + 1]],
            )
        tree.save(str(root / "qdtree.npz"))
        manifest = {
            "n_blocks": int(tree.n_leaves),
            "sizes": sizes.tolist(),
            "row_bytes": row_bytes,
            "n_rows": int(records.shape[0]),
        }
        (root / "manifest.json").write_text(json.dumps(manifest))
        return BlockStore(
            root=root, tree=tree, sizes=sizes, row_bytes=row_bytes
        )

    @staticmethod
    def open(path: str | pathlib.Path) -> "BlockStore":
        root = pathlib.Path(path)
        manifest = json.loads((root / "manifest.json").read_text())
        tree = FrozenQdTree.load(str(root / "qdtree.npz"))
        return BlockStore(
            root=root,
            tree=tree,
            sizes=np.asarray(manifest["sizes"], np.int64),
            row_bytes=int(manifest["row_bytes"]),
        )

    # -- reads ---------------------------------------------------------------
    def read_block(self, bid: int) -> np.ndarray:
        with np.load(self.root / f"block_{bid:06d}.npz") as z:
            return z["rows"]

    def scan_query(
        self, query: qry.Query, use_routing: bool = True
    ) -> ScanResult:
        """Execute a query: route → read → exact filter.

        ``use_routing=False`` is the paper's *no route* ablation: every block
        whose min-max description intersects is still skipped (the tightened
        descriptions double as min-max indexes), but without the qd-tree BID
        list the store must consider all blocks' metadata.  Both paths read
        the same blocks here because our descriptions subsume min-max —
        the physical difference (explicit BID pushdown) shows up in metadata
        touch counts.
        """
        t0 = time.perf_counter()
        bids = qry.route_query(self.tree, query)
        rows_out = []
        bytes_read = 0
        rows_scanned = 0
        for b in bids:
            rows = self.read_block(int(b))
            if rows.size == 0:
                continue
            rows_scanned += rows.shape[0]
            bytes_read += rows.shape[0] * self.row_bytes
            mask = query.evaluate(rows, self.tree.schema)
            if mask.any():
                rows_out.append(rows[mask])
        out = (
            np.concatenate(rows_out)
            if rows_out
            else np.zeros((0, self.tree.schema.ndims), np.int32)
        )
        return ScanResult(
            rows=out,
            blocks_considered=(
                len(bids) if use_routing else self.tree.n_leaves
            ),
            blocks_read=len(bids),
            bytes_read=bytes_read,
            rows_scanned=rows_scanned,
            wall_s=time.perf_counter() - t0,
        )
