"""Block store: persists a layout's blocks and serves scan queries.

This is the execution engine for the physical-runtime benchmarks (paper
Sec 7.4/7.5): each leaf block is stored columnar (npz), a manifest carries
sizes + semantic descriptions, and ``scan_query`` reads only the blocks the
qd-tree routes the query to (``BID IN (...)`` — paper Sec 3.3), counting
blocks/bytes/rows touched.  It also backs the LM-training data pipeline
(pipeline.py), where blocks are the unit of work assignment.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import time

import numpy as np

from repro.core import query as qry
from repro.core.qdtree import FrozenQdTree


class BlockBuffers:
    """In-memory per-block row buffers for streaming ingestion.

    ``LayoutEngine.ingest`` appends each routed micro-batch here; buffers
    accumulate per-BID row chunks (no per-batch rewrite of persisted
    blocks) and ``write_store`` materializes a :class:`BlockStore` once the
    stream drains.
    """

    def __init__(self, n_blocks: int, ndims: int, dtype=None):
        self.n_blocks = n_blocks
        self.ndims = ndims
        # None ⇒ adopt the first batch's dtype (no silent narrowing)
        self._dtype = None if dtype is None else np.dtype(dtype)
        self._chunks: list[list[np.ndarray]] = [[] for _ in range(n_blocks)]
        self.sizes = np.zeros(n_blocks, np.int64)

    @property
    def dtype(self) -> np.dtype:
        return self._dtype if self._dtype is not None else np.dtype(np.int32)

    @staticmethod
    def for_tree(tree: FrozenQdTree, dtype=None) -> "BlockBuffers":
        return BlockBuffers(tree.n_leaves, tree.schema.ndims, dtype)

    def append(self, records: np.ndarray, bids: np.ndarray) -> None:
        """Scatter one routed batch into the per-block buffers."""
        if records.shape[0] == 0:
            return
        if self._dtype is None:
            self._dtype = records.dtype
        order = np.argsort(bids, kind="stable")
        sorted_recs = records[order].astype(self.dtype, copy=False)
        counts = np.bincount(bids, minlength=self.n_blocks)
        bounds = np.concatenate([[0], np.cumsum(counts)])
        for b in np.nonzero(counts)[0]:
            self._chunks[b].append(sorted_recs[bounds[b] : bounds[b + 1]])
        self.sizes += counts

    def append_block(self, bid: int, rows: np.ndarray) -> None:
        """Append pre-routed rows to one block (sharded-merge spill path).

        ``MergeCoordinator.publish`` folds each shard's per-block chunks in
        here in shard-id order, so a contiguous record split reproduces the
        single-stream buffer contents row-for-row.
        """
        if rows.shape[0] == 0:
            return
        if self._dtype is None:
            self._dtype = rows.dtype
        self._chunks[bid].append(rows.astype(self.dtype, copy=False))
        self.sizes[bid] += rows.shape[0]

    @property
    def n_rows(self) -> int:
        return int(self.sizes.sum())

    def block(self, bid: int) -> np.ndarray:
        chunks = self._chunks[bid]
        if not chunks:
            return np.zeros((0, self.ndims), self.dtype)
        return chunks[0] if len(chunks) == 1 else np.concatenate(chunks)

    def write_store(
        self, path: str | pathlib.Path, tree: FrozenQdTree
    ) -> "BlockStore":
        """Persist the buffered blocks as a BlockStore (npz + manifest)."""
        root = pathlib.Path(path)
        root.mkdir(parents=True, exist_ok=True)
        row_bytes = self.ndims * self.dtype.itemsize
        for b in range(self.n_blocks):
            np.savez(root / f"block_{b:06d}.npz", rows=self.block(b))
        tree.save(str(root / "qdtree.npz"))
        manifest = {
            "n_blocks": int(self.n_blocks),
            "sizes": self.sizes.tolist(),
            "row_bytes": row_bytes,
            "n_rows": self.n_rows,
        }
        (root / "manifest.json").write_text(json.dumps(manifest))
        return BlockStore(
            root=root,
            tree=tree,
            sizes=self.sizes.copy(),
            row_bytes=row_bytes,
        )


@dataclasses.dataclass
class ScanResult:
    rows: np.ndarray  # exact matching records
    blocks_considered: int
    blocks_read: int
    bytes_read: int
    rows_scanned: int
    wall_s: float


@dataclasses.dataclass
class BlockStore:
    root: pathlib.Path
    tree: FrozenQdTree
    sizes: np.ndarray  # rows per block
    row_bytes: int

    # -- construction --------------------------------------------------------
    @staticmethod
    def create(
        path: str | pathlib.Path,
        tree: FrozenQdTree,
        records: np.ndarray,
        backend: str = "numpy",
    ) -> "BlockStore":
        """Route all records and persist one npz per block.

        One-shot convenience over the streaming path: a single ``ingest``
        batch through the tree's LayoutEngine.
        """
        return BlockStore.create_streaming(
            path, tree, [records], backend=backend,
            dtype=records.dtype,
        )

    @staticmethod
    def create_streaming(
        path: str | pathlib.Path,
        tree: FrozenQdTree,
        batches,
        backend: str = "numpy",
        dtype=None,
    ) -> "BlockStore":
        """Ingest a stream of record micro-batches into a new store.

        Routes each batch through the LayoutEngine, buffers rows per block,
        incrementally tightens leaf descriptions, then persists.
        """
        from repro.engine import engine_for

        buffers = BlockBuffers(tree.n_leaves, tree.schema.ndims, dtype)
        engine_for(tree).ingest(
            batches, tighten=True, buffers=buffers, backend=backend
        )
        return buffers.write_store(path, tree)

    @staticmethod
    def open(path: str | pathlib.Path) -> "BlockStore":
        root = pathlib.Path(path)
        manifest = json.loads((root / "manifest.json").read_text())
        tree = FrozenQdTree.load(str(root / "qdtree.npz"))
        return BlockStore(
            root=root,
            tree=tree,
            sizes=np.asarray(manifest["sizes"], np.int64),
            row_bytes=int(manifest["row_bytes"]),
        )

    # -- engine access -------------------------------------------------------
    @property
    def engine(self):
        """The store's LayoutEngine (shared plan cache via the tree)."""
        from repro.engine import engine_for

        return engine_for(self.tree)

    # -- reads ---------------------------------------------------------------
    def read_block(self, bid: int) -> np.ndarray:
        with np.load(self.root / f"block_{bid:06d}.npz") as z:
            return z["rows"]

    def scan_query(
        self, query: qry.Query, use_routing: bool = True
    ) -> ScanResult:
        """Execute a query: route → read → exact filter.

        ``use_routing=False`` is the paper's *no route* ablation: every block
        whose min-max description intersects is still skipped (the tightened
        descriptions double as min-max indexes), but without the qd-tree BID
        list the store must consider all blocks' metadata.  Both paths read
        the same blocks here because our descriptions subsume min-max —
        the physical difference (explicit BID pushdown) shows up in metadata
        touch counts.
        """
        t0 = time.perf_counter()
        bids = self.engine.route_query(query)
        rows_out = []
        bytes_read = 0
        rows_scanned = 0
        for b in bids:
            rows = self.read_block(int(b))
            if rows.size == 0:
                continue
            rows_scanned += rows.shape[0]
            bytes_read += rows.shape[0] * self.row_bytes
            mask = query.evaluate(rows, self.tree.schema)
            if mask.any():
                rows_out.append(rows[mask])
        out = (
            np.concatenate(rows_out)
            if rows_out
            else np.zeros((0, self.tree.schema.ndims), np.int32)
        )
        return ScanResult(
            rows=out,
            blocks_considered=(
                len(bids) if use_routing else self.tree.n_leaves
            ),
            blocks_read=len(bids),
            bytes_read=bytes_read,
            rows_scanned=rows_scanned,
            wall_s=time.perf_counter() - t0,
        )
