"""Pallas TPU kernels for batched record routing (paper Sec 3.1).

TPU adaptation (DESIGN.md §3): the paper's CPU router chases pointers down
the tree.  On TPU we factorize routing into two dense, gather-free kernels:

  1. ``eval_cuts_kernel`` — evaluate *every* candidate cut for a record tile:
       * column selection as a one-hot matmul (MXU),
       * IN-set membership as a global categorical one-hot (iota compares,
         VPU) times the packed membership masks (MXU),
       * advanced (col-vs-col) predicates as static column slices (VPU).
  2. ``locate_leaf_kernel`` — the *path-constraint* reformulation of tree
     descent: leaf ``l`` owns record ``r`` iff r's predicate vector M[r]
     satisfies every (cut, direction) constraint on l's root path, i.e.

         viol[r, l] = (1 - M[r]) @ PathPos[:, l] + M[r] @ PathNeg[:, l] == 0

     Two MXU matmuls replace ``depth`` sequential gathers; the unique
     zero-violation leaf is recovered with a weighted mask reduction.

All integer data is dictionary-encoded and must satisfy dom < 2**24 so
float32 MXU arithmetic is exact.
"""

from __future__ import annotations

# qdlint: deterministic-module

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# Kernel 1: predicate-matrix evaluation
# ---------------------------------------------------------------------------
def _eval_cuts_kernel(  # qdlint: jit-body
    # inputs (VMEM refs)
    records_ref,  # (TM, D) f32 — record tile (dictionary codes)
    dim_onehot_ref,  # (D, C) f32 — one-hot of each cut's column
    cutpoint_ref,  # (1, C) f32
    in_mask_ref,  # (B, C) f32 — transposed IN membership masks
    cat_onehot_dims_ref,  # (1, D) f32 — 1.0 where dim is categorical
    cat_offset_ref,  # (1, D) f32 — bit-space offset per dim (0 for numeric)
    adv_cols_ref,  # (A3, 3) f32 — rows: (col_a, op, col_b); A3 = max(n_adv,1)
    adv_sel_ref,  # (A3, C) f32 — one-hot map adv id -> cut column
    kind_ref,  # (1, C) f32 — cut kind per column
    # outputs
    m_ref,  # (TM, C) f32 — predicate matrix tile (0.0 / 1.0)
    *,
    n_adv: int,
    n_cat_bits: int,
):
    records = records_ref[...]  # (TM, D)
    tm = records.shape[0]

    # -- range cuts: select each cut's column, compare against cutpoint ----
    vals = jnp.dot(
        records, dim_onehot_ref[...], preferred_element_type=jnp.float32
    )  # (TM, C)
    rng = (vals < cutpoint_ref[...]).astype(jnp.float32)

    # -- IN cuts: global categorical one-hot  ×  membership masks ----------
    # GO[r, b] = 1 iff some categorical dim d has records[r, d] + off_d == b.
    # in_mask rows are zero outside their own dim segment, so the cross-dim
    # bits never contribute to the product.
    bit_iota = jax.lax.broadcasted_iota(jnp.float32, (tm, n_cat_bits), 1)
    bitpos = records + cat_offset_ref[...]  # (TM, D); junk for numeric dims
    is_cat = cat_onehot_dims_ref[...]  # (1, D)
    go = jnp.zeros((tm, n_cat_bits), jnp.float32)
    d_total = records.shape[1]
    for d in range(d_total):  # static loop over table columns
        hit = (bit_iota == bitpos[:, d][:, None]).astype(jnp.float32)
        go = go + hit * is_cat[0, d]
    inm = jnp.dot(go, in_mask_ref[...], preferred_element_type=jnp.float32)
    inm = (inm > 0.5).astype(jnp.float32)

    # -- advanced cuts: static small loop over binary predicates -----------
    c = vals.shape[1]
    advm = jnp.zeros((tm, c), jnp.float32)
    if n_adv > 0:
        adv_res = jnp.zeros((tm, adv_sel_ref.shape[0]), jnp.float32)
        for j in range(n_adv):  # n_adv is small and static (paper Sec 6.1)
            col_a = adv_cols_ref[j, 0]
            op = adv_cols_ref[j, 1]
            col_b = adv_cols_ref[j, 2]
            # one-hot select the two columns (dynamic col id, static loop j)
            d_iota = jax.lax.broadcasted_iota(jnp.float32, (tm, d_total), 1)
            va = jnp.sum(
                records * (d_iota == col_a).astype(jnp.float32), axis=1
            )
            vb = jnp.sum(
                records * (d_iota == col_b).astype(jnp.float32), axis=1
            )
            t = jnp.select(
                [op == 0, op == 1, op == 2, op == 3, op == 4],
                [va < vb, va <= vb, va > vb, va >= vb, va == vb],
                va != vb,
            ).astype(jnp.float32)
            adv_res = adv_res.at[:, j].set(t)
        advm = jnp.dot(
            adv_res, adv_sel_ref[...], preferred_element_type=jnp.float32
        )

    kind = kind_ref[...]  # (1, C): 0 range, 1 in, 2 adv
    out = jnp.where(kind == 0.0, rng, jnp.where(kind == 1.0, inm, advm))
    m_ref[...] = out


@functools.partial(
    jax.jit, static_argnames=("tile_m", "n_cat_bits", "n_adv", "interpret")
)
def eval_cuts_pallas(
    records_f32: jnp.ndarray,  # (M, D) f32, M % tile_m == 0
    dim_onehot: jnp.ndarray,  # (D, C)
    cutpoint: jnp.ndarray,  # (1, C)
    in_mask_t: jnp.ndarray,  # (B, C)
    is_cat_row: jnp.ndarray,  # (1, D)
    cat_offset_row: jnp.ndarray,  # (1, D)
    adv_cols: jnp.ndarray,  # (A3, 3)
    adv_sel: jnp.ndarray,  # (A3, C)
    kind_row: jnp.ndarray,  # (1, C)
    *,
    tile_m: int,
    n_cat_bits: int,
    n_adv: int,
    interpret: bool,
) -> jnp.ndarray:
    m, d = records_f32.shape
    c = dim_onehot.shape[1]
    grid = (m // tile_m,)
    kernel = functools.partial(
        _eval_cuts_kernel, n_adv=n_adv, n_cat_bits=n_cat_bits
    )
    full = lambda *shape: [pl.BlockSpec(shape, lambda i: (0,) * len(shape))]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, d), lambda i: (i, 0)),  # records
            *full(d, c),  # dim_onehot
            *full(1, c),  # cutpoint
            *full(in_mask_t.shape[0], c),  # in_mask^T
            *full(1, d),  # is_cat
            *full(1, d),  # cat_offset
            *full(adv_cols.shape[0], 3),  # adv_cols
            *full(adv_sel.shape[0], c),  # adv_sel
            *full(1, c),  # kind
        ],
        out_specs=pl.BlockSpec((tile_m, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, c), jnp.float32),
        interpret=interpret,
    )(
        records_f32,
        dim_onehot,
        cutpoint,
        in_mask_t,
        is_cat_row,
        cat_offset_row,
        adv_cols,
        adv_sel,
        kind_row,
    )


# ---------------------------------------------------------------------------
# Kernel 2: path-constraint leaf location
# ---------------------------------------------------------------------------
def _locate_leaf_kernel(  # qdlint: jit-body
    m_ref,  # (TM, C) f32 — predicate-matrix tile
    pathpos_ref,  # (C, TL) f32 — 1 iff leaf's path requires cut true
    pathneg_ref,  # (C, TL) f32 — 1 iff leaf's path requires cut false
    leafid_ref,  # (1, TL) f32 — global leaf index + 1 (0 ⇒ padding)
    out_ref,  # (TM, 1) f32 — accumulates (bid + 1) of the unique hit
):
    l_step = pl.program_id(1)

    @pl.when(l_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    m = m_ref[...]
    viol = jnp.dot(
        1.0 - m, pathpos_ref[...], preferred_element_type=jnp.float32
    ) + jnp.dot(m, pathneg_ref[...], preferred_element_type=jnp.float32)
    hit = (viol < 0.5).astype(jnp.float32)  # (TM, TL)
    # each record matches exactly one (unpadded) leaf across all L tiles
    partial = jnp.dot(
        hit, leafid_ref[...].T, preferred_element_type=jnp.float32
    )  # (TM, 1)
    out_ref[...] += partial


@functools.partial(
    jax.jit, static_argnames=("tile_m", "tile_l", "interpret")
)
def locate_leaf_pallas(
    m_mat: jnp.ndarray,  # (M, C) f32
    pathpos: jnp.ndarray,  # (C, L) f32
    pathneg: jnp.ndarray,  # (C, L) f32
    leafid: jnp.ndarray,  # (1, L) f32 — bid + 1, zero on padded columns
    *,
    tile_m: int,
    tile_l: int,
    interpret: bool,
) -> jnp.ndarray:
    m, c = m_mat.shape
    n_leaf = pathpos.shape[1]
    grid = (m // tile_m, n_leaf // tile_l)
    out = pl.pallas_call(
        _locate_leaf_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, c), lambda i, j: (i, 0)),
            pl.BlockSpec((c, tile_l), lambda i, j: (0, j)),
            pl.BlockSpec((c, tile_l), lambda i, j: (0, j)),
            pl.BlockSpec((1, tile_l), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tile_m, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=interpret,
    )(m_mat, pathpos, pathneg, leafid)
    return out[:, 0] - 1.0  # back to 0-based BIDs; padding rows ⇒ -1
