"""Jit'd wrappers around the Pallas kernels.

Responsibilities: build the dense kernel operands from a ``FrozenQdTree`` +
workload tensors (host-side, cached per tree), pad every axis to MXU-aligned
tile multiples, pick ``interpret=True`` automatically off-TPU, and slice the
padding back off.  Everything returned is numpy and bit-identical to the
numpy oracles in ``repro.core``.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import query as qry
from repro.core.qdtree import FrozenQdTree
from repro.kernels import route_records as rk
from repro.kernels import query_intersect as qk

LANE = 128  # TPU lane width; last-dim tiles should be multiples of this


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: np.ndarray, axis: int, mult: int, fill=0) -> np.ndarray:
    n = x.shape[axis]
    target = max(((n + mult - 1) // mult) * mult, mult)
    if target == n:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - n)
    return np.pad(x, pad, constant_values=fill)


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------
def path_matrices(tree: FrozenQdTree) -> tuple[np.ndarray, np.ndarray]:
    """PathPos/PathNeg (n_cuts, n_leaves): leaf path constraints."""
    n_cuts = tree.cuts.n_cuts
    pos = np.zeros((n_cuts, tree.n_leaves), np.float32)
    neg = np.zeros((n_cuts, tree.n_leaves), np.float32)
    stack: list[tuple[int, list[tuple[int, bool]]]] = [(0, [])]
    while stack:
        node, cons = stack.pop()
        bid = int(tree.leaf_bid[node])
        if bid >= 0:
            for c, d in cons:
                (pos if d else neg)[c, bid] = 1.0
        else:
            c = int(tree.cut_id[node])
            stack.append((int(tree.left[node]), cons + [(c, True)]))
            stack.append((int(tree.right[node]), cons + [(c, False)]))
    return pos, neg


def route_constants(tree: FrozenQdTree) -> dict:
    """Kernel operands derived from the frozen tree (cached on the tree)."""
    cached = getattr(tree, "_route_consts", None)
    if cached is not None:
        return cached
    cuts, schema = tree.cuts, tree.schema
    d = schema.ndims
    c_pad = max(((cuts.n_cuts + LANE - 1) // LANE) * LANE, LANE)
    dim_onehot = np.zeros((d, c_pad), np.float32)
    valid = np.arange(cuts.n_cuts)
    dim_onehot[np.maximum(cuts.dim, 0), valid] = (
        cuts.kind != 2
    ).astype(np.float32)[valid]
    cutpoint = np.zeros((1, c_pad), np.float32)
    cutpoint[0, : cuts.n_cuts] = cuts.cutpoint
    bits = max(schema.total_cat_bits, 1)
    b_pad = max(((bits + LANE - 1) // LANE) * LANE, LANE)
    in_mask_t = np.zeros((b_pad, c_pad), np.float32)
    in_mask_t[: cuts.in_mask.shape[1], : cuts.n_cuts] = (
        cuts.in_mask.T.astype(np.float32)
    )
    is_cat = schema.is_categorical.astype(np.float32)[None, :]
    cat_off = np.maximum(schema.cat_offsets, 0).astype(np.float32)[None, :]
    n_adv = cuts.n_adv
    a3 = max(n_adv, 1)
    adv_cols = np.zeros((a3, 3), np.float32)
    adv_sel = np.zeros((a3, c_pad), np.float32)
    for j, a in enumerate(cuts.adv):
        adv_cols[j] = (a.col_a, a.op, a.col_b)
    advc = np.nonzero(cuts.kind == 2)[0]
    adv_sel[cuts.adv_id[advc], advc] = 1.0
    kind = np.zeros((1, c_pad), np.float32)
    kind[0, : cuts.n_cuts] = cuts.kind

    pos, neg = path_matrices(tree)
    pos = np.pad(pos, ((0, c_pad - pos.shape[0]), (0, 0)))
    neg = np.pad(neg, ((0, c_pad - neg.shape[0]), (0, 0)))
    l_pad = max(((tree.n_leaves + LANE - 1) // LANE) * LANE, LANE)
    leafid = np.zeros((1, l_pad), np.float32)
    leafid[0, : tree.n_leaves] = np.arange(tree.n_leaves) + 1.0
    pos = _pad_to(pos, 1, LANE)
    neg = _pad_to(neg, 1, LANE)
    # padded leaf columns must always register ≥1 violation: require cut 0
    # both true and false
    pos[0, tree.n_leaves :] = 1.0
    neg[0, tree.n_leaves :] = 1.0

    consts = dict(
        dim_onehot=dim_onehot,
        cutpoint=cutpoint,
        in_mask_t=in_mask_t,
        is_cat=is_cat,
        cat_off=cat_off,
        adv_cols=adv_cols,
        adv_sel=adv_sel,
        kind=kind,
        pathpos=pos,
        pathneg=neg,
        leafid=leafid,
        n_adv=n_adv,
        n_cat_bits=b_pad,
    )
    object.__setattr__(tree, "_route_consts", consts)
    return consts


def route_records(
    tree: FrozenQdTree,
    records: np.ndarray,
    tile_m: int = 256,
    tile_l: int = LANE,
    interpret: bool | None = None,
) -> np.ndarray:
    """Record → BID via the Pallas path (paper Sec 3.1)."""
    if interpret is None:
        interpret = _interpret_default()
    k = route_constants(tree)
    m = records.shape[0]
    rec = _pad_to(records.astype(np.float32), 0, tile_m)
    m_mat = rk.eval_cuts_pallas(
        jnp.asarray(rec),
        jnp.asarray(k["dim_onehot"]),
        jnp.asarray(k["cutpoint"]),
        jnp.asarray(k["in_mask_t"]),
        jnp.asarray(k["is_cat"]),
        jnp.asarray(k["cat_off"]),
        jnp.asarray(k["adv_cols"]),
        jnp.asarray(k["adv_sel"]),
        jnp.asarray(k["kind"]),
        tile_m=tile_m,
        n_cat_bits=k["n_cat_bits"],
        n_adv=k["n_adv"],
        interpret=interpret,
    )
    tile_l = min(tile_l, k["pathpos"].shape[1])
    bids = rk.locate_leaf_pallas(
        m_mat,
        jnp.asarray(k["pathpos"]),
        jnp.asarray(k["pathneg"]),
        jnp.asarray(k["leafid"]),
        tile_m=tile_m,
        tile_l=tile_l,
        interpret=interpret,
    )
    return np.asarray(bids[:m]).astype(np.int32)


# ---------------------------------------------------------------------------
# Query ↔ block intersection (+ fused scan counting)
# ---------------------------------------------------------------------------
def query_intersect(
    tree: FrozenQdTree,
    wt: qry.WorkloadTensors,
    block_sizes: np.ndarray | None = None,
    tile_l: int = LANE,
    tile_c: int = LANE,
    interpret: bool | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Returns (query_hits (L, n_queries) bool, scanned_per_conj (n_conj,)).

    Mirrors ``rewards.block_query_hits`` (numpy oracle) bit-exactly.
    """
    if interpret is None:
        interpret = _interpret_default()
    schema = tree.schema
    L = tree.n_leaves
    sizes = (
        np.zeros(L, np.float32)
        if block_sizes is None
        else block_sizes.astype(np.float32)
    )
    n_adv = tree.cuts.n_adv
    a3 = max(n_adv, 1)

    leaf_lo = _pad_to(tree.leaf_lo.astype(np.float32), 0, tile_l)
    leaf_hi = _pad_to(tree.leaf_hi.astype(np.float32), 0, tile_l, fill=0)
    leaf_cat = _pad_to(tree.leaf_cat.astype(np.float32), 0, tile_l)
    advt = tree.leaf_adv[:, :, 0] if n_adv else np.zeros((L, 1), bool)
    advf = tree.leaf_adv[:, :, 1] if n_adv else np.zeros((L, 1), bool)
    leaf_advt = _pad_to(advt.astype(np.float32), 0, tile_l)
    leaf_advf = _pad_to(advf.astype(np.float32), 0, tile_l)
    leaf_size = _pad_to(sizes[:, None], 0, tile_l)

    nc = wt.n_conjuncts
    q_lo = _pad_to(wt.q_lo.astype(np.float32), 0, tile_c)
    q_hi = _pad_to(wt.q_hi.astype(np.float32), 0, tile_c, fill=0)
    q_cat = _pad_to(wt.q_cat.astype(np.float32), 0, tile_c)
    reqt = (wt.q_adv == qry.ADV_TRUE).astype(np.float32)
    reqf = (wt.q_adv == qry.ADV_FALSE).astype(np.float32)
    if reqt.shape[1] < a3:
        reqt = np.pad(reqt, ((0, 0), (0, a3 - reqt.shape[1])))
        reqf = np.pad(reqf, ((0, 0), (0, a3 - reqf.shape[1])))
    q_reqt = _pad_to(reqt, 0, tile_c)
    q_reqf = _pad_to(reqf, 0, tile_c)

    numeric_dims = tuple(
        int(i) for i in np.nonzero(~schema.is_categorical)[0]
    )
    off = schema.cat_offsets
    cat_segments = tuple(
        (int(off[d]), int(off[d]) + schema.columns[d].dom)
        for d in np.nonzero(schema.is_categorical)[0]
    )

    hits, scanned = qk.query_intersect_pallas(
        *map(
            jnp.asarray,
            (
                leaf_lo, leaf_hi, leaf_cat, leaf_advt, leaf_advf, leaf_size,
                q_lo, q_hi, q_cat, q_reqt, q_reqf,
            ),
        ),
        tile_l=tile_l,
        tile_c=tile_c,
        numeric_dims=numeric_dims,
        cat_segments=cat_segments,
        n_adv=n_adv,
        interpret=interpret,
    )
    conj_hits = np.asarray(hits)[:L, :nc] > 0.5
    scanned_per_conj = np.asarray(scanned)[0, :nc]
    q_hits = qry.queries_intersect(conj_hits, wt)
    return q_hits, scanned_per_conj
