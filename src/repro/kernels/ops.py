"""Jit'd wrappers around the Pallas kernels.

Dense kernel operands are packed and cached by the LayoutEngine's plan
cache (``repro.engine.plan``); this module keeps the kernel-level entry
points — padding every axis to MXU-aligned tile multiples, picking
``interpret=True`` automatically off-TPU, and slicing the padding back
off.  Everything returned is numpy and bit-identical to the numpy oracles
in ``repro.core``.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import query as qry
from repro.core.qdtree import FrozenQdTree
from repro.engine.plan import LANE  # noqa: F401 — one authoritative value
from repro.engine.plan import interpret_default as _interpret_default
from repro.kernels import query_intersect as qk


def _pad_to(x: np.ndarray, axis: int, mult: int, fill=0) -> np.ndarray:
    n = x.shape[axis]
    target = max(((n + mult - 1) // mult) * mult, mult)
    if target == n:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - n)
    return np.pad(x, pad, constant_values=fill)


# ---------------------------------------------------------------------------
# Routing — operand packing (engine/plan.py: pack_route_constants,
# path_matrices) and plan caching live in repro.engine.
# ---------------------------------------------------------------------------
def route_records(
    tree: FrozenQdTree,
    records: np.ndarray,
    tile_m: int = 256,
    tile_l: int = LANE,
    interpret: bool | None = None,
) -> np.ndarray:
    """Record → BID via the Pallas path (paper Sec 3.1).

    Dispatches through the tree's attached LayoutEngine so the packed
    operands and the compiled kernel pair are cached per padding bucket.
    """
    from repro.engine import engine_for

    return engine_for(tree).route(
        records,
        backend="pallas",
        tile_m=tile_m,
        tile_l=tile_l,
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Query ↔ block intersection (+ fused scan counting)
# ---------------------------------------------------------------------------
def query_intersect(
    tree: FrozenQdTree,
    wt: qry.WorkloadTensors,
    block_sizes: np.ndarray | None = None,
    tile_l: int = LANE,
    tile_c: int = LANE,
    interpret: bool | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Returns (query_hits (L, n_queries) bool, scanned_per_conj (n_conj,)).

    Mirrors ``rewards.block_query_hits`` (numpy oracle) bit-exactly.
    """
    if interpret is None:
        interpret = _interpret_default()
    schema = tree.schema
    L = tree.n_leaves
    sizes = (
        np.zeros(L, np.float32)
        if block_sizes is None
        else block_sizes.astype(np.float32)
    )
    n_adv = tree.cuts.n_adv
    a3 = max(n_adv, 1)

    leaf_lo = _pad_to(tree.leaf_lo.astype(np.float32), 0, tile_l)
    leaf_hi = _pad_to(tree.leaf_hi.astype(np.float32), 0, tile_l, fill=0)
    leaf_cat = _pad_to(tree.leaf_cat.astype(np.float32), 0, tile_l)
    advt = tree.leaf_adv[:, :, 0] if n_adv else np.zeros((L, 1), bool)
    advf = tree.leaf_adv[:, :, 1] if n_adv else np.zeros((L, 1), bool)
    leaf_advt = _pad_to(advt.astype(np.float32), 0, tile_l)
    leaf_advf = _pad_to(advf.astype(np.float32), 0, tile_l)
    leaf_size = _pad_to(sizes[:, None], 0, tile_l)

    nc = wt.n_conjuncts
    q_lo = _pad_to(wt.q_lo.astype(np.float32), 0, tile_c)
    q_hi = _pad_to(wt.q_hi.astype(np.float32), 0, tile_c, fill=0)
    q_cat = _pad_to(wt.q_cat.astype(np.float32), 0, tile_c)
    reqt = (wt.q_adv == qry.ADV_TRUE).astype(np.float32)
    reqf = (wt.q_adv == qry.ADV_FALSE).astype(np.float32)
    if reqt.shape[1] < a3:
        reqt = np.pad(reqt, ((0, 0), (0, a3 - reqt.shape[1])))
        reqf = np.pad(reqf, ((0, 0), (0, a3 - reqf.shape[1])))
    q_reqt = _pad_to(reqt, 0, tile_c)
    q_reqf = _pad_to(reqf, 0, tile_c)

    numeric_dims = tuple(
        int(i) for i in np.nonzero(~schema.is_categorical)[0]
    )
    off = schema.cat_offsets
    cat_segments = tuple(
        (int(off[d]), int(off[d]) + schema.columns[d].dom)
        for d in np.nonzero(schema.is_categorical)[0]
    )

    hits, scanned = qk.query_intersect_pallas(
        *map(
            jnp.asarray,
            (
                leaf_lo, leaf_hi, leaf_cat, leaf_advt, leaf_advf, leaf_size,
                q_lo, q_hi, q_cat, q_reqt, q_reqf,
            ),
        ),
        tile_l=tile_l,
        tile_c=tile_c,
        numeric_dims=numeric_dims,
        cat_segments=cat_segments,
        n_adv=n_adv,
        interpret=interpret,
    )
    conj_hits = np.asarray(hits)[:L, :nc] > 0.5
    scanned_per_conj = np.asarray(scanned)[0, :nc]
    q_hits = qry.queries_intersect(conj_hits, wt)
    return q_hits, scanned_per_conj
