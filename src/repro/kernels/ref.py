"""Pure-jnp oracles for the Pallas kernels (same inputs, no tiling).

These are the correctness references the kernel tests sweep against; the
end-to-end semantic oracle is ``FrozenQdTree.route`` / ``query.
conjuncts_intersect`` (numpy), which ``ops.py`` wires up identically.

``fused_ingest_ref`` is the *numpy* bit-identity oracle for the fused
single-pass ingestion path: route via the numpy descent, tighten via the
legacy ``IncrementalTightener`` arithmetic, packaged as the same
``(bids, TightenPartial)`` pair every fused backend returns.
``fused_ingest_ops_ref`` mirrors the Pallas kernel at the padded-operand
level (same inputs and f32 outputs, no tiling).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def eval_cuts_ref(
    records_f32,  # (M, D)
    dim_onehot,  # (D, C)
    cutpoint,  # (1, C)
    in_mask_t,  # (B, C)
    is_cat_row,  # (1, D)
    cat_offset_row,  # (1, D)
    adv_cols,  # (A3, 3)
    adv_sel,  # (A3, C)
    kind_row,  # (1, C)
    n_adv: int,
):
    m, d = records_f32.shape
    vals = records_f32 @ dim_onehot
    rng = (vals < cutpoint).astype(jnp.float32)

    bits = in_mask_t.shape[0]
    bitpos = records_f32 + cat_offset_row  # (M, D)
    onehots = (
        bitpos[:, :, None] == jnp.arange(bits, dtype=jnp.float32)
    ).astype(jnp.float32)
    go = (onehots * is_cat_row[0][None, :, None]).sum(axis=1)  # (M, B)
    inm = ((go @ in_mask_t) > 0.5).astype(jnp.float32)

    c = vals.shape[1]
    advm = jnp.zeros((m, c), jnp.float32)
    if n_adv > 0:
        res = []
        for j in range(n_adv):
            ca, op, cb = adv_cols[j, 0], adv_cols[j, 1], adv_cols[j, 2]
            didx = jnp.arange(d, dtype=jnp.float32)
            va = (records_f32 * (didx == ca)).sum(axis=1)
            vb = (records_f32 * (didx == cb)).sum(axis=1)
            t = jnp.select(
                [op == 0, op == 1, op == 2, op == 3, op == 4],
                [va < vb, va <= vb, va > vb, va >= vb, va == vb],
                va != vb,
            )
            res.append(t.astype(jnp.float32))
        pad = adv_sel.shape[0] - n_adv
        adv_res = jnp.stack(res, axis=1)
        if pad:
            adv_res = jnp.concatenate(
                [adv_res, jnp.zeros((m, pad), jnp.float32)], axis=1
            )
        advm = adv_res @ adv_sel

    return jnp.where(
        kind_row == 0.0, rng, jnp.where(kind_row == 1.0, inm, advm)
    )


def locate_leaf_ref(m_mat, pathpos, pathneg, leafid):
    viol = (1.0 - m_mat) @ pathpos + m_mat @ pathneg
    hit = (viol < 0.5).astype(jnp.float32)
    return hit @ leafid[0] - 1.0


def fused_ingest_ref(tree, records):
    """Numpy bit-identity oracle: one batch routed + reduced per leaf.

    Exactly the legacy two-pass arithmetic (``FrozenQdTree.route`` then
    ``IncrementalTightener.update``), returned in the fused-path shape:
    ``(bids int32, TightenPartial)``.  Every fused backend must reproduce
    this bit-for-bit.
    """
    from repro.core.qdtree import IncrementalTightener

    bids = tree.route(records)
    t = IncrementalTightener(tree)
    t.update(records, bids)
    return bids, t.as_partial()


def fused_ingest_ops_ref(
    records_f32,  # (M, D)
    valid,  # (M, 1)
    dim_onehot, cutpoint, in_mask_t, is_cat_row, cat_offset_row,
    adv_cols, adv_sel, kind_row,
    pathpos,  # (C, L)
    pathneg,  # (C, L)
    leafid,  # (1, L)
    n_adv: int,
    big: float = float(2**25),
):
    """Operand-level oracle for ``fused_ingest_pallas`` (same outputs)."""
    m_mat = eval_cuts_ref(
        records_f32, dim_onehot, cutpoint, in_mask_t, is_cat_row,
        cat_offset_row, adv_cols, adv_sel, kind_row, n_adv,
    )
    viol = (1.0 - m_mat) @ pathpos + m_mat @ pathneg
    hit = (viol < 0.5).astype(jnp.float32)  # (M, L)
    bids = hit @ leafid.T  # (M, 1), bid + 1
    hitv = hit * valid
    counts = hitv.sum(axis=0, keepdims=True)  # (1, L)

    d = records_f32.shape[1]
    lo = jnp.stack(
        [
            jnp.where(hitv > 0.5, records_f32[:, dd][:, None], big).min(0)
            for dd in range(d)
        ],
        axis=1,
    )
    hi = jnp.stack(
        [
            jnp.where(hitv > 0.5, records_f32[:, dd][:, None], -big).max(0)
            for dd in range(d)
        ],
        axis=1,
    )

    bits = in_mask_t.shape[0]
    bitpos = records_f32 + cat_offset_row
    onehots = (
        bitpos[:, :, None] == jnp.arange(bits, dtype=jnp.float32)
    ).astype(jnp.float32)
    go = (onehots * is_cat_row[0][None, :, None]).sum(axis=1)  # (M, B)
    cat = ((hitv.T @ go) > 0.5).astype(jnp.float32)  # (L, B)

    a3 = adv_sel.shape[0]
    adv_res = jnp.zeros((records_f32.shape[0], a3), jnp.float32)
    if n_adv > 0:
        didx = jnp.arange(d, dtype=jnp.float32)
        for a in range(n_adv):
            ca, op, cb = adv_cols[a, 0], adv_cols[a, 1], adv_cols[a, 2]
            va = (records_f32 * (didx == ca)).sum(axis=1)
            vb = (records_f32 * (didx == cb)).sum(axis=1)
            t = jnp.select(
                [op == 0, op == 1, op == 2, op == 3, op == 4],
                [va < vb, va <= vb, va > vb, va >= vb, va == vb],
                va != vb,
            )
            adv_res = adv_res.at[:, a].set(t.astype(jnp.float32))
    advtp = hitv.T @ adv_res  # (L, A3)
    advt = (advtp > 0.5).astype(jnp.float32)
    advf = ((counts[0][:, None] - advtp) > 0.5).astype(jnp.float32)
    return bids, counts, lo, hi, cat, advt, advf


def partial_from_fused(tree, counts, lo, hi, cat, advt, advf):
    """Convert fused-kernel f32 aggregates (already sliced to the tree's
    ``n_leaves``) into the numpy tightener's exchange format.

    Dictionary codes are < 2**24, so the f32 → int64 narrowing is exact;
    empty leaves get the tightener's int64 identity elements and ``hi``
    becomes exclusive (max + 1) — bit-identical to
    ``IncrementalTightener.update`` over the same records.
    """
    from repro.core.qdtree import TightenPartial

    i64 = np.iinfo(np.int64)
    counts = np.asarray(counts).astype(np.int64)
    ne = counts > 0
    lo64 = np.where(
        ne[:, None], np.asarray(lo).astype(np.int64), i64.max
    )
    hi64 = np.where(
        ne[:, None], np.asarray(hi).astype(np.int64) + 1, i64.min
    )
    pcat = np.zeros_like(tree.leaf_cat)
    nb = min(pcat.shape[1], cat.shape[1])
    pcat[:, :nb] = np.asarray(cat[:, :nb]) > 0.5
    pcat &= ne[:, None]
    padv = np.zeros_like(tree.leaf_adv)
    na = tree.cuts.n_adv
    if na:
        padv[:, :, 0] = np.asarray(advt[:, :na]) > 0.5
        padv[:, :, 1] = np.asarray(advf[:, :na]) > 0.5
        padv &= ne[:, None, None]
    return TightenPartial(
        counts=counts, lo=lo64, hi=hi64, cat=pcat, adv=padv
    )


def query_intersect_ref(
    leaf_lo, leaf_hi, leaf_cat, leaf_advt, leaf_advf, leaf_size,
    q_lo, q_hi, q_cat, q_reqt, q_reqf,
    numeric_dims, cat_segments, n_adv,
):
    tl, tc = leaf_lo.shape[0], q_lo.shape[0]
    ok = jnp.ones((tl, tc), jnp.float32)
    for d in numeric_dims:
        lo = jnp.maximum(leaf_lo[:, d][:, None], q_lo[:, d][None, :])
        hi = jnp.minimum(leaf_hi[:, d][:, None], q_hi[:, d][None, :])
        ok = ok * (lo < hi).astype(jnp.float32)
    for (s, e) in cat_segments:
        shared = leaf_cat[:, s:e] @ q_cat[:, s:e].T
        ok = ok * (shared > 0.5).astype(jnp.float32)
    for a in range(n_adv):
        ok = ok * (
            1.0 - q_reqt[:, a][None, :] * (1.0 - leaf_advt[:, a][:, None])
        )
        ok = ok * (
            1.0 - q_reqf[:, a][None, :] * (1.0 - leaf_advf[:, a][:, None])
        )
    scanned = leaf_size.T @ ok
    return ok, scanned
