"""Pure-jnp oracles for the Pallas kernels (same inputs, no tiling).

These are the correctness references the kernel tests sweep against; the
end-to-end semantic oracle is ``FrozenQdTree.route`` / ``query.
conjuncts_intersect`` (numpy), which ``ops.py`` wires up identically.
"""

from __future__ import annotations

import jax.numpy as jnp


def eval_cuts_ref(
    records_f32,  # (M, D)
    dim_onehot,  # (D, C)
    cutpoint,  # (1, C)
    in_mask_t,  # (B, C)
    is_cat_row,  # (1, D)
    cat_offset_row,  # (1, D)
    adv_cols,  # (A3, 3)
    adv_sel,  # (A3, C)
    kind_row,  # (1, C)
    n_adv: int,
):
    m, d = records_f32.shape
    vals = records_f32 @ dim_onehot
    rng = (vals < cutpoint).astype(jnp.float32)

    bits = in_mask_t.shape[0]
    bitpos = records_f32 + cat_offset_row  # (M, D)
    onehots = (
        bitpos[:, :, None] == jnp.arange(bits, dtype=jnp.float32)
    ).astype(jnp.float32)
    go = (onehots * is_cat_row[0][None, :, None]).sum(axis=1)  # (M, B)
    inm = ((go @ in_mask_t) > 0.5).astype(jnp.float32)

    c = vals.shape[1]
    advm = jnp.zeros((m, c), jnp.float32)
    if n_adv > 0:
        res = []
        for j in range(n_adv):
            ca, op, cb = adv_cols[j, 0], adv_cols[j, 1], adv_cols[j, 2]
            didx = jnp.arange(d, dtype=jnp.float32)
            va = (records_f32 * (didx == ca)).sum(axis=1)
            vb = (records_f32 * (didx == cb)).sum(axis=1)
            t = jnp.select(
                [op == 0, op == 1, op == 2, op == 3, op == 4],
                [va < vb, va <= vb, va > vb, va >= vb, va == vb],
                va != vb,
            )
            res.append(t.astype(jnp.float32))
        pad = adv_sel.shape[0] - n_adv
        adv_res = jnp.stack(res, axis=1)
        if pad:
            adv_res = jnp.concatenate(
                [adv_res, jnp.zeros((m, pad), jnp.float32)], axis=1
            )
        advm = adv_res @ adv_sel

    return jnp.where(
        kind_row == 0.0, rng, jnp.where(kind_row == 1.0, inm, advm)
    )


def locate_leaf_ref(m_mat, pathpos, pathneg, leafid):
    viol = (1.0 - m_mat) @ pathpos + m_mat @ pathneg
    hit = (viol < 0.5).astype(jnp.float32)
    return hit @ leafid[0] - 1.0


def query_intersect_ref(
    leaf_lo, leaf_hi, leaf_cat, leaf_advt, leaf_advf, leaf_size,
    q_lo, q_hi, q_cat, q_reqt, q_reqf,
    numeric_dims, cat_segments, n_adv,
):
    tl, tc = leaf_lo.shape[0], q_lo.shape[0]
    ok = jnp.ones((tl, tc), jnp.float32)
    for d in numeric_dims:
        lo = jnp.maximum(leaf_lo[:, d][:, None], q_lo[:, d][None, :])
        hi = jnp.minimum(leaf_hi[:, d][:, None], q_hi[:, d][None, :])
        ok = ok * (lo < hi).astype(jnp.float32)
    for (s, e) in cat_segments:
        shared = leaf_cat[:, s:e] @ q_cat[:, s:e].T
        ok = ok * (shared > 0.5).astype(jnp.float32)
    for a in range(n_adv):
        ok = ok * (
            1.0 - q_reqt[:, a][None, :] * (1.0 - leaf_advt[:, a][:, None])
        )
        ok = ok * (
            1.0 - q_reqf[:, a][None, :] * (1.0 - leaf_advf[:, a][:, None])
        )
    scanned = leaf_size.T @ ok
    return ok, scanned
