"""Fused single-pass ingestion kernel: route + tighten in one tiled sweep.

The two-pass hot path reads every record twice — once to route it
(``eval_cuts`` → ``locate_leaf``, paper Sec 3.1) and once to min-max-
tighten its destination leaf's description (``IncrementalTightener``,
Sec 3.2).  Ingestion is I/O-bound, so on the roofline that second pass
halves the attainable throughput.  This kernel does both in ONE pass:

    grid = (m // tile_m, l_pad // tile_l)   — leaf axis innermost

* At each record tile's first leaf step (``j == 0``) the full predicate
  matrix M, the global categorical one-hot GO, and the advanced-cut truth
  bits are evaluated once (the ``eval_cuts`` math) and stashed in VMEM
  scratch — the TPU grid runs sequentially on one core, so scratch
  persists across the ``j`` steps that reuse them.
* At every (record tile i, leaf tile j) step the path-constraint matmuls
  recover the hit matrix (the ``locate_leaf`` math).  BIDs accumulate over
  ``j`` in the revisit pattern of ``query_intersect_pallas``; the per-leaf
  aggregates — counts, min/max bounds, categorical presence, advanced-cut
  truth bits — reduce into *full-array* accumulator outputs whose block
  index never changes, i.e. they stay resident in VMEM for the whole grid
  and are flushed to HBM exactly once.

Padding rows (``valid == 0``) still produce a bid — identical to
``locate_leaf_pallas``, the caller slices them off — but are masked out of
every aggregate, so the partials cover exactly the real records.

All values are dictionary codes < 2**24, so f32 mins/maxes/sums are exact
and the host-side int64 conversion (``engine/backends.py``) reproduces the
numpy tightener bit-for-bit.
"""

from __future__ import annotations

# qdlint: deterministic-module

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# f32-exact sentinel beyond any dictionary code (codes < 2**24)
BIG = float(2**25)


def _fused_ingest_kernel(  # qdlint: jit-body
    # inputs (VMEM refs)
    records_ref,  # (TM, D) f32 — record tile (dictionary codes)
    valid_ref,  # (TM, 1) f32 — 1.0 real record, 0.0 padding row
    dim_onehot_ref,  # (D, C) f32
    cutpoint_ref,  # (1, C) f32
    in_mask_ref,  # (B, C) f32 — transposed IN membership masks
    is_cat_ref,  # (1, D) f32
    cat_off_ref,  # (1, D) f32
    adv_cols_ref,  # (A3, 3) f32 — rows: (col_a, op, col_b)
    adv_sel_ref,  # (A3, C) f32 — one-hot map adv id -> cut column
    kind_ref,  # (1, C) f32
    pathpos_ref,  # (C, TL) f32
    pathneg_ref,  # (C, TL) f32
    leafid_ref,  # (1, TL) f32 — global leaf index + 1 (0 ⇒ padding)
    # outputs
    bids_ref,  # (TM, 1) f32 — accumulates (bid + 1), revisited over j
    counts_ref,  # (1, L) f32 — full-array accumulator
    lo_ref,  # (L, D) f32 — full-array accumulator (init +BIG)
    hi_ref,  # (L, D) f32 — full-array accumulator (init -BIG)
    cat_ref,  # (L, B) f32 — full-array accumulator (presence bits)
    advt_ref,  # (L, A3) f32 — full-array accumulator (truth bits)
    advf_ref,  # (L, A3) f32 — full-array accumulator (falsity bits)
    # scratch (persists across grid steps: the grid is sequential)
    m_scratch,  # (TM, C) f32 — predicate matrix for record tile i
    go_scratch,  # (TM, B) f32 — global categorical one-hot
    adv_scratch,  # (TM, A3) f32 — advanced-predicate truth per record
    *,
    n_adv: int,
    n_cat_bits: int,
    tile_l: int,
):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(jnp.logical_and(i == 0, j == 0))
    def _init_accumulators():
        counts_ref[...] = jnp.zeros_like(counts_ref)
        lo_ref[...] = jnp.full_like(lo_ref, BIG)
        hi_ref[...] = jnp.full_like(hi_ref, -BIG)
        cat_ref[...] = jnp.zeros_like(cat_ref)
        advt_ref[...] = jnp.zeros_like(advt_ref)
        advf_ref[...] = jnp.zeros_like(advf_ref)

    @pl.when(j == 0)
    def _eval_cuts_once_per_record_tile():
        bids_ref[...] = jnp.zeros_like(bids_ref)
        records = records_ref[...]  # (TM, D)
        tm, d_total = records.shape

        # range cuts: one-hot column select (MXU) + compare
        vals = jnp.dot(
            records, dim_onehot_ref[...], preferred_element_type=jnp.float32
        )  # (TM, C)
        rng = (vals < cutpoint_ref[...]).astype(jnp.float32)

        # IN cuts: global categorical one-hot × membership masks
        bit_iota = jax.lax.broadcasted_iota(
            jnp.float32, (tm, n_cat_bits), 1
        )
        bitpos = records + cat_off_ref[...]
        is_cat = is_cat_ref[...]
        go = jnp.zeros((tm, n_cat_bits), jnp.float32)
        for d in range(d_total):  # static loop over table columns
            hit_d = (bit_iota == bitpos[:, d][:, None]).astype(jnp.float32)
            go = go + hit_d * is_cat[0, d]
        inm = jnp.dot(
            go, in_mask_ref[...], preferred_element_type=jnp.float32
        )
        inm = (inm > 0.5).astype(jnp.float32)

        # advanced cuts: static small loop over binary predicates
        c = vals.shape[1]
        advm = jnp.zeros((tm, c), jnp.float32)
        adv_res = jnp.zeros((tm, adv_sel_ref.shape[0]), jnp.float32)
        if n_adv > 0:
            for a in range(n_adv):
                col_a = adv_cols_ref[a, 0]
                op = adv_cols_ref[a, 1]
                col_b = adv_cols_ref[a, 2]
                d_iota = jax.lax.broadcasted_iota(
                    jnp.float32, (tm, d_total), 1
                )
                va = jnp.sum(
                    records * (d_iota == col_a).astype(jnp.float32), axis=1
                )
                vb = jnp.sum(
                    records * (d_iota == col_b).astype(jnp.float32), axis=1
                )
                t = jnp.select(
                    [op == 0, op == 1, op == 2, op == 3, op == 4],
                    [va < vb, va <= vb, va > vb, va >= vb, va == vb],
                    va != vb,
                ).astype(jnp.float32)
                adv_res = adv_res.at[:, a].set(t)
            advm = jnp.dot(
                adv_res, adv_sel_ref[...], preferred_element_type=jnp.float32
            )

        kind = kind_ref[...]
        m_scratch[...] = jnp.where(
            kind == 0.0, rng, jnp.where(kind == 1.0, inm, advm)
        )
        go_scratch[...] = go
        adv_scratch[...] = adv_res

    # -- leaf location for this (record tile, leaf tile) -------------------
    m = m_scratch[...]
    viol = jnp.dot(
        1.0 - m, pathpos_ref[...], preferred_element_type=jnp.float32
    ) + jnp.dot(m, pathneg_ref[...], preferred_element_type=jnp.float32)
    hit = (viol < 0.5).astype(jnp.float32)  # (TM, TL)
    # bids: identical to locate_leaf_pallas (padding rows included; the
    # host slices them off) — accumulated across leaf tiles
    bids_ref[...] += jnp.dot(
        hit, leafid_ref[...].T, preferred_element_type=jnp.float32
    )

    # -- per-leaf tightening partials (valid rows only) ---------------------
    valid = valid_ref[...]  # (TM, 1)
    hitv = hit * valid  # (TM, TL)
    sl = pl.ds(j * tile_l, tile_l)

    tile_counts = jnp.sum(hitv, axis=0, keepdims=True)  # (1, TL)
    counts_ref[:, sl] = counts_ref[:, sl] + tile_counts

    records = records_ref[...]
    lo_cols = []
    hi_cols = []
    for d in range(records.shape[1]):  # static loop over table columns
        col = records[:, d][:, None]  # (TM, 1)
        lo_cols.append(jnp.min(jnp.where(hitv > 0.5, col, BIG), axis=0))
        hi_cols.append(jnp.max(jnp.where(hitv > 0.5, col, -BIG), axis=0))
    lo_ref[sl, :] = jnp.minimum(
        lo_ref[sl, :], jnp.stack(lo_cols, axis=1)
    )
    hi_ref[sl, :] = jnp.maximum(
        hi_ref[sl, :], jnp.stack(hi_cols, axis=1)
    )

    # categorical presence: any hit record carrying bit b (mask matmul, MXU)
    catp = jnp.dot(
        hitv.T, go_scratch[...], preferred_element_type=jnp.float32
    )  # (TL, B)
    cat_ref[sl, :] = jnp.maximum(
        cat_ref[sl, :], (catp > 0.5).astype(jnp.float32)
    )

    # advanced-cut truth bits: Σ hitv·t  and  (Σ hitv) − Σ hitv·t
    advtp = jnp.dot(
        hitv.T, adv_scratch[...], preferred_element_type=jnp.float32
    )  # (TL, A3)
    advfp = tile_counts[0][:, None] - advtp
    advt_ref[sl, :] = jnp.maximum(
        advt_ref[sl, :], (advtp > 0.5).astype(jnp.float32)
    )
    advf_ref[sl, :] = jnp.maximum(
        advf_ref[sl, :], (advfp > 0.5).astype(jnp.float32)
    )


@functools.partial(
    jax.jit,
    static_argnames=("tile_m", "tile_l", "n_cat_bits", "n_adv", "interpret"),
)
def fused_ingest_pallas(
    records_f32: jnp.ndarray,  # (M, D) f32, M % tile_m == 0
    valid: jnp.ndarray,  # (M, 1) f32
    dim_onehot: jnp.ndarray,  # (D, C)
    cutpoint: jnp.ndarray,  # (1, C)
    in_mask_t: jnp.ndarray,  # (B, C)
    is_cat_row: jnp.ndarray,  # (1, D)
    cat_offset_row: jnp.ndarray,  # (1, D)
    adv_cols: jnp.ndarray,  # (A3, 3)
    adv_sel: jnp.ndarray,  # (A3, C)
    kind_row: jnp.ndarray,  # (1, C)
    pathpos: jnp.ndarray,  # (C, L)
    pathneg: jnp.ndarray,  # (C, L)
    leafid: jnp.ndarray,  # (1, L)
    *,
    tile_m: int,
    tile_l: int,
    n_cat_bits: int,
    n_adv: int,
    interpret: bool,
):
    """One fused pass: returns (bids+1, counts, lo, hi, cat, advt, advf).

    ``bids`` is (M, 1) f32 holding bid + 1 (0 on rows matching no real
    leaf, i.e. never for valid rows); all aggregates are f32 at the padded
    leaf geometry ``L`` and get sliced/converted by the caller.
    """
    m, d = records_f32.shape
    c = dim_onehot.shape[1]
    b = in_mask_t.shape[0]
    a3 = adv_sel.shape[0]
    n_leaf = pathpos.shape[1]
    grid = (m // tile_m, n_leaf // tile_l)  # leaf axis innermost
    kernel = functools.partial(
        _fused_ingest_kernel,
        n_adv=n_adv,
        n_cat_bits=n_cat_bits,
        tile_l=tile_l,
    )
    full = lambda *shape: [
        pl.BlockSpec(shape, lambda i, j: (0,) * len(shape))
    ]
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, d), lambda i, j: (i, 0)),  # records
            pl.BlockSpec((tile_m, 1), lambda i, j: (i, 0)),  # valid
            *full(d, c),  # dim_onehot
            *full(1, c),  # cutpoint
            *full(b, c),  # in_mask^T
            *full(1, d),  # is_cat
            *full(1, d),  # cat_offset
            *full(a3, 3),  # adv_cols
            *full(a3, c),  # adv_sel
            *full(1, c),  # kind
            pl.BlockSpec((c, tile_l), lambda i, j: (0, j)),  # pathpos
            pl.BlockSpec((c, tile_l), lambda i, j: (0, j)),  # pathneg
            pl.BlockSpec((1, tile_l), lambda i, j: (0, j)),  # leafid
        ],
        out_specs=[
            pl.BlockSpec((tile_m, 1), lambda i, j: (i, 0)),  # bids
            *full(1, n_leaf),  # counts
            *full(n_leaf, d),  # lo
            *full(n_leaf, d),  # hi
            *full(n_leaf, b),  # cat
            *full(n_leaf, a3),  # advt
            *full(n_leaf, a3),  # advf
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, n_leaf), jnp.float32),
            jax.ShapeDtypeStruct((n_leaf, d), jnp.float32),
            jax.ShapeDtypeStruct((n_leaf, d), jnp.float32),
            jax.ShapeDtypeStruct((n_leaf, b), jnp.float32),
            jax.ShapeDtypeStruct((n_leaf, a3), jnp.float32),
            jax.ShapeDtypeStruct((n_leaf, a3), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tile_m, c), jnp.float32),
            pltpu.VMEM((tile_m, b), jnp.float32),
            pltpu.VMEM((tile_m, a3), jnp.float32),
        ],
        interpret=interpret,
    )(
        records_f32, valid,
        dim_onehot, cutpoint, in_mask_t, is_cat_row, cat_offset_row,
        adv_cols, adv_sel, kind_row,
        pathpos, pathneg, leafid,
    )
    return outs
