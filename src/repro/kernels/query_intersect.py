"""Pallas TPU kernel for query↔block intersection + fused skip counting.

Computes, for a tile of block descriptions × a tile of workload conjuncts
(paper Sec 3.3):

    hits[l, c]  = 1  iff block l may contain records matching conjunct c
    scanned[c] += Σ_l |block l| · hits[l, c]      (fused Eq.-1 reduction)

Numeric box overlap is a static loop of broadcast compares (VPU); the
categorical any-shared-value test per dim is a mask matmul over that dim's
bit segment (MXU); advanced-cut polarity checks are a small static loop.

Grid = (n_conj_tiles, n_leaf_tiles) with the *leaf* axis innermost so the
``scanned`` accumulator block (0, c) is revisited in consecutive grid steps.
"""

from __future__ import annotations

# qdlint: deterministic-module

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _intersect_kernel(  # qdlint: jit-body
    leaf_lo_ref,  # (TL, D) f32
    leaf_hi_ref,  # (TL, D) f32
    leaf_cat_ref,  # (TL, B) f32
    leaf_advt_ref,  # (TL, A) f32 — may contain satisfying records
    leaf_advf_ref,  # (TL, A) f32 — may contain violating records
    leaf_size_ref,  # (TL, 1) f32
    q_lo_ref,  # (TC, D) f32
    q_hi_ref,  # (TC, D) f32
    q_cat_ref,  # (TC, B) f32
    q_reqt_ref,  # (TC, A) f32 — conjunct requires pred true
    q_reqf_ref,  # (TC, A) f32 — conjunct requires pred false
    hits_ref,  # out (TL, TC) f32
    scanned_ref,  # out (1, TC) f32, accumulated over leaf tiles
    *,
    numeric_dims: tuple[int, ...],
    cat_segments: tuple[tuple[int, int], ...],
    n_adv: int,
):
    i_leaf = pl.program_id(1)

    @pl.when(i_leaf == 0)
    def _init():
        scanned_ref[...] = jnp.zeros_like(scanned_ref)

    tl = leaf_lo_ref.shape[0]
    tc = q_lo_ref.shape[0]
    ok = jnp.ones((tl, tc), jnp.float32)

    # numeric box overlap: max(lo) < min(hi), per dim (static unroll)
    for d in numeric_dims:
        lo = jnp.maximum(leaf_lo_ref[:, d][:, None], q_lo_ref[:, d][None, :])
        hi = jnp.minimum(leaf_hi_ref[:, d][:, None], q_hi_ref[:, d][None, :])
        ok = ok * (lo < hi).astype(jnp.float32)

    # categorical: each dim must share ≥1 allowed value (mask matmul per dim)
    for (s, e) in cat_segments:
        shared = jnp.dot(
            leaf_cat_ref[:, s:e],
            q_cat_ref[:, s:e].T,
            preferred_element_type=jnp.float32,
        )
        ok = ok * (shared > 0.5).astype(jnp.float32)

    # advanced-cut polarity compatibility
    for a in range(n_adv):
        may_t = leaf_advt_ref[:, a][:, None]
        may_f = leaf_advf_ref[:, a][:, None]
        req_t = q_reqt_ref[:, a][None, :]
        req_f = q_reqf_ref[:, a][None, :]
        ok = ok * (1.0 - req_t * (1.0 - may_t))
        ok = ok * (1.0 - req_f * (1.0 - may_f))

    hits_ref[...] = ok
    scanned_ref[...] += jnp.dot(
        leaf_size_ref[...].T, ok, preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "tile_l", "tile_c", "numeric_dims", "cat_segments", "n_adv",
        "interpret",
    ),
)
def query_intersect_pallas(
    leaf_lo, leaf_hi, leaf_cat, leaf_advt, leaf_advf, leaf_size,
    q_lo, q_hi, q_cat, q_reqt, q_reqf,
    *,
    tile_l: int,
    tile_c: int,
    numeric_dims: tuple[int, ...],
    cat_segments: tuple[tuple[int, int], ...],
    n_adv: int,
    interpret: bool,
):
    l, d = leaf_lo.shape
    c = q_lo.shape[0]
    b = leaf_cat.shape[1]
    a = leaf_advt.shape[1]
    grid = (c // tile_c, l // tile_l)  # leaf axis innermost (accumulator)
    kernel = functools.partial(
        _intersect_kernel,
        numeric_dims=numeric_dims,
        cat_segments=cat_segments,
        n_adv=n_adv,
    )
    leaf_spec = lambda width: pl.BlockSpec(
        (tile_l, width), lambda j, i: (i, 0)
    )
    conj_spec = lambda width: pl.BlockSpec(
        (tile_c, width), lambda j, i: (j, 0)
    )
    hits, scanned = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            leaf_spec(d),  # leaf_lo
            leaf_spec(d),  # leaf_hi
            leaf_spec(b),  # leaf_cat
            leaf_spec(a),  # leaf_advt
            leaf_spec(a),  # leaf_advf
            leaf_spec(1),  # leaf_size
            conj_spec(d),  # q_lo
            conj_spec(d),  # q_hi
            conj_spec(b),  # q_cat
            conj_spec(a),  # q_reqt
            conj_spec(a),  # q_reqf
        ],
        out_specs=[
            pl.BlockSpec((tile_l, tile_c), lambda j, i: (i, j)),
            pl.BlockSpec((1, tile_c), lambda j, i: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((l, c), jnp.float32),
            jax.ShapeDtypeStruct((1, c), jnp.float32),
        ],
        interpret=interpret,
    )(
        leaf_lo, leaf_hi, leaf_cat, leaf_advt, leaf_advf, leaf_size,
        q_lo, q_hi, q_cat, q_reqt, q_reqf,
    )
    return hits, scanned
