"""LayoutService: one lifecycle API over qd-tree layouts.

Construction (the builder registry), serving (routing / batched query
routing through the LayoutEngine), and online re-optimization (versioned
rebuild with hot swap) behind a single facade:

    svc = LayoutService.build(records, workload, strategy="greedy")
    bids = svc.route(records)                 # live tree, any backend
    lists = svc.route_queries(workload)       # batched BID IN (...) lists
    report = svc.rebuild(recent, workload)    # candidate → score → hot swap

Versioning: every deployed tree gets a monotonically-increasing generation.
All generations share ONE compiled-plan cache — plan keys include the tree
signature (engine/plan.py), so the plans of the outgoing tree stay valid and
warm during a swap, and queries in flight against the old engine keep
routing bit-identically until :meth:`release` drops that generation and
evicts its plans.  ``rebuild`` builds a candidate on recent data, scores it
against the live tree with the paper's Eq. 1 skip rate, and swaps only on
strict improvement (or ``swap="always"``); :meth:`rollback` restores any
retained generation.  This is the "tree rebuild-in-place" step toward the
dynamic-layout follow-up (arXiv:2405.04984) and the online re-optimization
loop of Lachesis (arXiv:2006.16529).
"""

from __future__ import annotations

# qdlint: deterministic-module (timings use perf_counter and are
# reported, never folded into layouts or plan keys)

import dataclasses
import threading
import time
import warnings
from concurrent.futures import Executor  # noqa: F401 (re-export for callers)
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core import query as qry
from repro.core.qdtree import FrozenQdTree
from repro.engine import LayoutEngine, PlanCache
from repro.engine.engine import WorkloadTensorCache
from repro.engine import plan as planlib
from repro.engine.plan import PlanKey
from repro.service.builders import LayoutBuild, build_layout
from repro.service.epoch import Epoch
from repro.service.options import (
    IngestOptions,
    RebuildPolicy,
    resolve_ingest_options,
)
from repro.service.replica import (
    ReplicaRebuildReport,
    ReplicaRoute,
    ReplicaSet,
    block_sizes_for,
    cheapest_scanned_fraction,
    cluster_workloads,
    materialize_mix,
    workload_signature_weights,
)


@dataclasses.dataclass
class LayoutVersion:
    """One deployed tree: generation counter + its engine + build artifact.

    ``replica_id`` is the tree's position in the :class:`ReplicaSet` it
    was deployed into (0 for the primary — and for every version of a
    single-copy service).
    """

    generation: int
    build: LayoutBuild
    engine: LayoutEngine
    replica_id: int = 0

    @property
    def tree(self) -> FrozenQdTree:
        return self.build.tree


@dataclasses.dataclass
class RebuildReport:
    """Outcome of one ``rebuild`` cycle."""

    strategy: str
    build: LayoutBuild  # the candidate (deployed iff ``swapped``)
    candidate_scanned: float  # Eq. 1 scanned fraction on the rebuild inputs
    live_scanned: float
    swapped: bool
    old_generation: int
    new_generation: int  # == old_generation when not swapped
    build_s: float
    score_s: float

    @property
    def improvement(self) -> float:
        return self.live_scanned - self.candidate_scanned


class LayoutService:
    """Versioned layout lifecycle: build → serve → rebuild/swap/rollback."""

    def __init__(
        self,
        layout: LayoutBuild | FrozenQdTree,
        backend: str = "jax",
        interpret: Optional[bool] = None,
        plan_cache: Optional[PlanCache] = None,
    ):
        if isinstance(layout, FrozenQdTree):
            layout = _adopt_tree(layout)
        self.backend = backend
        self.interpret = interpret
        self.plans = plan_cache if plan_cache is not None else PlanCache()
        # one workload-tensor LRU for every generation: entries key on the
        # cut-table *content* signature, so a hot swap to a tree built from
        # an equal cut table keeps standing workloads tensorized
        self._wt_cache = WorkloadTensorCache()
        self._lock = threading.Lock()
        self._gen = 0  # guarded by: self._lock
        self._versions: dict[int, LayoutVersion] = {}  # guarded by: self._lock
        self._swap_listeners: list[Callable[[LayoutVersion], None]] = []  # guarded by: self._lock
        # resident ProcessShardSessions for sharded ingest, keyed by
        # (generation, shards, batch, fused, backend): the tree replica
        # ships to the spawn workers once per generation, not per call
        self._sessions: dict[tuple, object] = {}  # guarded by: self._lock
        # fleet-coordinator registrations: id(coordinator) -> (coordinator,
        # WorkerHandle); the coordinator object is pinned so ids stay unique
        self._coordinators: dict[int, tuple] = {}  # guarded by: self._lock
        self._live = self._new_version(layout)  # swap-guarded by: self._lock
        self._rset = ReplicaSet(  # swap-guarded by: self._lock
            (self._live,),
            (block_sizes_for(self._live.build, self._live.tree.n_leaves),),
        )

    # -- construction --------------------------------------------------------
    @classmethod
    def build(
        cls,
        records: np.ndarray,
        workload: qry.Workload,
        strategy: str = "greedy",
        backend: str = "jax",
        **cfg,
    ) -> "LayoutService":
        """Build an initial layout with any registered strategy and serve it."""
        return cls(
            build_layout(records, workload, strategy=strategy, **cfg),
            backend=backend,
        )

    def _new_version(  # qdlint: holds-lock
        self,
        build: LayoutBuild,
        replica_id: int = 0,
        engine: Optional[LayoutEngine] = None,
    ) -> LayoutVersion:
        # all versions share self.plans: plan keys carry the tree signature,
        # so old and new compiled plans coexist during a cutover
        eng = engine if engine is not None else LayoutEngine(
            build.tree,
            backend=self.backend,
            interpret=self.interpret,
            plan_cache=self.plans,
            wt_cache=self._wt_cache,
        )
        self._gen += 1
        v = LayoutVersion(
            generation=self._gen, build=build, engine=eng,
            replica_id=replica_id,
        )
        self._versions[v.generation] = v
        return v

    # -- introspection -------------------------------------------------------
    @property
    def generation(self) -> int:
        """Generation of the live tree."""
        return self._live.generation

    @property
    def engine(self) -> LayoutEngine:
        """The live engine (grab once for a consistent view across calls)."""
        return self._live.engine

    @property
    def tree(self) -> FrozenQdTree:
        return self._live.tree

    def live_version(self) -> LayoutVersion:
        """The live :class:`LayoutVersion` — ONE read of the swap pointer.

        Callers that must route and report against a single consistent
        generation (the serving tier's dispatch loop) grab this once and
        use ``v.engine``/``v.tree``/``v.generation`` together; reading the
        ``engine``/``generation`` properties separately can straddle a
        concurrent hot swap.
        """
        return self._live

    def live_epoch(self) -> Epoch:
        """The primary replica's serving :class:`Epoch`.

        Hot swaps and rollbacks change the generation; in-place
        tightening during ingest bumps the live tree's description
        version (changing ``query_hits`` results without a swap).  Either
        movement retires every result computed under the old epoch — this
        is the result-cache invalidation key (`repro.serve.cache`).
        Replicated services have one epoch per replica:
        :meth:`live_epochs`.
        """
        live = self._live
        return Epoch(live.generation, planlib.desc_version(live.tree), 0)

    def live_epochs(self) -> tuple[Epoch, ...]:
        """Per-replica serving epochs of the live ReplicaSet (one
        consistent read; index == replica_id)."""
        return self._rset.epochs()

    def live_replica_set(self) -> ReplicaSet:
        """The live :class:`ReplicaSet` — ONE read of the swap pointer
        (same consistency contract as :meth:`live_version`; its
        ``primary`` is the version every single-tree API serves)."""
        return self._rset

    def replica_generations(self) -> tuple[int, ...]:
        """Live generation per replica, index == replica_id."""
        return self._rset.generations()

    def versions(self) -> tuple[int, ...]:
        """Retained generations, oldest first."""
        with self._lock:
            return tuple(sorted(self._versions))

    def version(self, generation: int) -> LayoutVersion:
        with self._lock:
            return self._versions[generation]

    def stats(self) -> dict:
        return {
            "generation": self.generation,
            "versions": self.versions(),
            "backend": self.backend,
            "replicas": self._rset.k,
            "replica_generations": self.replica_generations(),
            "plan_cache": self.plans.stats(),
        }

    # -- serving facade (always the live tree) ------------------------------
    def route(self, records: np.ndarray, **kw) -> np.ndarray:
        return self._live.engine.route(records, **kw)

    def query_hits(self, workload, **kw) -> np.ndarray:
        return self._live.engine.query_hits(workload, **kw)

    def route_query(self, query: qry.Query, **kw) -> np.ndarray:
        return self._live.engine.route_query(query, **kw)

    def route_queries(self, workload, **kw) -> list[np.ndarray]:
        return self._live.engine.route_queries(workload, **kw)

    def serve(
        self, workload, tracker=None, tick: bool = True, **kw
    ) -> list[np.ndarray]:
        """Serve one batch of live queries: batched ``route_queries``
        against the live tree, optionally observed into a
        :class:`~repro.service.tracker.WorkloadTracker`.

        This is the workload auto-detection seam: with ``tracker`` set,
        each served query's canonical predicate signature is recorded, and
        ``tick=True`` (default) closes the serving round afterwards — one
        exponential-decay generation per ``serve`` call, so the inferred
        mix follows what users are asking *now*.  Sharded serving gives
        each worker its own tracker and folds the states
        (``tracker.merge_state`` / ``repro.service.tracker.merge_states``)
        — bit-identical to single-stream tracking, same algebra as
        ``ShardState``.
        """
        lists = self._live.engine.route_queries(
            workload, track=tracker, **kw
        )
        if tracker is not None and tick:
            tracker.tick()
        return lists

    def workload_tracker(self, config=None):
        """A :class:`~repro.service.tracker.WorkloadTracker` bound to the
        live schema — pass it to :meth:`serve`/``route_queries(track=...)``
        and to ``auto_rebuilder(workload="auto", tracker=...)`` to close
        the queries-in → layouts-out loop without a declared workload."""
        from repro.service.tracker import WorkloadTracker

        return WorkloadTracker(self.tree.schema, config=config)

    def skip_stats(self, records, workload, **kw):
        return self._live.engine.skip_stats(records, workload, **kw)

    def ingest(
        self,
        records,  # np.ndarray | Iterable[np.ndarray]
        options: Optional[IngestOptions] = None,
        **kw,
    ):
        """Ingestion into the live primary — the ONE ingest entry point.

        ``records`` is either an iterable of micro-batches (streamed
        through ``LayoutEngine.ingest``) or a single record array, which
        is micro-batched at ``options.batch`` rows.  Everything else is
        :class:`IngestOptions`:

        * ``shards=k`` (k >= 2; needs a record array) splits the stream
          across k ShardIngestors — resident spawn-pool workers by
          default (``executor``) — folds their ShardStates
          associatively, and publishes the merged tightening under the
          service lock.  Bit-identical to the streaming path over the
          same records.  The per-generation worker sessions are cached
          on the service, so the tree replica ships to the pool once per
          generation, not once per call.
        * ``monitor`` (an :class:`~repro.service.drift.AutoRebuilder`)
          tees batches into the monitor's reservoir and scores them
          against its standing workload (Eq. 1 per-batch accounting
          through the compiled plan); the monitor may fire a background
          rebuild mid-stream.
        * ``coordinator`` (a :class:`~repro.coordinator.FleetCoordinator`)
          turns the run into a fleet worker: route and aggregate here,
          publish THERE — the merged ShardState is submitted for the
          coordinator's cadence fold instead of being applied locally.

        Remaining ``**kw`` passes through to the engine layer
        (``tighten=``, ``buffers=``, ``backend=`` ...).

        The run routes/tightens the engine captured at call time — a
        concurrent hot swap takes effect for the *next* call.  On the
        streaming path, post-swap observations (which still measure the
        superseded tree) are dropped rather than fed to the freshly
        rebaselined monitor; on the sharded path, liveness is re-checked
        under the lock at publish time and a stale run returns its
        (still-valid) aggregates with ``published=False,
        stale_generation=True``.

        Replicated services ingest into the primary replica; secondary
        replicas are read-optimized copies refreshed by the next
        ``rebuild_replicas`` deploy (see ``repro.service.replica``).
        """
        options = resolve_ingest_options(options, kw, "ingest")
        shards = options.shards or 1
        sharded = shards >= 2 or options.coordinator is not None
        if isinstance(records, np.ndarray):
            if sharded:
                return self._ingest_sharded(records, shards, options, kw)
            from repro.engine.sharded import micro_batches

            batches = micro_batches(records, options.batch)
        elif sharded:
            raise TypeError(
                "IngestOptions(shards=/coordinator=) needs a record "
                "array, not a batch iterable"
            )
        else:
            batches = records
        live = self._live
        monitor = options.monitor
        if options.observe is not None:
            kw["observe"] = options.observe
        kw.setdefault("fused", options.fused)
        if monitor is not None:
            # a workload="auto" monitor resolves to the tracker-inferred
            # live mix here, at the start of each run; an empty inference
            # (nothing served yet) skips accounting rather than probing a
            # zero-query workload
            if "observe" not in kw:
                observed = monitor.current_workload()
                if observed is not None and len(observed):
                    kw["observe"] = observed

            def _observe_if_live(stat):
                if self._live is live:
                    monitor.observe(stat)

            kw.setdefault("on_observation", _observe_if_live)
            batches = monitor.tee(batches)
        return live.engine.ingest(batches, **kw)

    def _ingest_sharded(self, records, n_shards, options, kw):
        """The sharded arm of :meth:`ingest` (record array, shards >= 2
        and/or a fleet coordinator)."""
        from repro.engine.sharded import sharded_ingest

        live = self._live  # consistent engine/tree view for the whole run
        monitor = options.monitor
        coordinator = options.coordinator
        if options.observe is not None:
            kw["observe"] = options.observe
        kw.setdefault("fused", options.fused)
        if monitor is not None and "observe" not in kw:
            observed = monitor.current_workload()
            if observed is not None and len(observed):
                kw["observe"] = observed
        session = None
        if options.executor == "process" or (
            options.executor is None and n_shards >= 2
        ):
            session = self._shard_session(live, n_shards, options, kw)
        if coordinator is not None:
            # route-and-aggregate only: the coordinator owns every
            # publish, so local tightening is off and the merged partial
            # ships to its cadence fold instead
            kw.setdefault("tighten", False)
            kw["keep_state"] = True
        report = sharded_ingest(
            live.engine, records, n_shards, batch=options.batch,
            executor=options.executor, lock=self._lock,
            publish_check=lambda: self._live is live,
            session=session, **kw,
        )
        if coordinator is not None and report.state is not None:
            state = report.state
            if state.chunks:
                # the fleet protocol ships aggregates, never rows: any
                # spill chunks were already drained into the caller's
                # local buffers by sharded_ingest
                state = dataclasses.replace(state, chunks={})
            coordinator.submit(
                self._coordinator_handle(coordinator),
                state=state,
                generation=live.generation,
            )
        if monitor is not None:
            monitor.add_records(records)
            if report.observation is not None:
                monitor.observe(report.observation)
        return report

    def _shard_session(self, live, n_shards, options, kw):
        """The cached resident worker session for this (generation, shape).

        Sessions of superseded generations are closed and dropped on the
        way — their replicas route the outgoing tree and must not serve
        another round.
        """
        from repro.engine.sharded import ProcessShardSession

        backend = kw.get("backend")
        key = (
            live.generation, n_shards, options.batch, options.fused,
            backend,
        )
        with self._lock:
            dropped = [
                self._sessions.pop(k)
                for k in list(self._sessions)
                if k[0] != live.generation
            ]
            session = self._sessions.get(key)
            if session is None:
                session = ProcessShardSession(
                    live.engine, n_shards, batch=options.batch,
                    backend=backend, fused=options.fused,
                )
                self._sessions[key] = session
        for s in dropped:
            s.close()
        return session

    def close_ingest_sessions(self) -> None:
        """Release every cached sharded-ingest worker session (the
        resident spawn pool itself is module-owned:
        ``repro.engine.sharded.shutdown_process_pool``)."""
        with self._lock:
            sessions, self._sessions = list(self._sessions.values()), {}
        for s in sessions:
            s.close()

    def _coordinator_handle(self, coordinator):
        """This service's :class:`~repro.coordinator.WorkerHandle` with
        ``coordinator`` (registered once per coordinator object)."""
        with self._lock:
            entry = self._coordinators.get(id(coordinator))
            if entry is None:
                entry = (
                    coordinator,
                    coordinator.register(f"svc-{id(self):x}"),
                )
                self._coordinators[id(coordinator)] = entry
        return entry[1]

    def ingest_sharded(
        self,
        records: np.ndarray,
        n_shards: int,
        batch: int = 2048,
        options: Optional[IngestOptions] = None,
        **kw,
    ):
        """Deprecated spelling of ``ingest(records,
        IngestOptions(shards=n_shards, batch=batch))`` — forwards there
        (one release), then this method goes away."""
        warnings.warn(
            "ingest_sharded(records, n_shards, batch=...) is deprecated; "
            "use ingest(records, IngestOptions(shards=..., batch=...))",
            DeprecationWarning,
            stacklevel=2,
        )
        options = resolve_ingest_options(options, kw, "ingest_sharded")
        return self.ingest(
            records,
            dataclasses.replace(options, shards=n_shards, batch=batch),
            **kw,
        )

    def apply_partial(self, state, expected=None) -> bool:
        """Publish a merged :class:`~repro.engine.sharded.ShardState`
        tightening into the live tree; returns True iff it landed.

        The fleet-coordinator publish seam (``repro.coordinator``): fold
        worker partials anywhere — other processes, other hosts — and
        apply the merged aggregate here under the service lock, with the
        same ``IncrementalTightener.apply`` + description-version bump a
        local ``ingest`` run performs.  ``expected`` (a
        :class:`LayoutVersion`, usually from :meth:`live_version` at
        routing time) makes the publish a compare-and-check: if a rebuild
        swapped the live tree while the partials were in flight, nothing
        is mutated and False is returned — the exact stale-generation
        discipline of ``ingest_sharded``.
        """
        from repro.engine.sharded import MergeCoordinator

        with self._lock:
            live = self._live
            if expected is not None and live is not expected:
                return False
            if state.n_leaves != live.tree.n_leaves:
                raise ValueError(
                    f"partial has {state.n_leaves} leaves; live tree has "
                    f"{live.tree.n_leaves} (built against another layout?)"
                )
            coordinator = MergeCoordinator(live.tree)
            coordinator.add(state)
            coordinator.publish()
            return True

    def auto_rebuilder(self, policy: RebuildPolicy, **kw):
        """An :class:`~repro.service.drift.AutoRebuilder` bound to this
        service: pass it as the ingest monitor and the service becomes
        self-optimizing — skip-rate drift past the configured policy
        triggers a background ``rebuild`` whose deployment rides the same
        compare-and-swap as manual rebuilds.

        Takes one :class:`RebuildPolicy`::

            svc.auto_rebuilder(RebuildPolicy(workload="auto", tracker=t,
                                             drift=DriftConfig(...)))

        A policy with ``replicas > 1`` makes triggered rebuilds deploy a
        k-replica set (``rebuild_replicas``) instead of a single tree.
        ``RebuildPolicy.workload`` is either a declared standing
        :class:`~repro.core.query.Workload` or the string ``"auto"``:
        then drift accounting and rebuilds score against the live mix a
        :class:`~repro.service.tracker.WorkloadTracker` inferred from the
        serving path (``RebuildPolicy(tracker=...)`` shares the one
        :meth:`serve` records into; omitted, a fresh
        :meth:`workload_tracker` is created and exposed as
        ``rebuilder.tracker``).  Remaining ``**kw`` (``reservoir=``,
        ``on_event=``) forwards to ``AutoRebuilder.from_policy``.
        """
        from repro.service.drift import AutoRebuilder

        if not isinstance(policy, RebuildPolicy):
            raise TypeError(
                "auto_rebuilder takes a RebuildPolicy; the loose "
                "auto_rebuilder(workload, config=, tracker=) kwargs were "
                "removed after their deprecation release — use "
                "RebuildPolicy(workload=..., drift=..., tracker=...)"
            )
        return AutoRebuilder.from_policy(self, policy, **kw)

    # -- lifecycle: swap / rollback / release --------------------------------
    def subscribe(self, listener: Callable[[LayoutVersion], None]) -> None:
        """Register a callback fired after every live-version change.

        The callback receives the NEW live :class:`LayoutVersion` and runs
        on the swapping thread, outside the service lock (it may call back
        into the service).  The serving tier uses this to invalidate its
        result cache and warm the incoming generation's plans promptly,
        rather than discovering the swap at the next dispatch.
        """
        with self._lock:
            self._swap_listeners.append(listener)

    def unsubscribe(self, listener: Callable[[LayoutVersion], None]) -> None:
        with self._lock:
            try:
                self._swap_listeners.remove(listener)
            except ValueError:
                pass

    def _notify_swap(self, v: LayoutVersion) -> None:
        with self._lock:
            listeners = tuple(self._swap_listeners)
        for fn in listeners:
            fn(v)

    def swap(self, build: LayoutBuild) -> int:
        """Deploy ``build`` as the new PRIMARY generation (atomic);
        returns it.  Secondary replicas keep serving untouched — their
        cache entries stay valid (per-replica invalidation)."""
        with self._lock:
            v = self._new_version(build)
            self._live = v  # single reference assignment — atomic swap
            self._rset = self._rset.replace(
                0, v, block_sizes_for(build, build.tree.n_leaves)
            )
        self._notify_swap(v)
        return v.generation

    def _swap_if_live_is(
        self, expected: LayoutVersion, build: LayoutBuild
    ) -> Optional[int]:
        """Compare-and-swap: deploy ``build`` only if ``expected`` is still
        live.  Returns the new generation, or None if the baseline went
        stale (another swap won the race)."""
        with self._lock:
            if self._live is not expected:
                return None
            v = self._new_version(build)
            self._live = v
            self._rset = self._rset.replace(
                0, v, block_sizes_for(build, build.tree.n_leaves)
            )
        self._notify_swap(v)
        return v.generation

    def rollback(self, generation: Optional[int] = None) -> int:
        """Make a retained generation live again FOR ITS REPLICA.

        Rollback is per-replica: the restored version replaces only the
        slot it was deployed into (its ``replica_id``); the other
        replicas keep serving their current trees.  Default: the
        primary's previous retained generation.  A generation whose
        replica slot no longer exists (the live set shrank since it was
        deployed) cannot be restored.
        """
        with self._lock:
            if generation is None:
                older = [
                    g for g, u in self._versions.items()
                    if u.replica_id == 0 and g < self._live.generation
                ]
                if not older:
                    raise ValueError("no older generation to roll back to")
                generation = max(older)
            v = self._versions.get(generation)
            if v is None:
                raise ValueError(
                    f"unknown or released generation {generation}; "
                    f"retained: {tuple(sorted(self._versions))}"
                    f"{self._replica_holders()}"
                )
            rid = v.replica_id
            if rid >= self._rset.k:
                raise ValueError(
                    f"generation {generation} was deployed as replica "
                    f"{rid}, but the live set has k={self._rset.k}; "
                    f"deploy a replica set of that size first"
                )
            self._rset = self._rset.replace(
                rid, v, block_sizes_for(v.build, v.tree.n_leaves)
            )
            if rid == 0:
                self._live = v
        self._notify_swap(v)
        return generation

    def _replica_holders(self) -> str:  # qdlint: holds-lock
        """``" (held by replica r0: 1, 2)"``-style suffix naming which
        replica slot each retained generation belongs to."""
        by_rid: dict[int, list[int]] = {}
        for g in sorted(self._versions):
            by_rid.setdefault(self._versions[g].replica_id, []).append(g)
        parts = ", ".join(
            f"r{rid}: {', '.join(map(str, gens))}"
            for rid, gens in sorted(by_rid.items())
        )
        return f" (held by replica {parts})" if parts else ""

    def release(self, generation: int) -> int:
        """Drop a retained generation and evict its compiled plans.

        Returns the number of plan-cache entries evicted.  A generation
        live in ANY replica slot cannot be released.

        Plan signatures are refcounted across retained versions: when the
        released generation's tree also backs another retained generation
        (re-deploying the same build — e.g. force-swapping an ``if_better``
        candidate, then rolling forward again — yields distinct
        generations over one tree object), its compiled plans stay cached
        until the LAST holder is released.  Evicting on first release
        would silently cold-start a generation that is still serving.
        """
        with self._lock:
            live_gens = self._rset.generations()
            if generation in live_gens:
                raise ValueError(
                    f"cannot release the live generation (serving as "
                    f"replica {live_gens.index(generation)})"
                )
            v = self._versions.get(generation)
            if v is None:
                raise ValueError(
                    f"unknown or released generation {generation}; "
                    f"retained: {tuple(sorted(self._versions))}"
                    f"{self._replica_holders()}"
                )
            del self._versions[generation]
            sig = planlib.tree_signature(v.tree)
            if any(
                planlib.tree_signature(u.tree) == sig
                for u in self._versions.values()
            ):
                return 0  # another retained generation still holds these
            return self.plans.evict(
                lambda k: isinstance(k, PlanKey) and k.sig == sig
            )

    # -- rebuild-in-place ----------------------------------------------------
    def rebuild(
        self,
        records: np.ndarray,
        workload: qry.Workload,
        strategy: Optional[str] = None,
        swap: str = "if_better",  # "if_better" | "always" | "never"
        on_candidate: Optional[Callable[[LayoutBuild], None]] = None,
        **cfg,
    ) -> RebuildReport:
        """Build a candidate on ``records``, score vs live, hot-swap.

        The candidate is constructed and scored entirely off to the side:
        serving keeps hitting the current tree (and its cached plans)
        until the single atomic swap.  Scoring is the paper's Eq. 1
        scanned fraction over (records, workload); the live tree is scored
        with ``tighten=False`` so production descriptions aren't mutated.
        ``on_candidate`` (if given) runs after the candidate is built and
        scored but before any swap — a seam for tests and monitoring.
        """
        if swap not in ("if_better", "always", "never"):
            raise ValueError(f"invalid swap policy {swap!r}")
        live = self._live  # consistent view for the whole cycle
        if strategy is None:
            from repro.service.builders import available_strategies

            # adopted trees (bare FrozenQdTree) carry no registered
            # strategy — rebuild them with the greedy default
            strategy = live.build.strategy
            if strategy not in available_strategies():
                strategy = "greedy"
        candidate = build_layout(
            records, workload, strategy=strategy, **cfg
        )
        t0 = time.perf_counter()
        candidate_scanned = candidate.scanned_fraction
        live_scanned = live.engine.skip_stats(
            records, workload, tighten=False
        ).scanned_fraction
        score_s = time.perf_counter() - t0
        if on_candidate is not None:
            on_candidate(candidate)
        if swap == "always":
            new_gen = self.swap(candidate)
            do_swap = True
        elif swap == "if_better" and candidate_scanned < live_scanned:
            # compare-and-swap: the improvement was measured against
            # ``live`` — if a concurrent rebuild already replaced it, the
            # comparison is stale, so don't deploy on top of it
            got = self._swap_if_live_is(live, candidate)
            do_swap = got is not None
            new_gen = got if do_swap else live.generation
        else:
            do_swap = False
            new_gen = live.generation
        return RebuildReport(
            strategy=strategy,
            build=candidate,
            candidate_scanned=candidate_scanned,
            live_scanned=live_scanned,
            swapped=do_swap,
            old_generation=live.generation,
            new_generation=new_gen,
            build_s=candidate.build_s,
            score_s=score_s,
        )

    # -- replica sets: k layouts, cheapest-replica routing -------------------
    def route_queries_cheapest(
        self, workload: qry.Workload, backend: Optional[str] = None
    ) -> list[ReplicaRoute]:
        """Route every query to its cheapest live replica (Eq. 1 cost
        per replica through the shared plan cache).  With k=1 this is
        the plain batched ``route_queries`` answer plus its cost."""
        return self._rset.route_queries(workload, backend=backend)

    def deploy_replicas(
        self,
        builds: Sequence[LayoutBuild],
        provenance: Optional[dict] = None,
    ) -> ReplicaSet:
        """Atomically deploy one build per replica slot (index ==
        replica_id; the first becomes the primary every single-tree API
        serves).  Each build gets its own generation; swap listeners
        fire once per replica so the serving tier invalidates each
        replica's cache entries."""
        rset = self._deploy_replicas(builds, None, provenance, expected=None)
        assert rset is not None
        return rset

    def _deploy_replicas(
        self,
        builds: Sequence[LayoutBuild],
        engines: Optional[Sequence[LayoutEngine]],
        provenance: Optional[dict],
        expected: Optional[ReplicaSet],
    ) -> Optional[ReplicaSet]:
        """Deploy under the lock; with ``expected`` set this is a CAS on
        the replica-set pointer (None return = baseline went stale)."""
        builds = tuple(builds)
        if not builds:
            raise ValueError("deploy_replicas needs at least one build")
        with self._lock:
            if expected is not None and self._rset is not expected:
                return None
            versions = tuple(
                self._new_version(
                    b,
                    replica_id=i,
                    engine=engines[i] if engines is not None else None,
                )
                for i, b in enumerate(builds)
            )
            sizes = tuple(
                block_sizes_for(b, b.tree.n_leaves) for b in builds
            )
            rset = ReplicaSet(versions, sizes, provenance)
            self._rset = rset
            self._live = versions[0]
        for v in versions:
            self._notify_swap(v)
        return rset

    def rebuild_replicas(
        self,
        records: np.ndarray,
        workload: Optional[qry.Workload] = None,
        k: int = 2,
        lam: float = 0.25,
        strategy: Optional[str] = None,
        swap: str = "if_better",  # "if_better" | "always" | "never"
        tracker=None,
        top_k: int = 16,
        budget: Optional[int] = 64,
        **cfg,
    ) -> ReplicaRebuildReport:
        """Cluster the live mix into <= k workload clusters, build one
        qd-tree replica per cluster, score the set against the live one
        with cheapest-replica Eq. 1 routing, and hot-deploy on
        improvement.

        The clustering input is the ``tracker``'s top-k canonical
        signatures when given (the serving-path inferred mix), else the
        exact signature multiplicities of ``workload``.  Each cluster's
        build workload blends its share of the mix with a uniform prior
        over ALL tracked signatures (weight ``lam`` — the worst-case
        guarantee blend of arXiv 2405.04984).  ``k=1`` degrades to one
        replica built for the whole mix, i.e. today's single-copy path.

        Scoring routes ``workload`` (or the materialized mix) through
        both candidate and live sets with per-leaf record counts
        measured on the SAME ``records`` — monotone in k by
        construction, since each query takes its cheapest replica.
        Deployment is a compare-and-swap on the replica-set pointer:
        a concurrent deploy invalidates this cycle's comparison, so the
        candidate is dropped (``swapped=False``).
        """
        if swap not in ("if_better", "always", "never"):
            raise ValueError(f"invalid swap policy {swap!r}")
        live_rset = self._rset  # consistent view for the whole cycle
        schema = live_rset.primary.tree.schema
        items = tracker.top_signatures(top_k) if tracker is not None else []
        if not items:
            if workload is None or not len(workload):
                raise ValueError(
                    "rebuild_replicas needs a tracker with recorded "
                    "traffic or a non-empty workload to cluster"
                )
            items = workload_signature_weights(workload)
        eval_wl = (
            workload
            if workload is not None and len(workload)
            else materialize_mix(items, schema, budget)
        )
        if strategy is None:
            from repro.service.builders import available_strategies

            strategy = live_rset.primary.build.strategy
            if strategy not in available_strategies():
                strategy = "greedy"
        cluster_wls, cluster_sigs = cluster_workloads(
            items, schema, k, lam, budget
        )
        t0 = time.perf_counter()
        builds = tuple(
            build_layout(records, wl_c, strategy=strategy, **cfg)
            for wl_c in cluster_wls
        )
        build_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        # candidate engines share the service plan cache so the deployed
        # set starts warm; per-leaf sizes for BOTH sets come from the
        # same records, making the Eq. 1 comparison apples-to-apples
        cand_engines = tuple(
            LayoutEngine(
                b.tree,
                backend=self.backend,
                interpret=self.interpret,
                plan_cache=self.plans,
                wt_cache=self._wt_cache,
            )
            for b in builds
        )
        cand_sizes = [block_sizes_for(b, b.tree.n_leaves) for b in builds]
        candidate_scanned = cheapest_scanned_fraction(
            cand_engines, cand_sizes, eval_wl, len(records)
        )
        live_sizes = [
            np.bincount(
                v.engine.route(records), minlength=v.tree.n_leaves
            ).astype(np.int64)
            for v in live_rset.versions
        ]
        live_scanned = cheapest_scanned_fraction(
            [v.engine for v in live_rset.versions],
            live_sizes,
            eval_wl,
            len(records),
        )
        score_s = time.perf_counter() - t0
        provenance = {
            "k": int(k),
            "lam": float(lam),
            "strategy": strategy,
            "clusters": len(builds),
        }
        old_gens = live_rset.generations()
        deployed = None
        if swap == "always":
            deployed = self._deploy_replicas(
                builds, cand_engines, provenance, expected=None
            )
        elif swap == "if_better" and candidate_scanned < live_scanned:
            deployed = self._deploy_replicas(
                builds, cand_engines, provenance, expected=live_rset
            )
        return ReplicaRebuildReport(
            k=int(k),
            lam=float(lam),
            builds=builds,
            clusters=tuple(cluster_sigs),
            candidate_scanned=candidate_scanned,
            live_scanned=live_scanned,
            swapped=deployed is not None,
            old_generations=old_gens,
            new_generations=(
                deployed.generations() if deployed is not None else old_gens
            ),
            build_s=build_s,
            score_s=score_s,
        )


def _adopt_tree(tree: FrozenQdTree) -> LayoutBuild:
    """Wrap a pre-built FrozenQdTree as a minimal LayoutBuild artifact."""
    return LayoutBuild(
        tree=tree,
        bids=np.zeros(0, np.int32),
        strategy="adopted",
        build_s=0.0,
        metrics={"scanned_fraction": float("nan"), "n_leaves": tree.n_leaves},
        provenance={"strategy": "adopted"},
    )
