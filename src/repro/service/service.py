"""LayoutService: one lifecycle API over qd-tree layouts.

Construction (the builder registry), serving (routing / batched query
routing through the LayoutEngine), and online re-optimization (versioned
rebuild with hot swap) behind a single facade:

    svc = LayoutService.build(records, workload, strategy="greedy")
    bids = svc.route(records)                 # live tree, any backend
    lists = svc.route_queries(workload)       # batched BID IN (...) lists
    report = svc.rebuild(recent, workload)    # candidate → score → hot swap

Versioning: every deployed tree gets a monotonically-increasing generation.
All generations share ONE compiled-plan cache — plan keys include the tree
signature (engine/plan.py), so the plans of the outgoing tree stay valid and
warm during a swap, and queries in flight against the old engine keep
routing bit-identically until :meth:`release` drops that generation and
evicts its plans.  ``rebuild`` builds a candidate on recent data, scores it
against the live tree with the paper's Eq. 1 skip rate, and swaps only on
strict improvement (or ``swap="always"``); :meth:`rollback` restores any
retained generation.  This is the "tree rebuild-in-place" step toward the
dynamic-layout follow-up (arXiv:2405.04984) and the online re-optimization
loop of Lachesis (arXiv:2006.16529).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Executor
from typing import Callable, Iterable, Optional

import numpy as np

from repro.core import query as qry
from repro.core.qdtree import FrozenQdTree
from repro.engine import LayoutEngine, PlanCache
from repro.engine.engine import WorkloadTensorCache
from repro.engine import plan as planlib
from repro.engine.plan import PlanKey
from repro.service.builders import LayoutBuild, build_layout


@dataclasses.dataclass
class LayoutVersion:
    """One deployed tree: generation counter + its engine + build artifact."""

    generation: int
    build: LayoutBuild
    engine: LayoutEngine

    @property
    def tree(self) -> FrozenQdTree:
        return self.build.tree


@dataclasses.dataclass
class RebuildReport:
    """Outcome of one ``rebuild`` cycle."""

    strategy: str
    build: LayoutBuild  # the candidate (deployed iff ``swapped``)
    candidate_scanned: float  # Eq. 1 scanned fraction on the rebuild inputs
    live_scanned: float
    swapped: bool
    old_generation: int
    new_generation: int  # == old_generation when not swapped
    build_s: float
    score_s: float

    @property
    def improvement(self) -> float:
        return self.live_scanned - self.candidate_scanned


class LayoutService:
    """Versioned layout lifecycle: build → serve → rebuild/swap/rollback."""

    def __init__(
        self,
        layout: LayoutBuild | FrozenQdTree,
        backend: str = "jax",
        interpret: Optional[bool] = None,
        plan_cache: Optional[PlanCache] = None,
    ):
        if isinstance(layout, FrozenQdTree):
            layout = _adopt_tree(layout)
        self.backend = backend
        self.interpret = interpret
        self.plans = plan_cache if plan_cache is not None else PlanCache()
        # one workload-tensor LRU for every generation: entries key on the
        # cut-table *content* signature, so a hot swap to a tree built from
        # an equal cut table keeps standing workloads tensorized
        self._wt_cache = WorkloadTensorCache()
        self._lock = threading.Lock()
        self._gen = 0
        self._versions: dict[int, LayoutVersion] = {}
        self._swap_listeners: list[Callable[[LayoutVersion], None]] = []
        self._live = self._new_version(layout)

    # -- construction --------------------------------------------------------
    @classmethod
    def build(
        cls,
        records: np.ndarray,
        workload: qry.Workload,
        strategy: str = "greedy",
        backend: str = "jax",
        **cfg,
    ) -> "LayoutService":
        """Build an initial layout with any registered strategy and serve it."""
        return cls(
            build_layout(records, workload, strategy=strategy, **cfg),
            backend=backend,
        )

    def _new_version(self, build: LayoutBuild) -> LayoutVersion:
        # all versions share self.plans: plan keys carry the tree signature,
        # so old and new compiled plans coexist during a cutover
        eng = LayoutEngine(
            build.tree,
            backend=self.backend,
            interpret=self.interpret,
            plan_cache=self.plans,
            wt_cache=self._wt_cache,
        )
        self._gen += 1
        v = LayoutVersion(generation=self._gen, build=build, engine=eng)
        self._versions[v.generation] = v
        return v

    # -- introspection -------------------------------------------------------
    @property
    def generation(self) -> int:
        """Generation of the live tree."""
        return self._live.generation

    @property
    def engine(self) -> LayoutEngine:
        """The live engine (grab once for a consistent view across calls)."""
        return self._live.engine

    @property
    def tree(self) -> FrozenQdTree:
        return self._live.tree

    def live_version(self) -> LayoutVersion:
        """The live :class:`LayoutVersion` — ONE read of the swap pointer.

        Callers that must route and report against a single consistent
        generation (the serving tier's dispatch loop) grab this once and
        use ``v.engine``/``v.tree``/``v.generation`` together; reading the
        ``engine``/``generation`` properties separately can straddle a
        concurrent hot swap.
        """
        return self._live

    def live_epoch(self) -> tuple[int, int]:
        """The serving epoch: ``(generation, leaf-description version)``.

        Hot swaps and rollbacks change the generation; in-place
        tightening during ingest bumps the live tree's description
        version (changing ``query_hits`` results without a swap).  Either
        movement retires every result computed under the old epoch — this
        is the result-cache invalidation key (`repro.serve.cache`).
        """
        live = self._live
        return (live.generation, planlib.desc_version(live.tree))

    def versions(self) -> tuple[int, ...]:
        """Retained generations, oldest first."""
        return tuple(sorted(self._versions))

    def version(self, generation: int) -> LayoutVersion:
        return self._versions[generation]

    def stats(self) -> dict:
        return {
            "generation": self.generation,
            "versions": self.versions(),
            "backend": self.backend,
            "plan_cache": self.plans.stats(),
        }

    # -- serving facade (always the live tree) ------------------------------
    def route(self, records: np.ndarray, **kw) -> np.ndarray:
        return self._live.engine.route(records, **kw)

    def query_hits(self, workload, **kw) -> np.ndarray:
        return self._live.engine.query_hits(workload, **kw)

    def route_query(self, query: qry.Query, **kw) -> np.ndarray:
        return self._live.engine.route_query(query, **kw)

    def route_queries(self, workload, **kw) -> list[np.ndarray]:
        return self._live.engine.route_queries(workload, **kw)

    def serve(
        self, workload, tracker=None, tick: bool = True, **kw
    ) -> list[np.ndarray]:
        """Serve one batch of live queries: batched ``route_queries``
        against the live tree, optionally observed into a
        :class:`~repro.service.tracker.WorkloadTracker`.

        This is the workload auto-detection seam: with ``tracker`` set,
        each served query's canonical predicate signature is recorded, and
        ``tick=True`` (default) closes the serving round afterwards — one
        exponential-decay generation per ``serve`` call, so the inferred
        mix follows what users are asking *now*.  Sharded serving gives
        each worker its own tracker and folds the states
        (``tracker.merge_state`` / ``repro.service.tracker.merge_states``)
        — bit-identical to single-stream tracking, same algebra as
        ``ShardState``.
        """
        lists = self._live.engine.route_queries(
            workload, track=tracker, **kw
        )
        if tracker is not None and tick:
            tracker.tick()
        return lists

    def workload_tracker(self, config=None):
        """A :class:`~repro.service.tracker.WorkloadTracker` bound to the
        live schema — pass it to :meth:`serve`/``route_queries(track=...)``
        and to ``auto_rebuilder(workload="auto", tracker=...)`` to close
        the queries-in → layouts-out loop without a declared workload."""
        from repro.service.tracker import WorkloadTracker

        return WorkloadTracker(self.tree.schema, config=config)

    def skip_stats(self, records, workload, **kw):
        return self._live.engine.skip_stats(records, workload, **kw)

    def ingest(self, batches: Iterable[np.ndarray], monitor=None, **kw):
        """Streaming ingestion into the live tree (``LayoutEngine.ingest``).

        With ``monitor`` (an :class:`~repro.service.drift.AutoRebuilder`),
        every batch is teed into the monitor's record reservoir and scored
        against its standing workload (Eq. 1 per-batch accounting through
        the compiled plan); the monitor may fire a background rebuild
        mid-stream.  The run itself keeps routing/tightening the engine
        captured at call time — a concurrent hot swap takes effect for the
        *next* ingest call, exactly like any other in-flight operation.
        Once a swap lands, the remainder of this call's observations
        (which still measure the superseded tree) are dropped rather than
        fed to the freshly rebaselined monitor, so one long stream cannot
        re-trigger redundant rebuilds against a tree that no longer
        serves; batches keep filling the reservoir throughout.
        """
        live = self._live
        if monitor is not None:
            # a workload="auto" monitor resolves to the tracker-inferred
            # live mix here, at the start of each run; an empty inference
            # (nothing served yet) skips accounting rather than probing a
            # zero-query workload
            if "observe" not in kw:
                observed = monitor.current_workload()
                if observed is not None and len(observed):
                    kw["observe"] = observed

            def _observe_if_live(stat):
                if self._live is live:
                    monitor.observe(stat)

            kw.setdefault("on_observation", _observe_if_live)
            batches = monitor.tee(batches)
        return live.engine.ingest(batches, **kw)

    def ingest_sharded(
        self,
        records: np.ndarray,
        n_shards: int,
        batch: int = 2048,
        executor: "Executor | str | None" = None,
        monitor=None,
        **kw,
    ):
        """Shard-parallel ingestion into the live tree (engine.sharded).

        Splits ``records`` contiguously across ``n_shards`` ShardIngestors
        (a private thread pool by default; ``executor="process"`` runs
        spawn-context workers against a pickled tree replica instead —
        see ``sharded_ingest``), folds their ShardStates
        associatively, and publishes the merged
        tightening under the service lock — the description-version bump
        evicts stale per-signature query plans exactly as a single-stream
        ``ingest`` would, so readers hot-cut to the tightened descriptions
        atomically.  Bit-identical to ``ingest`` over the same records.

        If another thread hot-swaps the live tree while the shards are
        routing, the merged tightening is NOT silently published into the
        outgoing generation: liveness is re-checked under the lock at
        publish time, and a stale run returns its (still-valid) aggregates
        with ``published=False, stale_generation=True``.

        ``monitor`` (an :class:`~repro.service.drift.AutoRebuilder`) adds
        the records to the monitor's reservoir and feeds it the run's
        merged Eq. 1 window-stat partial — bit-identical to the
        single-stream per-batch totals — as one observation.
        """
        from repro.engine.sharded import sharded_ingest

        live = self._live  # consistent engine/tree view for the whole run
        if monitor is not None and "observe" not in kw:
            observed = monitor.current_workload()
            if observed is not None and len(observed):
                kw["observe"] = observed
        report = sharded_ingest(
            live.engine, records, n_shards, batch=batch,
            executor=executor, lock=self._lock,
            publish_check=lambda: self._live is live, **kw,
        )
        if monitor is not None:
            monitor.add_records(records)
            if report.observation is not None:
                monitor.observe(report.observation)
        return report

    def auto_rebuilder(self, workload, config=None, **kw):
        """An :class:`~repro.service.drift.AutoRebuilder` bound to this
        service: pass it as ``monitor=`` to ``ingest``/``ingest_sharded``
        and the service becomes self-optimizing — skip-rate drift past the
        configured policy triggers a background ``rebuild`` whose
        deployment rides the same compare-and-swap as manual rebuilds.

        ``workload`` is either a declared standing
        :class:`~repro.core.query.Workload` or the string ``"auto"``:
        then drift accounting and rebuilds score against the live mix a
        :class:`~repro.service.tracker.WorkloadTracker` inferred from the
        serving path (pass ``tracker=`` to share the one :meth:`serve`
        records into; omitted, a fresh :meth:`workload_tracker` is
        created and exposed as ``rebuilder.tracker``).
        """
        from repro.service.drift import AutoRebuilder

        return AutoRebuilder(self, workload, config=config, **kw)

    # -- lifecycle: swap / rollback / release --------------------------------
    def subscribe(self, listener: Callable[[LayoutVersion], None]) -> None:
        """Register a callback fired after every live-version change.

        The callback receives the NEW live :class:`LayoutVersion` and runs
        on the swapping thread, outside the service lock (it may call back
        into the service).  The serving tier uses this to invalidate its
        result cache and warm the incoming generation's plans promptly,
        rather than discovering the swap at the next dispatch.
        """
        with self._lock:
            self._swap_listeners.append(listener)

    def unsubscribe(self, listener: Callable[[LayoutVersion], None]) -> None:
        with self._lock:
            try:
                self._swap_listeners.remove(listener)
            except ValueError:
                pass

    def _notify_swap(self, v: LayoutVersion) -> None:
        with self._lock:
            listeners = tuple(self._swap_listeners)
        for fn in listeners:
            fn(v)

    def swap(self, build: LayoutBuild) -> int:
        """Deploy ``build`` as a new generation (atomic); returns it."""
        with self._lock:
            v = self._new_version(build)
            self._live = v  # single reference assignment — atomic swap
        self._notify_swap(v)
        return v.generation

    def _swap_if_live_is(
        self, expected: LayoutVersion, build: LayoutBuild
    ) -> Optional[int]:
        """Compare-and-swap: deploy ``build`` only if ``expected`` is still
        live.  Returns the new generation, or None if the baseline went
        stale (another swap won the race)."""
        with self._lock:
            if self._live is not expected:
                return None
            v = self._new_version(build)
            self._live = v
        self._notify_swap(v)
        return v.generation

    def rollback(self, generation: Optional[int] = None) -> int:
        """Make a retained generation live again (default: the previous)."""
        with self._lock:
            if generation is None:
                older = [
                    g for g in self._versions if g < self._live.generation
                ]
                if not older:
                    raise ValueError("no older generation to roll back to")
                generation = max(older)
            v = self._versions.get(generation)
            if v is None:
                raise ValueError(
                    f"unknown or released generation {generation}; "
                    f"retained: {tuple(sorted(self._versions))}"
                )
            self._live = v
        self._notify_swap(v)
        return generation

    def release(self, generation: int) -> int:
        """Drop a retained generation and evict its compiled plans.

        Returns the number of plan-cache entries evicted.  The live
        generation cannot be released.

        Plan signatures are refcounted across retained versions: when the
        released generation's tree also backs another retained generation
        (re-deploying the same build — e.g. force-swapping an ``if_better``
        candidate, then rolling forward again — yields distinct
        generations over one tree object), its compiled plans stay cached
        until the LAST holder is released.  Evicting on first release
        would silently cold-start a generation that is still serving.
        """
        with self._lock:
            if generation == self._live.generation:
                raise ValueError("cannot release the live generation")
            v = self._versions.get(generation)
            if v is None:
                raise ValueError(
                    f"unknown or released generation {generation}; "
                    f"retained: {tuple(sorted(self._versions))}"
                )
            del self._versions[generation]
            sig = planlib.tree_signature(v.tree)
            if any(
                planlib.tree_signature(u.tree) == sig
                for u in self._versions.values()
            ):
                return 0  # another retained generation still holds these
            return self.plans.evict(
                lambda k: isinstance(k, PlanKey) and k.sig == sig
            )

    # -- rebuild-in-place ----------------------------------------------------
    def rebuild(
        self,
        records: np.ndarray,
        workload: qry.Workload,
        strategy: Optional[str] = None,
        swap: str = "if_better",  # "if_better" | "always" | "never"
        on_candidate: Optional[Callable[[LayoutBuild], None]] = None,
        **cfg,
    ) -> RebuildReport:
        """Build a candidate on ``records``, score vs live, hot-swap.

        The candidate is constructed and scored entirely off to the side:
        serving keeps hitting the current tree (and its cached plans)
        until the single atomic swap.  Scoring is the paper's Eq. 1
        scanned fraction over (records, workload); the live tree is scored
        with ``tighten=False`` so production descriptions aren't mutated.
        ``on_candidate`` (if given) runs after the candidate is built and
        scored but before any swap — a seam for tests and monitoring.
        """
        if swap not in ("if_better", "always", "never"):
            raise ValueError(f"invalid swap policy {swap!r}")
        live = self._live  # consistent view for the whole cycle
        if strategy is None:
            from repro.service.builders import available_strategies

            # adopted trees (bare FrozenQdTree) carry no registered
            # strategy — rebuild them with the greedy default
            strategy = live.build.strategy
            if strategy not in available_strategies():
                strategy = "greedy"
        candidate = build_layout(
            records, workload, strategy=strategy, **cfg
        )
        t0 = time.perf_counter()
        candidate_scanned = candidate.scanned_fraction
        live_scanned = live.engine.skip_stats(
            records, workload, tighten=False
        ).scanned_fraction
        score_s = time.perf_counter() - t0
        if on_candidate is not None:
            on_candidate(candidate)
        if swap == "always":
            new_gen = self.swap(candidate)
            do_swap = True
        elif swap == "if_better" and candidate_scanned < live_scanned:
            # compare-and-swap: the improvement was measured against
            # ``live`` — if a concurrent rebuild already replaced it, the
            # comparison is stale, so don't deploy on top of it
            got = self._swap_if_live_is(live, candidate)
            do_swap = got is not None
            new_gen = got if do_swap else live.generation
        else:
            do_swap = False
            new_gen = live.generation
        return RebuildReport(
            strategy=strategy,
            build=candidate,
            candidate_scanned=candidate_scanned,
            live_scanned=live_scanned,
            swapped=do_swap,
            old_generation=live.generation,
            new_generation=new_gen,
            build_s=candidate.build_s,
            score_s=score_s,
        )


def _adopt_tree(tree: FrozenQdTree) -> LayoutBuild:
    """Wrap a pre-built FrozenQdTree as a minimal LayoutBuild artifact."""
    return LayoutBuild(
        tree=tree,
        bids=np.zeros(0, np.int32),
        strategy="adopted",
        build_s=0.0,
        metrics={"scanned_fraction": float("nan"), "n_leaves": tree.n_leaves},
        provenance={"strategy": "adopted"},
    )
