"""Replica sets: k-replica qd-tree layouts with cheapest-replica routing.

The source paper's critique of fixed blocking schemes is that they "are
unable to exploit additional available storage to drive this metric down
further" — a single qd-tree is one compromise layout for the whole mix.
This module spends a k× storage budget on k *replicas*, each a qd-tree
optimized for one cluster of the live workload, and answers every query
from whichever replica scans the least (the paper's Eq. 1 cost,
evaluated per replica through the same batched ``route_queries`` plan
cache the single-tree path uses).  k=1 degrades to exactly today's
single-copy path.

Clustering rides on the PR 5 tracker: the top-k canonical predicate
signatures (weight-decayed) are embedded as per-dimension
constrained/center features and grouped by deterministic farthest-point
seeding + Lloyd refinement.  Each cluster's build workload blends the
cluster's inferred mix with a **uniform prior over all tracked
signatures** (weight ``lam``, after "Dynamic Data Layout Optimization
with Worst-case Guarantees", arXiv 2405.04984): with ``lam > 0`` no
replica's layout is pathological for out-of-cluster queries, so a
drifting or adversarial mix has bounded regret — the cheapest-replica
router can always fall back to a replica that kept every signature in
view.

The :class:`ReplicaSet` is the deployable artifact: an ordered tuple of
``LayoutVersion``s (index == ``replica_id``), per-replica block sizes
for the Eq. 1 cost model, and the per-replica
:class:`~repro.service.epoch.Epoch` list the serving tier keys its
result cache on (hot-swapping one replica retires only that replica's
entries).
"""

from __future__ import annotations

# qdlint: deterministic-module

import dataclasses
from collections import Counter
from typing import Optional, Sequence

import numpy as np

from repro.core import query as qry
from repro.core.predicates import OP_GE, OP_LT, Schema
from repro.engine import plan as planlib
from repro.service.epoch import Epoch
from repro.service.tracker import (
    SIG_ADV,
    SIG_IN,
    SIG_RANGE,
    adv_filter_for,
    apportion_conjunct_budget,
    query_from_signature,
    query_signatures,
)

# Lossless canonicalization resolution (same trick as the serve cache's
# EXACT_RESOLUTION, duplicated here so the service layer never imports
# the serving tier): bucket_lo/bucket_hi degenerate to the identity.
_EXACT = 1 << 62


# ---------------------------------------------------------------------------
# Workload clustering over canonical signatures
# ---------------------------------------------------------------------------
def signature_features(sig: tuple, schema: Schema) -> np.ndarray:
    """Embed one canonical signature as ``(2 * ndims,)`` features.

    Per dimension: a constrained indicator (any range/IN/advanced atom
    touching it across the signature's conjuncts) and the normalized
    center of the constrained box (0.5 when unconstrained) — enough
    geometry that queries over different columns, or disjoint ranges of
    one column, land far apart, which is what the replica split needs.
    """
    nd = schema.ndims
    doms = schema.doms
    hit = np.zeros(nd, np.float64)
    center_sum = np.zeros(nd, np.float64)
    center_n = np.zeros(nd, np.float64)
    for conj_sig in sig:
        lo = {}
        hi = {}
        for atom in conj_sig:
            tag = atom[0]
            if tag == SIG_RANGE:
                _, d, op, v = atom
                hit[d] = 1.0
                if op == OP_GE:
                    lo[d] = max(lo.get(d, 0), int(v))
                elif op == OP_LT:
                    hi[d] = min(hi.get(d, int(doms[d])), int(v))
            elif tag == SIG_IN:
                d = atom[1]
                hit[d] = 1.0
                vals = atom[2:]
                if vals:
                    center_sum[d] += (
                        float(np.mean(vals)) / max(int(doms[d]), 1)
                    )
                    center_n[d] += 1.0
            elif tag == SIG_ADV:
                d = atom[1]
                hit[d] = 1.0
                center_sum[d] += 0.5
                center_n[d] += 1.0
        for d in sorted(set(lo) | set(hi)):
            a = lo.get(d, 0)
            b = hi.get(d, int(doms[d]))
            center_sum[d] += (a + b) / (2.0 * max(int(doms[d]), 1))
            center_n[d] += 1.0
    centers = np.where(center_n > 0, center_sum / np.maximum(center_n, 1.0),
                       0.5)
    return np.concatenate([hit, centers])


def cluster_signatures(
    items: Sequence[tuple[tuple, float]], schema: Schema, k: int
) -> list[list[int]]:
    """Partition ``[(signature, weight), ...]`` into <= k clusters.

    Deterministic for a fixed input order (callers pass the tracker's
    ``top_signatures`` ordering: weight desc, signature asc): seeds are
    chosen farthest-point-first weighted by signature mass, assignment
    refines through Lloyd rounds with weighted centroids, and every tie
    breaks toward the lowest index.  Empty clusters are dropped, so the
    result may have fewer than k clusters (identical signatures cannot
    be split).  k=1 returns one cluster holding everything.
    """
    n = len(items)
    if n == 0:
        return []
    k = max(1, min(int(k), n))
    if k == 1:
        return [list(range(n))]
    feats = np.stack([signature_features(s, schema) for s, _ in items])
    weights = np.asarray([w for _, w in items], np.float64)
    # farthest-point seeding, mass-weighted: the heaviest signature
    # anchors cluster 0, each next seed is the signature with the most
    # weighted distance to its nearest existing seed
    seeds = [0]
    d2 = ((feats - feats[0]) ** 2).sum(axis=1)
    while len(seeds) < k:
        score = weights * d2
        best = int(np.argmax(score))  # first max — lowest index on ties
        if score[best] <= 0.0:
            break  # every remaining signature sits on an existing seed
        seeds.append(best)
        d2 = np.minimum(d2, ((feats - feats[best]) ** 2).sum(axis=1))
    centers = feats[seeds]
    assign = np.zeros(n, np.int64)
    for _ in range(8):
        dist = ((feats[:, None, :] - centers[None, :, :]) ** 2).sum(axis=2)
        new_assign = dist.argmin(axis=1)  # argmin → lowest cluster on ties
        if np.array_equal(new_assign, assign) and _ > 0:
            break
        assign = new_assign
        for c in range(centers.shape[0]):
            mask = assign == c
            if mask.any():
                wsum = weights[mask].sum()
                centers[c] = (
                    (weights[mask, None] * feats[mask]).sum(axis=0)
                    / (wsum if wsum > 0 else mask.sum())
                )
    clusters = [
        [i for i in range(n) if assign[i] == c]
        for c in range(centers.shape[0])
    ]
    return [c for c in clusters if c]


def blended_mix(
    items: Sequence[tuple[tuple, float]],
    cluster: Sequence[int],
    lam: float,
) -> list[tuple[tuple, float]]:
    """One cluster's build mix: cluster share blended with a uniform
    prior over ALL tracked signatures.

    ``w_c(s) = (1 - lam) * w(s)/W_c * [s in c] + lam / n`` — the
    worst-case blend (arXiv 2405.04984): ``lam = 0`` specializes each
    replica fully, ``lam = 1`` makes every replica build for the uniform
    mix.  Returned heaviest-first (signature asc tie-break), the order
    :func:`materialize_mix` apportions in.
    """
    if not 0.0 <= lam <= 1.0:
        raise ValueError("lam must be in [0, 1]")
    member = set(cluster)
    total_c = sum(items[i][1] for i in cluster)
    total_c = total_c if total_c > 0 else 1.0
    n = len(items)
    out = []
    for i, (sig, w) in enumerate(items):
        blended = lam / n
        if i in member:
            blended += (1.0 - lam) * (w / total_c)
        if blended > 0.0:
            out.append((sig, blended))
    out.sort(key=lambda it: (-it[1], it[0]))
    return out


def materialize_mix(
    items: Sequence[tuple[tuple, float]],
    schema: Schema,
    budget: Optional[int] = 64,
) -> qry.Workload:
    """Weighted signatures → a Workload with integer multiplicities.

    Same conjunct-budget apportionment as
    :meth:`TrackerState.infer_workload` (shared helper), so per-cluster
    workloads get the same stable tensor geometry guarantees.
    """
    items = list(items)
    if not items:
        return qry.Workload(schema, ())
    if budget is None:
        mults = [1] * len(items)
    else:
        items, mults = apportion_conjunct_budget(items, int(budget))
    queries: list[qry.Query] = []
    for (sig, _), m in zip(items, mults):
        queries.extend([query_from_signature(sig, schema)] * m)
    return qry.Workload(schema, tuple(queries))


def workload_signature_weights(
    workload: qry.Workload,
) -> list[tuple[tuple, float]]:
    """Derive ``(signature, weight)`` items from a declared Workload —
    the clustering input when no tracker is serving (weights are exact
    multiplicities of each lossless canonical signature)."""
    counts = Counter(query_signatures(workload, _EXACT))
    items = [(sig, float(c)) for sig, c in counts.items()]
    items.sort(key=lambda it: (-it[1], it[0]))
    return items


def cluster_workloads(
    items: Sequence[tuple[tuple, float]],
    schema: Schema,
    k: int,
    lam: float = 0.25,
    budget: Optional[int] = 64,
) -> tuple[list[qry.Workload], list[tuple[tuple, ...]]]:
    """Cluster tracked signatures and materialize one blended build
    workload per cluster.  Returns ``(workloads, cluster_signatures)``
    (both <= k long; empty clusters dropped)."""
    clusters = cluster_signatures(items, schema, k)
    workloads = []
    sigs = []
    for cluster in clusters:
        workloads.append(
            materialize_mix(blended_mix(items, cluster, lam), schema, budget)
        )
        sigs.append(tuple(items[i][0] for i in cluster))
    return workloads, sigs


# ---------------------------------------------------------------------------
# The deployable artifact
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ReplicaRoute:
    """One query's cheapest-replica answer: the chosen replica's block
    IDs plus the Eq. 1 cost that won (tuples scanned when block sizes
    are known, block count otherwise)."""

    bids: np.ndarray
    replica_id: int
    cost: int


class ReplicaSet:
    """An ordered, immutable set of deployed replicas (index == id).

    ``versions[r]`` is the :class:`LayoutVersion` serving replica ``r``;
    ``block_sizes[r]`` is its per-leaf record count (the Eq. 1 cost
    model — ``None`` for adopted trees with unknown contents, which
    degrades the router to block *counts*).  All replicas share the
    service's one compiled-plan cache: plan keys carry each tree's
    signature, so per-replica routing here is bit-identical to a
    standalone engine over the same tree.
    """

    __slots__ = ("versions", "block_sizes", "provenance")

    def __init__(
        self,
        versions: Sequence,
        block_sizes: Optional[Sequence[Optional[np.ndarray]]] = None,
        provenance: Optional[dict] = None,
    ):
        versions = tuple(versions)
        if not versions:
            raise ValueError("a ReplicaSet needs at least one replica")
        for i, v in enumerate(versions):
            if getattr(v, "replica_id", 0) != i:
                raise ValueError(
                    f"replica at position {i} carries replica_id "
                    f"{v.replica_id}; ids must match positions"
                )
        if block_sizes is None:
            block_sizes = (None,) * len(versions)
        block_sizes = tuple(block_sizes)
        if len(block_sizes) != len(versions):
            raise ValueError("one block_sizes entry per replica required")
        self.versions = versions
        self.block_sizes = block_sizes
        self.provenance = dict(provenance or {})

    @property
    def k(self) -> int:
        return len(self.versions)

    @property
    def primary(self):
        """Replica 0 — the version every single-tree service API serves."""
        return self.versions[0]

    def epochs(self) -> tuple[Epoch, ...]:
        """Per-replica serving epochs, index == replica_id."""
        return tuple(
            Epoch(v.generation, planlib.desc_version(v.tree), i)
            for i, v in enumerate(self.versions)
        )

    def generations(self) -> tuple[int, ...]:
        return tuple(v.generation for v in self.versions)

    def adv_filter(self) -> Optional[frozenset]:
        """The advanced-atom filter for replica-sound cache keys: the
        UNION of every replica's cut-visible advanced predicates.  Equal
        signatures under the union imply equal tensorized forms on every
        replica, hence an identical cheapest-replica choice — for k=1
        this is exactly the single tree's filter (today's cache keys)."""
        parts = [adv_filter_for(v.tree.cuts) for v in self.versions]
        if any(p is None for p in parts):
            return None  # no filtering: strictly finer keys, still sound
        if len(parts) == 1:
            return parts[0]
        return frozenset().union(*parts)

    def replace(self, replica_id: int, version,
                block_sizes: Optional[np.ndarray] = None) -> "ReplicaSet":
        """A new ReplicaSet with one slot swapped (hot swap / rollback
        of a single replica — the others keep serving untouched)."""
        if not 0 <= replica_id < self.k:
            raise ValueError(
                f"replica {replica_id} not in live set (k={self.k})"
            )
        versions = list(self.versions)
        sizes = list(self.block_sizes)
        versions[replica_id] = version
        sizes[replica_id] = block_sizes
        return ReplicaSet(versions, sizes, self.provenance)

    # -- cheapest-replica routing -------------------------------------------
    def route_queries(
        self, workload: qry.Workload, backend: Optional[str] = None
    ) -> list[ReplicaRoute]:
        """Route every query on every replica (one batched
        ``route_queries`` dispatch per replica, through the shared plan
        cache) and keep each query's cheapest answer.

        Cost is Eq. 1 over the chosen replica: the total records in the
        blocks the query must scan (block counts when any replica lacks
        sizes, so costs stay comparable).  Ties break on
        ``(cost, n_blocks, block-id bytes)`` — intrinsic to the routed
        content, so the chosen answer is invariant under replica order
        permutation.
        """
        per_replica = [
            v.engine.route_queries(
                workload.tensorize(v.tree.cuts), backend=backend
            )
            for v in self.versions
        ]
        use_sizes = all(s is not None for s in self.block_sizes)
        out: list[ReplicaRoute] = []
        for qi in range(len(workload)):
            best = None
            for r in range(self.k):
                bids = per_replica[r][qi]
                if use_sizes:
                    cost = int(self.block_sizes[r][bids].sum())
                else:
                    cost = int(bids.shape[0])
                key = (cost, int(bids.shape[0]), bids.tobytes())
                if best is None or key < best[0]:
                    best = (key, r, bids, cost)
            out.append(
                ReplicaRoute(bids=best[2], replica_id=best[1], cost=best[3])
            )
        return out

    def scanned_fraction(
        self, workload: qry.Workload, n_records: Optional[int] = None
    ) -> float:
        """Eq. 1 over the whole mix with cheapest-replica routing:
        mean over queries of (records scanned / records total).  Needs
        per-replica block sizes; ``n_records`` defaults to the primary's
        total."""
        if not len(workload):
            return 0.0
        if not all(s is not None for s in self.block_sizes):
            raise ValueError(
                "scanned_fraction needs block sizes for every replica"
            )
        if n_records is None:
            n_records = int(self.block_sizes[0].sum())
        routes = self.route_queries(workload)
        total = sum(r.cost for r in routes)
        return total / float(max(n_records, 1) * len(workload))

    def describe(self) -> dict:
        return {
            "k": self.k,
            "generations": list(self.generations()),
            "epochs": [list(e) for e in self.epochs()],
            "n_leaves": [v.tree.n_leaves for v in self.versions],
            **{
                k: v
                for k, v in self.provenance.items()
                if isinstance(v, (int, float, str, bool))
            },
        }


@dataclasses.dataclass
class ReplicaRebuildReport:
    """Outcome of one ``rebuild_replicas`` cycle."""

    k: int  # requested replica count (len(builds) may be smaller)
    lam: float
    builds: tuple  # per-cluster LayoutBuild candidates
    clusters: tuple[tuple[tuple, ...], ...]  # signatures per cluster
    candidate_scanned: float  # cheapest-replica Eq. 1 on the inputs
    live_scanned: float
    swapped: bool
    old_generations: tuple[int, ...]
    new_generations: tuple[int, ...]
    build_s: float
    score_s: float

    @property
    def improvement(self) -> float:
        return self.live_scanned - self.candidate_scanned


def cheapest_scanned_fraction(
    engines: Sequence,
    sizes: Sequence[np.ndarray],
    workload: qry.Workload,
    n_records: int,
) -> float:
    """Eq. 1 scanned fraction under cheapest-replica routing, for
    engines that are not (yet) deployed as a ReplicaSet — the
    rebuild-time scoring path.  ``sizes[r]`` are per-leaf record counts
    measured on the SAME records for every engine, so candidate and
    live sets compare apples-to-apples."""
    if not len(workload):
        return 0.0
    per = [
        eng.route_queries(workload.tensorize(eng.tree.cuts))
        for eng in engines
    ]
    total = 0
    for qi in range(len(workload)):
        total += min(
            int(sizes[r][per[r][qi]].sum()) for r in range(len(engines))
        )
    return total / float(max(n_records, 1) * len(workload))


def block_sizes_for(build, n_leaves: int) -> Optional[np.ndarray]:
    """Per-leaf record counts from a build's routed bids (the Eq. 1
    cost model); None for adopted builds with no routed records."""
    bids = getattr(build, "bids", None)
    if bids is None or len(bids) == 0:
        return None
    return np.bincount(np.asarray(bids), minlength=n_leaves).astype(np.int64)


__all__ = [
    "ReplicaRebuildReport",
    "ReplicaRoute",
    "ReplicaSet",
    "blended_mix",
    "block_sizes_for",
    "cheapest_scanned_fraction",
    "cluster_signatures",
    "cluster_workloads",
    "materialize_mix",
    "signature_features",
    "workload_signature_weights",
]
