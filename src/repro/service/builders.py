"""Layout builder registry: one construction API over every strategy.

The paper describes several ways to arrive at a layout — greedy Algorithm 1,
the WOODBLOCK RL agent (Sec 5.2), the bottom-up baseline, and the trivial
random/range partitioners (Sec 7.3) — and the repo used to expose each as a
differently-shaped entry point.  Here they all implement one
:class:`LayoutBuilder` protocol and register under a strategy name, so

    build = build_layout(records, workload, strategy="greedy", min_block=600)

returns the same :class:`LayoutBuild` artifact regardless of strategy: a
tightened ``FrozenQdTree``, the build records' BIDs, Eq. 1 build metrics,
and provenance (config + input sizes) for reproducibility.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.core import greedy as greedy_mod
from repro.core import query as qry
from repro.core.predicates import CutTable
from repro.core.qdtree import FrozenQdTree

_REGISTRY: dict[str, "LayoutBuilder"] = {}


def register_builder(name: str):
    """Class decorator: instantiate and register a builder under ``name``."""

    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls()
        return cls

    return deco


def get_builder(name: str) -> "LayoutBuilder":
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown strategy {name!r}; available: {available_strategies()}"
        ) from None


def available_strategies() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


@dataclasses.dataclass
class LayoutBuild:
    """The common construction artifact every strategy returns.

    ``tree`` is frozen and min-max tightened on ``records``; ``bids`` is the
    layout's block assignment of those records (for qd-tree strategies this
    is ``tree.route(records)``, for baselines the directly-assigned BIDs).
    """

    tree: FrozenQdTree
    bids: np.ndarray
    strategy: str
    build_s: float
    metrics: dict  # scanned_fraction (Eq. 1 on build inputs) + extras
    provenance: dict  # config, input sizes, seed — enough to rebuild

    @property
    def n_leaves(self) -> int:
        return self.tree.n_leaves

    @property
    def scanned_fraction(self) -> float:
        return float(self.metrics["scanned_fraction"])


class LayoutBuilder:
    """Interface: construct one layout from (records, workload, cuts).

    Implementations return ``(frozen_tightened_tree, bids, extra_metrics)``;
    :func:`build_layout` wraps that with timing, Eq. 1 scoring, and
    provenance into a :class:`LayoutBuild`.
    """

    name: str = "?"

    def build(
        self,
        records: np.ndarray,
        workload: qry.Workload,
        cuts: CutTable,
        min_block: int,
        seed: int = 0,
        **cfg,
    ) -> tuple[FrozenQdTree, np.ndarray, dict]:
        raise NotImplementedError


@register_builder("greedy")
class GreedyBuilder(LayoutBuilder):
    """Paper Algorithm 1 (core/greedy.py)."""

    def build(self, records, workload, cuts, min_block, seed=0, **cfg):
        gcfg = greedy_mod.GreedyConfig(
            min_block=min_block,
            max_leaves=cfg.pop("max_leaves", None),
            allow_small_child=cfg.pop("allow_small_child", False),
        )
        _reject_unknown(self, cfg)
        tree = greedy_mod.build_greedy(records, workload, cuts, gcfg)
        frozen = tree.freeze()
        bids = frozen.route(records)
        frozen.tighten(records, bids)
        return frozen, bids, {"depth": int(frozen.depth)}


@register_builder("woodblock")
class WoodblockBuilder(LayoutBuilder):
    """WOODBLOCK deep-RL agent (paper Sec 5.2); deploys the best episode."""

    def build(self, records, workload, cuts, min_block, seed=0, **cfg):
        from repro.core.woodblock.agent import WoodblockConfig, build_woodblock

        wcfg = WoodblockConfig(
            min_block_sample=min_block,
            n_iters=cfg.pop("n_iters", 20),
            episodes_per_iter=cfg.pop("episodes_per_iter", 4),
            time_budget_s=cfg.pop("time_budget_s", None),
            seed=seed,
            max_leaves=cfg.pop("max_leaves", None),
            allow_small_child=cfg.pop("allow_small_child", False),
        )
        _reject_unknown(self, cfg)
        res = build_woodblock(records, workload, cuts, wcfg)
        frozen = res.best_tree.freeze()
        bids = frozen.route(records)
        frozen.tighten(records, bids)
        return frozen, bids, {
            "best_scanned_sample": float(res.best_scanned),
            "n_episodes": int(res.n_episodes),
            "curve": res.curve,
        }


@register_builder("bottom_up")
class BottomUpBuilder(LayoutBuilder):
    """Bottom-up baseline (paper Sec 7.3; BU+ via selectivity_ceiling)."""

    def build(self, records, workload, cuts, min_block, seed=0, **cfg):
        from repro.baselines import bottom_up

        bcfg = bottom_up.BottomUpConfig(
            block_size=min_block,
            max_features=cfg.pop("max_features", 15),
            selectivity_ceiling=cfg.pop("selectivity_ceiling", None),
            frequency_floor=cfg.pop("frequency_floor", 1),
        )
        _reject_unknown(self, cfg)
        tree, bids = bottom_up.build_bottom_up(records, workload, cuts, bcfg)
        return tree, bids, {}


@register_builder("random")
class RandomBuilder(LayoutBuilder):
    """Random shuffler into fixed-size blocks (TPC-H baseline, Sec 7.3)."""

    def build(self, records, workload, cuts, min_block, seed=0, **cfg):
        from repro.baselines import partitioners

        _reject_unknown(self, cfg)
        tree, bids = partitioners.random_layout(
            records, workload.schema, cuts, min_block, seed=seed
        )
        return tree, bids, {}


@register_builder("range")
class RangeBuilder(LayoutBuilder):
    """Range partitioning on one column (ErrorLog default scheme)."""

    def build(self, records, workload, cuts, min_block, seed=0, **cfg):
        from repro.baselines import partitioners

        column = cfg.pop("column", 0)
        _reject_unknown(self, cfg)
        tree, bids = partitioners.range_layout(
            records, workload.schema, cuts, min_block, column=column
        )
        return tree, bids, {}


def _reject_unknown(builder: LayoutBuilder, cfg: dict) -> None:
    if cfg:
        raise TypeError(
            f"strategy {builder.name!r} got unknown config keys "
            f"{sorted(cfg)}"
        )


def build_layout(
    records: np.ndarray,
    workload: qry.Workload,
    strategy: str = "greedy",
    cuts: Optional[CutTable] = None,
    min_block: Optional[int] = None,
    seed: int = 0,
    **cfg,
) -> LayoutBuild:
    """Construct a layout with any registered strategy → :class:`LayoutBuild`.

    ``cuts`` defaults to the workload's candidate cuts (paper Sec 3.4);
    ``min_block`` defaults to ``max(len(records) // 64, 1)``.  Remaining
    keyword arguments are strategy-specific (e.g. ``n_iters`` for
    ``woodblock``, ``column`` for ``range``).
    """
    builder = get_builder(strategy)
    if cuts is None:
        cuts = workload.candidate_cuts(max_adv=cfg.pop("max_adv", 8))
    if min_block is None:
        min_block = max(records.shape[0] // 64, 1)
    t0 = time.perf_counter()
    tree, bids, extra = builder.build(
        records, workload, cuts, min_block=min_block, seed=seed, **cfg
    )
    build_s = time.perf_counter() - t0

    bids = np.asarray(bids, np.int32)
    sizes = np.bincount(bids, minlength=tree.n_leaves).astype(np.int64)
    from repro.core import rewards

    hits = rewards.block_query_hits(tree, workload.tensorize(tree.cuts))
    denom = records.shape[0] * len(workload)
    scanned = float((hits * sizes[:, None]).sum() / denom) if denom else 0.0
    metrics = {
        "scanned_fraction": scanned,
        "n_leaves": int(tree.n_leaves),
        **extra,
    }
    provenance = {
        "strategy": strategy,
        "min_block": int(min_block),
        "seed": int(seed),
        "n_records": int(records.shape[0]),
        "n_queries": len(workload),
        "n_cuts": int(cuts.n_cuts),
        "config": {k: _jsonable(v) for k, v in cfg.items()},
    }
    return LayoutBuild(
        tree=tree,
        bids=bids,
        strategy=strategy,
        build_s=build_s,
        metrics=metrics,
        provenance=provenance,
    )


def _jsonable(v):
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    return v
