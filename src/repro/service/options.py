"""Typed option surfaces for the LayoutService facade.

Seven PRs of keyword accretion left ``LayoutService.ingest(observe=,
monitor=, fused=)`` / ``ingest_sharded(..., executor=)`` /
``auto_rebuilder(workload=, tracker=, config=)`` as an untyped kwarg
sprawl — and the replica dimension would have multiplied it.  These
dataclasses are the consolidated spellings, now covering the parallelism
axis too, so ONE entry point ingests everything:

    svc.ingest(batches)                                   # streaming
    svc.ingest(records, IngestOptions(shards=4))          # process-parallel
    svc.ingest(records, IngestOptions(shards=4,
                                      coordinator=fleet)) # fleet-folded
    svc.auto_rebuilder(RebuildPolicy(workload="auto", tracker=t))

The loose ``observe=``/``monitor=``/``fused=``/``executor=`` kwargs had
their one-release deprecation window (with warnings naming the
replacement); the window is closed and they now raise ``TypeError``.
The ``ingest_sharded(records, n_shards)`` method is the current
one-release shim: it forwards to ``ingest`` with a DeprecationWarning.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

#: kwargs that belong to IngestOptions; loose spellings are rejected.
_INGEST_OPTION_KEYS = (
    "observe", "monitor", "fused", "executor", "shards", "batch",
    "coordinator",
)


@dataclasses.dataclass(frozen=True)
class IngestOptions:
    """How one ingest run observes, monitors, and parallelizes.

    observe      Workload | WorkloadTensors | ObservationProbe — Eq. 1
                 per-batch skip accounting against a standing workload.
    monitor      an :class:`~repro.service.drift.AutoRebuilder`: batches
                 tee into its reservoir and observations drive its drift
                 policy (may fire a background rebuild mid-stream).
    fused        single-pass route+tighten kernels (default) vs the
                 two-pass route-then-tighten path.
    executor     sharded runs: ``None`` picks ``"process"`` (resident
                 spawn workers) for ``shards >= 2`` and ``"thread"``
                 otherwise; ``"thread"`` with multiple shards carries a
                 documented PerformanceWarning (GIL-bound, measured
                 0.44x); any ``concurrent.futures`` Executor instance is
                 used as-is.
    shards       None/1 streams single-stream; k >= 2 splits the record
                 array across k ShardIngestors and folds their states
                 associatively (requires an ndarray, not a batch
                 iterable).
    batch        micro-batch rows when ``ingest`` is handed a record
                 array (sharded or not).
    coordinator  a :class:`~repro.coordinator.FleetCoordinator`: the run
                 routes and aggregates but does NOT publish locally —
                 the merged ShardState is submitted to the coordinator,
                 which folds partials fleet-wide and owns every publish.
    """

    observe: object = None
    monitor: object = None
    fused: bool = True
    executor: object = None
    shards: Optional[int] = None
    batch: int = 2048
    coordinator: object = None

    def __post_init__(self):
        if self.shards is not None and self.shards < 1:
            raise ValueError("shards must be >= 1")
        if self.batch < 1:
            raise ValueError("batch must be >= 1")


@dataclasses.dataclass(frozen=True)
class RebuildPolicy:
    """When and how the service rebuilds itself.

    workload     a declared standing Workload, or ``"auto"`` to score
                 drift (and rebuild) against the tracker-inferred live
                 mix.
    tracker      the WorkloadTracker the serving path records into
                 (``workload="auto"``; omitted, one is created).
    drift        :class:`~repro.service.drift.DriftConfig` trigger
                 policy (threshold + hysteresis + cooldown).
    replicas     k > 1 makes triggered rebuilds deploy a k-replica
                 set via :meth:`LayoutService.rebuild_replicas`
                 (cheapest-replica routing); 1 keeps today's
                 single-tree rebuild.
    lam          uniform-prior blend weight for replica clustering
                 (see ``repro.service.replica``).
    reservoir_capacity  recent-record reservoir size for rebuilds.
    executor     ``None`` (private worker thread), ``"sync"``
                 (rebuild inline — deterministic tests/benchmarks),
                 or any Executor.
    rebuild_kw   extra kwargs forwarded to ``service.rebuild`` /
                 ``service.rebuild_replicas`` (e.g. ``swap=``,
                 ``strategy=``, ``min_block=``).
    """

    workload: object = "auto"
    tracker: object = None
    drift: object = None  # DriftConfig | None
    replicas: int = 1
    lam: float = 0.25
    reservoir_capacity: int = 65536
    executor: object = None
    rebuild_kw: Optional[dict] = None

    def __post_init__(self):
        if self.replicas < 1:
            raise ValueError("replicas must be >= 1")
        if not 0.0 <= self.lam <= 1.0:
            raise ValueError("lam must be in [0, 1]")


def resolve_ingest_options(
    options: Optional[IngestOptions],
    kw: dict,
    method: str,
) -> IngestOptions:
    """Reject retired loose option kwargs; return the effective options.

    The one-release shim that lifted loose ``observe=``/``monitor=``/
    ``fused=``/``executor=`` kwargs into IngestOptions (with a
    DeprecationWarning each) is retired: any option-surface kwarg in
    ``kw`` now raises ``TypeError`` naming the typed spelling.  The
    remaining ``kw`` passes through to the engine layer untouched
    (``tighten=``, ``buffers=``, ``backend=`` ...).
    """
    loose = sorted(k for k in _INGEST_OPTION_KEYS if k in kw)
    if loose:
        names = ", ".join(f"{k}=" for k in loose)
        raise TypeError(
            f"{method}() no longer accepts the loose kwarg(s) {names} "
            f"(the deprecation window closed); pass "
            f"options=IngestOptions({names}...)"
        )
    return options if options is not None else IngestOptions()


__all__ = ["IngestOptions", "RebuildPolicy", "resolve_ingest_options"]
