"""Typed option surfaces for the LayoutService facade.

Seven PRs of keyword accretion left ``LayoutService.ingest(observe=,
monitor=, fused=)`` / ``ingest_sharded(..., executor=)`` /
``auto_rebuilder(workload=, tracker=, config=)`` as an untyped kwarg
sprawl — and the replica dimension would have multiplied it.  These
dataclasses are the consolidated spellings:

    svc.ingest(batches, IngestOptions(monitor=rebuilder, fused=False))
    svc.ingest_sharded(records, 4, options=IngestOptions(executor="process"))
    svc.auto_rebuilder(RebuildPolicy(workload="auto", tracker=t))

The old kwargs remain accepted for one release via
:func:`resolve_ingest_options` / the ``auto_rebuilder`` shim: each use
raises a :class:`DeprecationWarning` naming the new spelling, then maps
onto the dataclass — so existing callers keep working bit-identically
while new code gets a typed surface.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

#: kwargs the IngestOptions shim lifts off ``ingest``/``ingest_sharded``.
_INGEST_OPTION_KEYS = ("observe", "monitor", "fused", "executor")


@dataclasses.dataclass(frozen=True)
class IngestOptions:
    """How one ingest run observes, monitors, and parallelizes.

    observe    Workload | WorkloadTensors | ObservationProbe — Eq. 1
               per-batch skip accounting against a standing workload.
    monitor    an :class:`~repro.service.drift.AutoRebuilder`: batches
               tee into its reservoir and observations drive its drift
               policy (may fire a background rebuild mid-stream).
    fused      single-pass route+tighten kernels (default) vs the
               two-pass route-then-tighten path.
    executor   sharded ingest only: ``None``/``"thread"`` (shared-plan
               thread pool), ``"process"`` (resident spawn workers), or
               any ``concurrent.futures`` Executor.
    """

    observe: object = None
    monitor: object = None
    fused: bool = True
    executor: object = None


@dataclasses.dataclass(frozen=True)
class RebuildPolicy:
    """When and how the service rebuilds itself.

    workload     a declared standing Workload, or ``"auto"`` to score
                 drift (and rebuild) against the tracker-inferred live
                 mix.
    tracker      the WorkloadTracker the serving path records into
                 (``workload="auto"``; omitted, one is created).
    drift        :class:`~repro.service.drift.DriftConfig` trigger
                 policy (threshold + hysteresis + cooldown).
    replicas     k > 1 makes triggered rebuilds deploy a k-replica
                 set via :meth:`LayoutService.rebuild_replicas`
                 (cheapest-replica routing); 1 keeps today's
                 single-tree rebuild.
    lam          uniform-prior blend weight for replica clustering
                 (see ``repro.service.replica``).
    reservoir_capacity  recent-record reservoir size for rebuilds.
    executor     ``None`` (private worker thread), ``"sync"``
                 (rebuild inline — deterministic tests/benchmarks),
                 or any Executor.
    rebuild_kw   extra kwargs forwarded to ``service.rebuild`` /
                 ``service.rebuild_replicas`` (e.g. ``swap=``,
                 ``strategy=``, ``min_block=``).
    """

    workload: object = "auto"
    tracker: object = None
    drift: object = None  # DriftConfig | None
    replicas: int = 1
    lam: float = 0.25
    reservoir_capacity: int = 65536
    executor: object = None
    rebuild_kw: Optional[dict] = None

    def __post_init__(self):
        if self.replicas < 1:
            raise ValueError("replicas must be >= 1")
        if not 0.0 <= self.lam <= 1.0:
            raise ValueError("lam must be in [0, 1]")


def _deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"{old} is deprecated; use {new}",
        DeprecationWarning,
        stacklevel=4,  # user code → service facade → resolver → here
    )


def resolve_ingest_options(
    options: Optional[IngestOptions],
    kw: dict,
    method: str,
) -> IngestOptions:
    """Fold deprecated loose kwargs out of ``kw`` into an IngestOptions.

    Mutates ``kw`` (popping the lifted keys); the remainder passes
    through to the engine layer untouched.  Mixing ``options`` with a
    deprecated kwarg is an error — the shim exists to migrate call
    sites, not to merge two spellings of the same thing.
    """
    lifted = {k: kw.pop(k) for k in _INGEST_OPTION_KEYS if k in kw}
    if not lifted:
        return options if options is not None else IngestOptions()
    names = ", ".join(f"{k}=" for k in sorted(lifted))
    if options is not None:
        raise TypeError(
            f"{method}() got both options=IngestOptions(...) and the "
            f"deprecated loose kwarg(s) {names}; pass everything via "
            f"IngestOptions"
        )
    _deprecated(
        f"{method}({names})",
        f"{method}(..., options=IngestOptions({names}...))",
    )
    return IngestOptions(**lifted)


__all__ = ["IngestOptions", "RebuildPolicy", "resolve_ingest_options"]
