"""LayoutService subsystem: one lifecycle API over qd-tree layouts.

Public surface:
  build_layout / LayoutBuild            — strategy-dispatched construction
  register_builder / get_builder / available_strategies — builder registry
  LayoutService                          — versioned serving facade with
                                           rebuild-in-place hot swap
  LayoutVersion / RebuildReport          — lifecycle artifacts
  DriftMonitor / DriftConfig / AutoRebuilder / RecordReservoir —
                                           drift-triggered auto-rebuild
  WorkloadTracker / TrackerConfig / TrackerState —
                                           workload auto-detection from the
                                           serving path (inferred live mix)
  Epoch                                  — the (generation, desc_version,
                                           replica_id) serving identity
  IngestOptions / RebuildPolicy          — typed option dataclasses for the
                                           ingest / auto-rebuild surfaces
  ReplicaSet / ReplicaRoute / ReplicaRebuildReport —
                                           k-replica layouts with
                                           cheapest-replica routing
"""

from repro.service.builders import (  # noqa: F401
    LayoutBuild,
    LayoutBuilder,
    available_strategies,
    build_layout,
    get_builder,
    register_builder,
)
from repro.service.drift import (  # noqa: F401
    AutoRebuilder,
    DriftConfig,
    DriftDecision,
    DriftMonitor,
    RebuildEvent,
    RecordReservoir,
)
from repro.service.epoch import Epoch  # noqa: F401
from repro.service.options import (  # noqa: F401
    IngestOptions,
    RebuildPolicy,
)
from repro.service.replica import (  # noqa: F401
    ReplicaRebuildReport,
    ReplicaRoute,
    ReplicaSet,
    cluster_signatures,
    cluster_workloads,
    workload_signature_weights,
)
from repro.service.service import (  # noqa: F401
    LayoutService,
    LayoutVersion,
    RebuildReport,
)
from repro.service.tracker import (  # noqa: F401
    TrackerConfig,
    TrackerState,
    WorkloadTracker,
    merge_states,
    query_signatures,
    query_signatures_from_tensors,
)
