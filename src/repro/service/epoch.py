"""Epoch: the one serving-provenance type for the layout lifecycle.

Before replica sets, the serving tier passed bare ``(generation,
desc_version)`` tuples between ``LayoutService.live_epoch``, the result
cache, and the dispatch loop.  Replicated layouts add a third coordinate
— *which replica* a result was computed against — and an untyped
3-tuple convention in four modules is exactly how provenance bugs are
born.  :class:`Epoch` is the shared frozen dataclass all of them speak:

* ``generation`` — the service-wide monotonic deploy counter
  (:meth:`LayoutService.swap` and friends); unique across replicas.
* ``desc_version`` — the tree's leaf-description version: in-place
  tightening during ingest bumps it without a swap, changing
  ``query_hits`` results for the same generation.
* ``replica_id`` — position of the tree in the live
  :class:`~repro.service.replica.ReplicaSet` (0 for the primary, and
  for every pre-replica call site via the default).

Ordered and hashable so epochs can key caches and sort into audit
trails; iterable so legacy ``list(epoch)`` / tuple-unpacking call sites
keep working during the migration.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator


@dataclasses.dataclass(frozen=True, order=True)
class Epoch:
    """One serving epoch: ``(generation, desc_version, replica_id)``.

    Any movement of the first two coordinates retires every result
    computed under the old epoch — this is the result-cache invalidation
    key (`repro.serve.cache`).  The third coordinate scopes that
    invalidation: hot-swapping one replica retires only that replica's
    entries.
    """

    generation: int
    desc_version: int
    replica_id: int = 0

    def __iter__(self) -> Iterator[int]:
        yield self.generation
        yield self.desc_version
        yield self.replica_id


__all__ = ["Epoch"]
