"""Drift-triggered auto-rebuild: the service watches its own Eq. 1 skip
rate and re-optimizes the layout when the workload shifts.

The paper's layout quality metric (Eq. 1 fraction of blocks scanned)
degrades silently when the data or query distribution drifts away from
what the live qd-tree was built for.  Online re-partitioning with bounded
regret (arXiv:2405.04984) and Lachesis' background re-optimization loop
(arXiv:2006.16529) both respond the same way: monitor, trigger, rebuild,
swap.  Three pieces close that loop over the existing lifecycle machinery:

* :class:`DriftMonitor` — folds per-batch :class:`~repro.engine.WindowStat`
  observations (produced by ``LayoutEngine.ingest(observe=...)`` or the
  merged shard partials of ``sharded_ingest``) into a sliding window and
  applies a trigger policy: an absolute scanned-fraction threshold and/or
  degradation relative to the best window seen since the last rebaseline,
  with hysteresis (consecutive breaching windows required) and a cooldown
  after every trigger.  Pure and deterministic: the same observation
  sequence always yields the same decisions.
* :class:`RecordReservoir` — a bounded ring of the most recent ingested
  records, the corpus an auto-rebuild trains on.
* :class:`AutoRebuilder` — ties monitor + reservoir to a
  :class:`~repro.service.service.LayoutService`: when the monitor trips it
  fires ``service.rebuild(reservoir, workload, swap="if_better")`` on a
  background executor.  Deployment goes through the service's existing
  compare-and-swap, so a concurrent rebuild (another trigger, an operator
  ``rebuild``) can never double-swap on the same baseline; an in-flight
  latch keeps the rebuilder itself single-shot until the running rebuild
  resolves.

``LayoutService.ingest(batches, monitor=rebuilder)`` and
``ingest_sharded(..., monitor=rebuilder)`` wire the accounting in; see
``benchmarks/drift_rebuild.py`` for the mid-stream workload shift this
machinery is built to absorb.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Executor, ThreadPoolExecutor
from typing import Callable, Iterable, Iterator, Optional

import numpy as np

from repro.engine.engine import WindowStat


# ---------------------------------------------------------------------------
# Trigger policy
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DriftConfig:
    """Sliding-window trigger policy for :class:`DriftMonitor`.

    window          sliding window length, in observations (per-batch
                    WindowStats for single-stream ingest, one merged stat
                    per ``ingest_sharded`` run).
    min_fill        observations required in the window before any
                    trigger can fire (warm-up).
    abs_threshold   trigger when the window's Eq. 1 scanned fraction
                    exceeds this (None disables the absolute rule).
    rel_degradation trigger when the window rate exceeds
                    ``best_seen * (1 + rel_degradation)`` where
                    ``best_seen`` is the lowest window rate since the
                    last rebaseline (None disables the relative rule).
    hysteresis      consecutive breaching observations required before a
                    trigger fires (debounces single noisy batches).
    cooldown        observations after a trigger (or rebaseline) during
                    which no new trigger may fire — gives the rebuild
                    time to land and the window time to refill with
                    post-swap observations.
    """

    window: int = 16
    min_fill: int = 4
    abs_threshold: Optional[float] = None
    rel_degradation: Optional[float] = 0.5
    hysteresis: int = 2
    cooldown: int = 16

    def __post_init__(self):
        if self.window < 1:
            raise ValueError("window must be >= 1")
        if not 1 <= self.min_fill <= self.window:
            raise ValueError("min_fill must be in [1, window]")
        if self.hysteresis < 1:
            raise ValueError("hysteresis must be >= 1")
        if self.cooldown < 0:
            raise ValueError("cooldown must be >= 0")
        if self.abs_threshold is None and self.rel_degradation is None:
            raise ValueError(
                "at least one of abs_threshold / rel_degradation required"
            )


@dataclasses.dataclass(frozen=True)
class DriftDecision:
    """Outcome of one :meth:`DriftMonitor.observe` step."""

    triggered: bool
    reason: str  # "" | "abs" | "rel" | "abs+rel" | "cooldown" | "warmup"
    window_rate: float  # Eq. 1 scanned fraction over the current window
    best_rate: float  # best (lowest) window rate since last rebaseline
    breaches: int  # current consecutive-breach count (hysteresis state)
    cooldown_left: int
    observations: int  # total observations since construction


class DriftMonitor:
    """Online skip-rate monitor with hysteresis + cooldown (deterministic).

    Not thread-safe by itself — :class:`AutoRebuilder` serializes calls;
    drive it directly only from one thread.
    """

    def __init__(self, config: Optional[DriftConfig] = None):
        self.config = config or DriftConfig()
        self._window: deque[WindowStat] = deque(maxlen=self.config.window)
        # exact int running totals (subtract-on-evict is lossless on ints)
        self._totals = WindowStat()
        self._best: Optional[float] = None
        self._breaches = 0
        self._cooldown_left = 0
        self._observations = 0

    # -- state ---------------------------------------------------------------
    @property
    def window_stat(self) -> WindowStat:
        """Exact totals over the current window (shard-merge comparable)."""
        return self._totals

    @property
    def window_rate(self) -> float:
        return self._totals.scanned_fraction

    @property
    def best_rate(self) -> float:
        return self._best if self._best is not None else float("nan")

    @property
    def observations(self) -> int:
        return self._observations

    # -- the policy ----------------------------------------------------------
    def observe(self, stat: WindowStat) -> DriftDecision:
        """Fold one observation; decide whether a rebuild should fire."""
        cfg = self.config
        if len(self._window) == cfg.window:
            evicted = self._window[0]
            self._totals = WindowStat(
                self._totals.scanned_tuples - evicted.scanned_tuples,
                self._totals.capacity - evicted.capacity,
                self._totals.n_records - evicted.n_records,
            )
        self._window.append(stat)
        self._totals = self._totals.merge(stat)
        self._observations += 1

        rate = self._totals.scanned_fraction
        filled = len(self._window) >= cfg.min_fill
        if filled and (self._best is None or rate < self._best):
            self._best = rate

        if self._cooldown_left > 0:
            self._cooldown_left -= 1
            self._breaches = 0
            return self._decision(False, "cooldown", rate)
        if not filled:
            self._breaches = 0
            return self._decision(False, "warmup", rate)

        reasons = []
        if cfg.abs_threshold is not None and rate > cfg.abs_threshold:
            reasons.append("abs")
        if (
            cfg.rel_degradation is not None
            and self._best is not None
            and rate > self._best * (1.0 + cfg.rel_degradation)
        ):
            reasons.append("rel")
        if reasons:
            self._breaches += 1
        else:
            self._breaches = 0
        if self._breaches >= cfg.hysteresis:
            self._breaches = 0
            self._cooldown_left = cfg.cooldown
            return self._decision(True, "+".join(reasons), rate)
        return self._decision(False, "+".join(reasons), rate)

    def _decision(self, trig: bool, reason: str, rate: float) -> DriftDecision:
        return DriftDecision(
            triggered=trig,
            reason=reason,
            window_rate=rate,
            best_rate=self.best_rate,
            breaches=self._breaches,
            cooldown_left=self._cooldown_left,
            observations=self._observations,
        )

    def rebaseline(self) -> None:
        """Reset after a layout change: the old window and best-seen were
        measured against a tree that no longer serves.  Keeps the cooldown
        so the refilling window cannot immediately re-trigger."""
        self._window.clear()
        self._totals = WindowStat()
        self._best = None
        self._breaches = 0
        self._cooldown_left = self.config.cooldown


# ---------------------------------------------------------------------------
# Recent-record reservoir
# ---------------------------------------------------------------------------
class RecordReservoir:
    """Bounded ring of the most recent ingested records (thread-safe).

    Rebuilds train on what the service saw *lately* — a sliding corpus,
    not a uniform-over-history sample — so after a distribution shift the
    reservoir converges to post-shift data at ingest speed.  ``snapshot``
    returns rows oldest→newest, matching a contiguous slice of the
    stream.
    """

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._buf: Optional[np.ndarray] = None  # guarded by: self._lock
        self._write = 0  # guarded by: self._lock -- next write position
        self._size = 0  # guarded by: self._lock
        self._seen = 0  # guarded by: self._lock

    def __len__(self) -> int:
        with self._lock:
            return self._size

    @property
    def records_seen(self) -> int:
        with self._lock:
            return self._seen

    def add(self, records: np.ndarray) -> None:
        if records.shape[0] == 0:
            return
        with self._lock:
            if self._buf is None:
                self._buf = np.empty(
                    (self.capacity,) + records.shape[1:], records.dtype
                )
            rows = records[-self.capacity:]  # only the tail can survive
            n = rows.shape[0]
            end = self._write + n
            if end <= self.capacity:
                self._buf[self._write:end] = rows
            else:
                split = self.capacity - self._write
                self._buf[self._write:] = rows[:split]
                self._buf[: end - self.capacity] = rows[split:]
            self._write = end % self.capacity
            self._size = min(self._size + n, self.capacity)
            self._seen += records.shape[0]

    def snapshot(self) -> np.ndarray:
        """Copy of the retained rows in arrival order (oldest first)."""
        with self._lock:
            if self._buf is None or self._size == 0:
                return np.zeros((0,), np.int32)
            if self._size < self.capacity:
                return self._buf[: self._size].copy()
            return np.concatenate(
                [self._buf[self._write:], self._buf[: self._write]]
            )

    def clear(self) -> None:
        with self._lock:
            self._size = 0
            self._write = 0


# ---------------------------------------------------------------------------
# The auto-rebuild loop
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class RebuildEvent:
    """One trigger's outcome, recorded in ``AutoRebuilder.events``."""

    observation: int  # monitor observation count at trigger time
    decision: DriftDecision
    report: object = None  # service.RebuildReport | None
    deployed: bool = False
    skipped: str = ""  # "" | "in_flight" | "empty_reservoir" | "empty_workload"
    error: str = ""
    wall_s: float = 0.0


class AutoRebuilder:
    """Fires ``LayoutService.rebuild`` when the drift monitor trips.

    Thread-safety: ``observe`` may be called from any ingest thread (the
    monitor is driven under an internal lock); at most one rebuild is in
    flight at a time (later triggers while one runs are recorded as
    ``skipped="in_flight"``), and deployment relies on the service's
    compare-and-swap so even external concurrent rebuilds can't
    double-swap on the same baseline.

    ``executor``: ``None`` → a private single-worker thread pool (created
    lazily, shut down by :meth:`close`); ``"sync"`` → rebuild inline in
    the observing thread (deterministic tests/benchmarks); otherwise any
    ``concurrent.futures`` executor.

    ``workload`` may be the string ``"auto"``: instead of a declared
    standing workload, drift accounting and rebuilds score against the
    live query mix a :class:`~repro.service.tracker.WorkloadTracker`
    infers from the serving path — :meth:`current_workload` re-infers it
    at every ingest run and again at trigger time, so a rebuild optimizes
    for what users are asking *now*, not what an operator once declared.
    Pass ``tracker=`` (the tracker ``LayoutService.serve`` records into);
    omitted, one is created via ``service.workload_tracker()`` and
    exposed as ``rebuilder.tracker``.
    """

    def __init__(
        self,
        service,  # LayoutService (kept untyped: service imports this module)
        workload,  # qry.Workload | "auto" the monitor scores against
        config: Optional[DriftConfig] = None,
        reservoir: Optional[RecordReservoir] = None,
        reservoir_capacity: int = 65536,
        executor: Optional[Executor | str] = None,
        rebuild_kw: Optional[dict] = None,  # forwarded to service.rebuild
        on_event: Optional[Callable[[RebuildEvent], None]] = None,
        tracker=None,  # tracker.WorkloadTracker (workload="auto")
    ):
        self.service = service
        if isinstance(workload, str):
            if workload != "auto":
                raise ValueError(
                    f"workload must be a Workload or 'auto', got "
                    f"{workload!r}"
                )
            if tracker is None:
                tracker = service.workload_tracker()
        self.workload = workload  # guarded by: self._lock
        self.tracker = tracker  # guarded by: self._lock
        self.monitor = DriftMonitor(config)  # guarded by: self._lock
        self.reservoir = (
            reservoir
            if reservoir is not None
            else RecordReservoir(reservoir_capacity)
        )
        self.rebuild_kw = dict(rebuild_kw or {})
        self.rebuild_kw.setdefault("swap", "if_better")
        self.policy = None  # RebuildPolicy when built via from_policy
        self.on_event = on_event
        self.events: list[RebuildEvent] = []
        self._lock = threading.Lock()
        self._inflight: Optional[threading.Event] = None  # guarded by: self._lock
        self._executor = executor
        self._own_executor: Optional[ThreadPoolExecutor] = None

    @classmethod
    def from_policy(
        cls,
        service,
        policy,  # repro.service.options.RebuildPolicy
        reservoir: Optional[RecordReservoir] = None,
        on_event: Optional[Callable[["RebuildEvent"], None]] = None,
    ) -> "AutoRebuilder":
        """Construct from a typed :class:`RebuildPolicy` (the
        consolidated ``auto_rebuilder`` surface).  A policy with
        ``replicas > 1`` makes triggered rebuilds deploy a k-replica
        set via ``service.rebuild_replicas`` (cheapest-replica routing,
        ``lam`` uniform-prior blend) instead of a single tree."""
        rb = cls(
            service,
            policy.workload,
            config=policy.drift,
            reservoir=reservoir,
            reservoir_capacity=policy.reservoir_capacity,
            executor=policy.executor,
            rebuild_kw=dict(policy.rebuild_kw or {}),
            on_event=on_event,
            tracker=policy.tracker,
        )
        rb.policy = policy
        return rb

    # -- stream plumbing -----------------------------------------------------
    def set_workload(self, workload, tracker=None) -> None:
        """Point the monitor (and future rebuilds) at a new standing
        workload (or ``"auto"`` + a tracker).  Deliberately does NOT
        rebaseline: the window should now show how badly the live tree
        serves the new queries — that degradation is exactly the drift
        signal."""
        if isinstance(workload, str) and workload != "auto":
            raise ValueError(
                f"workload must be a Workload or 'auto', got {workload!r}"
            )
        with self._lock:
            self.workload = workload
            if tracker is not None:
                self.tracker = tracker
            if workload == "auto" and self.tracker is None:
                self.tracker = self.service.workload_tracker()

    def current_workload(self):
        """The workload drift accounting and rebuilds score against *right
        now*: the declared one, or — with ``workload="auto"`` — the
        tracker-inferred live mix (re-inferred on every call; the tracker
        caches per version, so unchanged sketches cost nothing).  May be
        empty before any queries were served — callers skip observation
        then."""
        with self._lock:
            workload, tracker = self.workload, self.tracker
        if isinstance(workload, str):
            return tracker.infer_workload()
        return workload

    def tee(
        self, batches: Iterable[np.ndarray]
    ) -> Iterator[np.ndarray]:
        """Pass batches through, copying each into the reservoir."""
        for batch in batches:
            self.reservoir.add(batch)
            yield batch

    def add_records(self, records: np.ndarray) -> None:
        self.reservoir.add(records)

    # -- observation → trigger → rebuild -------------------------------------
    def observe(self, stat: WindowStat) -> DriftDecision:
        """Fold one observation; fire a background rebuild on trigger."""
        skip_ev = done = None
        with self._lock:
            decision = self.monitor.observe(stat)
            if decision.triggered:
                if self._inflight is not None:
                    skip_ev = RebuildEvent(
                        observation=decision.observations,
                        decision=decision,
                        skipped="in_flight",
                    )
                else:
                    done = threading.Event()
                    self._inflight = done
        # record/fire outside the lock: on_event callbacks may call back
        # into the rebuilder (drain, observe) without deadlocking
        if skip_ev is not None:
            self._record(skip_ev)
        if done is not None:
            if self._executor == "sync":
                self._run_rebuild(decision, done)
            else:
                self._pool().submit(self._run_rebuild, decision, done)
        return decision

    def _pool(self) -> Executor:
        if isinstance(self._executor, Executor):
            return self._executor
        if self._own_executor is None:
            self._own_executor = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="drift-rebuild"
            )
        return self._own_executor

    def _run_rebuild(
        self, decision: DriftDecision, done: threading.Event
    ) -> None:
        ev = RebuildEvent(
            observation=decision.observations, decision=decision
        )
        t0 = time.perf_counter()
        try:
            records = self.reservoir.snapshot()
            if records.shape[0] == 0:
                ev.skipped = "empty_reservoir"
                return
            # resolved at trigger time: an "auto" rebuild optimizes for
            # the mix the tracker is seeing NOW, not at construction
            workload = self.current_workload()
            if workload is not None and len(workload) == 0:
                ev.skipped = "empty_workload"
                return
            policy = self.policy
            if policy is not None and policy.replicas > 1:
                # replica policy: the triggered rebuild deploys a whole
                # k-replica set clustered from the tracked mix
                with self._lock:
                    tracker = (
                        self.tracker
                        if isinstance(self.workload, str)
                        else None
                    )
                report = self.service.rebuild_replicas(
                    records,
                    workload=workload,
                    k=policy.replicas,
                    lam=policy.lam,
                    tracker=tracker,
                    **self.rebuild_kw,
                )
            else:
                report = self.service.rebuild(
                    records, workload, **self.rebuild_kw
                )
            ev.report = report
            ev.deployed = bool(report.swapped)
            if report.swapped:
                # new live layout: the window/best-seen measured the old
                # one — restart the baseline (cooldown keeps the refill
                # from immediately re-triggering)
                with self._lock:
                    self.monitor.rebaseline()
        except Exception as e:  # surfaced via events, never kills ingest
            ev.error = f"{type(e).__name__}: {e}"
        finally:
            ev.wall_s = time.perf_counter() - t0
            with self._lock:
                self._inflight = None
            self._record(ev)  # outside the lock: see observe()
            done.set()

    def _record(self, ev: RebuildEvent) -> None:
        self.events.append(ev)
        if self.on_event is not None:
            self.on_event(ev)

    # -- lifecycle -----------------------------------------------------------
    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until the in-flight rebuild (if any) resolves."""
        with self._lock:
            pending = self._inflight
        return pending.wait(timeout) if pending is not None else True

    @property
    def rebuilds_deployed(self) -> int:
        return sum(1 for e in self.events if e.deployed)

    def close(self) -> None:
        self.drain()
        if self._own_executor is not None:
            self._own_executor.shutdown(wait=True)
            self._own_executor = None

    def __enter__(self) -> "AutoRebuilder":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


__all__ = [
    "AutoRebuilder",
    "DriftConfig",
    "DriftDecision",
    "DriftMonitor",
    "RebuildEvent",
    "RecordReservoir",
]
